"""Table 3 analogue: sub-clustering — replication (fr) vs distribution (fd).

Paper: Orkut BC total time vs fr at fixed p.  Here p = 8 host devices:
fr=1 runs one 2x4 fine-grained grid; fr=2 runs two 2x2 sub-clusters;
fr=4 runs four 1x2 sub-clusters (max replication possible with a 2-D
grid per replica).  More replication ⇒ fewer devices per traversal but
more concurrent rounds — the paper's observed trade-off.
"""
from __future__ import annotations

from benchmarks.common import emit, ensure_devices, make_mesh, time_call

ensure_devices(8)

from repro.core.distributed import distributed_betweenness_centrality
from repro.graphs import rmat_graph


def run() -> None:
    if not ensure_devices(8):
        emit("table3/skipped", 0.0, "needs 8 host devices")
        return
    g = rmat_graph(8, 8, seed=0)
    configs = {
        "fr1_fd8": ((2, 4), ("data", "model"), None),
        "fr2_fd4": ((2, 2, 2), ("pod", "data", "model"), "pod"),
        "fr4_fd2": ((4, 1, 2), ("pod", "data", "model"), "pod"),
    }
    for name, (shape, names, rep) in configs.items():
        mesh = make_mesh(shape, names)

        def job():
            return distributed_betweenness_centrality(
                g, mesh, replica_axis=rep, batch_size=16, heuristics="h0"
            )

        sec = time_call(job, warmup=1, iters=2)
        teps = g.num_edges * g.n / sec
        emit(f"table3/{name}", sec * 1e6, f"MTEPS={teps/1e6:.1f};n={g.n}")


if __name__ == "__main__":
    run()
