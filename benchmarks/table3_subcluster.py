"""Table 3 analogue: sub-clustering — replication (fr) vs distribution (fd)
— plus the straggler re-deal benchmark (``BENCH_subcluster.json``).

Part (a), the paper's table: Orkut BC total time vs fr at fixed p.  Here
p = 8 host devices: fr=1 runs one 2x4 fine-grained grid; fr=2 runs two
2x2 sub-clusters; fr=4 runs four 1x2 sub-clusters (max replication
possible with a 2-D grid per replica).  More replication ⇒ fewer devices
per traversal but more concurrent rounds — the paper's observed
trade-off.

Part (b), the scheduling benchmark: the paper notes that data-dependent
traversal depth makes round wall times wildly uneven across replicas.
``skewed_depth_graph`` makes the unevenness maximal — one replica draws
every deep-diameter (path) root batch, the other every shallow
(complete-graph) one — and, under a ring overlap policy, the replica
axis joins the loop-bound reductions, so every dispatch block costs the
*max* over its rounds' depths: the static deal burns the depth gap as
masked no-op levels on the shallow replica.  The benchmark runs the same
workload under every ``BCDriver`` straggler policy
(none | steal | redeal), checks exact BC parity against the Brandes
oracle, and writes per-policy wall, per-replica wall/levels, rounds
stolen/re-dealt and the recovered idle seconds to
``BENCH_subcluster.json`` — the machine-readable baseline future PRs
regress against (CI uploads it next to ``BENCH_overlap.json``).

Part (d), the integrity-overhead benchmark: the same distributed
workload with ``integrity`` off / audit / checksum — exact parity in
all three, the per-mode wall and the overhead ratios recorded under
``"integrity"`` so the cost of the self-verifying rounds (the ABFT
checksum lane widens every level SpMM by one column) is a tracked
number instead of folklore.

Part (c), the deal comparison: at a batch width spanning two components
the legacy vertex-id deal mixes a deep path root with shallow clique
roots in the same round — the shallow roots burn the depth difference
as masked no-op levels — while the eccentricity-packed deal
(``build_schedule(root_order="eccentricity")``) pairs like with like.
The exact total traversal levels of both deals are recorded under
``"deal"`` (structural: host BFS depths, deterministic schedules).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, ensure_devices, make_mesh, time_call

ensure_devices(8)

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.brandes_ref import brandes_reference
from repro.core.distributed import (
    distributed_betweenness_centrality,
    distributed_graph_arrays,
    make_distributed_round_fn,
    prior_round_seconds,
)
from repro.core.driver import BCDriver, INTEGRITY_MODES, STRAGGLER_POLICIES
from repro.core.scheduler import build_schedule
from repro.graphs import rmat_graph, skewed_depth_graph
from repro.graphs.partition import partition_2d

BENCH_JSON = os.environ.get("BENCH_SUBCLUSTER_JSON", "BENCH_subcluster.json")

#: skewed workload: 8 deep (path) + 8 shallow (complete) root batches of
#: 16 sources each — one component per round at batch_size=16.
PAIRS = 8
BLOCK = 16
OVERLAP = "expand"  # ring policy ⇒ replicas in loop-bound lockstep


def _replication_sweep() -> None:
    """(a) fr sweep at fixed p (the paper's Table 3 axis)."""
    g = rmat_graph(8, 8, seed=0)
    configs = {
        "fr1_fd8": ((2, 4), ("data", "model"), None),
        "fr2_fd4": ((2, 2, 2), ("pod", "data", "model"), "pod"),
        "fr4_fd2": ((4, 1, 2), ("pod", "data", "model"), "pod"),
    }
    for name, (shape, names, rep) in configs.items():
        mesh = make_mesh(shape, names)

        def job():
            return distributed_betweenness_centrality(
                g, mesh, replica_axis=rep, batch_size=16, heuristics="h0"
            )

        sec = time_call(job, warmup=1, iters=2)
        teps = g.num_edges * g.n / sec
        emit(f"table3/{name}", sec * 1e6, f"MTEPS={teps/1e6:.1f};n={g.n}")


def _straggler_bench() -> dict:
    """(b) skewed-depth workload under every straggler policy."""
    g = skewed_depth_graph(PAIRS, BLOCK)
    expected = brandes_reference(g)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    schedule, prep, residual, _ = build_schedule(g, batch_size=BLOCK, heuristics="h0")
    part = partition_2d(residual, 2, 2)
    fn = make_distributed_round_fn(
        part, mesh, replica_axis="pod", engine_kind="sparse", overlap=OVERLAP
    )
    graph_args = distributed_graph_arrays(part, "sparse", OVERLAP)
    omega = jnp.zeros(part.n_pad, jnp.float32)
    prior = prior_round_seconds(part, "sparse", BLOCK, OVERLAP)

    def block_fn(sources, derived):
        return fn(*graph_args, omega, sources, derived)

    # compile once up front with an all-padding block so the first
    # policy's wall is not charged for tracing/compilation
    jax.block_until_ready(
        block_fn(
            jnp.full((2, BLOCK), -1, jnp.int32),
            jnp.full((2, schedule.derived_per_round, 3), -1, jnp.int32),
        )
    )

    record: dict = {
        "graph": {
            "kind": f"skewed_depth_graph({PAIRS}, {BLOCK})",
            "n": g.n,
            "m": int(g.num_edges),
            "rounds": len(schedule.rounds),
        },
        "mesh": "2x2x2 (fr=2 replicas of a 2x2 grid)",
        "overlap": OVERLAP,
        "policies": {},
    }
    walls: dict[str, float] = {}
    for policy in STRAGGLER_POLICIES:
        result = BCDriver(
            block_fn,
            schedule,
            n=g.n,
            prep=prep,
            rounds_per_dispatch=2,
            straggler=policy,
            prior_round_s=prior if policy != "none" else None,
            profile=True,
        ).run()
        err = float(np.abs(result.bc - expected).max())
        assert err < 1e-6, f"straggler={policy} diverged from brandes_ref: {err}"
        stats = result.straggler_stats or {}
        walls[policy] = result.wall_s
        record["policies"][policy] = {
            "wall_s": result.wall_s,
            "rounds": result.rounds_run,
            "block_wall_s_median": float(np.median(result.block_times)),
            "max_abs_err_vs_brandes": err,
            "per_replica_wall_s": stats.get("per_replica_wall_s"),
            "per_replica_levels": stats.get("per_replica_levels"),
            "rounds_stolen": stats.get("rounds_stolen", 0),
            "rounds_redealt": stats.get("rounds_redealt", 0),
            "duplicates_dispatched": stats.get("duplicates_dispatched", 0),
            "duplicates_discarded": stats.get("duplicates_discarded", 0),
            "idle_levels": stats.get("idle_levels"),
            "idle_s_est": stats.get("idle_s_est"),
        }
        emit(
            f"table3/straggler_{policy}",
            result.wall_s * 1e6,
            f"rounds={result.rounds_run};"
            f"stolen={stats.get('rounds_stolen', 0)};"
            f"redealt={stats.get('rounds_redealt', 0)};"
            f"idle_s={stats.get('idle_s_est', 0.0):.3f}",
        )
    record["idle_s_recovered_redeal_vs_none"] = walls["none"] - walls["redeal"]
    emit(
        "table3/straggler_recovered",
        0.0,
        f"redeal_vs_none_s={record['idle_s_recovered_redeal_vs_none']:.3f};"
        f"speedup={walls['none'] / max(walls['redeal'], 1e-9):.2f}x",
    )
    return record


def _integrity_bench() -> dict:
    """(d) measured self-verification overhead, off vs audit vs checksum.

    Same workload and mesh as the straggler benchmark, static deal.  The
    wall per mode is a loose (machine-speed) metric; parity and the key
    set are the contract — `audit` must cost only the host-side block
    audit, `checksum`'s extra column on every level SpMM is the real
    overhead being tracked.
    """
    g = skewed_depth_graph(PAIRS, BLOCK)
    expected = brandes_reference(g)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    schedule, prep, residual, _ = build_schedule(g, batch_size=BLOCK, heuristics="h0")
    part = partition_2d(residual, 2, 2)
    graph_args = distributed_graph_arrays(part, "sparse", OVERLAP)
    omega = jnp.zeros(part.n_pad, jnp.float32)

    record: dict = {
        "graph": {
            "kind": f"skewed_depth_graph({PAIRS}, {BLOCK})",
            "n": g.n,
            "m": int(g.num_edges),
            "rounds": len(schedule.rounds),
        },
        "mesh": "2x2x2 (fr=2 replicas of a 2x2 grid)",
        "overlap": OVERLAP,
        "modes": {},
    }
    walls: dict[str, float] = {}
    for mode in INTEGRITY_MODES:
        fn = make_distributed_round_fn(
            part, mesh, replica_axis="pod", engine_kind="sparse",
            overlap=OVERLAP, integrity=mode,
        )

        def block_fn(sources, derived, fn=fn):
            return fn(*graph_args, omega, sources, derived)

        jax.block_until_ready(
            block_fn(
                jnp.full((2, BLOCK), -1, jnp.int32),
                jnp.full((2, schedule.derived_per_round, 3), -1, jnp.int32),
            )
        )
        result = BCDriver(
            block_fn,
            schedule,
            n=g.n,
            prep=prep,
            rounds_per_dispatch=2,
            integrity=mode,
            profile=True,
        ).run()
        err = float(np.abs(result.bc - expected).max())
        assert err < 1e-6, f"integrity={mode} diverged from brandes_ref: {err}"
        integ = result.recovery_stats["integrity"]
        failures = integ["checksum_failures"] + integ["audit_failures"]
        assert failures == 0, f"integrity={mode} false positives: {integ}"
        walls[mode] = result.wall_s
        record["modes"][mode] = {
            "wall_s": result.wall_s,
            "block_wall_s_median": float(np.median(result.block_times)),
            "max_abs_err_vs_brandes": err,
            "max_checksum_residual": integ["max_checksum_residual"],
            "false_positives": failures,
        }
        emit(
            f"table3/integrity_{mode}",
            result.wall_s * 1e6,
            f"err={err:.2e};residual={integ['max_checksum_residual']:.2e}",
        )
    record["overhead_ratio_audit_vs_off"] = walls["audit"] / max(walls["off"], 1e-9)
    record["overhead_ratio_checksum_vs_off"] = (
        walls["checksum"] / max(walls["off"], 1e-9)
    )
    emit(
        "table3/integrity_overhead",
        0.0,
        f"audit={record['overhead_ratio_audit_vs_off']:.2f}x;"
        f"checksum={record['overhead_ratio_checksum_vs_off']:.2f}x",
    )
    return record


#: deal comparison batch width: TWO components per round, so the
#: vertex-id deal mixes one deep path with one shallow clique per round
#: while the eccentricity deal pairs like with like
DEAL_BATCH = 2 * BLOCK


def _deal_bench() -> dict:
    """(c) interleaved vs eccentricity-packed round deal — exact levels.

    A round's traversal runs to its *deepest* root's level, so the total
    over rounds of ``max(root depth) + 1`` is the level count the
    traversal loop actually executes.  Computed from exact host BFS
    depths over deterministic schedules — a structural metric
    (tools/check_bench.py compares it exactly), no timing involved.
    """
    from repro.core.scheduler import bfs_depths

    g = skewed_depth_graph(PAIRS, BLOCK)
    ecc_exact = np.array(
        [int(bfs_depths(g, v).max()) for v in range(g.n)], np.int64
    )

    def total_levels(schedule) -> int:
        return sum(
            int(max(ecc_exact[v] for v in r.sources if v >= 0)) + 1
            for r in schedule.rounds
        )

    sched_id, _, _, _ = build_schedule(g, batch_size=DEAL_BATCH, root_order="id")
    sched_ecc, _, _, _ = build_schedule(
        g, batch_size=DEAL_BATCH, root_order="eccentricity"
    )
    interleaved = total_levels(sched_id)
    packed = total_levels(sched_ecc)
    assert packed < interleaved, (
        f"eccentricity deal must cut total levels: {packed} vs {interleaved}"
    )
    record = {
        "batch_size": DEAL_BATCH,
        "rounds": len(sched_id.rounds),
        "interleaved_total_levels": interleaved,
        "eccentricity_total_levels": packed,
        "levels_saved": interleaved - packed,
    }
    emit(
        "table3/deal_eccentricity",
        0.0,
        f"interleaved_levels={interleaved};packed_levels={packed};"
        f"saved={interleaved - packed}",
    )
    return record


def _sampling_bench() -> dict:
    """(e) error-vs-k: the sampled estimator's rank quality and wall at
    k ∈ {n/16, n/4, all} on a seeded rmat graph, plus one adaptive leg.

    ``rank_error_top10`` (1 − Jaccard of the served top-10 vs exact) is
    seeded and deterministic per jax version but sensitive to reduction
    order, so tools/check_bench.py gates the *key*, not the value;
    ``rounds`` per leg is ceil(k / batch) — structural.  The full-sample
    leg's ``max_abs_err_vs_brandes`` is the usual parity metric.
    """
    import time

    from repro.serving.sampling import eligible_roots, rank_stability

    g = rmat_graph(8, 8, seed=3)
    exact = brandes_reference(g)
    mesh = make_mesh((2, 4), ("data", "model"))
    n_elig = int(eligible_roots(g).size)
    batch = 16
    record: dict = {
        "graph": {"kind": "rmat_graph(8, 8, seed=3)", "n": g.n,
                  "m": int(g.num_edges), "eligible_roots": n_elig},
        "mesh": "2x4",
        "batch_size": batch,
        "legs": {},
    }
    legs = [("k16", {"sample_k": 16}), ("k64", {"sample_k": 64}),
            ("full", {"sample_frac": 1.0})]
    for name, size_kw in legs:
        t0 = time.perf_counter()
        result = distributed_betweenness_centrality(
            g, mesh, batch_size=batch, heuristics="h0",
            sampling="fixed", sample_seed=7, full_result=True, **size_kw,
        )
        sec = time.perf_counter() - t0
        rank_err = 1.0 - rank_stability(exact, result.bc, k=10)
        leg = {
            "k": result.sampling_stats["k_planned"],
            "rounds": len(result.schedule.rounds),
            "wall_s": sec,
            "rank_error_top10": rank_err,
        }
        if name == "full":
            leg["max_abs_err_vs_brandes"] = float(
                np.abs(result.bc - exact).max()
            )
            assert leg["max_abs_err_vs_brandes"] < 5e-3  # f32 @ BC ~1e4
        record["legs"][name] = leg
        emit(
            f"table3/sampling_{name}",
            sec * 1e6,
            f"k={leg['k']};rounds={leg['rounds']};rank_err={rank_err:.2f}",
        )
    return record


def _weighted_bench() -> dict:
    """(f) weighted (bucketed) traversal: wall + parity per engine.

    Dyadic weights keep every shortest distance an exact f32 sum, so the
    Dijkstra-oracle parity is deterministic per jax version; ``delta``
    is :func:`auto_delta`'s derivation — a pure function of the graph,
    gated exactly by tools/check_bench.py.  Walls are machine-speed
    (loose gate); the bucket loop's cost relative to the level loop is
    the number being tracked.
    """
    import time

    from repro.core.operators import auto_delta

    g = rmat_graph(6, 4, seed=5, weights="dyadic")
    exact = brandes_reference(g)
    mesh = make_mesh((2, 4), ("data", "model"))
    delta = auto_delta(g)
    record: dict = {
        "graph": {"kind": "rmat_graph(6, 4, seed=5, weights='dyadic')",
                  "n": g.n, "m": int(g.num_edges), "weights": "dyadic"},
        "mesh": "2x4",
        "batch_size": 16,
        "delta": delta,
        "engines": {},
    }
    for engine_kind in ("sparse", "pallas"):
        t0 = time.perf_counter()
        bc, schedule = distributed_betweenness_centrality(
            g, mesh, engine_kind=engine_kind, weighted=True, batch_size=16
        )
        sec = time.perf_counter() - t0
        err = float(np.abs(np.asarray(bc) - exact).max())
        assert err < 1e-4, f"weighted {engine_kind} diverged: {err}"
        record["engines"][engine_kind] = {
            "wall_s": sec,
            "rounds": len(schedule.rounds),
            "max_abs_err_vs_brandes": err,
        }
        emit(
            f"table3/weighted_{engine_kind}",
            sec * 1e6,
            f"delta={delta:.4g};rounds={len(schedule.rounds)};err={err:.2e}",
        )
    return record


def run() -> None:
    if not ensure_devices(8):
        emit("table3/skipped", 0.0, "needs 8 host devices")
        return
    _replication_sweep()
    record = _straggler_bench()
    record["deal"] = _deal_bench()
    record["integrity"] = _integrity_bench()
    record["sampling"] = _sampling_bench()
    record["weighted"] = _weighted_bench()
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    emit("table3/bench_json", 0.0, f"wrote={BENCH_JSON}")


if __name__ == "__main__":
    run()
