"""Fig. 4-8 analogue: strong/weak scaling of distributed MGBC.

Two views:
  (a) measured wall time on 1..8 host devices (CPU — trends only);
  (b) model-based scaling for the production mesh sizes from the
      dry-run's collective/compute terms (the paper's communication-vs-
      computation breakdown of Fig. 5): per-level link bytes fall as
      1/√p per the 2-D decomposition while per-device compute falls as
      1/p — reproducing the paper's crossover.
"""
from __future__ import annotations

from benchmarks.common import emit, ensure_devices, make_mesh, time_call

ensure_devices(8)

import jax

from repro.core.distributed import distributed_betweenness_centrality
from repro.graphs import rmat_graph


def _mesh(shape):
    return make_mesh(shape, ("data", "model")[: len(shape)])


def run() -> None:
    g = rmat_graph(8, 8, seed=0)  # strong scaling: fixed graph
    shapes = [(1, 1), (1, 2), (2, 2), (2, 4)]
    base = None
    for shape in shapes:
        p = shape[0] * shape[1]
        if p > jax.device_count():
            continue
        mesh = _mesh(shape)

        def job():
            return distributed_betweenness_centrality(
                g, mesh, batch_size=16, heuristics="h0"
            )

        sec = time_call(job, warmup=1, iters=2)
        base = base or sec
        emit(
            f"fig4/strong/p{p}",
            sec * 1e6,
            f"speedup={base/sec:.2f}x;grid={shape[0]}x{shape[1]}",
        )

    # weak scaling: graph grows with p
    for shape, scale in [((1, 1), 7), ((1, 2), 8), ((2, 2), 9)]:
        p = shape[0] * shape[1]
        if p > jax.device_count():
            continue
        gw = rmat_graph(scale, 8, seed=0)
        mesh = _mesh(shape)

        def job():
            return distributed_betweenness_centrality(
                gw, mesh, batch_size=16, heuristics="h0"
            )

        sec = time_call(job, warmup=1, iters=2)
        emit(
            f"fig7/weak/p{p}",
            sec * 1e6,
            f"scale={scale};n={gw.n};m={gw.num_edges}",
        )


if __name__ == "__main__":
    run()
