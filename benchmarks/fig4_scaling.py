"""Fig. 4-8 analogue: strong/weak scaling of distributed MGBC.

Three views:
  (a) measured wall time on 1..8 host devices (CPU — trends only);
  (b) model-based scaling for the production mesh sizes from the
      dry-run's collective/compute terms (the paper's communication-vs-
      computation breakdown of Fig. 5): per-level link bytes fall as
      1/√p per the 2-D decomposition while per-device compute falls as
      1/p — reproducing the paper's crossover;
  (c) dense-block vs blocked-sparse vs hybrid adjacency: nonzero-tile
      counts, per-level A-stream bytes, the hybrid engine's per-cell
      dense/BCSR decision with both layouts' host bytes, and per-round
      wall time of each engine on an RMAT graph — written to
      ``BENCH_sparse.json`` as the machine-readable regression baseline
      for the O(nnz-tiles) memory claim and the per-cell kernel choice
      (``make bench-check`` gates all structural fields against the
      committed baseline).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, ensure_devices, make_mesh, time_call

ensure_devices(8)

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.distributed import (
    distributed_betweenness_centrality,
    distributed_graph_arrays,
    hybrid_cell_choice,
    make_distributed_round_fn,
)
from repro.core.scheduler import build_schedule
from repro.graphs import rmat_graph
from repro.graphs.partition import partition_2d
from repro.roofline.model import adjacency_stream_bytes

BENCH_JSON = os.environ.get("BENCH_SPARSE_JSON", "BENCH_sparse.json")

SPARSE_MESH = (2, 4)
SPARSE_TILE = 16  # resolves RMAT sparsity at benchmark scale (128 = prod)
HYBRID_TILE = 32  # coarse enough that the densest RMAT cell flips dense
NUM_LEVELS = 10


def _mesh(shape):
    return make_mesh(shape, ("data", "model")[: len(shape)])


def _sparse_bench() -> dict:
    """(c): dense vs blocked-sparse A-stream + per-round wall time."""
    g = rmat_graph(10, 4, seed=0)
    R, C = SPARSE_MESH
    schedule, _, residual, _ = build_schedule(g, batch_size=16)
    part = partition_2d(residual, R, C)
    mesh = _mesh(SPARSE_MESH)
    tile = (SPARSE_TILE, SPARSE_TILE)
    layout = part.blocked_sparse(*tile)

    nnz_max = int(layout.nnz_tiles.max())
    dense_tiles = layout.num_tile_rows * layout.num_tile_cols
    bytes_dense = adjacency_stream_bytes("pallas", R=R, C=C, chunk=part.chunk)
    bytes_sparse = adjacency_stream_bytes(
        "pallas_sparse",
        R=R,
        C=C,
        chunk=part.chunk,
        nnz_tiles=nnz_max,
        bm=tile[0],
        bk=tile[1],
    )
    # hybrid: the roofline's per-cell dense/BCSR decision + what each
    # candidate layout costs on the host — the structural record the
    # bench gate (tools/check_bench.py) pins, so a silent change to the
    # choice model or the layout build fails the PR.  The hybrid section
    # uses its own coarser tile: at HYBRID_TILE the densest
    # community-structured cell crosses the bytes-streamed break-even
    # and resolves dense while the rest stay BCSR — the skewed-RMAT mix
    # the engine exists for.
    htile = (HYBRID_TILE, HYBRID_TILE)
    dense_cells, counts = hybrid_cell_choice(part, *htile)
    hybrid = part.blocked_hybrid(*htile, dense_cells=dense_cells)
    record: dict = {
        "graph": {"name": "rmat_s10_ef4", "n": g.n, "m": int(g.num_edges)},
        "mesh": f"{R}x{C}",
        "tile": list(tile),
        "nnz_tiles_max_per_device": nnz_max,
        "nnz_tiles_total": int(layout.nnz_tiles.sum()),
        "dense_tiles_per_device": dense_tiles,
        "a_stream_bytes_per_level": {
            "pallas": bytes_dense,
            "pallas_sparse": bytes_sparse,
        },
        "adjacency_stored_bytes_per_device": layout.adjacency_bytes(),
        "hybrid": {
            "tile": list(htile),
            "threshold": 1.0,
            "dense_cells": dense_cells.astype(int).tolist(),
            "cells_dense": int(dense_cells.sum()),
            "cells_sparse": int(dense_cells.size - dense_cells.sum()),
            "stored_tiles_per_cell": counts["stored_full_cell"].tolist(),
            "host_bytes": {
                "all_dense": int(R * C * (C * part.chunk) * (R * part.chunk) * 4),
                # counts["bytes_full"] == blocked_sparse().adjacency_bytes()
                # per device, without materializing a second tile layout
                "all_sparse": int(R * C * counts["bytes_full"]),
                "hybrid_materialized": int(hybrid.host_bytes()),
            },
        },
        "round_wall_s": {},
    }
    # per-round wall time through one compiled round call (Pallas engines
    # run in interpret mode on CPU — structure, not speed, is the signal)
    s, k = schedule.batch_size, schedule.derived_per_round
    omega = jnp.zeros(part.n_pad, jnp.float32)
    sources = jnp.asarray(np.arange(s, dtype=np.int32))[None]
    derived = jnp.full((1, k, 3), -1, jnp.int32)
    for engine_kind in ("sparse", "pallas", "pallas_sparse", "pallas_hybrid"):
        fn = make_distributed_round_fn(
            part, mesh, num_levels=NUM_LEVELS, engine_kind=engine_kind
        )
        gargs = distributed_graph_arrays(
            part,
            engine_kind,
            tile={"pallas_sparse": tile, "pallas_hybrid": htile}.get(engine_kind),
            dense_cells=dense_cells if engine_kind == "pallas_hybrid" else None,
        )
        sec = time_call(lambda: fn(*gargs, omega, sources, derived), warmup=1, iters=2)
        record["round_wall_s"][engine_kind] = sec
        emit(f"fig4/sparse_round_{engine_kind}", sec * 1e6, f"levels={NUM_LEVELS}")
    emit(
        "fig4/sparse_a_stream",
        0.0,
        f"dense_MB={bytes_dense/1e6:.3f};sparse_MB={bytes_sparse/1e6:.3f};"
        f"nnz_tiles={nnz_max}/{dense_tiles}",
    )
    return record


def run() -> None:
    g = rmat_graph(8, 8, seed=0)  # strong scaling: fixed graph
    shapes = [(1, 1), (1, 2), (2, 2), (2, 4)]
    base = None
    for shape in shapes:
        p = shape[0] * shape[1]
        if p > jax.device_count():
            continue
        mesh = _mesh(shape)

        def job():
            return distributed_betweenness_centrality(
                g, mesh, batch_size=16, heuristics="h0"
            )

        sec = time_call(job, warmup=1, iters=2)
        base = base or sec
        emit(
            f"fig4/strong/p{p}",
            sec * 1e6,
            f"speedup={base/sec:.2f}x;grid={shape[0]}x{shape[1]}",
        )

    # weak scaling: graph grows with p
    for shape, scale in [((1, 1), 7), ((1, 2), 8), ((2, 2), 9)]:
        p = shape[0] * shape[1]
        if p > jax.device_count():
            continue
        gw = rmat_graph(scale, 8, seed=0)
        mesh = _mesh(shape)

        def job():
            return distributed_betweenness_centrality(
                gw, mesh, batch_size=16, heuristics="h0"
            )

        sec = time_call(job, warmup=1, iters=2)
        emit(
            f"fig7/weak/p{p}",
            sec * 1e6,
            f"scale={scale};n={gw.n};m={gw.num_edges}",
        )

    # (c) dense vs blocked-sparse adjacency → BENCH_sparse.json
    if jax.device_count() >= 8:
        record = _sparse_bench()
        with open(BENCH_JSON, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
        emit("fig4/bench_json", 0.0, f"wrote={BENCH_JSON}")


if __name__ == "__main__":
    run()
