"""Table 4 analogue: impact of the 1-degree reduction.

Paper: R-MAT graphs at several edge factors + com-youtube; reports the
1-degree fraction, total/mean time with the heuristic on vs off, the
preprocessing cost and the speedup.  Lower edge factor ⇒ more leaves ⇒
bigger win (their EF4 1.8x vs EF32 1.3x) — the trend this benchmark
reproduces.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, time_call
from repro.core import betweenness_centrality
from repro.core.heuristics.one_degree import one_degree_reduce
from repro.graphs import rmat_graph, road_like_graph


def run() -> None:
    graphs = {
        "rmat_s9_ef4": rmat_graph(9, 4, seed=0),
        "rmat_s9_ef8": rmat_graph(9, 8, seed=0),
        "rmat_s9_ef16": rmat_graph(9, 16, seed=0),
        "youtube_like": road_like_graph(10, 10, spur_fraction=2.0, seed=0),
    }
    for name, g in graphs.items():
        t0 = time.perf_counter()
        prep = one_degree_reduce(g)
        prep_s = time.perf_counter() - t0
        frac = prep.num_removed / g.n * 100

        t_off = time_call(
            lambda: betweenness_centrality(g, batch_size=32, heuristics="h0"),
            warmup=1,
            iters=3,
        )
        t_on = time_call(
            lambda: betweenness_centrality(g, batch_size=32, heuristics="h1"),
            warmup=1,
            iters=3,
        )
        emit(
            f"table4/{name}",
            t_on * 1e6,
            f"speedup={t_off/t_on:.2f}x;one_degree_pct={frac:.1f};"
            f"prep_s={prep_s:.4f};t_off_s={t_off:.3f}",
        )


if __name__ == "__main__":
    run()
