"""Fig. 10 analogue: strong scaling of the distributed 1-degree
preprocessing (paper: near-linear speedup on R-MAT SCALE 23 EF 32)."""
from __future__ import annotations

from benchmarks.common import emit, ensure_devices, make_mesh, time_call

ensure_devices(8)

import jax

from repro.core.distributed import one_degree_reduce_distributed
from repro.graphs import rmat_graph


def run() -> None:
    g = rmat_graph(11, 16, seed=0)
    base = None
    for p in (1, 2, 4, 8):
        if p > jax.device_count():
            continue
        mesh = make_mesh((p,), ("data",))

        def job():
            return one_degree_reduce_distributed(g, mesh, "data")

        sec = time_call(job, warmup=1, iters=3)
        base = base or sec
        emit(f"fig10/preproc_p{p}", sec * 1e6, f"speedup={base/sec:.2f}x;m={g.num_edges}")


if __name__ == "__main__":
    run()
