"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run             # everything
    PYTHONPATH=src python -m benchmarks.run --only table4

Needs 8 host devices for the distributed benchmarks; each benchmark
module (and this entrypoint) calls :func:`benchmarks.common.ensure_devices`
to set the XLA flag before jax initializes — tests still see 1 device.
"""
from benchmarks.common import ensure_devices

ensure_devices(8)

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    from benchmarks import (
        fig4_scaling,
        fig9_overlap,
        fig10_preprocessing,
        table2_single_device,
        table3_subcluster,
        table4_one_degree,
        table5_heuristics,
    )

    suites = {
        "table2": table2_single_device.run,
        "table3": table3_subcluster.run,
        "table4": table4_one_degree.run,
        "table5": table5_heuristics.run,
        "fig4": fig4_scaling.run,
        "fig9": fig9_overlap.run,
        "fig10": fig10_preprocessing.run,
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        fn()


if __name__ == "__main__":
    main()
