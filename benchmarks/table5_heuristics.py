"""Table 5 / Fig. 12 analogue: H0-H3 on a road-network-like graph.

Reports, per heuristic mode: total time, explicit (traversed) sources,
1-degree-skipped vertices and 2-degree-derived vertices — the exact
accounting of the paper's Table 5 (their RoadNet-PA run), including the
H3 effect where the 1-degree pass *creates* new 2-degree vertices.
"""
from __future__ import annotations

from benchmarks.common import emit, time_call
from repro.core import betweenness_centrality, brandes_reference
import numpy as np

from repro.graphs import road_like_graph, suburb_graph


def run() -> None:
    graphs = {
        "road": road_like_graph(14, 14, spur_fraction=0.6, seed=0),
        # leaf-on-3-degree topology: the paper's H3>H2 composition regime
        "suburb": suburb_graph(7, 7, leaf_fraction=0.6, seed=0),
    }
    for gname, g in graphs.items():
        ref = brandes_reference(g)
        derived_h2 = None
        for h in ("h0", "h1", "h2", "h3", "h1t", "h3t"):  # *t = tree contraction
            def job():
                return betweenness_centrality(g, batch_size=32, heuristics=h)

            sec = time_call(job, warmup=1, iters=3)
            res = job()
            np.testing.assert_allclose(res.bc, ref, rtol=1e-4, atol=1e-4)
            sch = res.schedule
            if h == "h2":
                derived_h2 = sch.num_derived
            extra = ""
            if h == "h3" and derived_h2 is not None:
                extra = f";derived_gain_vs_h2={sch.num_derived - derived_h2}"
            emit(
                f"table5/{gname}/{h}",
                sec * 1e6,
                f"explicit={sch.num_explicit};leaf_skipped={sch.num_leaf_skipped};"
                f"derived2deg={sch.num_derived};n={g.n}" + extra,
            )


if __name__ == "__main__":
    run()
