"""Fig. 9 analogue: communication fusion/overlap + mask-cache impact.

(a) The paper overlaps σ/d exchanges (6 sync steps → 4).  Our structural
    equivalent fuses the backward payload into one collective per level;
    the benchmark compares the *link bytes and collective count* of the
    fused vs split schedules from the lowered HLO of one round.
(b) The paper's prefix-sum reuse is structural here (level masks reused
    between sweeps); the measurable analogue is the fused Pallas level
    kernel vs the unfused XLA reference — compared by HBM bytes of one
    level (kernel: A + 2x(σ,d) streams; unfused adds the frontier and
    product intermediates).
(c) Ring-pipelined expand/fold (paper Fig. 2 / §3.3): the barrier
    schedule's monolithic all_gather + psum_scatter vs the ppermute ring
    schedules, compared by per-round collective counts, link bytes, ring
    hops, and measured per-round wall time.  The numbers are written to
    ``BENCH_overlap.json`` so future PRs have a machine-readable
    baseline to regress against.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, ensure_devices, make_mesh

ensure_devices(8)

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.distributed import distributed_graph_arrays, make_distributed_round_fn
from repro.core.driver import BCDriver
from repro.core.scheduler import build_schedule
from repro.graphs import rmat_graph
from repro.graphs.partition import partition_2d
from repro.roofline.hlo import analyze_hlo_module
from repro.roofline.model import link_bytes, ring_steps

BENCH_JSON = os.environ.get("BENCH_OVERLAP_JSON", "BENCH_overlap.json")

NUM_LEVELS = 12
MESH_SHAPE = (2, 4)


def _interp_wall_opted_in() -> bool:
    """Explicit opt-in to timing the Pallas engines on CPU.

    The Pallas engines run in *interpret* mode on CPU hosts, so their
    wall clock measures the interpreter, not the kernel — recording it
    silently would poison the baseline.  ``round_wall_s`` stays null for
    those engines unless the caller opts in via ``--interp-wall`` or
    ``FIG9_INTERP_WALL=1`` (the gate, tools/check_bench.py, treats a
    null↔value flip on a wall metric as a timing artifact either way).
    """
    import sys

    return "--interp-wall" in sys.argv or os.environ.get("FIG9_INTERP_WALL") == "1"


def _collective_counts(coll_records: list[dict]) -> dict[str, int]:
    """Per-class collective executions per round (trip-count-multiplied
    instruction counts from the HLO parser — roofline/hlo.py)."""
    out = {
        cls: 0
        for cls in ("all-gather", "reduce-scatter", "all-reduce", "collective-permute")
    }
    for rec in coll_records:
        if rec["class"] in out:
            out[rec["class"]] += rec.get("count", 1)
    return out


def _overlap_bench(g, schedule, part, mesh) -> dict:
    """(c): barrier vs ring schedules — HLO collectives + wall time."""
    s, k = schedule.batch_size, schedule.derived_per_round
    omega = jnp.zeros(part.n_pad, jnp.float32)
    record: dict = {
        "graph": {"name": "rmat_s8_ef8", "n": g.n, "m": int(g.num_edges)},
        "mesh": f"{MESH_SHAPE[0]}x{MESH_SHAPE[1]}",
        "num_levels": NUM_LEVELS,
        "engines": {},
    }
    for engine_kind in ("sparse", "pallas"):
        engine_rec: dict = {}
        for overlap in ("none", "expand", "expand+fold"):
            fn = make_distributed_round_fn(
                part,
                mesh,
                num_levels=NUM_LEVELS,
                engine_kind=engine_kind,
                overlap=overlap,
            )
            graph_args = distributed_graph_arrays(part, engine_kind, overlap)
            arg_specs = tuple(
                jax.ShapeDtypeStruct(a.shape, a.dtype) for a in graph_args
            ) + (
                jax.ShapeDtypeStruct((part.n_pad,), jnp.float32),
                jax.ShapeDtypeStruct((1, s), jnp.int32),
                jax.ShapeDtypeStruct((1, k, 3), jnp.int32),
            )
            text = fn.lower(*arg_specs).compile().as_text()
            terms = analyze_hlo_module(text)
            colls = terms["collectives"]
            counts = _collective_counts(colls)

            # per-round wall time through the shared driver (profile
            # mode).  Sparse always; the Pallas engines only behind the
            # --interp-wall / FIG9_INTERP_WALL=1 opt-in — on CPU their
            # wall time measures the interpreter.
            per_round = None
            rounds = len(schedule.rounds)
            if engine_kind == "sparse" or _interp_wall_opted_in():

                def block_fn(sources, derived, _fn=fn, _ga=graph_args):
                    return _fn(*_ga, omega, sources, derived)

                result = BCDriver(block_fn, schedule, n=g.n, profile=True).run()
                per_round = float(np.median(result.block_times))
                rounds = result.rounds_run
            engine_rec[overlap] = {
                "link_bytes_per_round": link_bytes(colls),
                "collectives_per_round": int(sum(counts.values())),
                "collectives_per_round_by_class": counts,
                "ring_steps_per_round": ring_steps(colls),
                "round_wall_s": per_round,
                "rounds": rounds,
            }
            emit(
                f"fig9/overlap_{engine_kind}_{overlap.replace('+', '_')}",
                0.0 if per_round is None else per_round * 1e6,
                f"link_MB={link_bytes(colls)/1e6:.2f};"
                f"collectives={engine_rec[overlap]['collectives_per_round']};"
                f"all_gather={counts['all-gather']};"
                f"permute={counts['collective-permute']}",
            )
        record["engines"][engine_kind] = engine_rec
    return record


def run() -> None:
    if not ensure_devices(8):
        emit("fig9/skipped", 0.0, "needs 8 host devices")
        return
    g = rmat_graph(8, 8, seed=0)
    schedule, _, residual, _ = build_schedule(g, batch_size=16)
    part = partition_2d(residual, *MESH_SHAPE)
    mesh = make_mesh(MESH_SHAPE, ("data", "model"))
    omega = jnp.zeros(part.n_pad, jnp.float32)

    # (a) fused vs split backward payload (barrier schedule)
    stats = {}
    for fused in (True, False):
        fn = make_distributed_round_fn(
            part, mesh, fuse_backward_payload=fused, num_levels=NUM_LEVELS
        )
        lowered = fn.lower(
            jax.ShapeDtypeStruct(part.src_local.shape, jnp.int32),
            jax.ShapeDtypeStruct(part.dst_local.shape, jnp.int32),
            jax.ShapeDtypeStruct((part.n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((1, 16), jnp.int32),
            jax.ShapeDtypeStruct((1, 8, 3), jnp.int32),
        )
        terms = analyze_hlo_module(lowered.compile().as_text())
        n_coll = sum(1 for _ in terms["collectives"])
        stats[fused] = (link_bytes(terms["collectives"]), n_coll)
        emit(
            f"fig9/backward_{'fused' if fused else 'split'}",
            0.0,
            f"link_MB_per_round={stats[fused][0]/1e6:.2f};collective_sites={n_coll}",
        )
    ratio = stats[False][0] / max(stats[True][0], 1)
    emit("fig9/fusion_gain", 0.0, f"split_over_fused_link_bytes={ratio:.2f}x")

    # (b) fused kernel vs unfused reference — HBM bytes of one level
    from repro.kernels import ops

    n, s = 512, 128
    A = jnp.zeros((n, n), jnp.float32)
    sigma = jnp.zeros((n, s), jnp.float32)
    depth = jnp.zeros((n, s), jnp.int32)
    for use_pallas, tag in ((False, "xla_ref"),):
        low = jax.jit(
            lambda a, sg, d: ops.frontier_spmm(a, sg, d, 2, use_pallas=False)
        ).lower(A, sigma, depth)
        terms = analyze_hlo_module(low.compile().as_text())
        emit(f"fig9/level_{tag}", 0.0, f"hbm_MB={terms['bytes']/1e6:.1f}")
    # kernel model: A + sigma/depth in + out once
    kernel_bytes = n * n * 4 + 4 * (n * s * 4)
    emit("fig9/level_pallas_model", 0.0, f"hbm_MB={kernel_bytes/1e6:.1f}")

    # (c) barrier vs ring-pipelined schedules → BENCH_overlap.json
    record = _overlap_bench(g, schedule, part, mesh)
    with open(BENCH_JSON, "w") as f:
        json.dump(record, f, indent=2, sort_keys=True)
    emit("fig9/bench_json", 0.0, f"wrote={BENCH_JSON}")


if __name__ == "__main__":
    run()
