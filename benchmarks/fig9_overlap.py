"""Fig. 9 analogue: communication fusion/overlap + mask-cache impact.

(a) The paper overlaps σ/d exchanges (6 sync steps → 4).  Our structural
    equivalent fuses the backward payload into one collective per level;
    the benchmark compares the *link bytes and collective count* of the
    fused vs split schedules from the lowered HLO of one round.
(b) The paper's prefix-sum reuse is structural here (level masks reused
    between sweeps); the measurable analogue is the fused Pallas level
    kernel vs the unfused XLA reference — compared by HBM bytes of one
    level (kernel: A + 2x(σ,d) streams; unfused adds the frontier and
    product intermediates).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.scheduler import build_schedule
from repro.core.distributed import make_distributed_round_fn
from repro.graphs import rmat_graph
from repro.graphs.partition import partition_2d
from repro.roofline.hlo import analyze_hlo_module
from repro.roofline.model import link_bytes


def _mesh(shape, names):
    from repro.launch.mesh import make_mesh

    return make_mesh(shape, names)


def run() -> None:
    if jax.device_count() < 8:
        emit("fig9/skipped", 0.0, "needs 8 host devices")
        return
    g = rmat_graph(8, 8, seed=0)
    schedule, _, residual, _ = build_schedule(g, batch_size=16)
    part = partition_2d(residual, 2, 4)
    mesh = _mesh((2, 4), ("data", "model"))
    omega = jnp.zeros(part.n_pad, jnp.float32)
    rnd = schedule.rounds[0]

    stats = {}
    for fused in (True, False):
        fn = make_distributed_round_fn(
            part, mesh, fuse_backward_payload=fused, num_levels=12
        )
        lowered = fn.lower(
            jax.ShapeDtypeStruct(part.src_local.shape, jnp.int32),
            jax.ShapeDtypeStruct(part.dst_local.shape, jnp.int32),
            jax.ShapeDtypeStruct((part.n_pad,), jnp.float32),
            jax.ShapeDtypeStruct((1, 16), jnp.int32),
            jax.ShapeDtypeStruct((1, 8, 3), jnp.int32),
        )
        terms = analyze_hlo_module(lowered.compile().as_text())
        n_coll = sum(1 for _ in terms["collectives"])
        stats[fused] = (link_bytes(terms["collectives"]), n_coll)
        emit(
            f"fig9/backward_{'fused' if fused else 'split'}",
            0.0,
            f"link_MB_per_round={stats[fused][0]/1e6:.2f};collective_sites={n_coll}",
        )
    ratio = stats[False][0] / max(stats[True][0], 1)
    emit("fig9/fusion_gain", 0.0, f"split_over_fused_link_bytes={ratio:.2f}x")

    # (b) fused kernel vs unfused reference — HBM bytes of one level
    from repro.kernels import ops

    n, s = 512, 128
    A = jnp.zeros((n, n), jnp.float32)
    sigma = jnp.zeros((n, s), jnp.float32)
    depth = jnp.zeros((n, s), jnp.int32)
    for use_pallas, tag in ((False, "xla_ref"),):
        low = jax.jit(
            lambda a, sg, d: ops.frontier_spmm(a, sg, d, 2, use_pallas=False)
        ).lower(A, sigma, depth)
        terms = analyze_hlo_module(low.compile().as_text())
        emit(f"fig9/level_{tag}", 0.0, f"hbm_MB={terms['bytes']/1e6:.1f}")
    # kernel model: A + sigma/depth in + out once
    kernel_bytes = n * n * 4 + 4 * (n * s * 4)
    emit("fig9/level_pallas_model", 0.0, f"hbm_MB={kernel_bytes/1e6:.1f}")


if __name__ == "__main__":
    run()
