"""Benchmark utilities: device bootstrap, meshes, timing + CSV emission.

This module must stay importable before jax: :func:`ensure_devices` has
to set ``--xla_force_host_platform_device_count`` *before* the first jax
import locks the backend, so nothing here imports jax at module scope.
"""
from __future__ import annotations

import os
import re
import sys
import time

__all__ = ["ensure_devices", "make_mesh", "time_call", "emit"]

_DEVICE_FLAG = "--xla_force_host_platform_device_count"


def ensure_devices(n: int) -> bool:
    """Make sure at least ``n`` XLA host devices exist.

    When jax has not been imported yet, sets
    ``--xla_force_host_platform_device_count`` in ``XLA_FLAGS`` (raising
    a pre-existing smaller count — the last occurrence wins) so the
    backend initializes with ``n`` fake host devices — this is what lets
    every benchmark run standalone (``PYTHONPATH=src:. python
    benchmarks/fig9_overlap.py``) instead of hard-skipping outside the
    ``benchmarks.run`` entry point.  When jax is already initialized the
    count is locked; the return value then reports whether the
    requirement is met so callers can skip gracefully.
    """
    if "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        found = re.findall(rf"{_DEVICE_FLAG}=(\d+)", flags)
        if not found or int(found[-1]) < n:
            os.environ["XLA_FLAGS"] = f"{flags} {_DEVICE_FLAG}={n}".strip()
    import jax

    return jax.device_count() >= n


def make_mesh(shape, names=None):
    """The one mesh helper for all benchmark scripts.

    ``names`` defaults to the trailing axes of ("pod", "data", "model")
    matching ``len(shape)`` — the axis-role convention of
    :mod:`repro.launch.mesh`.
    """
    from repro.launch.mesh import make_mesh as _make_mesh

    if names is None:
        names = ("pod", "data", "model")[-len(shape):]
    return _make_mesh(tuple(shape), tuple(names))


def time_call(fn, *args, warmup: int = 1, iters: int = 3, **kw) -> float:
    """Median wall seconds of fn(*args) after warmup (blocks on results)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """One CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")
