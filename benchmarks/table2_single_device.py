"""Table 2 analogue: single-device BC time per source-round across graph
classes (road-network-like long diameter vs. scale-free short diameter)
and engines (dense MXU path / sparse segment-sum path / fused Pallas).

The paper compares MGBC against McLaughlin, Sariyüce and Gunrock on one
GPU; without those codes (or a GPU) the meaningful reproduction is the
per-round cost profile across the same topology classes.
"""
from __future__ import annotations

from benchmarks.common import emit, time_call
from repro.core import betweenness_centrality
from repro.core.bc import ENGINE_KINDS
from repro.graphs import grid_graph, gnp_graph, rmat_graph, road_like_graph


def run() -> None:
    graphs = {
        "roadnet_like": road_like_graph(16, 16, spur_fraction=0.4, seed=0),
        "grid_20x20": grid_graph(20, 20),
        "rmat_s9_ef8": rmat_graph(9, 8, seed=0),
        "gnp_400_p02": gnp_graph(400, 0.02, seed=0),
    }
    for name, g in graphs.items():
        for engine in ENGINE_KINDS:
            def job():
                return betweenness_centrality(
                    g, batch_size=32, heuristics="h0", engine_kind=engine
                )

            sec = time_call(job, warmup=1, iters=3)
            res = job()
            per_round_us = sec / max(res.rounds_run, 1) * 1e6
            teps = g.num_edges * res.forward_columns / sec
            emit(
                f"table2/{name}/{engine}",
                per_round_us,
                f"total_s={sec:.3f};MTEPS={teps/1e6:.1f};n={g.n};m={g.num_edges}",
            )


if __name__ == "__main__":
    run()
