"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.graphs import gnp_graph
from repro.kernels import ops, ref


def _bc_state(n, s, seed, lvl):
    """A plausible mid-traversal BC state for a random graph."""
    rng = np.random.default_rng(seed)
    g = gnp_graph(n, min(0.3, 8.0 / n), seed=seed)
    A = g.dense_adjacency(np.float32)
    sigma = rng.integers(0, 5, size=(n, s)).astype(np.float32)
    depth = rng.integers(-1, lvl + 3, size=(n, s)).astype(np.int32)
    sigma = np.where(depth >= 0, np.maximum(sigma, 1.0), 0.0)
    delta = rng.random((n, s)).astype(np.float32) * (depth >= 0)
    omega = rng.integers(0, 3, size=n).astype(np.float32)
    return A, sigma, depth, delta, omega


SHAPES = [(8, 4), (16, 16), (64, 8), (128, 128), (130, 33), (256, 64)]


@pytest.mark.parametrize("n,s", SHAPES)
@pytest.mark.parametrize("adj_dtype", [jnp.float32, jnp.bfloat16])
def test_frontier_spmm_matches_ref(n, s, adj_dtype):
    lvl = 2
    A, sigma, depth, _, _ = _bc_state(n, s, seed=n + s, lvl=lvl)
    A = jnp.asarray(A, adj_dtype)
    got_s, got_d = ops.frontier_spmm(
        A, jnp.asarray(sigma), jnp.asarray(depth), lvl, interpret=True
    )
    exp_s, exp_d = ref.frontier_spmm_ref(A, jnp.asarray(sigma), jnp.asarray(depth), lvl)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(exp_s), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(exp_d))


@pytest.mark.parametrize("n,s", SHAPES)
@pytest.mark.parametrize("adj_dtype", [jnp.float32, jnp.bfloat16])
def test_dependency_spmm_matches_ref(n, s, adj_dtype):
    lvl = 1
    A, sigma, depth, delta, omega = _bc_state(n, s, seed=2 * n + s, lvl=lvl)
    A = jnp.asarray(A, adj_dtype)
    got = ops.dependency_spmm(
        A,
        jnp.asarray(sigma),
        jnp.asarray(depth),
        jnp.asarray(delta),
        jnp.asarray(omega),
        lvl,
        interpret=True,
    )
    exp = ref.dependency_spmm_ref(
        A,
        jnp.asarray(sigma),
        jnp.asarray(depth),
        jnp.asarray(delta),
        jnp.asarray(omega),
        lvl,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-6)


def test_frontier_spmm_full_level_sequence():
    """Kernel levels chained end-to-end reproduce the engine's forward."""
    from repro.core import engine

    g = gnp_graph(48, 0.12, seed=11)
    A = jnp.asarray(g.dense_adjacency(np.float32))
    n, s = 48, 8
    sources = jnp.arange(s, dtype=jnp.int32)
    onehot = (jnp.arange(n)[:, None] == sources[None, :]).astype(jnp.float32)
    want = engine.forward_counting(engine.make_dense_operator(A), onehot)

    sigma = onehot
    depth = jnp.where(onehot > 0, 0, -1).astype(jnp.int32)
    for lvl in range(1, 20):
        sigma, depth = ops.frontier_spmm(A, sigma, depth, lvl, interpret=True)
    np.testing.assert_allclose(np.asarray(sigma), np.asarray(want.sigma), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(depth), np.asarray(want.depth))


# rectangular pre-fold variants feeding the 2-D distributed engine
RECT_SHAPES = [(8, 8, 4), (16, 8, 16), (64, 24, 8), (130, 40, 33)]


@pytest.mark.parametrize("m,k,s", RECT_SHAPES)
@pytest.mark.parametrize("adj_dtype", [jnp.float32, jnp.bfloat16])
def test_frontier_spmm_partial_matches_ref(m, k, s, adj_dtype):
    lvl = 2
    rng = np.random.default_rng(m + k + s)
    A = jnp.asarray((rng.random((m, k)) < 0.3), adj_dtype)
    sigma = jnp.asarray(rng.integers(0, 5, (k, s)), jnp.float32)
    depth = jnp.asarray(rng.integers(-1, lvl + 3, (k, s)), jnp.int32)
    got = ops.frontier_spmm_partial(A, sigma, depth, lvl, interpret=True)
    exp = ref.frontier_partial_ref(A, sigma, depth, lvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-6)


@pytest.mark.parametrize("m,k,s", RECT_SHAPES)
@pytest.mark.parametrize("adj_dtype", [jnp.float32, jnp.bfloat16])
def test_dependency_spmm_partial_matches_ref(m, k, s, adj_dtype):
    lvl = 1
    rng = np.random.default_rng(2 * m + k + s)
    A = jnp.asarray((rng.random((m, k)) < 0.3), adj_dtype)
    sigma = jnp.asarray(
        np.maximum(rng.integers(0, 5, (k, s)), 1).astype(np.float32)
    )
    depth = jnp.asarray(rng.integers(-1, lvl + 3, (k, s)), jnp.int32)
    delta = jnp.asarray(rng.random((k, s)), jnp.float32)
    omega = jnp.asarray(rng.integers(0, 3, k), jnp.float32)
    got = ops.dependency_spmm_partial(A, sigma, depth, delta, omega, lvl, interpret=True)
    exp = ref.dependency_partial_ref(A, sigma, depth, delta, omega, lvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("m,k,s", RECT_SHAPES)
@pytest.mark.parametrize("adj_dtype", [jnp.float32, jnp.bfloat16])
def test_frontier_partial_acc_chains_chunks(m, k, s, adj_dtype):
    """Chunked-operand mode: threading ``acc`` over column chunks equals
    one whole-block partial (the ring-pipelined expand contract)."""
    lvl = 2
    rng = np.random.default_rng(3 * m + k + s)
    A = jnp.asarray((rng.random((m, 2 * k)) < 0.3), adj_dtype)
    sigma = jnp.asarray(rng.integers(0, 5, (2 * k, s)), jnp.float32)
    depth = jnp.asarray(rng.integers(-1, lvl + 3, (2 * k, s)), jnp.int32)
    want = ops.frontier_spmm_partial(A, sigma, depth, lvl, interpret=True)
    acc = jnp.zeros((m, s), jnp.float32)
    for c in range(2):
        sl = slice(c * k, (c + 1) * k)
        acc = ops.frontier_spmm_partial(
            A[:, sl], sigma[sl], depth[sl], lvl, acc=acc, interpret=True
        )
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("m,k,s", RECT_SHAPES)
def test_dependency_partial_acc_chains_chunks(m, k, s):
    lvl = 1
    rng = np.random.default_rng(4 * m + k + s)
    A = jnp.asarray((rng.random((m, 2 * k)) < 0.3), jnp.float32)
    sigma = jnp.asarray(np.maximum(rng.integers(0, 5, (2 * k, s)), 1), jnp.float32)
    depth = jnp.asarray(rng.integers(-1, lvl + 3, (2 * k, s)), jnp.int32)
    delta = jnp.asarray(rng.random((2 * k, s)), jnp.float32)
    omega = jnp.asarray(rng.integers(0, 3, 2 * k), jnp.float32)
    want = ops.dependency_spmm_partial(
        A, sigma, depth, delta, omega, lvl, interpret=True
    )
    acc = jnp.zeros((m, s), jnp.float32)
    for c in range(2):
        sl = slice(c * k, (c + 1) * k)
        acc = ops.dependency_spmm_partial(
            A[:, sl], sigma[sl], depth[sl], delta[sl], omega[sl], lvl,
            acc=acc, interpret=True,
        )
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("V,D,B,L", [(32, 8, 4, 3), (64, 128, 8, 5), (128, 96, 16, 10), (1000, 64, 32, 26)])
@pytest.mark.parametrize("table_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("weighted", [False, True])
def test_segment_bag_matches_ref(V, D, B, L, table_dtype, weighted):
    rng = np.random.default_rng(V + D + B + L)
    table = jnp.asarray(rng.standard_normal((V, D)), table_dtype)
    indices = rng.integers(-1, V, size=(B, L)).astype(np.int32)
    weights = (
        jnp.asarray(rng.random((B, L)), jnp.float32) if weighted else None
    )
    got = ops.segment_bag(table, jnp.asarray(indices), weights, interpret=True)
    exp = ref.segment_bag_ref(table, jnp.asarray(indices), weights)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), rtol=2e-2 if table_dtype == jnp.bfloat16 else 1e-6, atol=1e-5)


def test_segment_bag_all_padding_bag():
    table = jnp.ones((16, 8), jnp.float32)
    indices = jnp.full((3, 4), -1, jnp.int32)
    out = ops.segment_bag(table, indices, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 0.0)


@pytest.mark.parametrize("engine_kind", ["pallas", "pallas_bf16"])
def test_bc_end_to_end_with_pallas_engine(engine_kind):
    """Full BC through the fused-kernel engine (interpret mode) == oracle."""
    from repro.core import betweenness_centrality, brandes_reference

    g = gnp_graph(20, 0.18, seed=21)
    got = betweenness_centrality(
        g, batch_size=8, heuristics="h3", engine_kind=engine_kind
    )
    np.testing.assert_allclose(got.bc, brandes_reference(g), rtol=1e-5, atol=1e-5)
