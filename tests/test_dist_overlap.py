"""Ring-pipelined expand/fold schedules == barrier schedule == oracle.

Two layers of checks on an 8-host-device mesh:

* traversal-state parity — σ, d, δ of a forward+backward pass through
  the distributed operators under ``overlap="expand"`` /
  ``"expand+fold"`` must match the single-device dense reference (and
  therefore the barrier schedule, which test_operators.py already pins
  to the same reference) for every distributed engine kind on 2x4 and
  4x2 grids;
* end-to-end parity — ``distributed_betweenness_centrality`` under the
  ring schedules matches ``brandes_reference`` within 1e-6;
* HLO structure — the pipelined lowering contains ring
  ``collective-permute`` steps and *no* monolithic frontier
  ``all-gather`` (and no ``reduce-scatter`` under "expand+fold"), while
  the barrier lowering keeps the all-gather.
"""
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import brandes_reference, engine
from repro.core.distributed import (
    distributed_betweenness_centrality,
    make_distributed_round_fn,
)
from repro.core.operators import (
    DenseOperator,
    DistributedOperator,
    DistributedPallasOperator,
    normalize_overlap,
)
from repro.core.scheduler import build_schedule
from repro.graphs import gnp_graph, road_like_graph
from repro.graphs.partition import partition_2d
from repro.launch.mesh import make_mesh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)

S = 8  # sources per batch
OVERLAPS = ["expand", "expand+fold"]
ENGINE_KINDS = ["sparse", "pallas", "pallas_bf16"]


def _dense_state(graph):
    """(σ, d, δ) of the single-device dense reference operator."""
    n = graph.n
    op = DenseOperator(jnp.asarray(graph.dense_adjacency(np.float32)))
    sources = jnp.arange(min(S, n), dtype=jnp.int32)
    onehot = (jnp.arange(n)[:, None] == sources[None, :]).astype(jnp.float32)
    rng = np.random.default_rng(7)
    omega = jnp.asarray(rng.integers(0, 3, n), jnp.float32)
    fwd = engine.forward_counting(op, onehot)
    delta = engine.backward_accumulation(op, fwd.sigma, fwd.depth, omega, fwd.max_depth)
    return np.asarray(fwd.sigma), np.asarray(fwd.depth), np.asarray(delta)


def _ring_state(graph, engine_kind, overlap, R, C):
    """Same traversal through the ring-scheduled 2-D operators."""
    mesh = make_mesh((R, C), ("data", "model"))
    part = partition_2d(graph, R, C)
    chunk, n_pad = part.chunk, part.n_pad
    rng = np.random.default_rng(7)
    omega_pad = np.zeros(n_pad, np.float32)
    omega_pad[: graph.n] = rng.integers(0, 3, graph.n)
    sources = jnp.arange(min(S, graph.n), dtype=jnp.int32)

    def run(op, omega, srcs):
        row_ids = op.row_ids()
        onehot = (
            (row_ids[:, None] == srcs[None, :]) & (srcs[None, :] >= 0)
        ).astype(jnp.float32)
        fwd = engine.forward_counting(op, onehot)
        delta = engine.backward_accumulation(
            op, fwd.sigma, fwd.depth, omega, fwd.max_depth
        )
        return fwd.sigma, fwd.depth, delta

    if engine_kind == "sparse":
        ring_src, ring_dst = part.ring_arcs()

        def body(rs, rd, omega, srcs):
            op = DistributedOperator(
                None,
                None,
                chunk=chunk,
                R=R,
                C=C,
                row_axis="data",
                col_axis="model",
                overlap=overlap,
                ring_src_local=rs[0, 0],
                ring_dst_local=rd[0, 0],
            )
            return run(op, omega, srcs)

        graph_args = (jnp.asarray(ring_src), jnp.asarray(ring_dst))
        graph_specs = (P("data", "model", None, None), P("data", "model", None, None))
    else:

        def body(blocks, omega, srcs):
            op = DistributedPallasOperator(
                blocks[0, 0],
                chunk=chunk,
                R=R,
                C=C,
                row_axis="data",
                col_axis="model",
                interpret=True,
                overlap=overlap,
            )
            return run(op, omega, srcs)

        dt = jnp.bfloat16 if engine_kind == "pallas_bf16" else jnp.float32
        graph_args = (jnp.asarray(part.dense_blocks(np.float32), dt),)
        graph_specs = (P("data", "model", None, None),)

    owner = P(("model", "data"), None)  # chunk layout == identity vertex order
    fn = jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=graph_specs + (P(("model", "data")), P()),
            out_specs=(owner, owner, owner),
            check_vma=False,
        )
    )
    sigma, depth, delta = fn(*graph_args, jnp.asarray(omega_pad), sources)
    n = graph.n
    return np.asarray(sigma)[:n], np.asarray(depth)[:n], np.asarray(delta)[:n]


@pytest.mark.parametrize("grid", [(2, 4), (4, 2)])
@pytest.mark.parametrize("overlap", OVERLAPS)
@pytest.mark.parametrize("engine_kind", ENGINE_KINDS)
def test_ring_operator_state_parity(engine_kind, overlap, grid):
    graph = gnp_graph(26, 0.15, seed=0)
    want = _dense_state(graph)
    got = _ring_state(graph, engine_kind, overlap, *grid)
    np.testing.assert_array_equal(got[1], want[1])  # depth: exact
    np.testing.assert_allclose(got[0], want[0], rtol=1e-6)  # σ: integer-valued
    np.testing.assert_allclose(got[2], want[2], rtol=1e-5, atol=1e-6)  # δ


@pytest.mark.parametrize("grid", [(2, 4), (4, 2)])
@pytest.mark.parametrize("engine_kind", ENGINE_KINDS)
def test_ring_end_to_end_matches_oracle(engine_kind, grid):
    g = gnp_graph(26, 0.15, seed=0)
    mesh = make_mesh(grid, ("data", "model"))
    expected = brandes_reference(g)
    bc_none, _ = distributed_betweenness_centrality(
        g, mesh, heuristics="h3", batch_size=8, engine_kind=engine_kind
    )
    bc_ring, _ = distributed_betweenness_centrality(
        g,
        mesh,
        heuristics="h3",
        batch_size=8,
        engine_kind=engine_kind,
        overlap="expand+fold",
    )
    np.testing.assert_allclose(bc_ring, expected, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(bc_ring, bc_none, rtol=1e-6, atol=1e-6)


def test_ring_expand_only_matches_oracle():
    g = road_like_graph(4, 4, spur_fraction=0.6, seed=2)
    mesh = make_mesh((2, 4), ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g, mesh, heuristics="h3", overlap="expand"
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


def test_ring_subcluster_replicas():
    g = gnp_graph(25, 0.15, seed=2)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", heuristics="h1", overlap="expand+fold"
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("overlap", OVERLAPS)
def test_ring_subcluster_divergent_depths(overlap):
    """Replicas whose rounds traverse very different depths (a 41-level
    path round paired with a 2-level G(n,p) round) must not deadlock.

    ppermute ring hops are mesh-wide collective-permutes, so replicas
    with data-dependent level-loop trip counts would arrive at different
    hop instructions and hang the rendezvous; the operators' sync_axes
    loop-bound agreement pins all replicas to max-over-replicas levels
    (regression test for the deadlock the distributed example hit).
    """
    from repro.graphs import disjoint_union, path_graph

    g = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", batch_size=8, overlap=overlap
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


# ------------------------------------------------------- HLO structure
def _lowered_text(part, mesh, schedule, engine_kind, overlap):
    fn = make_distributed_round_fn(
        part, mesh, num_levels=12, engine_kind=engine_kind, overlap=overlap
    )
    if engine_kind == "sparse":
        if overlap == "none":
            gargs = (part.src_local, part.dst_local)
        else:
            gargs = part.ring_arcs()
        specs = tuple(jax.ShapeDtypeStruct(a.shape, jnp.int32) for a in gargs)
    else:
        blocks = part.dense_blocks(np.float32)
        specs = (jax.ShapeDtypeStruct(blocks.shape, jnp.float32),)
    s, k = schedule.batch_size, schedule.derived_per_round
    return fn.lower(
        *specs,
        jax.ShapeDtypeStruct((part.n_pad,), jnp.float32),
        jax.ShapeDtypeStruct((1, s), jnp.int32),
        jax.ShapeDtypeStruct((1, k, 3), jnp.int32),
    ).compile().as_text()


def _sites(text, cls):
    return len(re.findall(rf"\b{cls}\b", text))


@pytest.mark.parametrize("engine_kind", ["sparse", "pallas"])
def test_pipelined_hlo_has_ring_permutes_no_all_gather(engine_kind):
    g = gnp_graph(26, 0.15, seed=0)
    schedule, _, residual, _ = build_schedule(g, batch_size=8)
    part = partition_2d(residual, 2, 4)
    mesh = make_mesh((2, 4), ("data", "model"))

    barrier = _lowered_text(part, mesh, schedule, engine_kind, "none")
    assert _sites(barrier, "all-gather") > 0  # sanity: barrier gathers
    assert _sites(barrier, "collective-permute") == 0

    expand = _lowered_text(part, mesh, schedule, engine_kind, "expand")
    assert _sites(expand, "all-gather") == 0
    assert _sites(expand, "collective-permute") > 0
    assert _sites(expand, "reduce-scatter") > 0  # fold still a barrier

    full = _lowered_text(part, mesh, schedule, engine_kind, "expand+fold")
    assert _sites(full, "all-gather") == 0
    assert _sites(full, "reduce-scatter") == 0
    assert _sites(full, "collective-permute") > _sites(expand, "collective-permute")


# ------------------------------------------------------- policy plumbing
def test_overlap_policy_validation():
    with pytest.raises(ValueError):
        normalize_overlap("ring")
    assert normalize_overlap(None) == "none"
    with pytest.raises(ValueError):
        DistributedOperator(
            None,
            None,
            chunk=4,
            R=2,
            C=4,
            row_axis="data",
            col_axis="model",
            overlap="expand",
            split_backward=True,
        )
    g = gnp_graph(16, 0.2, seed=0)
    schedule, _, residual, _ = build_schedule(g, batch_size=8)
    part = partition_2d(residual, 2, 4)
    mesh = make_mesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError):
        make_distributed_round_fn(
            part, mesh, overlap="expand", fuse_backward_payload=False
        )


def test_single_device_rejects_overlap():
    from repro.core import betweenness_centrality

    with pytest.raises(ValueError):
        betweenness_centrality(gnp_graph(10, 0.3, seed=1), overlap="expand")
