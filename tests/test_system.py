"""End-to-end behaviour tests for the paper's system.

These exercise the *whole* stack the way a user would: launchers,
schedule bookkeeping, fault-injection recovery, and the BC-round ledger —
complementing the unit/oracle tests elsewhere.
"""
import numpy as np
import pytest

import jax

from repro.core import betweenness_centrality, brandes_reference
from repro.core.scheduler import build_schedule
from repro.distributed.fault_tolerance import RoundLedger
from repro.graphs import gnp_graph


def test_bc_resumes_from_partial_rounds():
    """Kill-and-resume: accumulating only uncommitted rounds (the round
    ledger protocol) gives the exact same scores as an unbroken run."""
    g = gnp_graph(30, 0.15, seed=11)
    full = betweenness_centrality(g, batch_size=4, heuristics="h3")

    # simulate: run rounds one at a time, "crash" halfway, resume via ledger
    from repro.core.bc import make_round_fn
    from repro.core import engine
    import jax.numpy as jnp

    schedule, prep, residual, omega_i = build_schedule(
        g, batch_size=4, heuristics="h3"
    )
    adjacency = jnp.asarray(residual.dense_adjacency(np.float32))
    round_fn = jax.jit(
        make_round_fn(lambda: engine.make_dense_operator(adjacency), g.n)
    )
    omega = jnp.asarray(omega_i, jnp.float32)

    def run_rounds(ledger, bc, ns_by_root, round_ids):
        for rid in round_ids:
            if not ledger.try_commit(rid):
                continue  # duplicate completion (speculative re-execution)
            rnd = schedule.rounds[rid]
            bc_r, ns, roots, _levels = round_fn(
                jnp.asarray(rnd.sources), jnp.asarray(rnd.derived), omega
            )
            bc += np.asarray(bc_r, np.float64)
            for r, nv in zip(np.asarray(roots), np.asarray(ns, np.float64)):
                if r >= 0:
                    ns_by_root[int(r)] = float(nv)
        return bc

    n_rounds = len(schedule.rounds)
    ledger = RoundLedger()
    bc = np.zeros(g.n, np.float64)
    ns_by_root: dict[int, float] = {}
    # first "process" dies after half the rounds
    bc = run_rounds(ledger, bc, ns_by_root, range(n_rounds // 2))
    # resume from persisted ledger state; re-issue EVERYTHING (duplicates
    # must be dropped), plus a speculative duplicate of round 0
    ledger2 = RoundLedger.from_state(ledger.state())
    bc = run_rounds(ledger2, bc, ns_by_root, [0] + list(range(n_rounds)))

    from repro.core.heuristics.one_degree import leaf_correction

    omega_np = omega_i.astype(np.float64)
    for v, nv in ns_by_root.items():
        if omega_np[v] > 0:
            bc[v] += leaf_correction(omega_np[v], nv)
    for v, n_comp in schedule.analytic_corrections:
        bc[int(v)] += leaf_correction(omega_np[int(v)], float(n_comp))

    np.testing.assert_allclose(bc, full.bc, rtol=1e-6)
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-5, atol=1e-5)


def test_bc_driver_checkpoint_kill_and_resume(tmp_path):
    """A run killed mid-loop leaves a consistent BCCheckpoint; a fresh
    driver resumes from it and reproduces the unbroken result exactly."""
    import jax.numpy as jnp

    from repro.core import engine
    from repro.core.bc import make_round_fn
    from repro.core.driver import BCDriver
    from repro.distributed.fault_tolerance import BCCheckpoint

    g = gnp_graph(30, 0.15, seed=13)
    full = betweenness_centrality(g, batch_size=4, heuristics="h3")

    schedule, prep, residual, omega_i = build_schedule(g, batch_size=4, heuristics="h3")
    adjacency = jnp.asarray(residual.dense_adjacency(np.float32))
    omega = jnp.asarray(omega_i, jnp.float32)
    base_fn = jax.jit(
        make_round_fn(lambda: engine.make_dense_operator(adjacency), g.n)
    )

    class Crash(RuntimeError):
        pass

    def crashing_round_fn(limit):
        calls = {"n": 0}

        def fn(sources, derived):
            calls["n"] += 1
            if calls["n"] > limit:
                raise Crash
            bc_r, ns, roots, levels = base_fn(sources[0], derived[0], omega)
            return bc_r, ns[None], roots[None], levels[None]

        return fn

    ckpt = BCCheckpoint(str(tmp_path / "bc.npz"))
    n_rounds = len(schedule.rounds)
    assert n_rounds >= 4
    with pytest.raises(Crash):
        BCDriver(
            crashing_round_fn(n_rounds // 2),
            schedule,
            n=g.n,
            prep=prep,
            checkpoint=ckpt,
            checkpoint_every=1,
        ).run()
    assert ckpt.exists()
    _, _, committed = ckpt.load()
    assert 0 < len(committed) < n_rounds

    # resume: only the uncommitted tail is re-dealt
    resumed = BCDriver(
        crashing_round_fn(10**9),
        schedule,
        n=g.n,
        prep=prep,
        checkpoint=ckpt,
        checkpoint_every=1,
    ).run()
    assert resumed.rounds_run == n_rounds - len(committed)
    np.testing.assert_allclose(resumed.bc, full.bc, rtol=1e-6)
    np.testing.assert_allclose(resumed.bc, brandes_reference(g), rtol=1e-5, atol=1e-5)
    # a third run is a no-op that still reproduces the full scores
    third = BCDriver(
        crashing_round_fn(0), schedule, n=g.n, prep=prep, checkpoint=ckpt
    ).run()
    assert third.rounds_run == 0
    np.testing.assert_allclose(third.bc, full.bc, rtol=1e-6)

    # resuming against a different schedule must refuse, not mix sums
    other_schedule, other_prep, _, _ = build_schedule(g, batch_size=8, heuristics="h3")
    with pytest.raises(ValueError, match="different"):
        BCDriver(
            crashing_round_fn(0),
            other_schedule,
            n=g.n,
            prep=other_prep,
            checkpoint=ckpt,
        )


def test_bc_launcher_cli(tmp_path, capsys):
    import sys
    from repro.launch import bc as bc_cli

    out = tmp_path / "scores.npy"
    argv = sys.argv
    sys.argv = [
        "bc", "--grid", "6x6", "--heuristics", "h3", "--out", str(out),
    ]
    try:
        bc_cli.main()
    finally:
        sys.argv = argv
    scores = np.load(str(out))
    from repro.graphs import grid_graph

    np.testing.assert_allclose(
        scores, brandes_reference(grid_graph(6, 6)), rtol=1e-5, atol=1e-5
    )


def test_training_loss_decreases():
    from repro.configs.registry import get_arch
    from repro.launch.train import reduced_lm, train_lm

    cfg = reduced_lm(get_arch("gemma-7b").arch, layers=2, d_model=128, vocab=512)
    out = train_lm(cfg, steps=25, batch=4, seq=96)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first, (first, last)


def test_serve_loop_runs():
    from repro.configs.registry import get_arch
    from repro.launch.serve import serve_loop
    from repro.launch.train import reduced_lm

    cfg = reduced_lm(get_arch("codeqwen1.5-7b").arch, 2, 128, 512)
    out, t_p, t_d = serve_loop(cfg, batch=2, prompt_len=8, gen=4)
    assert out.shape == (2, 4)
    assert (out >= 0).all() and (out < cfg.vocab).all()
