"""Operator-seam parity: every TraversalOperator implementation must
produce bit-identical level structure (d), path counts (σ) and — up to
f32 summation order — dependencies (δ) on the same graphs.

This checks the unified engine at the operator protocol boundary rather
than only end-to-end: forward_counting / backward_accumulation are run
directly against each operator and the raw traversal state is compared.
The distributed operators run inside a shard_map harness whose out_specs
reassemble the owner-sharded chunks into global arrays (the chunk layout
is identity in vertex order — graphs/partition.py).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import engine
from repro.core.operators import (
    DenseOperator,
    DistributedOperator,
    DistributedPallasOperator,
    PallasDenseOperator,
    SparseOperator,
)
from repro.graphs import cycle_graph, gnp_graph, road_like_graph
from repro.graphs.partition import partition_2d

GRAPHS = {
    "gnp26": lambda: gnp_graph(26, 0.15, seed=0),
    "gnp23": lambda: gnp_graph(23, 0.2, seed=1),
    "cycle17": lambda: cycle_graph(17),
    "road4x4": lambda: road_like_graph(4, 4, spur_fraction=0.5, seed=2),
}

S = 8  # sources per batch


def _single_device_state(graph, operator, num_levels=None):
    """(σ, d, δ) of one forward+backward pass against ``operator``."""
    n = graph.n
    sources = jnp.arange(min(S, n), dtype=jnp.int32)
    onehot = (jnp.arange(n)[:, None] == sources[None, :]).astype(jnp.float32)
    rng = np.random.default_rng(7)
    omega = jnp.asarray(rng.integers(0, 3, n), jnp.float32)

    fwd = engine.forward_counting(operator, onehot, num_levels=num_levels)
    delta = engine.backward_accumulation(
        operator, fwd.sigma, fwd.depth, omega, fwd.max_depth, num_levels=num_levels
    )
    return np.asarray(fwd.sigma), np.asarray(fwd.depth), np.asarray(delta)


def _make_operator(kind, graph):
    n = graph.n
    if kind == "dense":
        return DenseOperator(jnp.asarray(graph.dense_adjacency(np.float32)))
    if kind == "sparse":
        src_p, dst_p, _ = graph.padded_arcs(multiple=8)
        return SparseOperator(jnp.asarray(src_p), jnp.asarray(dst_p), n)
    if kind == "pallas":
        return PallasDenseOperator(
            jnp.asarray(graph.dense_adjacency(np.float32)), interpret=True
        )
    if kind == "pallas_bf16":
        return PallasDenseOperator(
            jnp.asarray(graph.dense_adjacency(np.float32), jnp.bfloat16),
            interpret=True,
        )
    raise ValueError(kind)


@pytest.mark.parametrize("graph_name", sorted(GRAPHS))
@pytest.mark.parametrize("kind", ["sparse", "pallas", "pallas_bf16"])
def test_single_device_operator_parity(graph_name, kind):
    graph = GRAPHS[graph_name]()
    want = _single_device_state(graph, _make_operator("dense", graph))
    got = _single_device_state(graph, _make_operator(kind, graph))
    np.testing.assert_array_equal(got[1], want[1])  # depth: exact
    np.testing.assert_allclose(got[0], want[0], rtol=1e-6)  # σ: integer-valued
    np.testing.assert_allclose(got[2], want[2], rtol=1e-5, atol=1e-6)  # δ


@pytest.mark.parametrize("graph_name", sorted(GRAPHS))
def test_static_num_levels_operator_parity(graph_name):
    graph = GRAPHS[graph_name]()
    want = _single_device_state(graph, _make_operator("dense", graph))
    got = _single_device_state(
        graph, _make_operator("dense", graph), num_levels=graph.n + 1
    )
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_allclose(got[0], want[0], rtol=1e-6)
    np.testing.assert_allclose(got[2], want[2], rtol=1e-5, atol=1e-6)


# ------------------------------------------------- distributed operators
needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)


def _distributed_state(graph, engine_kind, R=2, C=4):
    """Same traversal through the 2-D operators, reassembled to global."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((R, C), ("data", "model"))
    part = partition_2d(graph, R, C)
    chunk, n_pad = part.chunk, part.n_pad
    rng = np.random.default_rng(7)
    omega_pad = np.zeros(n_pad, np.float32)
    omega_pad[: graph.n] = rng.integers(0, 3, graph.n)
    sources = jnp.arange(min(S, graph.n), dtype=jnp.int32)

    def run(op, omega, srcs):
        row_ids = op.row_ids()
        onehot = (
            (row_ids[:, None] == srcs[None, :]) & (srcs[None, :] >= 0)
        ).astype(jnp.float32)
        fwd = engine.forward_counting(op, onehot)
        delta = engine.backward_accumulation(
            op, fwd.sigma, fwd.depth, omega, fwd.max_depth
        )
        return fwd.sigma, fwd.depth, delta

    if engine_kind == "sparse":

        def body(src_local, dst_local, omega, srcs):
            op = DistributedOperator(
                src_local[0, 0],
                dst_local[0, 0],
                chunk=chunk,
                R=R,
                C=C,
                row_axis="data",
                col_axis="model",
            )
            return run(op, omega, srcs)

        graph_args = (jnp.asarray(part.src_local), jnp.asarray(part.dst_local))
        graph_specs = (P("data", "model", None), P("data", "model", None))
    else:

        def body(blocks, omega, srcs):
            op = DistributedPallasOperator(
                blocks[0, 0],
                chunk=chunk,
                R=R,
                C=C,
                row_axis="data",
                col_axis="model",
                interpret=True,
            )
            return run(op, omega, srcs)

        dt = jnp.bfloat16 if engine_kind == "pallas_bf16" else jnp.float32
        graph_args = (jnp.asarray(part.dense_blocks(np.float32), dt),)
        graph_specs = (P("data", "model", None, None),)

    owner = P(("model", "data"), None)  # chunk layout == identity vertex order
    fn = jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=graph_specs + (P(("model", "data")), P()),
            out_specs=(owner, owner, owner),
            check_vma=False,
        )
    )
    sigma, depth, delta = fn(*graph_args, jnp.asarray(omega_pad), sources)
    n = graph.n
    return np.asarray(sigma)[:n], np.asarray(depth)[:n], np.asarray(delta)[:n]


@needs_mesh
@pytest.mark.parametrize("graph_name", sorted(GRAPHS))
@pytest.mark.parametrize("engine_kind", ["sparse", "pallas", "pallas_bf16"])
def test_distributed_operator_parity(graph_name, engine_kind):
    graph = GRAPHS[graph_name]()
    want = _single_device_state(graph, _make_operator("dense", graph))
    got = _distributed_state(graph, engine_kind)
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_allclose(got[0], want[0], rtol=1e-6)
    np.testing.assert_allclose(got[2], want[2], rtol=1e-5, atol=1e-6)
