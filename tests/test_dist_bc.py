"""Distributed 2-D MGBC == numpy oracle, on an 8-host-device mesh."""
import numpy as np
import pytest

import jax

from repro.core import brandes_reference
from repro.core.distributed import distributed_betweenness_centrality
from repro.graphs import (
    cycle_graph,
    disjoint_union,
    gnp_graph,
    grid_graph,
    path_graph,
    rmat_graph,
    road_like_graph,
    star_graph,
)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)


def _mesh(shape, names):
    from repro.launch.mesh import make_mesh

    return make_mesh(shape, names)


def _check(graph, mesh_shape=(2, 4), heuristics="h0", replica=False, **kw):
    if replica:
        mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
        bc, _ = distributed_betweenness_centrality(
            graph,
            mesh,
            replica_axis="pod",
            heuristics=heuristics,
            **kw,
        )
    else:
        mesh = _mesh(mesh_shape, ("data", "model"))
        bc, _ = distributed_betweenness_centrality(
            graph, mesh, heuristics=heuristics, **kw
        )
    expected = brandes_reference(graph)
    np.testing.assert_allclose(bc, expected, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("heuristics", ["h0", "h1", "h2", "h3"])
def test_gnp_2x4(heuristics):
    _check(gnp_graph(26, 0.15, seed=0), (2, 4), heuristics)


@pytest.mark.parametrize("heuristics", ["h0", "h3"])
def test_gnp_4x2(heuristics):
    _check(gnp_graph(23, 0.2, seed=1), (4, 2), heuristics)


@pytest.mark.parametrize("heuristics", ["h0", "h1", "h2", "h3"])
def test_subcluster_replicas(heuristics):
    _check(gnp_graph(25, 0.15, seed=2), heuristics=heuristics, replica=True)


def test_structured_graphs():
    _check(grid_graph(4, 5), (2, 4))
    _check(cycle_graph(17), (2, 4), "h2")
    _check(star_graph(9), (2, 4), "h1")


def test_multi_component_distributed():
    g = disjoint_union(path_graph(7), star_graph(5), gnp_graph(14, 0.2, seed=3))
    _check(g, (2, 4), "h3")


def test_rmat_distributed():
    _check(rmat_graph(6, 4, seed=5), (2, 4), "h3", batch_size=8)


def test_road_like_distributed():
    _check(road_like_graph(4, 4, spur_fraction=0.6, seed=2), (2, 4), "h3")


@pytest.mark.parametrize("mesh_shape", [(2, 4), (4, 2)])
@pytest.mark.parametrize("engine_kind", ["pallas", "pallas_bf16"])
def test_pallas_dense_block_engine(mesh_shape, engine_kind):
    """Fused Pallas kernels as the 2-D block-local compute == oracle."""
    g = gnp_graph(26, 0.15, seed=0)
    mesh = _mesh(mesh_shape, ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g, mesh, heuristics="h3", batch_size=8, engine_kind=engine_kind
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("engine_kind", ["pallas"])
def test_pallas_dense_block_engine_subcluster(engine_kind):
    g = gnp_graph(25, 0.15, seed=2)
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", heuristics="h0", engine_kind=engine_kind
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


def test_static_levels_distributed():
    g = gnp_graph(20, 0.18, seed=7)
    mesh = _mesh((2, 4), ("data", "model"))
    bc, _ = distributed_betweenness_centrality(g, mesh, num_levels=22)
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-5, atol=1e-5)


def test_unfused_backward_matches():
    from repro.core.distributed import make_distributed_round_fn
    from repro.graphs.partition import partition_2d
    from repro.core.scheduler import build_schedule
    import jax.numpy as jnp

    g = gnp_graph(24, 0.2, seed=9)
    mesh = _mesh((2, 4), ("data", "model"))
    schedule, _, residual, omega = build_schedule(g, batch_size=24)
    part = partition_2d(residual, 2, 4)
    omega_pad = np.zeros(part.n_pad, np.float32)
    outs = []
    for fuse in (True, False):
        fn = make_distributed_round_fn(part, mesh, fuse_backward_payload=fuse)
        rnd = schedule.rounds[0]
        bc_r, _, _, _ = fn(
            jnp.asarray(part.src_local),
            jnp.asarray(part.dst_local),
            jnp.asarray(omega_pad),
            jnp.asarray(rnd.sources[None]),
            jnp.asarray(rnd.derived[None]),
        )
        outs.append(np.asarray(bc_r))
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-6)


def test_distributed_one_degree_matches_host():
    from repro.core.distributed import one_degree_reduce_distributed
    from repro.core.heuristics.one_degree import one_degree_reduce

    g = road_like_graph(4, 4, spur_fraction=0.8, seed=3)
    mesh = _mesh((2, 4), ("data", "model"))
    omega_d, removed_d = one_degree_reduce_distributed(g, mesh, ("data", "model"))
    host = one_degree_reduce(g)
    np.testing.assert_array_equal(omega_d, host.omega)
    # residual graphs identical
    res_d = g.subgraph_mask(~removed_d)
    np.testing.assert_array_equal(res_d.src, host.residual.src)
    np.testing.assert_array_equal(res_d.dst, host.residual.dst)


@pytest.mark.parametrize("heuristics", ["h1t", "h3t"])
def test_tree_contraction_distributed(heuristics):
    g = road_like_graph(4, 4, spur_fraction=1.0, seed=6)
    _check(g, (2, 4), heuristics)
