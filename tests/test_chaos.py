"""Chaos-harness coverage: every fault class injected and self-healed.

Four layers:

* :class:`FaultPlan` parsing / query semantics (pure, no jax);
* forced-fault driver runs on the fake two-lane round fn (the
  test_straggler.py harness): transient retry + backoff, poison
  quarantine + fallback recompute, replica kill + elastic re-mesh,
  crash + generational resume — BC parity with ``brandes_reference``
  and exactly-once commit counts throughout;
* self-verifying rounds: finite ``flip`` corruption caught by the
  ABFT/claim audits (and, for the audit-evading deep flip, by the
  duplicate vote on steal-duplicated tail rounds); ``stall`` past the
  dispatch deadline tripped by the watchdog on an injectable fake
  clock, escalating re-dispatch → replica loss; detection counters
  surviving kill-and-resume;
* durable-state corruption: torn / garbled :class:`BCCheckpoint`
  generations and autotune cache files must warn and fall back (or
  cold-start), never traceback; a kill mid-save touches only the
  ``.tmp.npz``; ``Checkpointer.close()`` joins its writer thread even
  when a queued write failed;
* real-mesh fault matrix (8 fake host devices): the distributed entry
  point under combined plans stays within 1e-6 of the oracle on 2x4
  and 2x2x2 meshes with recovery telemetry reported.
"""
import json
import logging

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import brandes_reference, engine
from repro.core.driver import BCDriver, traversal_round
from repro.core.scheduler import build_schedule
from repro.checkpoint import BCCheckpoint
from repro.checkpoint.checkpointer import Checkpointer
from repro.distributed.chaos import (
    FAULT_KINDS,
    ChaosCostCache,
    ChaosCrash,
    ChaosFS,
    ChaosRoundFn,
    FaultPlan,
)
from repro.distributed.fault_tolerance import (
    ReplicaLostError,
    StragglerPolicy,
    TransientRoundError,
    schedule_fingerprint,
)
from repro.graphs import disjoint_union, gnp_graph, path_graph, skewed_depth_graph


# ------------------------------------------------------------ fault plans
def test_fault_plan_parse_and_queries():
    assert set(FAULT_KINDS) == {
        "transient", "poison", "kill", "crash", "torn", "cache",
        "flip", "stall",
    }
    plan = FaultPlan.parse(
        "seed=7; transient@1x2, poison@3:inf; kill@4:r1; torn@0; "
        "cache@2x2; crash@9"
    )
    assert plan.seed == 7 and len(plan.events) == 6 and bool(plan)
    assert plan.transient_at(1) and plan.transient_at(2)
    assert not plan.transient_at(0) and not plan.transient_at(3)
    assert plan.poison_at(3) == "inf" and plan.poison_at(2) is None
    assert plan.killed_replicas(3) == set()
    # a kill is permanent: count is ignored, loss is loss
    assert plan.killed_replicas(4) == {1} == plan.killed_replicas(99)
    assert plan.crash_at(9) and not plan.crash_at(8)
    assert plan.torn_save(0) and not plan.torn_save(1)
    assert plan.corrupt_cache_put(2) and plan.corrupt_cache_put(3)
    assert not plan.corrupt_cache_put(4)
    # idempotent on FaultPlan / None
    assert FaultPlan.parse(plan) is plan
    assert not FaultPlan.parse(None)
    # repr round-trips through parse
    inner = repr(plan)[len("FaultPlan("):-1]
    again = FaultPlan.parse(inner)
    assert again.events == plan.events and again.seed == plan.seed


def test_fault_plan_flip_and_stall_queries():
    plan = FaultPlan.parse(
        "flip@1; flip@2:r1; flip@3:d0; flip@4:neg; stall@5x2; stall@7:120"
    )
    assert plan.flip_at(0) is None
    assert plan.flip_at(1) == ("scale", 0)  # bare flip: lane 0, sum moves
    assert plan.flip_at(2) == ("scale", 1)
    assert plan.flip_at(3) == ("deep", 0)  # claim recomputed: SDC-style
    assert plan.flip_at(4) == ("neg", 0)
    assert plan.stall_ms(4) is None
    from repro.distributed.chaos import DEFAULT_STALL_MS

    assert plan.stall_ms(5) == plan.stall_ms(6) == DEFAULT_STALL_MS
    assert plan.stall_ms(7) == 120.0
    # repr round-trips through parse with the new kinds present
    inner = repr(plan)[len("FaultPlan("):-1]
    again = FaultPlan.parse(inner)
    assert again.events == plan.events


@pytest.mark.parametrize(
    "spec",
    ["bogus@1", "transient", "transient@-1", "kill@2", "poison@1:huge",
     "transient@1x0", "kill@2:one", "flip@1:x3", "flip@1:rr", "stall@2:fast"],
)
def test_fault_plan_rejects_bad_entries(spec):
    with pytest.raises(ValueError):
        FaultPlan.parse(spec)


def test_straggler_policy_history_is_bounded():
    pol = StragglerPolicy(window=16)
    for i in range(1000):
        pol.observe(float(i))
    assert len(pol.times) == 16
    assert pol.times[0] == 984.0  # oldest observations fell off


# ------------------------------------------------ forced-fault driver runs
@pytest.fixture(scope="module")
def case():
    g = skewed_depth_graph(4, 8)  # 8 source rounds at batch_size=8
    schedule, prep, _, _ = build_schedule(g, batch_size=8)
    assert len(schedule.rounds) == 8
    return g, schedule, prep, brandes_reference(g)


def _two_lane_round_fn(graph, integrity="off"):
    """Fake two-replica dispatch (see tests/test_straggler.py): each lane
    runs the real single-device traversal of its round."""
    adjacency = jnp.asarray(graph.dense_adjacency(np.float32))
    omega = jnp.zeros(graph.n, jnp.float32)
    base = jax.jit(
        lambda s, d: traversal_round(
            engine.make_dense_operator(adjacency), s, d, omega,
            integrity=integrity,
        )
    )

    def fn(sources, derived):
        outs = [base(sources[r], derived[r]) for r in range(sources.shape[0])]
        return tuple(
            jnp.stack([o[i] for o in outs]) for i in range(len(outs[0]))
        )

    return fn


class FakeClock:
    """Deterministic time source for the watchdog: time only advances
    when something sleeps through it (the chaos stall or retry backoff),
    so a stalled dispatch is the *only* thing that can exceed a deadline."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, seconds):
        self.t += seconds


def _driver(case, plan=None, sleeper=None, **kw):
    g, schedule, prep, _ = case
    fn = _two_lane_round_fn(g, integrity=kw.get("integrity", "off"))
    round_fn = (
        ChaosRoundFn(fn, FaultPlan.parse(plan), sleeper=sleeper)
        if plan
        else fn
    )
    kw.setdefault("retry_backoff_s", 1e-4)
    return BCDriver(
        round_fn, schedule, n=g.n, prep=prep, rounds_per_dispatch=2,
        sleeper=sleeper, **kw
    )


def test_transient_rounds_are_retried(case):
    result = _driver(case, "transient@1x2").run()
    np.testing.assert_allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    rec = result.recovery_stats
    assert rec["transient_errors"] == 2 and rec["retries"] == 2
    assert result.rounds_run == 8


def test_transient_budget_exhausted_raises(case):
    drv = _driver(case, "transient@0x5", max_retries=1)
    with pytest.raises(TransientRoundError):
        drv.run()
    assert drv.recovery["retries"] == 1


def test_poison_block_quarantined_and_recovered(case):
    result = _driver(case, "poison@1", numeric_guard=True).run()
    np.testing.assert_allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    rec = result.recovery_stats
    assert rec["quarantined_blocks"] == 1 and rec["retries"] == 1
    assert rec["fallback_recomputes"] == 0


def test_persistent_poison_falls_back_to_clean_round_fn(case):
    g, schedule, prep, expected = case
    clean = _two_lane_round_fn(g)
    drv = BCDriver(
        ChaosRoundFn(clean, FaultPlan.parse("poison@1x100")),
        schedule, n=g.n, prep=prep, rounds_per_dispatch=2,
        retry_backoff_s=1e-4, fallback_round_fn=clean,
    )
    result = drv.run()  # numeric guard auto-on: a fallback was supplied
    np.testing.assert_allclose(result.bc, expected, rtol=1e-6, atol=1e-6)
    rec = result.recovery_stats
    # blocks 1..3 each burn the 2-re-dispatch budget then recompute clean
    assert rec["quarantined_blocks"] == 9
    assert rec["fallback_recomputes"] == 3
    assert result.rounds_run == 8


def test_persistent_poison_without_fallback_raises(case):
    drv = _driver(case, "poison@0x10", numeric_guard=True, max_retries=0)
    with pytest.raises(FloatingPointError, match="non-finite"):
        drv.run()


@pytest.mark.parametrize("policy", ["steal", "redeal"])
def test_replica_kill_triggers_remesh_and_parity(case, policy):
    drv = _driver(case, "kill@1:r1", straggler=policy, prior_round_s=1e-3)
    result = drv.run()
    np.testing.assert_allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    rec = result.recovery_stats
    assert rec["remesh_events"] == 1 and rec["dead_replicas"] == [1]
    assert result.rounds_run == 8
    # exactly-once: the committed union is every round, no duplicates
    committed = sorted(r for led in drv.ledgers for r in led.state())
    assert committed == list(range(8))


def test_all_replicas_dead_reraises(case):
    drv = _driver(case, "kill@0:r0;kill@0:r1", straggler="steal")
    with pytest.raises(ReplicaLostError):
        drv.run()
    assert drv.recovery["remesh_events"] == 1  # first loss healed, second fatal


# --------------------------------------- self-verifying rounds (integrity)
@pytest.mark.parametrize("mode", ["audit", "checksum"])
@pytest.mark.parametrize("spec", ["flip@1", "flip@1:neg", "flip@1:r1"])
def test_flip_detected_quarantined_and_redispatched(case, mode, spec):
    """A finite silent corruption is invisible to the numeric guard but
    must be caught by the block audit, quarantined and recomputed."""
    result = _driver(case, spec, integrity=mode).run()
    np.testing.assert_allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    rec = result.recovery_stats
    integ = rec["integrity"]
    assert integ["mode"] == mode
    assert integ["checksum_failures"] + integ["audit_failures"] >= 1
    assert rec["quarantined_blocks"] >= 1
    assert result.rounds_run == 8  # exactly-once despite the re-dispatch


def test_flip_unnoticed_without_integrity(case):
    """Control: the same corruption with integrity off silently lands in
    the accumulator — this is exactly the gap the audits close."""
    result = _driver(case, "flip@1").run()
    assert not np.allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    integ = result.recovery_stats["integrity"]
    assert integ["mode"] == "off"
    assert integ["audit_failures"] == 0  # nothing looked, nothing found


def test_healthy_checksum_run_reports_tiny_residual(case):
    result = _driver(case, integrity="checksum").run()
    np.testing.assert_allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    integ = result.recovery_stats["integrity"]
    assert integ["checksum_failures"] == 0 and integ["audit_failures"] == 0
    assert 0.0 <= integ["max_checksum_residual"] < 1e-4


def test_deep_flip_caught_by_duplicate_vote():
    """A 'deep' flip also forges the block's claimed sum, so every block
    audit passes — only comparing the duplicated tail lanes catches it."""
    g = gnp_graph(20, 0.25, seed=5)
    schedule, prep, _, _ = build_schedule(g, batch_size=4)
    assert len(schedule.rounds) == 5  # odd deal: the tail gets duplicated
    expected = brandes_reference(g)
    fn = _two_lane_round_fn(g, integrity="checksum")
    drv = BCDriver(
        ChaosRoundFn(fn, FaultPlan.parse("flip@2:d1")),
        schedule, n=g.n, prep=prep, rounds_per_dispatch=2,
        straggler="steal", prior_round_s=1e-3, retry_backoff_s=1e-4,
        integrity="checksum",
    )
    result = drv.run()
    np.testing.assert_allclose(result.bc, expected, rtol=1e-6, atol=1e-6)
    integ = result.recovery_stats["integrity"]
    assert integ["votes"] >= 2 and integ["vote_mismatches"] >= 1
    assert integ["quarantined_rounds"] >= 1
    assert any(v["matched"] == "owner" for v in integ["vote_verdicts"])
    # the block audits really were blind to it
    assert integ["checksum_failures"] == 0 and integ["audit_failures"] == 0
    committed = sorted(r for led in drv.ledgers for r in led.state())
    assert committed == list(range(5))


# ------------------------------------------------------ dispatch watchdog
def test_watchdog_static_escalates_to_replica_lost(case):
    """Without a replica pool to absorb the loss, a wedged dispatch ends
    the run with ReplicaLostError instead of hanging forever."""
    clk = FakeClock()
    drv = _driver(
        case, "stall@0x3:50", sleeper=clk.sleep,
        clock=clk, dispatch_deadline_s=0.02, max_retries=2,
    )
    with pytest.raises(ReplicaLostError):
        drv.run()
    integ = drv.recovery["integrity"]
    assert integ["watchdog_trips"] == 3
    assert integ["watchdog_redispatches"] == 2
    assert integ["watchdog_escalations"] == 1


def test_watchdog_stall_escalates_into_remesh_and_parity(case):
    """Under a straggler policy the watchdog's escalation is absorbed by
    the elastic re-mesh: the survivor re-deals the rounds, result exact."""
    clk = FakeClock()
    drv = _driver(
        case, "stall@0x3:50", sleeper=clk.sleep,
        clock=clk, dispatch_deadline_s=0.02, max_retries=2,
        straggler="steal", prior_round_s=1e-3, integrity="audit",
    )
    result = drv.run()
    np.testing.assert_allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    rec = result.recovery_stats
    integ = rec["integrity"]
    assert integ["watchdog_trips"] == 3
    assert integ["watchdog_escalations"] == 1
    assert rec["remesh_events"] == 1
    assert result.rounds_run == 8
    committed = sorted(r for led in drv.ledgers for r in led.state())
    assert committed == list(range(8))


def test_watchdog_ignores_fast_dispatches(case):
    clk = FakeClock()
    result = _driver(
        case, sleeper=clk.sleep, clock=clk, dispatch_deadline_s=10.0,
        integrity="audit",
    ).run()
    np.testing.assert_allclose(result.bc, case[3], rtol=1e-6, atol=1e-6)
    integ = result.recovery_stats["integrity"]
    assert integ["watchdog_trips"] == 0


def test_integrity_stats_survive_crash_and_resume(tmp_path, case):
    """Detection counters are part of the durable story: after a crash
    the resumed run still reports the pre-crash detections."""
    g, schedule, prep, expected = case
    path = str(tmp_path / "bc.npz")

    def driver(plan, ckpt):
        fn = _two_lane_round_fn(g, integrity="audit")
        round_fn = ChaosRoundFn(fn, FaultPlan.parse(plan)) if plan else fn
        return BCDriver(
            round_fn, schedule, n=g.n, prep=prep, rounds_per_dispatch=2,
            straggler="redeal", checkpoint=ckpt, checkpoint_every=1,
            integrity="audit", retry_backoff_s=1e-4,
        )

    # flip@1 is detected and recomputed (call 2); the crash lands later
    with pytest.raises(ChaosCrash):
        driver("flip@1;crash@4", BCCheckpoint(path)).run()

    resumed = driver(None, BCCheckpoint(path)).run()
    np.testing.assert_allclose(resumed.bc, expected, rtol=1e-6, atol=1e-6)
    rec = resumed.recovery_stats
    assert rec["integrity"]["audit_failures"] == 1  # remembered, not re-hit
    assert rec["quarantined_blocks"] == 1
    assert resumed.rounds_run < 8  # some blocks survived the crash


def test_crash_and_generational_resume(tmp_path, case):
    g, schedule, prep, expected = case
    path = str(tmp_path / "bc.npz")

    def driver(plan, ckpt):
        fn = _two_lane_round_fn(g)
        round_fn = ChaosRoundFn(fn, FaultPlan.parse(plan)) if plan else fn
        return BCDriver(
            round_fn, schedule, n=g.n, prep=prep, rounds_per_dispatch=2,
            straggler="redeal", checkpoint=ckpt, checkpoint_every=1,
        )

    with pytest.raises(ChaosCrash):
        driver("crash@2", BCCheckpoint(path)).run()
    ckpt = BCCheckpoint(path)
    assert ckpt.exists()
    assert (tmp_path / "bc.npz.g1").exists()  # two snapshots rotated

    resumed = driver(None, ckpt).run()
    np.testing.assert_allclose(resumed.bc, expected, rtol=1e-6, atol=1e-6)
    assert resumed.rounds_run == 4  # blocks 0 and 1 survived the crash
    assert resumed.recovery_stats["resumed_generation"] == 0

    third = driver(None, BCCheckpoint(path)).run()
    assert third.rounds_run == 0
    np.testing.assert_allclose(third.bc, expected, rtol=1e-6, atol=1e-6)


# ---------------------------------------------- durable-state corruption
def test_generation_fallback_after_torn_newest(tmp_path, case, caplog):
    g, schedule, prep, _ = case
    fp = schedule_fingerprint(g.n, schedule)
    ckpt = BCCheckpoint(str(tmp_path / "bc.npz"))
    bc1 = np.ones(g.n)
    ckpt.save(bc1, {}, [0], fp)
    ckpt.save(np.full(g.n, 2.0), {}, [0, 1], fp)
    ChaosFS(FaultPlan.parse("seed=3")).tear_file(tmp_path / "bc.npz")

    with caplog.at_level(logging.WARNING, logger="repro.checkpoint.checkpointer"):
        bc, _, committed = ckpt.load(fp)
    assert ckpt.loaded_generation == 1
    np.testing.assert_array_equal(bc, bc1)
    assert committed == [0]
    assert any("falling back" in r.getMessage() for r in caplog.records)

    # the driver reports the fallback generation in its telemetry
    drv = BCDriver(
        _two_lane_round_fn(g), schedule, n=g.n, rounds_per_dispatch=2,
        checkpoint=ckpt,
    )
    assert drv.recovery["resumed_generation"] == 1


def test_all_generations_corrupt_cold_start(tmp_path, case, caplog):
    g, schedule, prep, expected = case
    fp = schedule_fingerprint(g.n, schedule)
    ckpt = BCCheckpoint(str(tmp_path / "bc.npz"))
    ckpt.save(np.ones(g.n), {}, [0], fp)
    ckpt.save(np.ones(g.n), {}, [0, 1], fp)
    fs = ChaosFS(FaultPlan.parse("seed=4"))
    fs.garble_file(tmp_path / "bc.npz")
    fs.garble_file(tmp_path / "bc.npz.g1")

    with caplog.at_level(logging.WARNING, logger="repro.checkpoint.checkpointer"):
        bc, ns, committed = ckpt.load(fp)  # never a traceback
    assert bc is None and ns == {} and committed == []
    assert ckpt.loaded_generation is None
    assert any("cold start" in r.getMessage() for r in caplog.records)

    # a full run from the dead checkpoint recomputes everything, exactly
    result = BCDriver(
        _two_lane_round_fn(g), schedule, n=g.n, prep=prep,
        rounds_per_dispatch=2, checkpoint=ckpt,
    ).run()
    np.testing.assert_allclose(result.bc, expected, rtol=1e-6, atol=1e-6)
    assert result.rounds_run == 8
    assert result.recovery_stats["resumed_generation"] is None


def test_fingerprint_mismatch_on_intact_snapshot_still_raises(tmp_path):
    ckpt = BCCheckpoint(str(tmp_path / "bc.npz"))
    ckpt.save(np.ones(4), {}, [0], "fp-a")
    with pytest.raises(ValueError, match="different"):
        ckpt.load("fp-b")


def test_legacy_snapshot_without_manifest_loads(tmp_path):
    path = tmp_path / "bc.npz"
    np.savez(
        path,
        bc=np.arange(4, dtype=np.float64),
        ns_roots=np.asarray([0], np.int64),
        ns_vals=np.asarray([4.0]),
        committed=np.asarray([0, 2], np.int64),
        fingerprint=np.asarray("legacy-fp"),
    )
    ckpt = BCCheckpoint(str(path))
    bc, ns, committed = ckpt.load("legacy-fp")
    np.testing.assert_array_equal(bc, np.arange(4))
    assert ns == {0: 4.0} and committed == [0, 2]
    assert ckpt.loaded_generation == 0


def test_kill_mid_save_touches_only_the_tmp_file(tmp_path, monkeypatch):
    ckpt = BCCheckpoint(str(tmp_path / "bc.npz"))
    ckpt.save(np.ones(4), {}, [0], "fp")
    before = (tmp_path / "bc.npz").read_bytes()

    real_savez = np.savez

    def dying_savez(path, **arrays):
        real_savez(path, **arrays)
        with open(path, "r+b") as f:  # torn flush, then the kill
            f.truncate(10)
        raise ChaosCrash("killed mid-save")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(ChaosCrash):
        ckpt.save(np.full(4, 2.0), {}, [0, 1], "fp")
    monkeypatch.undo()

    # the committed snapshot and its rotation are untouched; only the
    # temp file carries the torn write
    assert (tmp_path / "bc.npz").read_bytes() == before
    assert not (tmp_path / "bc.npz.g1").exists()
    assert (tmp_path / "bc.npz.tmp.npz").exists()
    bc, _, committed = ckpt.load("fp")
    np.testing.assert_array_equal(bc, np.ones(4))
    assert committed == [0]


def test_checkpointer_close_joins_worker_after_write_error(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path / "ckpt"), async_writes=True)

    def failing_write(*args, **kwargs):
        raise IOError("disk full")

    monkeypatch.setattr(ck, "_write", failing_write)
    ck.save(0, {"w": np.ones(3)})
    with pytest.raises(IOError, match="disk full"):
        ck.close()  # wait() re-raises, but the worker must still stop
    assert not ck._worker.is_alive()


def test_corrupt_autotune_cache_cold_starts_with_warning(tmp_path, caplog):
    from repro.autotune.cache import CACHE_VERSION, CostCache, CostRecord

    path = tmp_path / "autotune_cache.json"
    cache_logger = "repro.autotune.cache"

    path.write_bytes(b"\x00{{{garbage")
    with caplog.at_level(logging.WARNING, logger=cache_logger):
        assert CostCache(path).num_records() == 0
    assert any("unreadable" in r.getMessage() for r in caplog.records)

    caplog.clear()
    path.write_text(json.dumps({"version": 999, "entries": {}}))
    with caplog.at_level(logging.WARNING, logger=cache_logger):
        assert CostCache(path).num_records() == 0
    assert any("version" in r.getMessage() for r in caplog.records)

    caplog.clear()
    path.write_text(json.dumps({
        "version": CACHE_VERSION,
        "entries": {
            "g_good": {"cfg": CostRecord(0.5).to_json()},
            "g_bad": {"cfg": {"nope": 1}},
        },
    }))
    with caplog.at_level(logging.WARNING, logger=cache_logger):
        cache = CostCache(path)
    assert cache.num_records() == 1 and "g_good" in cache.entries
    assert any("malformed" in r.getMessage() for r in caplog.records)


def test_chaos_cost_cache_garbles_the_named_put(tmp_path, caplog):
    from repro.autotune.cache import CostCache, CostRecord

    path = str(tmp_path / "cache.json")
    fs = ChaosFS(FaultPlan.parse("seed=2;cache@1"))
    cache = ChaosCostCache(path, fs)
    assert isinstance(cache, CostCache)  # as_cache() accepts it unchanged
    cache.put("g", "c0", CostRecord(0.1))  # put 0: intact
    cache.put("g", "c1", CostRecord(0.2))  # put 1: garbled after write
    assert fs.cache_puts == 2 and fs.files_corrupted == [path]

    with caplog.at_level(logging.WARNING, logger="repro.autotune.cache"):
        fresh = CostCache(path)  # warm-start empty, never traceback
    assert fresh.num_records() == 0
    assert any("unreadable" in r.getMessage() for r in caplog.records)


def test_chaos_fs_tear_is_seed_deterministic(tmp_path):
    data = bytes(range(256)) * 8
    (tmp_path / "a").write_bytes(data)
    (tmp_path / "b").write_bytes(data)
    ChaosFS(FaultPlan.parse("seed=9")).tear_file(tmp_path / "a")
    ChaosFS(FaultPlan.parse("seed=9")).tear_file(tmp_path / "b")
    a = (tmp_path / "a").read_bytes()
    assert a == (tmp_path / "b").read_bytes()
    assert 0 < len(a) < len(data)


# ------------------------------------------------- real-mesh fault matrix
@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_chaos_matrix_2x4_mesh():
    """Grid-only mesh (fr=1): transient + poison healed by retry and the
    chaos-supplied clean fallback, parity within 1e-6."""
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.launch.mesh import make_mesh

    g = gnp_graph(24, 0.2, seed=3)
    mesh = make_mesh((2, 4), ("data", "model"))
    result = distributed_betweenness_centrality(
        g, mesh, batch_size=8,
        chaos="seed=5;transient@1x2;poison@3:nan",
        retry_backoff_s=1e-3,
        full_result=True,
    )
    np.testing.assert_allclose(
        result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6
    )
    rec = result.recovery_stats
    assert rec["transient_errors"] == 2
    assert rec["quarantined_blocks"] >= 1
    assert result.rounds_run == len(result.schedule.rounds)  # exactly-once
    assert rec["chaos"]["dispatch_calls"] > len(result.schedule.rounds)


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_chaos_matrix_2x2x2_mesh_replica_kill():
    """Replicated mesh: a replica kill mid-run re-meshes onto the
    survivor and still matches the oracle, every round exactly once."""
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.launch.mesh import make_mesh

    g = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    result = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", batch_size=8, overlap="expand",
        straggler="steal",
        chaos="seed=1;kill@1:r1",
        retry_backoff_s=1e-3,
        full_result=True,
    )
    np.testing.assert_allclose(
        result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6
    )
    rec = result.recovery_stats
    assert rec["remesh_events"] == 1 and rec["dead_replicas"] == [1]
    assert result.rounds_run == len(result.schedule.rounds)  # exactly-once
    assert rec["chaos"]["plan"].startswith("FaultPlan(")


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
@pytest.mark.parametrize("engine_kind,overlap", [
    ("sparse", "none"), ("pallas", "expand"),
])
def test_flip_matrix_2x4_mesh(engine_kind, overlap):
    """Grid-only mesh: an injected bit-flip-style corruption is detected
    by the checksum/claim audits on every engine x overlap, the block is
    recomputed and the result matches the oracle to 1e-6."""
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.launch.mesh import make_mesh

    g = gnp_graph(24, 0.2, seed=3)
    mesh = make_mesh((2, 4), ("data", "model"))
    result = distributed_betweenness_centrality(
        g, mesh, batch_size=8, engine_kind=engine_kind, overlap=overlap,
        integrity="checksum",
        chaos="seed=5;flip@1",
        retry_backoff_s=1e-3,
        full_result=True,
    )
    np.testing.assert_allclose(
        result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6
    )
    integ = result.recovery_stats["integrity"]
    assert integ["checksum_failures"] + integ["audit_failures"] >= 1
    assert result.recovery_stats["quarantined_blocks"] >= 1
    assert result.rounds_run == len(result.schedule.rounds)  # exactly-once
    assert integ["max_checksum_residual"] < 1e-3  # the ABFT lane is healthy


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_flip_matrix_2x2x2_mesh_duplicate_vote():
    """Replicated mesh under steal: a deep flip on the duplicated tail
    lane is caught by the duplicate vote and settled by the tie-breaker."""
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.launch.mesh import make_mesh

    g = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    result = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", batch_size=8, straggler="steal",
        integrity="checksum",
        chaos="seed=1;flip@3:d1",
        retry_backoff_s=1e-3,
        full_result=True,
    )
    np.testing.assert_allclose(
        result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6
    )
    integ = result.recovery_stats["integrity"]
    assert integ["votes"] >= 1
    assert result.rounds_run == len(result.schedule.rounds)  # exactly-once


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_stall_matrix_2x2x2_mesh_watchdog_remesh():
    """Replicated mesh: a dispatch stalled past its deadline is tripped,
    re-dispatched, escalated to replica loss and absorbed by the
    re-mesh — the run finishes exact instead of hanging."""
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.launch.mesh import make_mesh

    g = gnp_graph(20, 0.25, seed=5)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    result = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", batch_size=4, straggler="steal",
        integrity="audit",
        chaos="seed=13;stall@0x3:200",
        dispatch_deadline_s=0.05, max_retries=2, retry_backoff_s=1e-3,
        full_result=True,
    )
    np.testing.assert_allclose(
        result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6
    )
    rec = result.recovery_stats
    integ = rec["integrity"]
    assert integ["watchdog_trips"] >= 3
    assert integ["watchdog_escalations"] >= 1
    assert rec["remesh_events"] >= 1
    assert result.rounds_run == len(result.schedule.rounds)  # exactly-once


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_checksum_rejects_split_backward_payload():
    from repro.core.distributed import make_distributed_round_fn
    from repro.graphs.partition import partition_2d
    from repro.launch.mesh import make_mesh

    g = gnp_graph(16, 0.3, seed=0)
    mesh = make_mesh((2, 4), ("data", "model"))
    part = partition_2d(g, 2, 4)
    with pytest.raises(ValueError, match="checksum lane"):
        make_distributed_round_fn(
            part, mesh, fuse_backward_payload=False, integrity="checksum"
        )
