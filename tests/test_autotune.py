"""Measured-cost autotuning: cache, planner, seams, eccentricity deals.

Five layers of checks:

* the persistent :class:`CostCache` — roundtrip, atomic persistence,
  corrupt-file tolerance, hit/miss/store accounting;
* the staged planner (:func:`plan_autotune`) on an injected fake bench —
  measure-once semantics (a second plan over the same cache re-measures
  nothing), mode contracts ("off" never consults, "cache" never
  measures), tile/hybrid/overlap stage resolution;
* the four choice seams, each demonstrably preferring a measured cost
  over its roofline estimate: ``cell_kernel_choice(measured=)``,
  ``auto_overlap_policy(measured=)``,
  ``prior_round_seconds(measured_level_s=)``, and the BCSR tile pick;
* scheduler additions — ``validate_batch_size`` (both entrypoints),
  sampled eccentricities, the cost-packed :func:`split_rounds` deal, and
  eccentricity-ordered schedules cutting total traversal levels on the
  depth-skewed graph;
* end-to-end on 8 fake devices — depth-divergent rounds stay at oracle
  parity across every distributed engine × overlap policy, and
  ``distributed_betweenness_centrality(autotune=...)`` round-trips
  measure → cache-hit against a persisted file.
"""
import json
import logging

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.autotune import (
    AUTOTUNE_MODES,
    Candidate,
    CostCache,
    CostRecord,
    config_key,
    graph_key,
    graph_key_for,
    measure_walls,
    normalize_autotune,
    plan_autotune,
    sample_batch,
)
from repro.core import betweenness_centrality, brandes_reference, engine
from repro.core.distributed import (
    DIST_ENGINE_KINDS,
    PRIOR_LEVELS,
    distributed_betweenness_centrality,
    prior_round_seconds,
)
from repro.core.driver import BCDriver, traversal_round
from repro.core.operators import OVERLAP_POLICIES
from repro.core.scheduler import (
    ROOT_ORDERS,
    bfs_depths,
    build_schedule,
    estimate_eccentricities,
    split_rounds,
    validate_batch_size,
)
from repro.graphs import (
    complete_graph,
    disjoint_union,
    gnp_graph,
    path_graph,
    skewed_depth_graph,
)
from repro.graphs.partition import partition_2d
from repro.roofline.model import auto_overlap_policy, cell_kernel_choice

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)


# ---------------------------------------------------------- cost cache
def test_cache_roundtrip_and_persistence(tmp_path):
    path = tmp_path / "tune.json"
    cache = CostCache(path)
    gkey = graph_key(32, 100, R=2, C=4)
    ckey = config_key("sparse", "none", 16)
    assert cache.get(gkey, ckey) is None
    assert cache.misses == 1
    rec = CostRecord(level_s=0.25, levels=4, walls=(2.0, 2.1))
    cache.put(gkey, ckey, rec)
    assert cache.stores == 1 and path.exists()
    assert cache.get(gkey, ckey) == rec
    assert cache.hits == 1

    # a fresh instance loads the persisted record
    cache2 = CostCache(path)
    assert cache2.num_records() == 1
    assert cache2.get(gkey, ckey) == rec
    # a different graph key is a miss — measurements never cross graphs
    assert cache2.get(graph_key(64, 100, R=2, C=4), ckey) is None
    stats = cache2.stats()
    assert stats["records"] == 1 and stats["hits"] == 1 and stats["misses"] == 1

    # the persisted file is valid versioned JSON
    obj = json.loads(path.read_text())
    assert obj["version"] == 1 and gkey in obj["entries"]


def test_cache_tolerates_corrupt_and_foreign_files(tmp_path):
    garbage = tmp_path / "garbage.json"
    garbage.write_text("{not json")
    cache = CostCache(garbage)
    assert cache.num_records() == 0

    wrong_version = tmp_path / "old.json"
    wrong_version.write_text(json.dumps({"version": 999, "entries": {"g": {}}}))
    assert CostCache(wrong_version).num_records() == 0

    # a corrupt-at-load cache is still writable (fresh start)
    cache.put("g", "c", CostRecord(level_s=1.0))
    assert CostCache(garbage).num_records() == 1

    # in-memory mode: no path, nothing on disk
    mem = CostCache(None)
    mem.put("g", "c", CostRecord(level_s=1.0))
    assert mem.num_records() == 1 and mem.stats()["path"] is None


def test_key_schemas():
    assert graph_key(32, 100, R=2, C=4, fr=2, nnz_tiles=7, degree_skew=3.14) == (
        "n32_m100_r2x4x2_t7_k3.1"
    )
    assert config_key("pallas_sparse", "expand", 16, (8, 8)) == (
        "pallas_sparse|expand|b16|t8x8"
    )
    assert config_key("sparse", "none", 4) == "sparse|none|b4|t-"

    g = gnp_graph(30, 0.2, seed=1)
    part = partition_2d(g, 2, 2)
    key = graph_key_for(part, g, fr=2)
    assert key.startswith(f"n{part.n}_m{int(part.arc_counts.sum())}_r2x2x2_")
    # same configuration -> same key (measure-once across runs)
    assert key == graph_key_for(partition_2d(g, 2, 2), g, fr=2)


def test_normalize_autotune():
    assert normalize_autotune(None) == "off"
    for mode in AUTOTUNE_MODES:
        assert normalize_autotune(mode) == mode
    with pytest.raises(ValueError, match="autotune"):
        normalize_autotune("bogus")
    # the distributed entrypoint validates before touching the mesh
    with pytest.raises(ValueError, match="autotune"):
        distributed_betweenness_centrality(
            gnp_graph(6, 0.5, seed=0), None, autotune="bogus"
        )


def test_measure_walls_fake_clock():
    ticks = iter(float(t) for t in range(100))
    runs = []
    walls = measure_walls(
        lambda: runs.append(1), clock=lambda: next(ticks), warmup=1, iters=3
    )
    assert len(runs) == 4  # 1 warmup + 3 timed
    assert walls == [1.0, 1.0, 1.0]  # clock pairs straddle each run


# ------------------------------------------------- planner (fake bench)
def _plan_fixture():
    g = gnp_graph(64, 0.15, seed=5)
    part = partition_2d(g, 2, 2)  # chunk 16 -> tile menu [(16,16), (8,8)]
    assert len(part.tile_candidates()) >= 2
    return g, part


def test_plan_off_mode_consults_nothing():
    g, part = _plan_fixture()

    def bench(cand):  # pragma: no cover - must never run
        raise AssertionError("off mode measured a candidate")

    cache = CostCache(None)
    plan = plan_autotune(
        part, engine_kind="pallas_sparse", overlap="auto", batch_size=16,
        mode="off", cache=cache, graph=g, bench=bench,
    )
    assert plan.mode == "off" and plan.tile is None
    assert plan.hits == plan.misses == plan.measured == 0
    assert cache.hits == cache.misses == 0


def test_plan_cache_mode_never_measures_and_rooflines_tile():
    g, part = _plan_fixture()

    def bench(cand):  # pragma: no cover - must never run
        raise AssertionError("cache mode measured a candidate")

    plan = plan_autotune(
        part, engine_kind="pallas_sparse", overlap="auto", batch_size=16,
        mode="cache", cache=CostCache(None), graph=g, bench=bench,
    )
    assert plan.measured == 0 and plan.misses > 0
    # empty cache -> no measured costs anywhere; tile falls back to roofline
    assert plan.tile_source == "roofline"
    assert plan.tile in part.tile_candidates()
    assert plan.overlap_level_s == {} and plan.cell_costs is None
    assert plan.level_s_for("none") is None


def test_tile_pick_prefers_measured_over_roofline():
    g, part = _plan_fixture()
    cands = part.tile_candidates()
    roof = plan_autotune(
        part, engine_kind="pallas_sparse", overlap="none", batch_size=16,
        mode="cache", cache=CostCache(None), graph=g,
    )
    assert roof.tile_source == "roofline"
    # make the tile the roofline did NOT pick measure cheapest
    other = next(t for t in cands if t != roof.tile)

    def bench(cand):
        return CostRecord(level_s=1.0 if cand.tile == other else 9.0, levels=4)

    meas = plan_autotune(
        part, engine_kind="pallas_sparse", overlap="none", batch_size=16,
        mode="measure", cache=CostCache(None), graph=g, bench=bench,
    )
    assert meas.tile_source == "measured"
    assert meas.tile == other and meas.tile != roof.tile
    # the stage-3 overlap consult reuses the stage-1 record (same key)
    assert meas.level_s_for("none") == 1.0
    assert meas.hits >= 1

    # an explicit tile is never second-guessed
    explicit = plan_autotune(
        part, engine_kind="pallas_sparse", overlap="none", batch_size=16,
        tile=cands[0], mode="measure", cache=CostCache(None), graph=g,
        bench=bench,
    )
    assert explicit.tile == cands[0] and explicit.tile_source == "explicit"


def test_plan_measure_once_across_runs(tmp_path):
    g, part = _plan_fixture()
    path = tmp_path / "tune.json"

    def make_bench(calls):
        def bench(cand):
            calls.append(cand.key())
            cost = {"pallas": 3.0, "pallas_sparse": 1.0}.get(cand.engine_kind, 2.0)
            cost += {"none": 0.3, "expand": 0.2, "expand+fold": 0.1}[cand.overlap]
            return CostRecord(level_s=cost, levels=4, walls=(cost,))

        return bench

    kwargs = dict(
        engine_kind="pallas_hybrid", overlap="auto", batch_size=16,
        mode="measure", graph=g,
    )
    cold_calls: list[str] = []
    plan1 = plan_autotune(
        part, cache=CostCache(path), bench=make_bench(cold_calls), **kwargs
    )
    assert plan1.measured == len(cold_calls) > 0
    assert len(set(cold_calls)) == len(cold_calls)  # no key measured twice
    assert plan1.tile is not None and plan1.tile_source == "measured"
    assert plan1.cell_costs is not None
    assert set(plan1.overlap_level_s) == set(OVERLAP_POLICIES)

    # a second planner over the persisted file re-measures NOTHING and
    # resolves identically
    warm_calls: list[str] = []
    plan2 = plan_autotune(
        part, cache=CostCache(path), bench=make_bench(warm_calls), **kwargs
    )
    assert warm_calls == [] and plan2.measured == 0
    assert plan2.hits == plan1.hits + plan1.measured  # every consult hit
    assert plan2.tile == plan1.tile
    assert plan2.cell_costs == plan1.cell_costs
    assert plan2.overlap_level_s == plan1.overlap_level_s
    report = plan2.report()
    assert report["mode"] == "measure" and report["measured"] == 0


# ------------------------------------------------------ the four seams
def test_seam_cell_kernel_choice_prefers_measured():
    stored = np.array([[10.0, 0.0], [5.0, 10.0]])
    kw = dict(R=2, C=2, chunk=16, bm=8, bk=8)
    roofline = cell_kernel_choice(stored, **kw)
    # measured calibration overrides the bytes model entirely: a cheap
    # BCSR wall keeps every cell sparse, a cheap dense wall flips every
    # populated cell dense
    all_sparse = cell_kernel_choice(stored, measured=(1.0, 1e-3), **kw)
    assert not all_sparse.any()
    all_dense = cell_kernel_choice(stored, measured=(1e-6, 10.0), **kw)
    assert all_dense[stored > 0].all()
    # at least one extreme disagrees with the bytes model on this grid —
    # the measured pair, not the model, decided
    assert (all_sparse != roofline).any() or (all_dense != roofline).any()
    # threshold still applies on the measured scale
    forced_sparse = cell_kernel_choice(stored, measured=(1e-6, 10.0),
                                       R=2, C=2, chunk=16, bm=8, bk=8,
                                       threshold=1e12)
    assert not forced_sparse.any()


def test_seam_auto_overlap_policy_prefers_measured():
    model_pick, estimates = auto_overlap_policy(1e-3, 5e-4, 5e-4, 2, 4)
    assert model_pick in estimates
    # measure a DIFFERENT policy as cheapest -> it must win
    target = next(p for p in OVERLAP_POLICIES if p != model_pick)
    measured = {p: 1.0 for p in OVERLAP_POLICIES}
    measured[target] = 0.125
    pick, est = auto_overlap_policy(1e-3, 5e-4, 5e-4, 2, 4, measured=measured)
    assert pick == target and pick != model_pick
    assert est[target] == 0.125  # the audit table carries measured values

    # restrict-to-measured: a single measured policy wins outright even
    # when the model thinks another is faster (no cross-scale mixing)
    lone = next(p for p in OVERLAP_POLICIES if p != model_pick)
    pick, est = auto_overlap_policy(
        1e-3, 5e-4, 5e-4, 2, 4, measured={lone: 999.0}
    )
    assert pick == lone and est[lone] == 999.0


def test_seam_prior_round_seconds_prefers_measured():
    g = gnp_graph(30, 0.2, seed=1)
    part = partition_2d(g, 2, 2)
    model_prior = prior_round_seconds(part, "sparse", 8, "none")
    measured_prior = prior_round_seconds(
        part, "sparse", 8, "none", measured_level_s=0.1234
    )
    assert measured_prior == pytest.approx(0.1234 * PRIOR_LEVELS)
    assert measured_prior != model_prior


# (the fourth seam — the BCSR tile pick — is
# test_tile_pick_prefers_measured_over_roofline above)


# ------------------------------------------------ batch-size validation
def test_validate_batch_size_rejects_nonpositive():
    with pytest.raises(ValueError, match="batch_size"):
        validate_batch_size(0)
    g = gnp_graph(10, 0.3, seed=1)
    with pytest.raises(ValueError, match="batch_size"):
        betweenness_centrality(g, batch_size=0)
    with pytest.raises(ValueError, match="batch_size"):
        build_schedule(g, batch_size=-3)
    # the distributed entrypoint rejects before touching the mesh
    with pytest.raises(ValueError, match="batch_size"):
        distributed_betweenness_centrality(g, None, batch_size=-1)


def test_validate_batch_size_pad_hint(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.core.scheduler"):
        assert validate_batch_size(48) == 48  # pads to 128: 80 dead lanes
    assert any("wasted MXU" in r.message for r in caplog.records)
    assert any("128" in r.message for r in caplog.records)

    caplog.clear()
    with caplog.at_level(logging.WARNING, logger="repro.core.scheduler"):
        validate_batch_size(128)  # exact tile: no padding
        validate_batch_size(65)   # pads 63 lanes: less than half a tile
    assert not caplog.records


# ------------------------------------- eccentricity + cost-packed deals
def test_bfs_depths():
    np.testing.assert_array_equal(
        bfs_depths(path_graph(5), 0), [0, 1, 2, 3, 4]
    )
    g = disjoint_union(path_graph(3), complete_graph(3))
    depth = bfs_depths(g, 0)
    assert depth[2] == 2 and (depth[3:] == -1).all()


def test_estimate_eccentricities_orders_deep_above_shallow():
    g = disjoint_union(path_graph(8), complete_graph(8))
    ecc = estimate_eccentricities(g, num_samples=4, seed=0)
    # farthest-first hits the path endpoints: the full length is seen
    assert ecc[:8].max() == 7
    # every component got a landmark, so the clique measures its true 1
    assert (ecc[8:] == 1).all()
    # and every path vertex sorts above every clique vertex
    assert ecc[:8].min() > ecc[8:].max()


def test_estimate_eccentricities_covers_many_components_past_budget():
    # 6 components but a 2-sample budget: coverage still guaranteed
    g = disjoint_union(*[path_graph(5) for _ in range(6)])
    ecc = estimate_eccentricities(g, num_samples=2, seed=3)
    assert (ecc.reshape(6, 5).max(axis=1) >= 2).all()


def test_split_rounds_cost_packed_deal():
    costs = [7, 1, 7, 1, 7, 1, 7, 1]
    # costliest-first row-major deal — the redeal_rounds shape, seeded
    # from the prior instead of the EWMA
    assert split_rounds(8, 2, round_costs=costs) == [[0, 4, 1, 5], [2, 6, 3, 7]]
    assert split_rounds(8, 2, committed={0, 1}, round_costs=costs) == [
        [2, 6, 5],
        [4, 3, 7],
    ]
    # exactly-once: the deal is a permutation
    assert sorted(
        r for q in split_rounds(8, 3, round_costs=costs) for r in q
    ) == list(range(8))
    # no costs -> the legacy interleaved deal, unchanged
    assert split_rounds(7, 2) == [[0, 2, 4, 6], [1, 3, 5]]
    with pytest.raises(ValueError, match="costs"):
        split_rounds(8, 2, round_costs=[1.0])


def test_build_schedule_root_order_validation():
    g = gnp_graph(10, 0.3, seed=1)
    with pytest.raises(ValueError, match="root_order"):
        build_schedule(g, root_order="degree")
    assert set(ROOT_ORDERS) == {"id", "eccentricity"}
    schedule, _, _, _ = build_schedule(g, batch_size=4)
    assert schedule.round_depths is None  # id order carries no prior


def _sum_traversal_levels(graph, schedule):
    """Total level iterations of running the schedule's rounds on the
    single-device dense engine (the depth-divergence cost metric)."""
    adjacency = jnp.asarray(graph.dense_adjacency(np.float32))
    omega = jnp.zeros(graph.n, jnp.float32)
    total = 0
    for r in schedule.rounds:
        _, _, _, levels = traversal_round(
            engine.make_dense_operator(adjacency),
            jnp.asarray(r.sources),
            jnp.asarray(r.derived),
            omega,
        )
        total += int(levels)
    return total


def test_ecc_packed_rounds_cut_total_levels_and_keep_parity():
    # alternating path/clique blocks: the id-order deal mixes one deep
    # and one shallow component per round, the eccentricity deal packs
    # deep with deep — measurably fewer total level iterations
    g = skewed_depth_graph(2, 8)  # n=32: path, K8, path, K8
    batch = 16
    sched_id, _, _, _ = build_schedule(g, batch_size=batch)
    sched_ecc, prep, _, _ = build_schedule(
        g, batch_size=batch, root_order="eccentricity"
    )
    assert len(sched_id.rounds) == len(sched_ecc.rounds)
    interleaved = _sum_traversal_levels(g, sched_id)
    packed = _sum_traversal_levels(g, sched_ecc)
    assert packed < interleaved

    # the prior the replica deal consumes: one depth per round, with the
    # deep-root round(s) strictly costlier than the clique round(s)
    depths = sched_ecc.round_depths
    assert depths is not None and len(depths) == len(sched_ecc.rounds)
    assert depths.max() > depths.min()

    # reordering sources never changes BC (additive accumulation)
    adjacency = jnp.asarray(g.dense_adjacency(np.float32))
    omega = jnp.zeros(g.n, jnp.float32)

    def block_fn(sources, derived):
        bc_r, ns, roots, levels = traversal_round(
            engine.make_dense_operator(adjacency), sources[0], derived[0], omega
        )
        return bc_r, ns[None], roots[None], levels[None]

    result = BCDriver(block_fn, sched_ecc, n=g.n, prep=prep).run()
    np.testing.assert_allclose(
        result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6
    )


# ----------------------------------------- depth-divergent rounds, mesh
@needs_mesh
@pytest.mark.parametrize("overlap", list(OVERLAP_POLICIES))
@pytest.mark.parametrize("engine_kind", list(DIST_ENGINE_KINDS))
def test_depth_divergent_batches_distributed(engine_kind, overlap):
    """A round mixing one deep path root with shallow clique roots stays
    at oracle parity for every engine × overlap policy (masked no-op
    levels mask correctly)."""
    from repro.launch.mesh import make_mesh

    g = skewed_depth_graph(2, 8)
    mesh = make_mesh((2, 4), ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g, mesh, batch_size=16, engine_kind=engine_kind, overlap=overlap
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-5, atol=1e-5)


@needs_mesh
def test_distributed_autotune_measure_then_cache_roundtrip(tmp_path):
    from repro.launch.mesh import make_mesh

    g = gnp_graph(24, 0.2, seed=3)
    expected = brandes_reference(g)
    mesh = make_mesh((2, 4), ("data", "model"))
    path = tmp_path / "tune.json"

    def run(mode):
        cache = CostCache(path)
        bc, schedule = distributed_betweenness_centrality(
            g, mesh, batch_size=8, engine_kind="sparse", overlap="auto",
            autotune=mode, autotune_cache=cache,
        )
        np.testing.assert_allclose(bc, expected, rtol=1e-5, atol=1e-5)
        # autotune switches the scheduler to eccentricity packing
        assert schedule.round_depths is not None
        return cache

    cold = run("measure")
    assert cold.stores > 0 and path.exists()
    persisted = path.read_bytes()

    warm = run("measure")
    assert warm.hits > 0
    assert warm.stores == 0, "measure-once violated: warm run re-measured"
    assert path.read_bytes() == persisted

    cached = run("cache")
    assert cached.hits > 0 and cached.stores == 0


@needs_mesh
def test_distributed_autotune_off_is_status_quo():
    from repro.launch.mesh import make_mesh

    g = gnp_graph(20, 0.2, seed=4)
    mesh = make_mesh((2, 4), ("data", "model"))
    bc, schedule = distributed_betweenness_centrality(g, mesh, batch_size=8)
    assert schedule.round_depths is None  # id-order schedule, no prior
    np.testing.assert_allclose(
        bc, brandes_reference(g), rtol=1e-5, atol=1e-5
    )


def test_sample_batch_replicates_first_round():
    g = gnp_graph(20, 0.2, seed=4)
    schedule, _, _, _ = build_schedule(g, batch_size=8)
    sources, derived = sample_batch(schedule, fr=2)
    assert sources.shape == (2, 8)
    np.testing.assert_array_equal(sources[0], sources[1])
    assert derived.shape[0] == 2 and derived.shape[2] == 3
    assert Candidate("sparse", "none", 8).key() == "sparse|none|b8|t-"
