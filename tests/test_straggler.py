"""Multi-ledger straggler scheduling (BCDriver straggler="steal"|"redeal").

Three layers of checks:

* pure scheduling functions — ``split_rounds`` / ``redeal_rounds``
  (core/scheduler.py) and the per-replica ledger namespacing of
  ``BCCheckpoint`` (checkpoint/checkpointer.py);
* forced-straggler driver runs on a *fake* two-lane round function (each
  lane runs the real single-device traversal, no mesh needed): BC parity
  with ``brandes_reference`` under steal and redeal, exactly-once across
  speculative duplicates (no double-commit) and across kill-and-resume —
  including a policy change between the crash and the resume;
* real-mesh parity — ``distributed_betweenness_centrality`` with
  ``straggler=`` on a replicated 8-fake-device mesh stays within 1e-6 of
  the oracle under a ring overlap policy (the lockstep schedule the
  re-deal optimizes).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import betweenness_centrality, brandes_reference, engine
from repro.core.driver import (
    BCDriver,
    STRAGGLER_POLICIES,
    normalize_straggler,
    traversal_round,
)
from repro.core.scheduler import build_schedule, redeal_rounds, split_rounds
from repro.checkpoint import BCCheckpoint
from repro.distributed.fault_tolerance import RoundLedger
from repro.graphs import (
    disjoint_union,
    gnp_graph,
    path_graph,
    skewed_depth_graph,
)


# ------------------------------------------------- pure scheduling logic
def test_split_rounds_matches_legacy_block_order():
    # lane r gets rounds r, r+fr, ... — the legacy interleaved deal
    assert split_rounds(7, 2) == [[0, 2, 4, 6], [1, 3, 5]]
    assert split_rounds(6, 3) == [[0, 3], [1, 4], [2, 5]]
    assert split_rounds(5, 2, committed={0, 3}) == [[2, 4], [1]]
    with pytest.raises(ValueError):
        split_rounds(4, 0)


def test_redeal_rounds_packs_similar_costs_together():
    queues = [[0, 2, 4, 6], [1, 3, 5, 7]]  # lane 0 deep (cost 10), lane 1 cheap
    new, moved = redeal_rounds(queues, [10.0, 1.0])
    # costliest-first row-major deal: the first blocks pair lane-0 rounds
    assert new == [[0, 4, 1, 5], [2, 6, 3, 7]]
    assert moved == 4  # half the pool changed lanes
    # exactly-once: the re-deal is a permutation, never a duplication
    assert sorted(r for q in new for r in q) == list(range(8))
    with pytest.raises(ValueError):
        redeal_rounds(queues, [1.0])


def test_straggler_policy_validation():
    assert normalize_straggler(None) == "none"
    assert set(STRAGGLER_POLICIES) == {"none", "steal", "redeal"}
    with pytest.raises(ValueError, match="straggler"):
        normalize_straggler("work-steal")
    with pytest.raises(ValueError, match="straggler"):
        betweenness_centrality(gnp_graph(10, 0.3, seed=1), straggler="steal")
    g = gnp_graph(10, 0.3, seed=1)
    schedule, prep, _, _ = build_schedule(g, batch_size=4)
    with pytest.raises(ValueError, match="ledger"):
        BCDriver(
            lambda s, d: None,
            schedule,
            n=g.n,
            straggler="redeal",
            rounds_per_dispatch=2,
            ledger=RoundLedger(),
        )


# ------------------------------------------- checkpoint ledger namespacing
def test_bc_checkpoint_namespacing_roundtrip(tmp_path):
    ckpt = BCCheckpoint(str(tmp_path / "bc.npz"))
    bc = np.arange(5, dtype=np.float64)
    ckpt.save(bc, {3: 7.0}, [[0, 2], [1]], "fp")
    # legacy load sees the merged union
    bc2, ns, committed = ckpt.load("fp")
    np.testing.assert_array_equal(bc2, bc)
    assert ns == {3: 7.0}
    assert committed == [0, 1, 2]
    # namespaced load keeps per-replica attribution
    _, _, by_lane = ckpt.load_namespaced("fp")
    assert by_lane == [[0, 2], [1]]
    with pytest.raises(ValueError, match="different"):
        ckpt.load_namespaced("other-fp")
    # a flat (single-ledger) save loads as one namespaced lane
    ckpt.save(bc, {}, [4, 1], "fp")
    _, _, by_lane = ckpt.load_namespaced("fp")
    assert by_lane == [[1, 4]]


# ------------------------------------------------ forced-straggler driver
class Crash(RuntimeError):
    pass


def _two_lane_round_fn(graph, crash_after=None):
    """Fake two-replica dispatch: each lane runs the real single-device
    traversal of its round (bc [2, n]; the driver treats the leading dim
    as the replica dim exactly as on a mesh)."""
    adjacency = jnp.asarray(graph.dense_adjacency(np.float32))
    omega = jnp.zeros(graph.n, jnp.float32)
    base = jax.jit(
        lambda s, d: traversal_round(
            engine.make_dense_operator(adjacency), s, d, omega
        )
    )
    calls = {"n": 0}

    def fn(sources, derived):
        calls["n"] += 1
        if crash_after is not None and calls["n"] > crash_after:
            raise Crash
        outs = [base(sources[r], derived[r]) for r in range(sources.shape[0])]
        return tuple(jnp.stack([o[i] for o in outs]) for i in range(4))

    return fn


def _run(graph, schedule, prep, policy, **kw):
    return BCDriver(
        _two_lane_round_fn(graph),
        schedule,
        n=graph.n,
        prep=prep,
        rounds_per_dispatch=2,
        straggler=policy,
        **kw,
    ).run()


@pytest.mark.parametrize("policy", ["steal", "redeal"])
def test_forced_straggler_parity(policy):
    """One lane draws every deep (path) round, the other every shallow
    (complete-graph) round; both policies must reproduce the oracle."""
    g = skewed_depth_graph(4, 8)  # 8 rounds: deep/shallow alternating
    schedule, prep, _, _ = build_schedule(g, batch_size=8)
    assert len(schedule.rounds) == 8
    result = _run(g, schedule, prep, policy, prior_round_s=1e-3)
    np.testing.assert_allclose(result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6)
    assert result.rounds_run == 8
    stats = result.straggler_stats
    assert stats["policy"] == policy
    assert sum(stats["per_replica_rounds"]) == 8
    if policy == "redeal":
        # the EWMA skew (path depth 8 vs clique depth 2) must have fired
        assert stats["redeal_events"] >= 1
        assert stats["rounds_redealt"] > 0


def test_steal_duplicates_are_discarded_not_double_committed():
    """With an odd round count one lane idles at the tail and dispatches a
    speculative duplicate of the straggler's round; BC parity proves the
    loser was masked out before accumulation (a double commit would
    double that round's contribution)."""
    g = disjoint_union(skewed_depth_graph(3, 8), path_graph(8))  # 7 rounds
    schedule, prep, _, _ = build_schedule(g, batch_size=8)
    assert len(schedule.rounds) == 7
    result = _run(g, schedule, prep, "steal")
    np.testing.assert_allclose(result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6)
    stats = result.straggler_stats
    assert stats["duplicates_dispatched"] >= 1
    assert stats["duplicates_discarded"] == stats["duplicates_dispatched"]
    assert result.rounds_run == 7  # duplicates are not extra commits


@pytest.mark.parametrize("resume_policy", ["redeal", "steal", "none"])
def test_straggler_kill_and_resume(tmp_path, resume_policy):
    """Kill mid-run under redeal, resume under any policy: the merged
    per-replica ledgers keep every round exactly-once (a round committed
    by the replica that stole it before the kill is never re-accumulated,
    no matter which lane would execute it after the resume)."""
    g = skewed_depth_graph(4, 8)
    schedule, prep, _, _ = build_schedule(g, batch_size=8)
    n_rounds = len(schedule.rounds)
    expected = brandes_reference(g)
    ckpt = BCCheckpoint(str(tmp_path / "bc.npz"))

    def driver(policy, crash_after=None):
        return BCDriver(
            _two_lane_round_fn(g, crash_after=crash_after),
            schedule,
            n=g.n,
            prep=prep,
            rounds_per_dispatch=2,
            straggler=policy,
            checkpoint=ckpt,
            checkpoint_every=1,
        )

    with pytest.raises(Crash):
        driver("redeal", crash_after=2).run()
    assert ckpt.exists()
    _, _, by_lane = ckpt.load_namespaced()
    committed = {rid for lane in by_lane for rid in lane}
    assert 0 < len(committed) < n_rounds
    assert len(by_lane) == 2  # namespaced per replica

    resumed = driver(resume_policy).run()
    assert resumed.rounds_run == n_rounds - len(committed)
    np.testing.assert_allclose(resumed.bc, expected, rtol=1e-6, atol=1e-6)

    # a third run is a no-op that still reproduces the full scores
    third = driver(resume_policy).run()
    assert third.rounds_run == 0
    np.testing.assert_allclose(third.bc, expected, rtol=1e-6, atol=1e-6)


def test_straggler_requires_levels_output():
    g = gnp_graph(12, 0.3, seed=0)
    schedule, prep, _, _ = build_schedule(g, batch_size=4)
    lane_fn = _two_lane_round_fn(g)

    def legacy_fn(sources, derived):  # 3-tuple: no levels signal
        return lane_fn(sources, derived)[:3]

    driver = BCDriver(
        legacy_fn, schedule, n=g.n, prep=prep,
        rounds_per_dispatch=2, straggler="steal",
    )
    with pytest.raises(ValueError, match="levels"):
        driver.run()


# ----------------------------------------------------- real-mesh parity
@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
@pytest.mark.parametrize("policy", ["steal", "redeal"])
def test_distributed_straggler_matches_oracle(policy):
    """Replicated mesh + ring overlap (loop-bound lockstep) + divergent
    per-replica depths: the exact regime the re-deal schedules for."""
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.launch.mesh import make_mesh

    g = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g,
        mesh,
        replica_axis="pod",
        batch_size=8,
        overlap="expand",
        straggler=policy,
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_distributed_straggler_needs_replicas():
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.launch.mesh import make_mesh

    g = gnp_graph(16, 0.3, seed=0)
    mesh = make_mesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="replica"):
        distributed_betweenness_centrality(g, mesh, straggler="redeal")
