"""Statistical test suite for source-sampled approximate BC.

Three layers prove the estimator (repro/serving/sampling.py + the
entrypoints' rescale):

* **estimator math** — Brandes' outer loop is per-root additive, so the
  mean of the rescaled estimator over *all* k-subsets of the eligible
  roots must equal exact BC (true unbiasedness, enumerated on small
  graphs); the pipeline's sampled run must equal the oracle's rescaled
  per-root contribution sum for the planned subset; and
  ``sample_frac=1.0`` must be *bitwise* the unsampled run — no sampled
  code path is left at full fraction.
* **plan / stop-rule properties** (hypothesis) — sample sizes stay in
  bounds, same-seed samples are nested in k, rank stability is exactly
  1.0 for unchanged scores and the adaptive stop never fires before
  ``min_blocks``.
* **distributed composition** (8 fake host devices) — a full-fraction
  sampled run matches ``brandes_reference`` within 1e-6 across engines,
  overlap schedules and meshes, and the stop-rule seam composes with
  straggler re-deal, ABFT checksums and chaos without breaking
  exactly-once commits.
"""
import itertools
import os

import numpy as np
import pytest

try:  # hypothesis widens the deterministic sweeps below when available
    from hypothesis import HealthCheck, given, settings, strategies as st

    def hyp(*strategies):
        def deco(fn):
            return settings(
                max_examples=25,
                deadline=None,
                suppress_health_check=[
                    HealthCheck.too_slow, HealthCheck.data_too_large
                ],
            )(given(*strategies)(fn))

        return deco

    HAVE_HYPOTHESIS = True
except ImportError:  # the container ships without it; CI installs it

    def hyp(*strategies):
        return pytest.mark.skip(reason="hypothesis not installed")

    class st:  # strategy expressions must still evaluate at import
        integers = floats = sampled_from = data = staticmethod(
            lambda *a, **k: None
        )

    HAVE_HYPOTHESIS = False

import jax

from repro.core import betweenness_centrality, brandes_reference
from repro.core.brandes_ref import single_source_dependencies
from repro.core.distributed import (
    DIST_ENGINE_KINDS,
    distributed_betweenness_centrality,
)
from repro.core.operators import OVERLAP_POLICIES
from repro.graphs import disjoint_union, gnp_graph, path_graph, rmat_graph
from repro.serving.sampling import (
    AdaptiveStopRule,
    BlockBudgetStop,
    eligible_roots,
    plan_sampling,
    rank_stability,
    resolve_sample_size,
    top_k_indices,
)

needs8 = pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")


def _per_root_contributions(graph):
    """[n, n] matrix: row s = source s's dependency contribution."""
    adj = graph.adjacency_lists()
    out = np.zeros((graph.n, graph.n))
    for s in range(graph.n):
        delta, _, _ = single_source_dependencies(adj, graph.n, s)
        delta[s] = 0.0
        out[s] = delta
    return out


# ----------------------------------------------------- plan properties
def _check_sample_size_bounds(n, frac):
    k = resolve_sample_size(n, sample_frac=frac)
    assert 1 <= k <= n
    assert resolve_sample_size(n, sample_frac=1.0) == n
    assert resolve_sample_size(n) == n  # no size given: the full pool


def test_resolve_sample_size_stays_in_bounds():
    for n in (1, 2, 7, 64, 500):
        for frac in (1e-6, 0.01, 0.25, 0.5, 0.999, 1.0):
            _check_sample_size_bounds(n, frac)


@hyp(st.integers(1, 500), st.floats(1e-6, 1.0))
def test_resolve_sample_size_stays_in_bounds_fuzzed(n, frac):
    _check_sample_size_bounds(n, frac)


def test_resolve_sample_size_rejects_bad_inputs():
    with pytest.raises(ValueError):
        resolve_sample_size(10, sample_frac=0.5, sample_k=3)  # both
    with pytest.raises(ValueError):
        resolve_sample_size(10, sample_k=0)
    with pytest.raises(ValueError):
        resolve_sample_size(10, sample_k=11)
    with pytest.raises(ValueError):
        resolve_sample_size(10, sample_frac=0.0)
    with pytest.raises(ValueError):
        resolve_sample_size(10, sample_frac=1.5)
    with pytest.raises(ValueError):
        plan_sampling(np.arange(8), "bogus")
    with pytest.raises(ValueError):
        plan_sampling(np.array([], np.int64), "fixed", sample_frac=0.5)


def _check_nesting(n, k1, k2, seed):
    """k' > k ⇒ sample_k ⊂ sample_k' — a grown sample strictly extends
    the evidence a serving snapshot already accumulated."""
    eligible = np.arange(n, dtype=np.int64) * 3 + 1  # arbitrary ids
    p1 = plan_sampling(eligible, "fixed", sample_k=k1, seed=seed)
    p2 = plan_sampling(eligible, "fixed", sample_k=k2, seed=seed)
    small = p1.roots
    big = eligible if p2.roots is None else p2.roots
    assert small is not None and small.size == k1
    assert np.setdiff1d(small, big).size == 0  # subset
    assert np.array_equal(small, np.unique(small))  # sorted unique
    assert np.setdiff1d(small, eligible).size == 0  # drawn from the pool


def test_same_seed_samples_are_nested_in_k():
    for n, seed in itertools.product((2, 9, 40, 200), (0, 1, 7, 991)):
        for k1 in {1, n // 3, n - 1} - {0}:
            for k2 in {k1 + 1, (k1 + n) // 2 + 1, n}:
                if k1 < k2 <= n:
                    _check_nesting(n, k1, k2, seed)


@hyp(st.integers(2, 200), st.data(), st.integers(0, 10_000))
def test_same_seed_samples_are_nested_in_k_fuzzed(n, data, seed):
    k1 = data.draw(st.integers(1, n - 1))
    k2 = data.draw(st.integers(k1 + 1, n))
    _check_nesting(n, k1, k2, seed)


def test_full_fraction_plan_is_the_identity():
    """sample_frac=1.0 leaves no sampled code path: roots is None, so
    the scheduler input is identical to the unsampled call."""
    eligible = np.arange(17, dtype=np.int64)
    for mode in ("fixed", "adaptive"):
        plan = plan_sampling(eligible, mode, sample_frac=1.0)
        assert plan.roots is None and plan.k == 17 and plan.scale == 1.0
    # adaptive with no explicit size defaults to the full pool too
    plan = plan_sampling(eligible, "adaptive")
    assert plan.roots is None and plan.k == 17


# ------------------------------------------------------- estimator math
def test_estimator_unbiased_over_all_k_subsets():
    """Mean over ALL k-subsets S of (N/k)·Σ_{s∈S} contribution_s equals
    exact BC — enumerated, not sampled, so this is exact unbiasedness
    of the estimator the pipeline implements."""
    g = gnp_graph(9, 0.35, seed=2)
    contrib = _per_root_contributions(g)
    eligible = eligible_roots(g)
    n_elig = eligible.size
    exact = brandes_reference(g)
    for k in (1, 3):
        subsets = list(itertools.combinations(eligible.tolist(), k))
        est = np.zeros(g.n)
        for sub in subsets:
            est += (n_elig / k) * contrib[list(sub)].sum(axis=0)
        est /= len(subsets)
        np.testing.assert_allclose(est, exact, rtol=1e-9, atol=1e-9)


def test_estimator_unbiased_singletons_64_vertices():
    """k=1 unbiasedness on a 64-vertex graph: the mean over all
    1-subsets is N · mean_s contribution_s = exact BC."""
    g = gnp_graph(64, 0.08, seed=5)
    contrib = _per_root_contributions(g)
    eligible = eligible_roots(g)
    est = contrib[eligible].mean(axis=0) * eligible.size
    np.testing.assert_allclose(est, brandes_reference(g), rtol=1e-9, atol=1e-9)


@pytest.mark.parametrize("k,seed", [(5, 0), (10, 3), (17, 7)])
def test_pipeline_matches_rescaled_oracle(k, seed):
    """The sampled pipeline (plan → restricted schedule → rescale) must
    equal the oracle's rescaled contribution sum for the same subset."""
    g = gnp_graph(40, 0.15, seed=2)
    res = betweenness_centrality(
        g, batch_size=4, heuristics="h0", engine_kind="sparse",
        sampling="fixed", sample_k=k, sample_seed=seed,
    )
    plan = plan_sampling(eligible_roots(g), "fixed", None, k, seed)
    oracle = plan.scale * brandes_reference(g, sources=plan.roots)
    np.testing.assert_allclose(res.bc, oracle, rtol=1e-5, atol=1e-4)
    stats = res.sampling_stats
    assert stats["roots_accumulated"] == k
    assert stats["scale"] == pytest.approx(plan.scale)
    assert not res.stopped_early


def test_full_fraction_is_bitwise_the_unsampled_run():
    """Rescaling invariance: sample_frac=1.0 reproduces the unsampled
    schedule exactly — same rounds, same accumulation order, bitwise
    equal scores (no rescale drift: scale is exactly 1.0)."""
    g = gnp_graph(32, 0.15, seed=4)
    off = betweenness_centrality(g, batch_size=8, heuristics="h0")
    full = betweenness_centrality(
        g, batch_size=8, heuristics="h0", sampling="fixed", sample_frac=1.0
    )
    assert np.array_equal(off.bc, full.bc)  # bitwise, not allclose
    assert full.sampling_stats["scale"] == 1.0
    assert full.rounds_run == off.rounds_run


def test_sampling_validation():
    g = gnp_graph(12, 0.3, seed=0)
    with pytest.raises(ValueError):  # corrections are not root-additive
        betweenness_centrality(g, heuristics="h1", sampling="fixed",
                               sample_frac=0.5)
    with pytest.raises(ValueError):  # truncation needs the rescale
        betweenness_centrality(g, stop_rule=BlockBudgetStop(1))
    with pytest.raises(ValueError):
        betweenness_centrality(g, sampling="fixed", sample_frac=0.5,
                               sample_k=3)


# ------------------------------------------------- rank stability metric
def _check_stability_identity(n, seed, method):
    """Unchanged (or merely rescaled) scores are exactly 1.0-stable —
    watching the raw accumulator is equivalent to watching BC_hat."""
    rng = np.random.default_rng(seed)
    x = rng.random(n)
    assert rank_stability(x, x.copy(), k=10, method=method) == 1.0
    assert rank_stability(x, 2.5 * x, k=10, method=method) == 1.0


@pytest.mark.parametrize("method", ["jaccard", "kendall"])
def test_rank_stability_identity_and_scale_invariance(method):
    for n, seed in itertools.product((2, 5, 10, 11, 64), range(6)):
        _check_stability_identity(n, seed, method)


@hyp(
    st.integers(2, 64),
    st.integers(0, 10_000),
    st.sampled_from(["jaccard", "kendall"]),
)
def test_rank_stability_identity_fuzzed(n, seed, method):
    _check_stability_identity(n, seed, method)


def test_rank_stability_detects_divergence():
    a = np.zeros(20)
    b = np.zeros(20)
    a[:5] = [5, 4, 3, 2, 1]
    b[10:15] = [5, 4, 3, 2, 1]
    assert rank_stability(a, b, k=5) == 0.0  # disjoint top-5 sets
    swapped = a.copy()
    swapped[0], swapped[1] = a[1], a[0]
    # same set, different internal order: jaccard blind, kendall not
    assert rank_stability(a, swapped, k=5, method="jaccard") == 1.0
    assert rank_stability(a, swapped, k=5, method="kendall") < 1.0
    with pytest.raises(ValueError):
        rank_stability(a, b, method="spearman")


def test_top_k_ties_break_deterministically():
    scores = np.array([1.0, 3.0, 3.0, 2.0])
    assert top_k_indices(scores, 3).tolist() == [1, 2, 3]


# ------------------------------------------------------ stop-rule seam
def _check_stop_respects_min_blocks(window, min_blocks):
    """Even a perfectly frozen accumulator cannot stop the run before
    min_blocks dispatch blocks — and once frozen, every stability check
    is exactly 1.0 (monotone stability of an unchanging accumulator)."""
    rule = AdaptiveStopRule(top_k=4, window=window, min_blocks=min_blocks)
    bc = np.arange(16, dtype=np.float64)
    fired_at = None
    for block in range(1, 40):
        if rule(bc, block):
            fired_at = block
            break
    assert fired_at == max(min_blocks, window + 1)
    assert rule.stats["fired_at_block"] == fired_at
    assert all(s == 1.0 for s in rule.stats["stability"])


def test_adaptive_stop_never_fires_before_min_blocks():
    for window, min_blocks in itertools.product(range(1, 9), range(1, 9)):
        _check_stop_respects_min_blocks(window, min_blocks)


@hyp(st.integers(1, 8), st.integers(1, 8))
def test_adaptive_stop_never_fires_before_min_blocks_fuzzed(window, min_blocks):
    _check_stop_respects_min_blocks(window, min_blocks)


def test_adaptive_stop_defers_while_ranks_move():
    """A top-k that keeps changing defers the stop indefinitely."""
    rule = AdaptiveStopRule(top_k=3, window=2, min_blocks=1)
    n = 24
    for block in range(1, 21):
        bc = np.zeros(n)
        bc[(3 * block) % n] = 10.0  # rotating top vertex
        bc[(3 * block + 1) % n] = 5.0
        assert not rule(bc, block)
    assert rule.stats["fired_at_block"] is None
    assert all(s < 1.0 for s in rule.stats["stability"])


def test_block_budget_stop_fires_exactly_at_budget():
    rule = BlockBudgetStop(3)
    bc = np.zeros(4)
    assert [rule(bc, b) for b in (1, 2, 3, 4)] == [False, False, True, True]
    assert rule.stats["fired_at_block"] == 3
    with pytest.raises(ValueError):
        BlockBudgetStop(0)


def test_adaptive_acceptance_rmat_8_8():
    """The headline acceptance: adaptive mode on seeded rmat(8,8)
    reaches top-10 Jaccard ≥ 0.9 vs exact BC while dispatching < 50%
    of the schedule's rounds."""
    g = rmat_graph(8, 8, seed=3)
    exact = brandes_reference(g)
    rule = AdaptiveStopRule(top_k=10, window=3, min_blocks=3)
    res = betweenness_centrality(
        g, batch_size=8, heuristics="h0", engine_kind="sparse",
        sampling="adaptive", stop_rule=rule,
    )
    assert res.stopped_early
    total_rounds = len(res.schedule.rounds)
    assert res.rounds_run < 0.5 * total_rounds, (res.rounds_run, total_rounds)
    jac = rank_stability(exact, res.bc, k=10, method="jaccard")
    assert jac >= 0.9, jac
    stats = res.sampling_stats
    assert stats["scale"] > 1.0  # a truncated run really was rescaled
    assert stats["roots_accumulated"] < stats["num_eligible"]
    assert res.stop_stats["fired_at_block"] is not None


def test_checkpoint_resume_composes_with_sampling(tmp_path):
    """Rescale and resume commute: the checkpoint stores the *raw*
    accumulator, so a run killed mid-sample resumes and finishes with
    the same estimate an uninterrupted run produces."""
    from repro.distributed.fault_tolerance import BCCheckpoint

    g = gnp_graph(36, 0.15, seed=6)
    kw = dict(
        batch_size=4, heuristics="h0", engine_kind="sparse",
        sampling="fixed", sample_k=12, sample_seed=5,
    )
    ckpt = BCCheckpoint(os.path.join(tmp_path, "s.npz"))
    partial = betweenness_centrality(
        g, checkpoint=ckpt, stop_rule=BlockBudgetStop(1), **kw
    )
    assert partial.stopped_early
    assert 0 < partial.sampling_stats["roots_accumulated"] < 12
    resumed = betweenness_centrality(g, checkpoint=ckpt, **kw)
    assert not resumed.stopped_early
    assert resumed.sampling_stats["roots_accumulated"] == 12
    assert resumed.sampling_stats["scale"] == pytest.approx(
        resumed.sampling_stats["num_eligible"] / 12
    )
    uninterrupted = betweenness_centrality(g, **kw)
    np.testing.assert_allclose(resumed.bc, uninterrupted.bc,
                               rtol=1e-6, atol=1e-6)


# --------------------------------------- distributed composition (8 dev)
FULL_SAMPLE_MATRIX = [
    (kind, overlap, (2, 4))
    for kind in DIST_ENGINE_KINDS
    for overlap in OVERLAP_POLICIES
] + [("sparse", overlap, (4, 2)) for overlap in OVERLAP_POLICIES]


@needs8
@pytest.mark.parametrize("engine_kind,overlap,grid", FULL_SAMPLE_MATRIX)
def test_full_sample_distributed_parity(engine_kind, overlap, grid):
    """sampling="fixed", sample_frac=1.0 must match brandes_reference
    within 1e-6 for every distributed engine × overlap schedule × grid
    orientation — the sampled plumbing adds nothing at full fraction."""
    from repro.launch.mesh import make_mesh

    g = gnp_graph(26, 0.15, seed=0)
    mesh = make_mesh(grid, ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g, mesh, batch_size=8, engine_kind=engine_kind, overlap=overlap,
        sampling="fixed", sample_frac=1.0,
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


class _NeverStop:
    """Inert stop rule: exercises the seam without truncating."""

    stats = {"rule": "never"}

    def __call__(self, bc, blocks_done):
        return False


@needs8
def test_subcluster_sampled_redeal_checksum_chaos():
    """The stop-rule seam composes with the whole fault stack: a
    full-fraction sampled run under straggler="redeal" +
    integrity="checksum" + transient chaos still commits every round
    exactly once and matches the oracle within 1e-6."""
    from repro.launch.mesh import make_mesh

    g = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    result = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", batch_size=8,
        straggler="redeal", integrity="checksum",
        chaos="seed=5;transient@1x2", retry_backoff_s=1e-3,
        sampling="fixed", sample_frac=1.0, stop_rule=_NeverStop(),
        full_result=True,
    )
    np.testing.assert_allclose(
        result.bc, brandes_reference(g), rtol=1e-6, atol=1e-6
    )
    assert result.rounds_run == len(result.schedule.rounds)  # exactly-once
    assert not result.stopped_early
    assert result.sampling_stats["scale"] == 1.0
    assert result.recovery_stats["transient_errors"] == 2
    assert result.recovery_stats["integrity"]["mode"] == "checksum"


@needs8
def test_subcluster_straggler_loop_honors_stop_rule():
    """The straggler (re-deal) loop consults the same stop seam: a
    block budget truncates the replicated run and the estimate is
    rescaled by the roots actually committed."""
    from repro.launch.mesh import make_mesh

    g = gnp_graph(25, 0.15, seed=2)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    result = distributed_betweenness_centrality(
        g, mesh, replica_axis="pod", batch_size=4, straggler="redeal",
        sampling="fixed", sample_frac=1.0, stop_rule=BlockBudgetStop(2),
        full_result=True,
    )
    assert result.stopped_early
    stats = result.sampling_stats
    assert 0 < stats["roots_accumulated"] < stats["num_eligible"]
    assert stats["scale"] == pytest.approx(
        stats["num_eligible"] / stats["roots_accumulated"]
    )
    assert np.all(np.isfinite(result.bc)) and np.all(result.bc >= -1e-9)
