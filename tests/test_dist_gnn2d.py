"""2-D distributed GNN (paper's decomposition) == flat GSPMD reference."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.graphs import (
    full_graph_batch,
    minibatch_batch,
    molecule_batch,
    to_2d_batch,
)
from repro.data.sampler import NeighborSampler, block_budget
from repro.graphs import gnp_graph
from repro.models import gnn as gnn_mod
from repro.models.gnn2d import make_gnn2d_loss_fn

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)

R, C = 2, 4


def _mesh():
    from repro.launch.mesh import make_mesh

    return make_mesh((R, C), ("data", "model"))


def _reduced(name, **kw):
    return dataclasses.replace(get_arch(name).arch, n_layers=2, d_hidden=8, **kw)


def _compare(cfg, batch, shape_kind, d_feat, d_out, n_graphs=0, rtol=1e-4):
    params = gnn_mod.init_params(cfg, d_feat, d_out, jax.random.PRNGKey(0))
    flat_loss, _ = gnn_mod.gnn_loss(
        cfg, params, jax.tree.map(jnp.asarray, batch), shape_kind
    )

    mesh = _mesh()
    n_nodes = batch["node_feat"].shape[0]
    chunk = -(-n_nodes // (R * C))
    b2d = to_2d_batch(batch, n_nodes, R, C)
    loss_fn, _ = make_gnn2d_loss_fn(
        cfg,
        mesh,
        shape_kind,
        chunk=chunk,
        max_arcs=b2d["src_local"].shape[2],
        n_graphs=n_graphs,
    )
    loss_2d = jax.jit(loss_fn)(params, jax.tree.map(jnp.asarray, b2d))
    np.testing.assert_allclose(float(loss_2d), float(flat_loss), rtol=rtol)

    # gradients agree too (the training path)
    g_flat = jax.grad(
        lambda p: gnn_mod.gnn_loss(cfg, p, jax.tree.map(jnp.asarray, batch), shape_kind)[0]
    )(params)
    g_2d = jax.grad(lambda p: loss_fn(p, jax.tree.map(jnp.asarray, b2d)))(params)
    for a, b in zip(jax.tree.leaves(g_flat), jax.tree.leaves(g_2d)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("arch", ["graphcast", "gin-tu", "meshgraphnet", "gat-cora"])
def test_gnn2d_matches_flat_full_graph(arch):
    cfg = _reduced(arch, n_vars=5)
    d_feat, d_out = 12, gnn_mod.output_dim(cfg, get_arch(arch).shapes["full_graph_sm"])
    d_out = 5 if cfg.kind in ("graphcast",) else (3 if cfg.kind == "meshgraphnet" else 7)
    g = gnp_graph(40, 0.15, seed=3)
    batch = full_graph_batch(cfg, g, 48, 256, d_feat, d_out, n_classes=7, seed=1)
    _compare(cfg, batch, "full_graph", d_feat, d_out)


def test_gnn2d_matches_flat_molecule():
    cfg = _reduced("gin-tu")
    batch = molecule_batch(cfg, n_graphs=6, nodes_per=8, edges_per=16,
                           n_nodes_pad=64, n_edges_pad=128, d_feat=10, d_out=2,
                           n_classes=2, seed=2)
    _compare(cfg, batch, "batched_graphs", 10, 2, n_graphs=6)


def test_gnn2d_matches_flat_minibatch():
    cfg = _reduced("gat-cora")
    g = gnp_graph(120, 0.08, seed=5)
    feats = np.random.default_rng(0).standard_normal((120, 12)).astype(np.float32)
    fanout = (4, 3)
    sampler = NeighborSampler(g, fanout, seed=1)
    n_blk, e_blk = block_budget(8, fanout)
    batch = minibatch_batch(
        cfg, g, feats, sampler, np.arange(8), n_blk + 8, e_blk + 8, n_classes=5
    )
    _compare(cfg, batch, "minibatch", 12, 5)
