"""Per-architecture smoke tests: REDUCED configs of the same family run
one real forward/train step on CPU; asserts output shapes + finite values.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct,
no allocation) — launch/dryrun.py.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import GNNArch, LMArch
from repro.configs.registry import get_arch, list_archs
from repro.launch.steps import _make_optimizer
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf

LM_ARCHS = [
    "llama4-maverick-400b-a17b",
    "granite-moe-1b-a400m",
    "codeqwen1.5-7b",
    "deepseek-coder-33b",
    "gemma-7b",
]
GNN_ARCHS = ["graphcast", "gat-cora", "gin-tu", "meshgraphnet"]


def test_registry_has_all_assigned_archs():
    known = set(list_archs())
    for a in LM_ARCHS + GNN_ARCHS + ["dlrm-rm2", "bc-rmat"]:
        assert a in known


def _reduced_lm(arch: LMArch) -> LMArch:
    from repro.launch.train import reduced_lm

    return reduced_lm(arch, layers=2, d_model=128, vocab=512)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_train_step(name):
    cfg = _reduced_lm(get_arch(name).arch)
    optimizer = _make_optimizer(cfg.optimizer, lr=1e-3)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": optimizer.init(params)}
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab)

    @jax.jit
    def step(state, tokens):
        (loss, m), grads = jax.value_and_grad(
            lambda p: tf.lm_loss(cfg, p, tokens), has_aux=True
        )(state["params"])
        p2, o2 = optimizer.update(grads, state["opt"], state["params"])
        return {"params": p2, "opt": o2}, loss

    state2, loss = step(state, tokens)
    assert np.isfinite(float(loss)) and float(loss) > 0
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(
            jax.tree.leaves(state["params"]), jax.tree.leaves(state2["params"])
        )
    )
    assert delta > 0


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_decode_step(name):
    cfg = _reduced_lm(get_arch(name).arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    cache = jax.tree.map(
        lambda sds: jnp.zeros(sds.shape, sds.dtype), tf.cache_specs(cfg, b, s)
    )
    logits, cache2 = jax.jit(
        lambda p, c, t: tf.decode_step(cfg, p, c, t, jnp.int32(0))
    )(params, cache, jnp.zeros((b,), jnp.int32))
    assert logits.shape == (b, tf.padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits)).all()
    assert cache2["k"].shape == cache["k"].shape


def _gnn_batch(cfg: GNNArch, n=24, e=60, d_feat=12, d_out=5, kind="full_graph"):
    rng = np.random.default_rng(0)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    batch = {
        "node_feat": rng.standard_normal((n, d_feat)).astype(np.float32),
        "edge_src": src,
        "edge_dst": dst,
    }
    if cfg.kind in ("graphcast", "meshgraphnet"):
        batch["target"] = rng.standard_normal((n, d_out)).astype(np.float32)
        if cfg.kind == "meshgraphnet":
            batch["edge_feat"] = rng.standard_normal((e, d_feat)).astype(np.float32)
    elif kind == "batched_graphs":
        batch["graph_ids"] = (np.arange(n) // (n // 4)).astype(np.int32)
        batch["labels"] = rng.integers(0, d_out, 4).astype(np.int32)
    else:
        batch["labels"] = rng.integers(0, d_out, n).astype(np.int32)
        batch["label_mask"] = np.ones(n, np.float32)
    return jax.tree.map(jnp.asarray, batch)


@pytest.mark.parametrize("name", GNN_ARCHS)
def test_gnn_smoke_train_step(name):
    full = get_arch(name).arch
    cfg = dataclasses.replace(full, n_layers=2, d_hidden=8, n_vars=5)
    d_out = 5
    params = gnn_mod.init_params(cfg, 12, d_out, jax.random.PRNGKey(0))
    batch = _gnn_batch(cfg)

    @jax.jit
    def step(params, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: gnn_mod.gnn_loss(cfg, p, batch, "full_graph"), has_aux=True
        )(params)
        return loss, grads

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_gnn_molecule_pooling():
    cfg = dataclasses.replace(get_arch("gin-tu").arch, n_layers=2, d_hidden=8)
    batch = _gnn_batch(cfg, kind="batched_graphs")
    params = gnn_mod.init_params(cfg, 12, 5, jax.random.PRNGKey(0))
    loss, _ = gnn_mod.gnn_loss(cfg, params, batch, "batched_graphs")
    assert np.isfinite(float(loss))


def test_dlrm_smoke_train_and_retrieval():
    full = get_arch("dlrm-rm2").arch
    cfg = dataclasses.replace(full, rows_per_table=100, hot_size=3)
    params = dlrm_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b = 8
    batch = {
        "dense": jnp.asarray(rng.standard_normal((b, cfg.n_dense)), jnp.float32),
        "sparse": jnp.asarray(
            rng.integers(-1, cfg.rows_per_table, (b, cfg.n_sparse, cfg.hot_size)),
            jnp.int32,
        ),
        "labels": jnp.asarray(rng.integers(0, 2, b), jnp.float32),
    }
    loss, m = jax.jit(lambda p, bt: dlrm_mod.dlrm_loss(cfg, p, bt))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: dlrm_mod.dlrm_loss(cfg, p, batch)[0])(params)
    assert np.isfinite(
        float(jnp.sum(jnp.abs(grads["tables"])))
    )

    batch["candidates"] = jnp.asarray(
        rng.standard_normal((100, cfg.embed_dim)), jnp.float32
    )
    scores, idx = dlrm_mod.retrieval_scores(cfg, params, batch, top_k=7)
    assert scores.shape == (b, 7) and idx.shape == (b, 7)
    assert np.isfinite(np.asarray(scores)).all()


def test_dlrm_pallas_bag_matches_xla():
    full = get_arch("dlrm-rm2").arch
    cfg = dataclasses.replace(full, rows_per_table=50, hot_size=2)
    params = dlrm_mod.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    idx = jnp.asarray(rng.integers(-1, 50, (4, cfg.n_sparse, 2)), jnp.int32)
    a = dlrm_mod.embedding_bag_lookup(cfg, params["tables"], idx, use_pallas=False)
    b = dlrm_mod.embedding_bag_lookup(cfg, params["tables"], idx, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_bc_arch_registered_with_shapes():
    bundle = get_arch("bc-rmat")
    assert set(bundle.shapes) == {"rmat_s23_ef16", "rmat_s25_ef16"}
