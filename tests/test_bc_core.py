"""End-to-end correctness of single-device MGBC vs. the numpy oracle."""
import numpy as np
import pytest

from repro.core import betweenness_centrality, brandes_reference
from repro.graphs import (
    complete_graph,
    cycle_graph,
    disjoint_union,
    gnp_graph,
    grid_graph,
    path_graph,
    rmat_graph,
    road_like_graph,
    star_graph,
)

ALL_HEURISTICS = ["h0", "h1", "h2", "h3"]
ENGINES = ["dense", "sparse"]


def _check(graph, heuristics="h0", engine="dense", batch_size=8, **kw):
    expected = brandes_reference(graph)
    got = betweenness_centrality(
        graph, batch_size=batch_size, heuristics=heuristics, engine_kind=engine, **kw
    )
    np.testing.assert_allclose(got.bc, expected, rtol=1e-5, atol=1e-5)
    return got


# ------------------------------------------------------ structured graphs
@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_path_graph(heuristics):
    # path P_n: BC(v_i) = 2*i*(n-1-i)
    n = 9
    got = _check(path_graph(n), heuristics)
    expected = np.array([2.0 * i * (n - 1 - i) for i in range(n)])
    np.testing.assert_allclose(got.bc, expected, rtol=1e-6)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
@pytest.mark.parametrize("n", [4, 5, 8, 13])
def test_cycle_graph(heuristics, n):
    _check(cycle_graph(n), heuristics)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_star_graph(heuristics):
    k = 7
    got = _check(star_graph(k), heuristics)
    np.testing.assert_allclose(got.bc[0], k * (k - 1), rtol=1e-6)
    np.testing.assert_allclose(got.bc[1:], 0.0, atol=1e-9)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_complete_graph(heuristics):
    got = _check(complete_graph(6), heuristics)
    np.testing.assert_allclose(got.bc, 0.0, atol=1e-9)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_grid_graph(heuristics):
    _check(grid_graph(4, 5), heuristics)


# --------------------------------------------------------- random graphs
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_gnp(heuristics, engine, seed):
    _check(gnp_graph(24, 0.12, seed=seed), heuristics, engine)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_rmat(heuristics):
    _check(rmat_graph(6, 4, seed=3), heuristics, batch_size=16)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_road_like(heuristics):
    _check(road_like_graph(4, 4, spur_fraction=0.5, seed=1), heuristics)


# --------------------------------------------------- multiple components
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_multi_component(heuristics, engine):
    g = disjoint_union(
        path_graph(6), star_graph(4), cycle_graph(5), gnp_graph(12, 0.2, seed=7)
    )
    _check(g, heuristics, engine)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_k2_components(heuristics):
    # isolated edges: both endpoints are 1-degree — the degenerate case
    g = disjoint_union(path_graph(2), path_graph(2), path_graph(5))
    _check(g, heuristics)


@pytest.mark.parametrize("heuristics", ALL_HEURISTICS)
def test_isolated_vertices(heuristics):
    g = disjoint_union(gnp_graph(10, 0.25, seed=9), path_graph(1), path_graph(1))
    _check(g, heuristics)


# ----------------------------------------------------------- misc modes
def test_static_num_levels_matches_dynamic():
    g = gnp_graph(20, 0.15, seed=4)
    a = betweenness_centrality(g, heuristics="h0", num_levels=None)
    b = betweenness_centrality(g, heuristics="h0", num_levels=22)
    np.testing.assert_allclose(a.bc, b.bc, rtol=1e-6)


def test_batch_size_invariance():
    g = gnp_graph(30, 0.1, seed=5)
    ref = brandes_reference(g)
    for bs in (1, 4, 7, 32, 64):
        got = betweenness_centrality(g, batch_size=bs, heuristics="h3")
        np.testing.assert_allclose(got.bc, ref, rtol=1e-5, atol=1e-5)


def test_two_degree_actually_skips_forward_work():
    g = cycle_graph(12)
    h0 = betweenness_centrality(g, heuristics="h0")
    h2 = betweenness_centrality(g, heuristics="h2")
    assert h2.forward_columns < h0.forward_columns
    # cycle upper bound from the paper: n/2 derivable
    assert h0.forward_columns - h2.forward_columns == 6


def test_one_degree_skips_leaves():
    g = road_like_graph(3, 3, spur_fraction=1.0, seed=0)
    h0 = betweenness_centrality(g, heuristics="h0")
    h1 = betweenness_centrality(g, heuristics="h1")
    assert h1.forward_columns < h0.forward_columns


# ---------------------------------------------- beyond-paper: tree contraction
TREE_MODES = ["h1t", "h3t"]


@pytest.mark.parametrize("heuristics", TREE_MODES)
def test_tree_contraction_path_graph_fully_analytic(heuristics):
    """A path fully contracts: zero rounds, exact analytic scores."""
    n = 11
    got = betweenness_centrality(path_graph(n), heuristics=heuristics)
    expected = np.array([2.0 * i * (n - 1 - i) for i in range(n)])
    np.testing.assert_allclose(got.bc, expected, rtol=1e-6)
    assert got.forward_columns == 0  # every vertex resolved analytically


@pytest.mark.parametrize("heuristics", TREE_MODES)
def test_tree_contraction_random_trees(heuristics):
    rng = np.random.default_rng(5)
    # random tree: attach each vertex to a random earlier vertex
    n = 40
    edges = np.array([[rng.integers(0, i), i] for i in range(1, n)])
    from repro.graphs import Graph

    g = Graph.from_edges(n, edges)
    got = betweenness_centrality(g, heuristics=heuristics)
    np.testing.assert_allclose(got.bc, brandes_reference(g), rtol=1e-6, atol=1e-8)
    assert got.forward_columns == 0


@pytest.mark.parametrize("heuristics", TREE_MODES)
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_tree_contraction_general_graphs(heuristics, seed):
    g = gnp_graph(26, 0.08, seed=seed)  # sparse: trees hang off a core
    got = betweenness_centrality(g, heuristics=heuristics)
    np.testing.assert_allclose(
        got.bc, brandes_reference(g), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("heuristics", TREE_MODES)
def test_tree_contraction_road_like(heuristics):
    g = road_like_graph(5, 5, spur_fraction=1.2, seed=4)
    h0 = betweenness_centrality(g, heuristics="h0")
    got = betweenness_centrality(g, heuristics=heuristics)
    np.testing.assert_allclose(got.bc, h0.bc, rtol=1e-5, atol=1e-5)
    # deep spur chains contract fully — strictly better than single-pass h1
    h1 = betweenness_centrality(g, heuristics="h1")
    assert got.forward_columns < h1.forward_columns


@pytest.mark.parametrize("heuristics", TREE_MODES)
def test_tree_contraction_multi_component(heuristics):
    g = disjoint_union(
        path_graph(7), star_graph(5), cycle_graph(6), gnp_graph(15, 0.15, seed=9),
        path_graph(2),
    )
    got = betweenness_centrality(g, heuristics=heuristics)
    np.testing.assert_allclose(
        got.bc, brandes_reference(g), rtol=1e-5, atol=1e-5
    )


def test_h3_composition_effect_on_suburb_topology():
    """Paper §4.4: 1-degree removal creates new 2-degree vertices, so H3
    derives strictly more than H2 (their RoadNet-PA: +8% derived)."""
    from repro.graphs import suburb_graph

    g = suburb_graph(5, 5, leaf_fraction=0.6, seed=2)
    ref = brandes_reference(g)
    h2 = betweenness_centrality(g, heuristics="h2")
    h3 = betweenness_centrality(g, heuristics="h3")
    np.testing.assert_allclose(h2.bc, ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h3.bc, ref, rtol=1e-5, atol=1e-5)
    assert h3.schedule.num_derived > h2.schedule.num_derived
    assert h3.forward_columns < h2.forward_columns
