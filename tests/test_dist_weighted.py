"""Distributed weighted (bucketed) BC == Dijkstra oracle, 8-device mesh.

The acceptance matrix from the weighted-traversal work: every
distributed engine kind × overlap policy on 2x4, 4x2 and a replicated
sub-cluster mesh must match ``brandes_reference`` (which runs Dijkstra
when the graph carries weights).  Dyadic weights make every shortest
distance an exact f32 sum, so the tolerance is tight (1e-6).
"""
import numpy as np
import pytest

import jax

from repro.core import brandes_reference
from repro.core.distributed import (
    DIST_ENGINE_KINDS,
    distributed_betweenness_centrality,
    weighted_prior_levels,
)
from repro.core.operators import OVERLAP_POLICIES
from repro.graphs import rmat_graph, road_like_graph
from repro.graphs.generators import weighted_copy

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)


def _mesh(shape, names):
    from repro.launch.mesh import make_mesh

    return make_mesh(shape, names)


def _check(graph, mesh_shape=(2, 4), replica=False, tol=1e-6, **kw):
    kw.setdefault("batch_size", 8)
    if replica:
        mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
        bc, _ = distributed_betweenness_centrality(
            graph, mesh, replica_axis="pod", weighted=True, **kw
        )
    else:
        mesh = _mesh(mesh_shape, ("data", "model"))
        bc, _ = distributed_betweenness_centrality(
            graph, mesh, weighted=True, **kw
        )
    expected = brandes_reference(graph)
    np.testing.assert_allclose(bc, expected, rtol=tol, atol=tol)
    return bc


def _graph(seed=7):
    return rmat_graph(5, 3, seed=seed, weights="dyadic")


# --------------------------------------------- full engine×overlap matrix


@pytest.mark.parametrize("overlap", OVERLAP_POLICIES)
@pytest.mark.parametrize("engine_kind", DIST_ENGINE_KINDS)
def test_weighted_matrix_2x4(engine_kind, overlap):
    _check(_graph(), (2, 4), engine_kind=engine_kind, overlap=overlap)


@pytest.mark.parametrize("engine_kind", DIST_ENGINE_KINDS)
def test_weighted_4x2(engine_kind):
    _check(_graph(seed=11), (4, 2), engine_kind=engine_kind)


@pytest.mark.parametrize("engine_kind", ["sparse", "pallas"])
def test_weighted_subcluster(engine_kind):
    _check(_graph(seed=5), replica=True, engine_kind=engine_kind,
           overlap="expand")


def test_weighted_road_like_explicit_delta():
    g = road_like_graph(4, 6, seed=2, weights="dyadic")
    _check(g, (2, 4), engine_kind="pallas_sparse", delta=0.5)


def test_weighted_heuristics_h1():
    _check(_graph(seed=3), (2, 4), engine_kind="sparse", heuristics="h1")


# ------------------------------------------------------ unit-weight exact


@pytest.mark.parametrize("engine_kind", ["sparse", "pallas"])
def test_unit_weights_match_unweighted_distributed(engine_kind):
    g = rmat_graph(5, 3, seed=3, weights="unit")
    mesh = _mesh((2, 4), ("data", "model"))
    bare = type(g)(n=g.n, src=g.src, dst=g.dst)
    bc_u, _ = distributed_betweenness_centrality(
        bare, mesh, engine_kind=engine_kind, batch_size=8
    )
    bc_w, _ = distributed_betweenness_centrality(
        g, mesh, engine_kind=engine_kind, weighted=True, delta=1.0,
        batch_size=8,
    )
    np.testing.assert_array_equal(np.asarray(bc_u), np.asarray(bc_w))


# ------------------------------------------------------- bucket tie cases


def test_bucket_boundary_ties_deterministic_across_dist_engines():
    from repro.graphs.graph import Graph

    edges = np.array([[0, 1], [1, 2], [2, 3], [0, 3], [1, 3], [2, 4], [4, 0]])
    w = np.array([0.5, 0.5, 0.5, 1.0, 1.0, 0.5, 1.0], np.float32)
    g = Graph.from_edges(5, edges, weights=w)
    results = [
        np.asarray(_check(g, (2, 4), engine_kind=ek, delta=0.5, batch_size=5))
        for ek in DIST_ENGINE_KINDS
    ]
    for other in results[1:]:
        np.testing.assert_array_equal(results[0], other)


def test_weighted_copy_grid_parity():
    from repro.graphs import grid_graph

    g = weighted_copy(grid_graph(5, 5), weights="dyadic", seed=1)
    _check(g, (2, 4), engine_kind="pallas_hybrid")


# ------------------------------------------------------------------ gates


def test_weighted_rejects_checksum_integrity():
    mesh = _mesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="checksum"):
        distributed_betweenness_centrality(
            _graph(), mesh, weighted=True, integrity="checksum", batch_size=8
        )


def test_weighted_rejects_autotune():
    mesh = _mesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="autotune"):
        distributed_betweenness_centrality(
            _graph(), mesh, weighted=True, autotune="measure", batch_size=8
        )


def test_weighted_needs_graph_weights():
    mesh = _mesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="edge weights"):
        distributed_betweenness_centrality(
            rmat_graph(5, 3, seed=0), mesh, weighted=True, batch_size=8
        )


def test_delta_requires_weighted_distributed():
    mesh = _mesh((2, 4), ("data", "model"))
    with pytest.raises(ValueError, match="weighted=True"):
        distributed_betweenness_centrality(
            _graph(), mesh, delta=0.5, batch_size=8
        )


def test_weighted_prior_levels_scales_with_bucket_count():
    w = np.full(10, 4.0, np.float32)
    wide = weighted_prior_levels(w, 0.25)   # mean/delta = 16x buckets
    tight = weighted_prior_levels(w, 4.0)   # one weight per bucket
    assert wide > tight
    assert tight >= 1
