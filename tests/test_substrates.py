"""Substrate tests: optimizers, checkpoint/resume, compression, fault
tolerance, data pipelines, neighbor sampler."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, Checkpointer
from repro.data.pipeline import Prefetcher
from repro.data.sampler import NeighborSampler, block_budget
from repro.data.tokens import TokenStream
from repro.distributed.compression import (
    compress_tree,
    decompress_tree,
    init_residual,
    quantize,
    dequantize,
)
from repro.distributed.fault_tolerance import (
    RoundLedger,
    StragglerPolicy,
    plan_elastic_remesh,
)
from repro.graphs import gnp_graph
from repro.optim import adafactor, adamw, sgd_momentum, cosine_with_warmup


# ------------------------------------------------------------- optimizers
@pytest.mark.parametrize("make", [adamw, adafactor, sgd_momentum])
def test_optimizer_descends_quadratic(make):
    opt = make(1e-1)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5]), "b": jnp.asarray(4.0)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_adafactor_memory_is_factored():
    opt = adafactor(1e-2)
    params = {"w": jnp.zeros((64, 32))}
    state = opt.init(params)
    assert state.vr["w"].shape == (64,)
    assert state.vc["w"].shape == (32,)


def test_schedule_warmup_cosine():
    s = cosine_with_warmup(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) < 1e-6


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.int32(7)}}
    ck.save(3, state, {"cursor": 42})
    restored, meta = ck.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(state["a"]))
    assert meta["cursor"] == 42


def test_checkpoint_detects_corruption(tmp_path):
    ck = Checkpointer(str(tmp_path))
    state = {"a": jnp.ones((4,))}
    ck.save(1, state)
    # corrupt the shard
    shard = os.path.join(ck.step_dir(1), "shard_p0.npz")
    np.savez(shard, a=np.zeros(4, np.float32))
    with pytest.raises(IOError):
        ck.restore(state)


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, save_every=1, async_writes=True)
    state = {"x": jnp.zeros((3,))}
    for step in range(5):
        state = {"x": state["x"] + 1}
        mgr.maybe_save(step, state, {"stream_step": step + 1})
    mgr.ckpt.close()
    assert mgr.ckpt.available_steps() == [3, 4]
    restored, meta, start = mgr.restore_or_init({"x": jnp.zeros((3,))})
    assert start == 5 and meta["stream_step"] == 5
    np.testing.assert_allclose(np.asarray(restored["x"]), 5.0)


def test_exact_resume_equivalence(tmp_path):
    """Training with a mid-run restore reproduces the uninterrupted run."""
    from repro.launch.train import reduced_lm, train_lm
    from repro.configs.registry import get_arch

    cfg = reduced_lm(get_arch("codeqwen1.5-7b").arch, 1, 64, 256)
    a = train_lm(cfg, steps=6, batch=2, seq=32, ckpt_dir=None)
    ck = str(tmp_path / "ck")
    train_lm(cfg, steps=3, batch=2, seq=32, ckpt_dir=ck, save_every=3)
    b = train_lm(cfg, steps=6, batch=2, seq=32, ckpt_dir=ck, save_every=3)
    np.testing.assert_allclose(a["final_loss"], b["final_loss"], rtol=1e-5)


# ------------------------------------------------------------ compression
def test_quantize_dequantize_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((130, 7)), jnp.float32)
    err = np.asarray(dequantize(quantize(x)) - x)
    # int8 with per-block max scaling: error < scale = max/127
    assert np.abs(err).max() <= float(jnp.abs(x).max()) / 127 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((256,)) * 1e-3, jnp.float32)}
    residual = init_residual(g)
    acc_plain = np.zeros(256)
    acc_ef = np.zeros(256)
    for _ in range(50):
        q, residual = compress_tree(g, residual)
        acc_ef += np.asarray(decompress_tree(q)["w"])
        acc_plain += np.asarray(dequantize(quantize(g["w"])))
    true = np.asarray(g["w"]) * 50
    assert np.abs(acc_ef - true).mean() <= np.abs(acc_plain - true).mean() + 1e-7


# -------------------------------------------------------- fault tolerance
def test_elastic_remesh_drops_pod_first():
    plan = plan_elastic_remesh((2, 16, 16), ("pod", "data", "model"), 256)
    assert plan.shape == (1, 16, 16)
    assert not plan.reload_from_checkpoint  # replicas hold full state


def test_elastic_remesh_halves_data_axis():
    plan = plan_elastic_remesh((16, 16), ("data", "model"), 128)
    assert plan.shape == (8, 16)
    assert plan.reload_from_checkpoint and plan.reshard_params


def test_round_ledger_exactly_once():
    led = RoundLedger()
    assert led.try_commit(0) and not led.try_commit(0)
    assert led.pending(3) == [1, 2]
    led2 = RoundLedger.from_state(led.state())
    assert not led2.try_commit(0) and led2.try_commit(1)


def test_straggler_policy():
    pol = StragglerPolicy(factor=2.0, min_samples=3)
    for t in (1.0, 1.1, 0.9, 1.0):
        pol.observe(t)
    assert pol.should_speculate(5.0)
    assert not pol.should_speculate(1.5)


# --------------------------------------------------------------- pipeline
def test_token_stream_deterministic_resume():
    s = TokenStream(vocab=100, batch=2, seq_len=8, seed=3)
    direct = s.batch_at(7)
    again = TokenStream(vocab=100, batch=2, seq_len=8, seed=3).batch_at(7)
    np.testing.assert_array_equal(direct, again)


def test_prefetcher_orders_and_closes():
    pf = Prefetcher(lambda step: step * 10, depth=2)
    got = [pf.get() for _ in range(4)]
    pf.close()
    assert got == [(0, 0), (1, 10), (2, 20), (3, 30)]


def test_neighbor_sampler_budget_and_validity():
    g = gnp_graph(60, 0.1, seed=2)
    fanout = (5, 3)
    sampler = NeighborSampler(g, fanout, seed=0)
    targets = np.arange(8)
    block = sampler.sample(targets)
    n_nodes, n_edges = block_budget(8, fanout)
    assert len(block.node_ids) == n_nodes
    assert len(block.edge_src) == n_edges
    # all local indices in range, all sampled edges are real or self-loops
    assert block.edge_src.max() < n_nodes and block.edge_dst.max() < n_nodes
    adj = {(int(u), int(v)) for u, v in zip(g.src, g.dst)}
    gids = block.node_ids
    for s_, d_ in zip(block.edge_src, block.edge_dst):
        u, v = int(gids[s_]), int(gids[d_])
        assert (u, v) in adj or u == v
