"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

import jax.numpy as jnp

from repro.core import betweenness_centrality, brandes_reference
from repro.core.brandes_ref import single_source_dependencies
from repro.core.scheduler import build_schedule
from repro.graphs import Graph, cycle_graph, gnp_graph, path_graph, star_graph
from repro.graphs.partition import partition_2d

SETTINGS = dict(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def random_graph(draw, max_n=18):
    n = draw(st.integers(4, max_n))
    p = draw(st.floats(0.05, 0.5))
    seed = draw(st.integers(0, 10_000))
    return gnp_graph(n, p, seed=seed)


# ------------------------------------------------------------ BC invariants
@given(random_graph(), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_bc_invariant_under_relabeling(graph, perm_seed):
    """BC(π(v)) on the relabeled graph equals BC(v)."""
    rng = np.random.default_rng(perm_seed)
    perm = rng.permutation(graph.n)
    edges = np.stack([perm[graph.src], perm[graph.dst]], axis=1)
    relabeled = Graph.from_edges(graph.n, edges)
    bc = betweenness_centrality(graph, heuristics="h0").bc
    bc_rel = betweenness_centrality(relabeled, heuristics="h0").bc
    np.testing.assert_allclose(bc_rel[perm], bc, rtol=1e-5, atol=1e-5)


@given(random_graph())
@settings(**SETTINGS)
def test_heuristics_exactness(graph):
    """All heuristic modes compute the exact same scores."""
    base = betweenness_centrality(graph, heuristics="h0").bc
    for h in ("h1", "h2", "h3"):
        got = betweenness_centrality(graph, heuristics=h).bc
        np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


@given(random_graph())
@settings(**SETTINGS)
def test_bc_sum_rule(graph):
    """Σ_v BC(v) = Σ_{ordered connected pairs s≠t} (d(s,t) - 1), because
    Σ_v σ_st(v)/σ_st = (interior vertices of any shortest path) = d-1."""
    bc = betweenness_centrality(graph, heuristics="h0").bc
    adj = graph.adjacency_lists()
    total = 0.0
    for s in range(graph.n):
        _, _, depth = single_source_dependencies(adj, graph.n, s)
        d = depth[(depth > 0)]
        total += float((d - 1).sum())
    np.testing.assert_allclose(bc.sum(), total, rtol=1e-6, atol=1e-6)


@given(st.integers(3, 40))
@settings(**SETTINGS)
def test_path_graph_closed_form(n):
    bc = betweenness_centrality(path_graph(n), heuristics="h3").bc
    expected = np.array([2.0 * i * (n - 1 - i) for i in range(n)])
    np.testing.assert_allclose(bc, expected, rtol=1e-5, atol=1e-5)


@given(st.integers(3, 40))
@settings(**SETTINGS)
def test_cycle_graph_closed_form(n):
    """Cycle C_n: all vertices equivalent; BC = 2·(pairs routed through a
    vertex).  Cross-check against the oracle (closed form differs for
    odd/even n)."""
    bc = betweenness_centrality(cycle_graph(n), heuristics="h2").bc
    expected = brandes_reference(cycle_graph(n))
    np.testing.assert_allclose(bc, expected, rtol=1e-5, atol=1e-5)
    assert np.allclose(bc, bc[0])  # vertex-transitive


@given(st.integers(2, 30))
@settings(**SETTINGS)
def test_star_graph_closed_form(k):
    bc = betweenness_centrality(star_graph(k), heuristics="h1").bc
    np.testing.assert_allclose(bc[0], k * (k - 1), rtol=1e-6)
    np.testing.assert_allclose(bc[1:], 0.0, atol=1e-9)


# -------------------------------------------------- traversal invariants
@given(random_graph(), st.integers(1, 8))
@settings(**SETTINGS)
def test_sigma_conservation(graph, num_sources):
    """σ-flow conservation: for every non-root reached vertex v,
    σ_v = Σ σ_u over neighbors u one level above — path counts are
    created only at the root and otherwise sum along BFS layers."""
    from repro.core import engine

    adjacency = jnp.asarray(graph.dense_adjacency(np.float32))
    k = min(num_sources, graph.n)
    src = jnp.eye(graph.n, dtype=jnp.float32)[:, :k]
    fwd = engine.forward_counting(engine.make_dense_operator(adjacency), src)
    sigma = np.asarray(fwd.sigma)
    depth = np.asarray(fwd.depth)
    adj = np.asarray(adjacency) > 0
    for s in range(k):
        for v in range(graph.n):
            if depth[v, s] >= 1:
                preds = adj[v] & (depth[:, s] == depth[v, s] - 1)
                np.testing.assert_allclose(
                    sigma[v, s], sigma[preds, s].sum(), rtol=1e-5
                )


@given(random_graph(), st.integers(1, 8))
@settings(**SETTINGS)
def test_checksum_lane_invariant(graph, num_sources):
    """The ABFT ones-lane invariant: healthy traversals keep the relative
    column-sum residual at float-noise level through both sweeps, and a
    corrupted SpMM output pushes it past the driver's detection
    threshold — the property the integrity='checksum' mode audits."""
    from repro.core import engine
    from repro.core.driver import CHECKSUM_TOL
    from repro.core.operators import DenseOperator

    adjacency = jnp.asarray(graph.dense_adjacency(np.float32))
    k = min(num_sources, graph.n)
    src = jnp.eye(graph.n, dtype=jnp.float32)[:, :k]
    omega = jnp.zeros(graph.n, jnp.float32)

    op = engine.make_dense_operator(adjacency)
    fwd = engine.forward_counting(op, src, checksum=True)
    assert fwd.check_err is not None and float(fwd.check_err) < CHECKSUM_TOL
    _, bwd_err = engine.backward_accumulation(
        op, fwd.sigma, fwd.depth, omega, fwd.max_depth, checksum=True
    )
    assert float(bwd_err) < CHECKSUM_TOL

    class CorruptOperator(DenseOperator):
        # a silent single-entry hit on every SpMM product, additive so
        # the checksum lane (computed from the same product) cannot
        # track it
        def apply(self, x):
            return super().apply(x).at[0, 0].add(64.0)

    bad = engine.forward_counting(
        CorruptOperator(adjacency), src, checksum=True
    )
    assert float(bad.check_err) > CHECKSUM_TOL


# ----------------------------------------------------------- scheduler/graph
@given(random_graph(), st.integers(1, 16), st.sampled_from(["h0", "h1", "h2", "h3"]))
@settings(**SETTINGS)
def test_schedule_covers_each_source_once(graph, batch_size, heuristics):
    schedule, prep, residual, omega = build_schedule(
        graph, batch_size=batch_size, heuristics=heuristics
    )
    seen: list[int] = []
    for rnd in schedule.rounds:
        seen += [int(v) for v in rnd.sources if v >= 0]
        seen += [int(c) for c in rnd.derived[:, 0] if c >= 0]
        # derived positions must reference in-round explicit sources
        for c, ap, bp in rnd.derived:
            if c >= 0:
                assert rnd.sources[ap] >= 0 and rnd.sources[bp] >= 0
    assert len(seen) == len(set(seen))  # nobody runs twice
    res_deg = residual.degrees()
    eligible = set(np.nonzero(res_deg >= 1)[0].tolist())
    analytic = {int(v) for v, _ in schedule.analytic_corrections}
    assert set(seen) == eligible
    assert analytic.isdisjoint(seen)


@given(random_graph(), st.integers(1, 4), st.integers(1, 4))
@settings(**SETTINGS)
def test_partition_2d_preserves_arcs(graph, R, C):
    part = partition_2d(graph, R, C)
    chunk = part.chunk
    rebuilt = []
    for i in range(R):
        for j in range(C):
            cnt = int(part.arc_counts[i, j])
            src_l = part.src_local[i, j, :cnt]
            dst_l = part.dst_local[i, j, :cnt]
            src_g = src_l + j * R * chunk
            blk = dst_l // chunk
            dst_g = (blk * R + i) * chunk + dst_l % chunk
            rebuilt.append(np.stack([src_g, dst_g], axis=1))
    rebuilt = np.concatenate(rebuilt) if rebuilt else np.zeros((0, 2), np.int64)
    want = np.stack([graph.src, graph.dst], axis=1)
    got = rebuilt[np.lexsort((rebuilt[:, 1], rebuilt[:, 0]))]
    want = want[np.lexsort((want[:, 1], want[:, 0]))]
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------------ kernels
@given(
    st.integers(1, 80),
    st.integers(1, 40),
    st.integers(1, 12),
    st.integers(1, 6),
    st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_segment_bag_property(v, b, l, d_div8, seed):
    from repro.kernels import ops, ref

    d = 8 * d_div8
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, v, (b, l)), jnp.int32)
    got = ops.segment_bag(table, idx, interpret=True)
    want = ref.segment_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(st.integers(1, 400), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_quantization_error_bound(n, seed):
    from repro.distributed.compression import dequantize, quantize

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(n) * rng.uniform(0.01, 100), jnp.float32)
    back = dequantize(quantize(x))
    bound = float(jnp.abs(x).max()) / 127 + 1e-6
    assert float(jnp.abs(back - x).max()) <= bound


# ------------------------------------------------------- elastic re-mesh
@given(
    st.integers(1, 4),
    st.sampled_from([1, 2, 4, 8]),
    st.integers(1, 4),
    st.data(),
)
@settings(**SETTINGS)
def test_remesh_plan_fits_and_preserves_model_axis(pods, data, model, payload):
    """plan_elastic_remesh invariants: the planned shape fits in the
    surviving devices, the model axis (weight layout) is never touched,
    and the note/reload flags match the branch taken (pod drop keeps
    replica-local state, data halving reshards from checkpoint)."""
    from repro.distributed.fault_tolerance import plan_elastic_remesh

    axes = ("pod", "data", "model")
    shape = (pods, data, model)
    n = pods * data * model
    if n < 2:
        return
    lost = payload.draw(st.integers(1, n - 1))
    try:
        plan = plan_elastic_remesh(shape, axes, lost)
    except ValueError:
        return  # an unshrinkable mesh (odd data axis) may refuse
    prod = 1
    for s in plan.shape:
        prod *= s
    assert prod <= n - lost  # fits in what's left
    assert plan.axes == axes
    assert plan.shape[2] == model  # model axis untouched
    if plan.shape[0] != pods:  # pod drop: replicas hold full state
        assert "pods" in plan.note
        assert not plan.reload_from_checkpoint and not plan.reshard_params
    else:  # data halving: reload + reshard required
        assert plan.shape[1] < data
        assert "data axis halved" in plan.note
        assert plan.reload_from_checkpoint and plan.reshard_params
