"""Snapshot store + serving front end: atomicity, accounting, refresh.

* the store's single-reference swap is atomic under a racing reader —
  a grabbed snapshot is internally consistent forever and generations
  only move forward;
* every query is exactly one of hit / stale_hit / miss
  (``queries == hits + stale_hits + misses`` is an invariant);
* republishing from a growing checkpoint can only improve the served
  top-k (rank error vs exact is non-increasing across generations on a
  seeded rmat graph, ending exact);
* a killed background refresher's replacement republishes the last
  *committed* generation at startup and finishes the remaining rounds
  instead of recomputing (kill-and-resume through BCCheckpoint).
"""
import os
import threading

import numpy as np
import pytest

from repro.core import betweenness_centrality, brandes_reference
from repro.distributed.fault_tolerance import BCCheckpoint
from repro.graphs import gnp_graph, rmat_graph
from repro.launch.serve_bc import run_serving
from repro.serving import BCSnapshotStore, BlockBudgetStop
from repro.serving.sampling import eligible_roots, rank_stability, top_k_indices


# ------------------------------------------------------------ the store
def test_query_accounting_is_exhaustive():
    store = BCSnapshotStore()
    assert store.top_k(3) is None  # cold: miss
    assert store.score(0) is None  # also a miss
    gen = store.publish(np.array([1.0, 3.0, 2.0]), {"tag": "a"})
    assert gen == 1 and store.generation == 1
    snap, top = store.top_k(2)
    assert snap.generation == 1 and [v for v, _ in top] == [1, 2]
    snap, val = store.score(1)
    assert val == 3.0
    store.begin_refresh()
    assert store.refreshing
    store.top_k(1)  # served, but stale
    store.end_refresh()
    store.top_k(1)
    st = store.stats
    assert st == {
        "queries": 6, "hits": 3, "misses": 2, "stale_hits": 1, "publishes": 1,
    }
    assert st["queries"] == st["hits"] + st["stale_hits"] + st["misses"]


def test_snapshots_are_isolated_from_caller_mutation():
    store = BCSnapshotStore()
    bc = np.array([1.0, 2.0])
    store.publish(bc)
    bc[0] = 99.0  # caller keeps mutating its buffer
    assert store.snapshot().bc[0] == 1.0


def test_atomic_swap_under_racing_reader():
    """Writer publishes bc ≡ generation; a racing reader must always see
    a self-consistent snapshot (all entries equal, and equal to the
    snapshot's generation number) and a non-decreasing generation."""
    store = BCSnapshotStore()
    n, gens = 512, 300
    stop = threading.Event()
    bad: list[str] = []

    def reader():
        last = 0
        while not stop.is_set():
            res = store.top_k(4)
            if res is None:
                continue
            snap, top = res
            vals = {score for _, score in top}
            if len(vals) != 1 or vals != {float(snap.generation)}:
                bad.append(f"torn snapshot: gen={snap.generation} {vals}")
            if snap.generation < last:
                bad.append(f"generation regressed {last}->{snap.generation}")
            last = snap.generation

    threads = [threading.Thread(target=reader) for _ in range(3)]
    for t in threads:
        t.start()
    for g in range(gens):
        store.publish(np.full(n, float(g + 1)))
    stop.set()
    for t in threads:
        t.join()
    assert not bad, bad[:5]
    assert store.generation == gens
    st = store.stats
    assert st["queries"] == st["hits"] + st["stale_hits"] + st["misses"]


def test_publish_from_checkpoint_rescales_raw_accumulator(tmp_path):
    """The checkpoint stores the raw accumulator; the store recomputes
    the N/k rescale from the committed per-root ledger at publish."""
    ckpt = BCCheckpoint(os.path.join(tmp_path, "c.npz"))
    assert BCSnapshotStore().publish_from_checkpoint(ckpt) is None  # cold
    raw = np.array([2.0, 0.5, 1.0])
    ckpt.save(raw, {3: 4.0, 7: 2.0}, [0, 1], "fp")
    store = BCSnapshotStore()
    gen = store.publish_from_checkpoint(ckpt, num_eligible=8)
    assert gen == 1
    snap = store.snapshot()
    np.testing.assert_allclose(snap.bc, raw * 4.0)  # N/k = 8/2
    assert snap.meta["roots_accumulated"] == 2
    assert snap.meta["scale"] == 4.0
    assert snap.meta["committed_rounds"] == 2
    # without num_eligible the raw accumulator is served unscaled
    store2 = BCSnapshotStore()
    store2.publish_from_checkpoint(ckpt)
    np.testing.assert_allclose(store2.snapshot().bc, raw)


# ------------------------------------------------- refresh generations
def test_generation_rank_error_non_increasing(tmp_path):
    """Each refresh slice extends the committed prefix, so the served
    top-10's rank error vs exact can only shrink — and the last
    generation (full schedule) is exact."""
    g = rmat_graph(7, 8, seed=1)
    exact = brandes_reference(g)
    ckpt = BCCheckpoint(os.path.join(tmp_path, "g.npz"))
    store = BCSnapshotStore()
    n_elig = eligible_roots(g).size
    jaccards = []
    for _ in range(40):
        res = betweenness_centrality(
            g, batch_size=8, heuristics="h0", engine_kind="sparse",
            checkpoint=ckpt, sampling="fixed", sample_frac=1.0,
            stop_rule=BlockBudgetStop(2),
        )
        store.publish_from_checkpoint(ckpt, num_eligible=n_elig)
        jaccards.append(rank_stability(exact, store.snapshot().bc, k=10))
        if not res.stopped_early:
            break
    assert len(jaccards) > 2  # really was refined across generations
    assert store.generation == len(jaccards)
    assert all(b >= a for a, b in zip(jaccards, jaccards[1:])), jaccards
    assert jaccards[-1] == 1.0
    np.testing.assert_allclose(store.snapshot().bc, exact,
                               rtol=1e-5, atol=1e-4)


# ------------------------------------------------ the serving front end
def test_run_serving_single_device(tmp_path):
    g = gnp_graph(40, 0.15, seed=2)
    out = run_serving(
        g, None, ckpt_path=os.path.join(tmp_path, "s.npz"),
        batch_size=4, sampling="fixed", sample_frac=1.0,
        refresh_blocks=2, generations=4, queries=6, top_k=5,
    )
    st = out["stats"]
    assert st["queries"] == st["hits"] + st["stale_hits"] + st["misses"]
    assert st["misses"] >= 1 and st["hits"] >= 1
    gens = [h["generation"] for h in out["history"]]
    assert gens == sorted(gens) and out["generations_published"] >= 2
    assert not out["refresh_runs"][-1]["stopped_early"]  # last slice final
    exact = brandes_reference(g)
    np.testing.assert_allclose(out["final_bc"], exact, rtol=1e-5, atol=1e-4)
    assert out["final_top_k"] == [int(v) for v in top_k_indices(exact, 5)]


def test_run_serving_rejects_unsampled():
    g = gnp_graph(12, 0.3, seed=0)
    with pytest.raises(ValueError):
        run_serving(g, None, ckpt_path="/tmp/unused.npz", sampling="off")


def test_killed_refresher_resumes_from_committed_generation(tmp_path):
    """A refresher killed mid-sample leaves a committed checkpoint; its
    replacement serves that generation immediately (no cold miss) and
    runs only the remaining rounds."""
    g = gnp_graph(40, 0.15, seed=2)
    ckpt_path = os.path.join(tmp_path, "s.npz")
    kw = dict(batch_size=4, heuristics="h0", engine_kind="sparse",
              sampling="fixed", sample_frac=1.0)
    # the "killed" refresher: two committed blocks, then gone
    partial = betweenness_centrality(
        g, checkpoint=BCCheckpoint(ckpt_path),
        stop_rule=BlockBudgetStop(2), **kw,
    )
    assert partial.stopped_early
    out = run_serving(
        g, None, ckpt_path=ckpt_path, batch_size=4,
        sampling="fixed", sample_frac=1.0,
        refresh_blocks=2, generations=3, queries=4, top_k=5,
    )
    st = out["stats"]
    assert st["misses"] == 0  # startup republish served the cold query
    assert any(h["meta"].get("resumed") for h in out["history"][:1])
    total_rounds = -(-eligible_roots(g).size // 4)
    resumed_rounds = sum(r["rounds_run"] for r in out["refresh_runs"])
    assert resumed_rounds == total_rounds - partial.rounds_run  # no recompute
    np.testing.assert_allclose(out["final_bc"], brandes_reference(g),
                               rtol=1e-5, atol=1e-4)
