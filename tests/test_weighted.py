"""Weighted (bucketed-traversal) BC == Dijkstra oracle, single device.

Covers the weighted operator family end-to-end through the public
``betweenness_centrality(weighted=True)`` seam: hand-checked graphs,
random dyadic-weighted parity across every engine × weight-sound
heuristic, exact unit-weight reduction to the unweighted engine, the
weight/delta validation gates, and the bucket edge cases (boundary
ties, zero-weight rejection, delta auto-derivation determinism).
"""
import logging

import numpy as np
import pytest

from repro.core.bc import ENGINE_KINDS, WEIGHTED_HEURISTICS, betweenness_centrality
from repro.core.brandes_ref import brandes_reference
from repro.core.operators import (
    WeightedDenseOperator,
    WeightedSparseOperator,
    auto_delta,
)
from repro.core.scheduler import validate_batch_size
from repro.graphs.generators import (
    WEIGHT_MODES,
    rmat_graph,
    road_like_graph,
    sample_weights,
    weighted_copy,
)
from repro.graphs.graph import Graph


def _weighted_path():
    # 0 -1.0- 1 -2.0- 2: all pairs route through 1 -> BC = [0, 2, 0]
    return Graph.from_edges(
        3, np.array([[0, 1], [1, 2]]), weights=np.array([1.0, 2.0], np.float32)
    )


def _weighted_square():
    # unit square + a 0-2 shortcut of weight 2 that TIES the two
    # two-hop routes: sigma(0,2)=3, so the tie-splitting is exercised.
    # Hand-derived: BC = [1, 2/3, 1, 2/3].
    edges = np.array([[0, 1], [1, 2], [2, 3], [3, 0], [0, 2]])
    w = np.array([1.0, 1.0, 1.0, 1.0, 2.0], np.float32)
    return Graph.from_edges(4, edges, weights=w)


# ------------------------------------------------------------ hand-checked


def test_weighted_path_hand_checked():
    g = _weighted_path()
    got = betweenness_centrality(g, weighted=True, batch_size=3)
    np.testing.assert_allclose(got.bc, [0.0, 2.0, 0.0], atol=1e-6)


def test_weighted_square_tie_splitting():
    g = _weighted_square()
    got = betweenness_centrality(g, weighted=True, batch_size=4)
    np.testing.assert_allclose(
        got.bc, [1.0, 2.0 / 3.0, 1.0, 2.0 / 3.0], rtol=1e-6
    )
    np.testing.assert_allclose(got.bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


# ------------------------------------------------- oracle parity, engines


@pytest.mark.parametrize("engine_kind", ENGINE_KINDS)
@pytest.mark.parametrize("heuristics", WEIGHTED_HEURISTICS)
def test_weighted_parity_engines_heuristics(engine_kind, heuristics):
    g = rmat_graph(5, 3, seed=7, weights="dyadic")
    got = betweenness_centrality(
        g, engine_kind=engine_kind, heuristics=heuristics, weighted=True,
        batch_size=8,
    )
    np.testing.assert_allclose(got.bc, brandes_reference(g), rtol=1e-5, atol=1e-5)


def test_weighted_road_like_parity():
    g = road_like_graph(4, 5, seed=3, weights="dyadic")
    got = betweenness_centrality(g, weighted=True, heuristics="h1", batch_size=8)
    np.testing.assert_allclose(got.bc, brandes_reference(g), rtol=1e-5, atol=1e-5)


def test_weighted_explicit_delta_parity():
    g = rmat_graph(5, 3, seed=9, weights="dyadic")
    ref = brandes_reference(g)
    # delta below the min weight (every bucket a single settled front),
    # at the dyadic quantum, and above the max weight (one giant bucket,
    # pure within-bucket fixpoint) must all agree
    for delta in (0.125, 0.25, 1.0, 8.0):
        got = betweenness_centrality(g, weighted=True, delta=delta, batch_size=8)
        np.testing.assert_allclose(got.bc, ref, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ unit-weight exact


@pytest.mark.parametrize("engine_kind", ENGINE_KINDS)
def test_unit_weights_reproduce_unweighted_exactly(engine_kind):
    g = rmat_graph(5, 3, seed=3, weights="unit")
    unweighted = betweenness_centrality(
        Graph(n=g.n, src=g.src, dst=g.dst), engine_kind=engine_kind, batch_size=8
    )
    weighted = betweenness_centrality(
        g, engine_kind=engine_kind, weighted=True, delta=1.0, batch_size=8
    )
    # bitwise, not approximate: at delta=1 the bucket loop visits the
    # same frontiers and the dense sigma/delta contractions are the
    # same dot_generals the level-synchronous engine runs
    np.testing.assert_array_equal(
        np.asarray(unweighted.bc), np.asarray(weighted.bc)
    )


# ------------------------------------------------------------------ gates


def test_weighted_needs_weights():
    g = rmat_graph(4, 2, seed=0)
    with pytest.raises(ValueError, match="edge weights"):
        betweenness_centrality(g, weighted=True, batch_size=4)


def test_delta_needs_weighted():
    g = rmat_graph(4, 2, seed=0, weights="dyadic")
    with pytest.raises(ValueError, match="weighted=True"):
        betweenness_centrality(g, delta=0.5, batch_size=4)


@pytest.mark.parametrize("heuristics", ["h2", "h3", "h3t"])
def test_weighted_rejects_level_based_heuristics(heuristics):
    g = rmat_graph(4, 2, seed=0, weights="dyadic")
    with pytest.raises(ValueError, match="unit edge lengths"):
        betweenness_centrality(g, weighted=True, heuristics=heuristics, batch_size=4)


def test_weighted_rejects_num_levels():
    g = rmat_graph(4, 2, seed=0, weights="dyadic")
    with pytest.raises(ValueError, match="data-dependent"):
        betweenness_centrality(g, weighted=True, num_levels=4, batch_size=4)


def test_weighted_rejects_bad_delta():
    g = rmat_graph(4, 2, seed=0, weights="dyadic")
    for bad in (0.0, -1.0, float("inf"), float("nan")):
        with pytest.raises(ValueError, match="delta"):
            betweenness_centrality(g, weighted=True, delta=bad, batch_size=4)


# ------------------------------------------------------- weight edge cases


def test_zero_weight_edges_rejected():
    with pytest.raises(ValueError, match="strictly positive"):
        Graph.from_edges(
            3, np.array([[0, 1], [1, 2]]), weights=np.array([1.0, 0.0])
        )


def test_negative_and_nonfinite_weights_rejected():
    edges = np.array([[0, 1]])
    for bad in (-0.5, float("inf"), float("nan")):
        with pytest.raises(ValueError, match="strictly positive"):
            Graph.from_edges(2, edges, weights=np.array([bad]))


def test_weight_modes_constant():
    assert WEIGHT_MODES == ("none", "unit", "dyadic")
    rng = np.random.default_rng(0)
    w = sample_weights(rng, 1000, "dyadic")
    assert w.dtype == np.float32
    # dyadic = k/4 for k in 1..16: exactly representable, never zero
    np.testing.assert_array_equal(w, np.round(w * 4) / 4)
    assert w.min() >= 0.25 and w.max() <= 4.0
    np.testing.assert_array_equal(sample_weights(rng, 10, "unit"), 1.0)
    with pytest.raises(ValueError, match="weight"):
        sample_weights(rng, 4, "bogus")


def test_bucket_boundary_ties_deterministic_across_engines():
    # weights sitting exactly ON the light/heavy boundary (w == delta)
    # and exactly at a bucket edge (dist lands on k*delta): every engine
    # must classify them identically and agree with the oracle
    edges = np.array([[0, 1], [1, 2], [2, 3], [0, 3], [1, 3]])
    w = np.array([0.5, 0.5, 0.5, 1.0, 1.0], np.float32)
    g = Graph.from_edges(4, edges, weights=w)
    ref = brandes_reference(g)
    results = []
    for ek in ENGINE_KINDS:
        got = betweenness_centrality(
            g, engine_kind=ek, weighted=True, delta=0.5, batch_size=4
        )
        np.testing.assert_allclose(got.bc, ref, rtol=1e-6, atol=1e-6)
        results.append(np.asarray(got.bc))
    for other in results[1:]:
        np.testing.assert_array_equal(results[0], other)


def test_auto_delta_deterministic_and_positive():
    g1 = rmat_graph(5, 3, seed=42, weights="dyadic")
    g2 = rmat_graph(5, 3, seed=42, weights="dyadic")
    d1, d2 = auto_delta(g1), auto_delta(g2)
    assert d1 == d2  # same seed -> bit-identical derivation
    assert d1 > 0 and np.isfinite(d1)
    assert d1 >= float(g1.w.min())  # never below the min weight
    with pytest.raises(ValueError, match="weight"):
        auto_delta(Graph(n=2, src=np.array([0, 1]), dst=np.array([1, 0])))


def test_weighted_copy_deterministic():
    g = rmat_graph(5, 3, seed=1)
    a = weighted_copy(g, weights="dyadic", seed=5)
    b = weighted_copy(g, weights="dyadic", seed=5)
    np.testing.assert_array_equal(a.w, b.w)
    assert a.w is not None and a.w.min() > 0
    np.testing.assert_array_equal(a.src, g.src)
    np.testing.assert_array_equal(a.dst, g.dst)


# -------------------------------------------------- operator-level checks


def test_weighted_operator_rejects_bad_delta():
    w = np.ones((3, 3), np.float32)
    for bad in (0.0, -2.0, float("inf")):
        with pytest.raises(ValueError, match="delta"):
            WeightedDenseOperator(np.asarray(w), bad)
    with pytest.raises(ValueError, match="delta"):
        WeightedSparseOperator(
            np.array([0]), np.array([1]), np.array([1.0], np.float32), 2, 0.0
        )


# ------------------------------------------- batch-size hint suppression


def test_mxu_hint_fires_without_population(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.core.scheduler"):
        validate_batch_size(48)
    assert any("wasted MXU" in r.message for r in caplog.records)


def test_mxu_hint_suppressed_when_population_binds(caplog):
    # sampled run with sample_k=32 < batch_size=48: no wider batch could
    # ever fill, so the hint would nag about an unfixable number
    with caplog.at_level(logging.WARNING, logger="repro.core.scheduler"):
        validate_batch_size(48, population=32)
    assert not any("wasted MXU" in r.message for r in caplog.records)


def test_mxu_hint_kept_when_population_is_wide(caplog):
    with caplog.at_level(logging.WARNING, logger="repro.core.scheduler"):
        validate_batch_size(48, population=500)
    assert any("wasted MXU" in r.message for r in caplog.records)


def test_sampled_run_with_small_k_no_hint(caplog):
    # end-to-end: the binding constraint is the sampled root pool
    g = rmat_graph(5, 3, seed=2)
    with caplog.at_level(logging.WARNING, logger="repro.core.scheduler"):
        betweenness_centrality(
            g, batch_size=48, sampling="fixed", sample_k=16, sample_seed=0
        )
    assert not any("wasted MXU" in r.message for r in caplog.records)
