"""Hybrid dense/sparse per-cell engine + the shared counting pass.

Four layers:

* counting pass — `blocked_sparse_counts`, the layout builds and the
  hybrid cell choice all consume ONE cached arc→tile unique pass per
  tile shape (a call-count spy on the `_arc_tile_unique` seam pins the
  no-duplicate-pass property), and the no-materialize accounting equals
  the shipped layouts byte-for-byte in both the full and ring forms;
* layout — `blocked_sparse(ring=True)` no longer materializes the full
  tile array, and `blocked_hybrid` writes dense data only into the
  dense-chosen cells' block slots while the sparse side stores tiles
  only for the sparse-chosen cells;
* choice — `cell_kernel_choice` resolves mixed on a skewed mesh and
  degenerates to all-dense / all-sparse at the threshold extremes;
* engine — `engine_kind="pallas_hybrid"` matches `brandes_reference`
  within the repo's 1e-6 tolerance on 2x4 and 4x2 meshes for every
  overlap policy on a mixed mesh, at both threshold edge cases, on a
  skewed RMAT graph with at least one dense and one sparse cell, and on
  sub-cluster meshes with divergent round depths.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.graphs.partition as partition_mod
from repro.core import brandes_reference
from repro.core.distributed import (
    distributed_betweenness_centrality,
    distributed_graph_arrays,
    estimate_device_footprint,
    hybrid_cell_choice,
    level_time_estimates,
    resolve_overlap,
)
from repro.graphs import disjoint_union, gnp_graph, path_graph, rmat_graph
from repro.graphs.partition import partition_2d
from repro.kernels.blocked_spmm import tiles_to_dense
from repro.roofline.model import cell_kernel_choice

needs_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)


def _skewed_graph():
    """A dense community ⊕ a sparse path: at tile (2, 2) half the mesh
    cells cross the bytes-streamed break-even and resolve dense while
    the path cells stay BCSR — on both the 2x4 and 4x2 grids."""
    return disjoint_union(gnp_graph(32, 1.0, seed=0), path_graph(32))


# ------------------------------------------------------ counting pass
def test_counting_pass_runs_exactly_once(monkeypatch):
    """counts → choice → full layout → ring layout → hybrid layout is
    ONE arc→tile unique pass per cell, not one per consumer."""
    calls = {"n": 0}
    orig = partition_mod._arc_tile_unique

    def spy(*args, **kwargs):
        calls["n"] += 1
        return orig(*args, **kwargs)

    monkeypatch.setattr(partition_mod, "_arc_tile_unique", spy)
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    counts = part.blocked_sparse_counts(2, 2)
    dense_cells, _ = hybrid_cell_choice(part, 2, 2, tile_counts=counts)
    part.blocked_sparse(2, 2)
    part.blocked_sparse(2, 2, ring=True)
    part.blocked_hybrid(2, 2, dense_cells=dense_cells, ring=True)
    part.blocked_sparse_counts(2, 2, cells=~dense_cells)  # guard's masked view
    assert calls["n"] == part.R * part.C


@pytest.mark.parametrize("ring", [False, True])
def test_counts_equal_layout_with_and_without_mask(ring):
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    key = "ring" if ring else "full"
    counts = part.blocked_sparse_counts(2, 2)
    lay = part.blocked_sparse(2, 2, ring=ring)
    assert counts[f"bytes_{key}"] == lay.adjacency_bytes()
    assert counts["nnz_total"] == int(lay.nnz_tiles.sum())
    mask = np.zeros((2, 4), bool)
    mask[0, 0] = mask[1, 2] = True
    counts_m = part.blocked_sparse_counts(2, 2, cells=mask)
    lay_m = part.blocked_sparse(2, 2, ring=ring, cells=mask)
    assert counts_m[f"bytes_{key}"] == lay_m.adjacency_bytes()
    assert counts_m["nnz_total"] == int(lay_m.nnz_tiles.sum())
    assert int(lay_m.nnz_tiles[~mask].sum()) == 0


def test_ring_layout_materializes_only_ring():
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    full = part.blocked_sparse(2, 2)
    ring = part.blocked_sparse(2, 2, ring=True)
    assert full.ring_tiles is None and full.tiles is not None
    assert ring.tiles is None and ring.ring_tiles is not None


# ------------------------------------------------------------- choice
def test_cell_kernel_choice_thresholds():
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    counts = part.blocked_sparse_counts(2, 2)
    mixed = cell_kernel_choice(
        counts["stored_full_cell"], R=2, C=4, chunk=part.chunk, bm=2, bk=2
    )
    assert 0 < int(mixed.sum()) < mixed.size  # skewed mesh → genuine mix
    all_dense = cell_kernel_choice(
        counts["stored_full_cell"], R=2, C=4, chunk=part.chunk, bm=2, bk=2,
        threshold=0.0,
    )
    assert all_dense.all()
    all_sparse = cell_kernel_choice(
        counts["stored_full_cell"], R=2, C=4, chunk=part.chunk, bm=2, bk=2,
        threshold=1e9,
    )
    assert not all_sparse.any()
    with pytest.raises(ValueError):
        cell_kernel_choice(np.zeros((3, 3)), R=2, C=4, chunk=part.chunk, bm=2, bk=2)


def test_hybrid_layout_per_cell_materialization():
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    dense_cells, _ = hybrid_cell_choice(part, 2, 2)
    hyb = part.blocked_hybrid(2, 2, dense_cells=dense_cells)
    dense = part.dense_blocks()
    m, kdim = part.C * part.chunk, part.R * part.chunk
    for i in range(2):
        for j in range(4):
            if dense_cells[i, j]:
                # dense-chosen: block data present, tile list filler-only
                np.testing.assert_array_equal(hyb.blocks[i, j], dense[i, j])
                assert int(hyb.sparse.nnz_tiles[i, j]) == 0
                assert not hyb.sparse.tiles[i, j].any()
            else:
                # sparse-chosen: untouched zero block, tiles reconstruct
                assert not hyb.blocks[i, j].any()
                got = tiles_to_dense(
                    jnp.asarray(hyb.sparse.tiles[i, j]),
                    jnp.asarray(hyb.sparse.tile_rows[i, j]),
                    jnp.asarray(hyb.sparse.tile_cols[i, j]),
                    m,
                    kdim,
                )
                np.testing.assert_array_equal(np.asarray(got), dense[i, j])
    # materialized host bytes undercut the all-dense layout on this mix
    assert hyb.host_bytes() < dense.nbytes
    with pytest.raises(ValueError):
        part.blocked_hybrid(2, 2, dense_cells=np.zeros((3, 3), bool))


def test_graph_arrays_hybrid_arity():
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    full = distributed_graph_arrays(part, "pallas_hybrid", "none", tile=(2, 2))
    assert len(full) == 5
    blocks, tiles, _, _, dcell = full
    assert blocks.ndim == 4 and tiles.ndim == 5
    assert dcell.shape == (2, 4) and dcell.dtype == jnp.int32
    ring = distributed_graph_arrays(part, "pallas_hybrid", "expand", tile=(2, 2))
    assert len(ring) == 5 and ring[1].ndim == 6 and ring[1].shape[2] == part.R


# ------------------------------------------- footprint + roofline plumbing
def test_hybrid_footprint_prices_shipped_union():
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    dense = estimate_device_footprint(part, "pallas", 8)
    sparse = estimate_device_footprint(part, "pallas_sparse", 8, bm=2, bk=2)
    hybrid = estimate_device_footprint(part, "pallas_hybrid", 8, bm=2, bk=2)
    # shard_map uniformity: the mixed layout ships the dense operand on
    # every device plus the (sparse-cell-masked) tile list
    assert hybrid["adjacency_bytes"] > dense["adjacency_bytes"]
    assert hybrid["adjacency_bytes"] < dense["adjacency_bytes"] + sparse["adjacency_bytes"]
    # the sparse side must be the masked counts, not the full tile list
    all_sparse = estimate_device_footprint(
        part, "pallas_hybrid", 8, bm=2, bk=2,
        dense_cells=np.zeros((2, 4), bool),
    )
    assert all_sparse["adjacency_bytes"] >= hybrid["adjacency_bytes"]


def test_hybrid_level_estimates_and_auto_overlap():
    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    comp, exp, fold = level_time_estimates(part, "pallas_hybrid", 8, bm=2, bk=2)
    assert comp > 0 and exp > 0 and fold > 0
    # an all-dense choice prices exactly like the dense engine's compute
    comp_dense, _, _ = level_time_estimates(
        part, "pallas_hybrid", 8, bm=2, bk=2,
        dense_cells=np.ones((2, 4), bool),
    )
    comp_pallas, _, _ = level_time_estimates(part, "pallas", 8)
    assert comp_dense == pytest.approx(comp_pallas)
    assert resolve_overlap("auto", part, "pallas_hybrid", 8, bm=2, bk=2) in (
        "none",
        "expand",
        "expand+fold",
    )


# ----------------------------------------------------- distributed engine
@needs_devices
@pytest.mark.parametrize("grid", [(2, 4), (4, 2)])
@pytest.mark.parametrize("overlap", ["none", "expand", "expand+fold", "auto"])
def test_pallas_hybrid_matches_oracle_mixed_mesh(grid, overlap):
    from repro.launch.mesh import make_mesh

    g = _skewed_graph()
    part = partition_2d(g, *grid)
    dense_cells, _ = hybrid_cell_choice(part, 2, 2)
    assert 0 < int(dense_cells.sum()) < dense_cells.size  # genuinely mixed
    mesh = make_mesh(grid, ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g,
        mesh,
        batch_size=8,
        engine_kind="pallas_hybrid",
        overlap=overlap,
        tile=(2, 2),
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


@needs_devices
@pytest.mark.parametrize("threshold", [0.0, 1e9])
def test_pallas_hybrid_threshold_edge_cases(threshold):
    """All-dense (threshold 0) and all-sparse (huge threshold) are the
    degenerate hybrids; both must stay exact under a ring schedule."""
    from repro.launch.mesh import make_mesh

    g = _skewed_graph()
    part = partition_2d(g, 2, 4)
    dense_cells, _ = hybrid_cell_choice(part, 2, 2, threshold=threshold)
    assert dense_cells.all() if threshold == 0.0 else not dense_cells.any()
    mesh = make_mesh((2, 4), ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g,
        mesh,
        batch_size=8,
        engine_kind="pallas_hybrid",
        overlap="expand",
        tile=(2, 2),
        hybrid_threshold=threshold,
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


@needs_devices
def test_pallas_hybrid_skewed_rmat_mixed_cells():
    """The engine's motivating case: a skewed RMAT graph whose mesh
    resolves part dense, part BCSR — parity against the oracle."""
    from repro.launch.mesh import make_mesh

    g = rmat_graph(8, 8, seed=0)
    part = partition_2d(g, 2, 4)
    dense_cells, _ = hybrid_cell_choice(part, 8, 8)
    assert 0 < int(dense_cells.sum()) < dense_cells.size
    mesh = make_mesh((2, 4), ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g,
        mesh,
        batch_size=64,
        engine_kind="pallas_hybrid",
        overlap="expand",
        tile=(8, 8),
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


@needs_devices
def test_pallas_hybrid_subcluster_divergent_depths():
    """Replicas with divergent data-dependent level counts must not
    deadlock the mixed ring (lax.cond stays inside block-local compute,
    so the ppermute rendezvous is identical across the mesh)."""
    from repro.launch.mesh import make_mesh

    g = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g,
        mesh,
        replica_axis="pod",
        batch_size=8,
        engine_kind="pallas_hybrid",
        overlap="expand",
        tile=(2, 2),
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)
