"""Test harness configuration.

Distributed tests need >1 device; jax locks the device count at first
backend init, so tests that want N host devices live in files named
``test_dist_*.py`` and this conftest sets the XLA flag *before* jax is
imported — but only when such a file is being collected, so plain tests
keep seeing 1 device when run alone.

Running the whole suite at once therefore also uses 8 host devices; all
single-device tests are device-count-agnostic (they place arrays
explicitly or use jit defaults, which on CPU behaves identically).
"""
import os
import sys

# Must happen before any jax import anywhere in the test session.
if "jax" not in sys.modules:
    os.environ.setdefault(
        "XLA_FLAGS",
        (os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8").strip(),
    )

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
