"""Unit tests for the bench perf-regression gate (tools/check_bench.py).

The gate's comparison semantics are the contract CI relies on: any
structural metric drift fails, wall-clock drifts only outside a loose
machine-speed factor, and timing-dependent scheduler artifacts never
fail — but a key appearing or disappearing always does.
"""
import importlib.util
import pathlib

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "check_bench",
    pathlib.Path(__file__).resolve().parent.parent / "tools" / "check_bench.py",
)
check_bench = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(check_bench)


BASE = {
    "graph": {"name": "rmat_s8_ef8", "n": 256},
    "engines": {
        "sparse": {
            "expand": {
                "link_bytes_per_round": 12345.0,
                "collectives_per_round_by_class": {"collective-permute": 23},
                "ring_steps_per_round": 100,
                "round_wall_s": 0.05,
                "rounds": 16,
            }
        }
    },
    "policies": {
        "redeal": {
            "rounds_redealt": 6,
            "per_replica_wall_s": [0.14, 0.10],
            "idle_s_est": 0.02,
            "max_abs_err_vs_brandes": 0.0,
        }
    },
}


def _mutated(path_keys, value):
    import copy

    rec = copy.deepcopy(BASE)
    node = rec
    for k in path_keys[:-1]:
        node = node[k]
    node[path_keys[-1]] = value
    return rec


def test_identical_records_pass():
    assert check_bench.compare(BASE, BASE, "b.json", 25.0) == []


@pytest.mark.parametrize(
    "path_keys,value",
    [
        (("engines", "sparse", "expand", "link_bytes_per_round"), 99.0),
        (("engines", "sparse", "expand", "ring_steps_per_round"), 101),
        (
            ("engines", "sparse", "expand", "collectives_per_round_by_class"),
            {"collective-permute": 24},
        ),
        (("engines", "sparse", "expand", "rounds"), 17),
        (("graph", "n"), 512),
        (("policies", "redeal", "max_abs_err_vs_brandes"), 0.5),
    ],
)
def test_structural_drift_fails(path_keys, value):
    failures = check_bench.compare(BASE, _mutated(path_keys, value), "b.json", 25.0)
    assert failures, path_keys


def test_wall_within_factor_passes_outside_fails():
    ok = _mutated(("engines", "sparse", "expand", "round_wall_s"), 0.05 * 10)
    assert check_bench.compare(BASE, ok, "b.json", 25.0) == []
    slow = _mutated(("engines", "sparse", "expand", "round_wall_s"), 0.05 * 100)
    assert check_bench.compare(BASE, slow, "b.json", 25.0)
    fast = _mutated(("engines", "sparse", "expand", "round_wall_s"), 0.05 / 100)
    assert check_bench.compare(BASE, fast, "b.json", 25.0)


def test_parity_error_has_float_tolerance():
    jitter = _mutated(("policies", "redeal", "max_abs_err_vs_brandes"), 5.9e-8)
    assert check_bench.compare(BASE, jitter, "b.json", 25.0) == []
    broken = _mutated(("policies", "redeal", "max_abs_err_vs_brandes"), 1e-3)
    assert check_bench.compare(BASE, broken, "b.json", 25.0)


def test_wall_null_transitions_are_timing_artifacts():
    """Walls are recorded only behind opt-in measurement modes (fig9
    --interp-wall), so null↔value flips must pass — in both directions —
    while a vanished key still fails."""
    gone = _mutated(("engines", "sparse", "expand", "round_wall_s"), None)
    assert check_bench.compare(BASE, gone, "b.json", 25.0) == []
    # value appearing where the baseline had null (opt-in enabled later)
    assert check_bench.compare(gone, BASE, "b.json", 25.0) == []
    # both null: trivially equal
    assert check_bench.compare(gone, gone, "b.json", 25.0) == []


def test_timing_artifacts_ignored():
    rec = _mutated(("policies", "redeal", "rounds_redealt"), 0)
    assert check_bench.compare(BASE, rec, "b.json", 25.0) == []


def test_key_set_drift_fails_both_ways():
    import copy

    extra = copy.deepcopy(BASE)
    extra["engines"]["sparse"]["expand"]["new_metric"] = 1
    assert any(
        "not in committed baseline" in f
        for f in check_bench.compare(BASE, extra, "b.json", 25.0)
    )
    missing = copy.deepcopy(BASE)
    del missing["engines"]["sparse"]["expand"]["rounds"]
    assert any(
        "missing from fresh" in f
        for f in check_bench.compare(BASE, missing, "b.json", 25.0)
    )


def test_classify():
    assert check_bench.classify("engines/sparse/expand/round_wall_s") == "wall"
    assert check_bench.classify("policies/redeal/per_replica_wall_s/0") == "wall"
    # signed difference of measured walls — a ratio test is meaningless
    assert check_bench.classify("policies/redeal/idle_s_est") == "ignored"
    assert check_bench.classify("idle_s_recovered_redeal_vs_none") == "ignored"
    assert check_bench.classify("policies/none/max_abs_err_vs_brandes") == "err"
    assert (
        check_bench.classify("engines/sparse/none/link_bytes_per_round")
        == "structural"
    )
    assert check_bench.classify("hybrid/dense_cells/0/1") == "structural"
    assert check_bench.classify("hybrid/host_bytes/all_dense") == "structural"
    assert check_bench.classify("policies/redeal/rounds_redealt") == "ignored"
    assert check_bench.classify("policies/steal/duplicates_dispatched") == "ignored"
    # the scheduler-deal comparison is exact: BFS depths + deterministic
    # schedules, no timing involved
    assert check_bench.classify("deal/interleaved_total_levels") == "structural"
    assert check_bench.classify("deal/eccentricity_total_levels") == "structural"
    assert check_bench.classify("deal/levels_saved") == "structural"


def test_gate_against_real_committed_baselines():
    """The committed BENCH_*.json must satisfy the gate against
    themselves (the local `make bench-check` pass criterion)."""
    for name in check_bench.BASELINES:
        baseline = check_bench.committed_json(name, "HEAD")
        if baseline is None:
            pytest.skip(f"{name} not committed yet")
        assert check_bench.compare(baseline, baseline, name, 25.0) == []
