"""HLO cost parser validated against closed-form matmul/scan costs."""
import pytest

import jax
import jax.numpy as jnp

from repro.roofline.hlo import analyze_hlo_module
from repro.roofline.model import (
    V5E,
    link_bytes,
    overlap_step_time,
    ring_latency_s,
    ring_steps,
    roofline_terms,
)


def _compile(fn, *specs, in_shardings=None):
    j = jax.jit(fn) if in_shardings is None else jax.jit(fn, in_shardings=in_shardings)
    return j.lower(*specs).compile()


def test_plain_matmul_flops():
    m = k = n = 512
    c = _compile(
        lambda a, b: a @ b,
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    )
    terms = analyze_hlo_module(c.as_text())
    expected = 2.0 * m * k * n
    assert abs(terms["flops"] - expected) / expected < 0.05, terms["flops"]
    # bytes at least inputs+outputs
    assert terms["bytes"] >= 3 * m * n * 4


def test_scan_multiplies_trip_count():
    L, m, k = 8, 128, 128

    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None

        x, _ = jax.lax.scan(body, x, ws)
        return x

    c = _compile(
        f,
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((L, k, k), jnp.float32),
    )
    terms = analyze_hlo_module(c.as_text())
    expected = 2.0 * m * k * k * L
    assert abs(terms["flops"] - expected) / expected < 0.05, terms["flops"]
    assert terms["unknown_trip_whiles"] == 0


def test_collectives_counted_with_groups():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    m = k = n = 256

    def f(a, b):
        return a @ b

    c = (
        jax.jit(
            f,
            in_shardings=(
                NamedSharding(mesh, P("data", "model")),
                NamedSharding(mesh, P("model", None)),
            ),
            out_shardings=NamedSharding(mesh, P("data", None)),
        )
        .lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
        )
        .compile()
    )
    terms = analyze_hlo_module(c.as_text())
    # contraction over the sharded k axis must produce a cross-"model"
    # reduction (all-reduce or reduce-scatter) over groups of 4
    colls = terms["collectives"]
    assert colls, c.as_text()[:2000]
    assert any(r["group_size"] == 4 for r in colls)
    assert link_bytes(colls) > 0


def test_roofline_terms_shape():
    hlo_terms = {
        "flops": 197e12,
        "bytes": 819e9,
        "collectives": [
            {"class": "all-reduce", "group_size": 4, "operand_bytes": 50e9}
        ],
        "collective_operand_bytes": {"all-reduce": 50e9},
        "unknown_trip_whiles": 0,
    }
    t = roofline_terms(hlo_terms, n_devices=256, model_flops_total=197e12 * 256)
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 1.0) < 1e-9
    assert abs(t.collective_s - 1.5) < 1e-9  # 2*(4-1)/4 * 50e9 / 50e9
    assert t.bottleneck == "collective"
    assert abs(t.useful_fraction - 1.0) < 1e-9
    assert t.ring_steps == 6  # all-reduce over g=4: 2*(g-1) hops
    assert abs(t.ring_latency_s - 6 * V5E.ici_step_latency_s) < 1e-15


def test_ring_step_counts_by_class():
    recs = [
        {"class": "all-gather", "group_size": 4, "operand_bytes": 1.0},
        {"class": "reduce-scatter", "group_size": 4, "operand_bytes": 1.0},
        {"class": "all-reduce", "group_size": 8, "operand_bytes": 1.0},
        {"class": "collective-permute", "group_size": 4, "operand_bytes": 1.0},
    ]
    # (4-1) + (4-1) + 2*(8-1) + 1
    assert ring_steps(recs) == 3 + 3 + 14 + 1
    assert abs(ring_latency_s(recs) - 21 * V5E.ici_step_latency_s) < 1e-15


def test_overlap_step_time_model():
    # barrier (k=1) is strictly additive
    assert abs(overlap_step_time(3.0, 1.0, 1) - 4.0) < 1e-12
    # deep ring exposes only the dominant term (+ one slice of the minor)
    assert abs(overlap_step_time(3.0, 1.0, 4) - (3.0 + 0.25)) < 1e-12
    assert abs(overlap_step_time(1.0, 3.0, 4) - (3.0 + 0.25)) < 1e-12
    # pipelining never loses to the barrier schedule
    for k in (2, 4, 16):
        assert overlap_step_time(2.0, 2.0, k) <= 4.0


def test_ring_lowering_counted_by_parser():
    """A hand-rolled ppermute ring round-trips through the HLO parser."""
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8,), ("data",))
    perm = [(s, (s + 1) % 8) for s in range(8)]

    def body(x):
        acc = x
        for _ in range(7):
            x = jax.lax.ppermute(x, "data", perm)
            acc = acc + x
        return acc

    fn = jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=P("data"), out_specs=P("data"), check_vma=False
        )
    )
    text = fn.lower(jax.ShapeDtypeStruct((64, 16), jnp.float32)).compile().as_text()
    terms = analyze_hlo_module(text)
    permutes = [
        r for r in terms["collectives"] if r["class"] == "collective-permute"
    ]
    assert permutes, text[:2000]
    assert ring_steps(permutes) >= 7
