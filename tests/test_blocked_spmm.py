"""Blocked-sparse (BCSR) layout + kernels + distributed engine parity.

Three layers:

* layout — tile lists of :meth:`TwoDPartition.blocked_sparse` reconstruct
  the dense device blocks exactly (full and per-ring-chunk slices), keep
  the row-sorted / row-complete invariants the kernels rely on, and their
  storage scales with the nonzero-tile count, not the dense block area;
* kernels — ``frontier_spmm_sparse`` / ``dependency_spmm_sparse`` match
  the dense partial kernels on every device block, in full, ring-chunk
  and chunked-``acc`` modes, while iterating only the stored tiles;
* engine — ``engine_kind="pallas_sparse"`` matches ``brandes_reference``
  within 1e-6 on 2x4 and 4x2 meshes for every overlap policy (plus
  ``"auto"``), including sub-cluster meshes with divergent round depths.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import brandes_reference
from repro.core.distributed import (
    check_device_memory,
    distributed_betweenness_centrality,
    distributed_graph_arrays,
    estimate_device_footprint,
    resolve_overlap,
)
from repro.graphs import gnp_graph, rmat_graph
from repro.graphs.partition import default_tile_dim, partition_2d
from repro.kernels import ops
from repro.kernels.blocked_spmm import tiles_to_dense

S = 8


def _layout(graph, R, C, bm=2, bk=2):
    """(partition, full layout, ring layout) — two builds sharing one
    cached arc→tile counting pass; each form materializes only itself."""
    part = partition_2d(graph, R, C)
    return part, part.blocked_sparse(bm, bk), part.blocked_sparse(bm, bk, ring=True)


# ----------------------------------------------------------------- layout
@pytest.mark.parametrize("grid", [(2, 4), (4, 2)])
def test_layout_roundtrip_dense(grid):
    """dense ⊕ reconstruct == original, for the full and ring layouts."""
    g = gnp_graph(26, 0.15, seed=0)
    part, lay, ring_lay = _layout(g, *grid)
    # each form materializes only itself (no discarded double build)
    assert lay.ring_tiles is None and ring_lay.tiles is None
    dense = part.dense_blocks()
    R, C, chunk = part.R, part.C, part.chunk
    m, kdim = C * chunk, R * chunk
    for i in range(R):
        for j in range(C):
            got = tiles_to_dense(
                jnp.asarray(lay.tiles[i, j]),
                jnp.asarray(lay.tile_rows[i, j]),
                jnp.asarray(lay.tile_cols[i, j]),
                m,
                kdim,
            )
            np.testing.assert_array_equal(np.asarray(got), dense[i, j])
            # ring slices re-based per chunk: sum of slot reconstructions
            ring = np.zeros((m, kdim), np.float32)
            for r in range(R):
                slot = tiles_to_dense(
                    jnp.asarray(ring_lay.ring_tiles[i, j, r]),
                    jnp.asarray(ring_lay.ring_tile_rows[i, j, r]),
                    jnp.asarray(ring_lay.ring_tile_cols[i, j, r]),
                    m,
                    chunk,
                )
                ring[:, r * chunk : (r + 1) * chunk] += np.asarray(slot)
            np.testing.assert_array_equal(ring, dense[i, j])


def test_layout_invariants_and_validation():
    g = gnp_graph(26, 0.15, seed=0)
    part, lay, ring_lay = _layout(g, 2, 4)
    num_tr = lay.num_tile_rows
    for i in range(2):
        for j in range(4):
            rows = lay.tile_rows[i, j]
            assert np.all(np.diff(rows) >= 0)  # row-sorted
            assert set(range(num_tr)) <= set(rows.tolist())  # row-complete
            for r in range(2):
                ring_rows = ring_lay.ring_tile_rows[i, j, r]
                assert np.all(np.diff(ring_rows) >= 0)
                assert set(range(num_tr)) <= set(ring_rows.tolist())
    with pytest.raises(ValueError):
        part.blocked_sparse(3, 2)  # 3 does not divide chunk=4
    assert default_tile_dim(128) == 128
    assert default_tile_dim(48) == 48  # lane-aligned divisor preferred
    assert default_tile_dim(7) == 7  # falls back to any divisor


def test_layout_memory_scales_with_nnz_tiles():
    """On a sparse RMAT block the stored-tile footprint is a small
    fraction of the dense block — the O(nnz-tiles) memory claim."""
    g = rmat_graph(10, 4, seed=1)
    part = partition_2d(g, 2, 4)
    lay = part.blocked_sparse(8, 8)
    dense_tiles = lay.num_tile_rows * lay.num_tile_cols
    assert int(lay.nnz_tiles.max()) < dense_tiles // 2
    dense_bytes = (part.C * part.chunk) * (part.R * part.chunk) * 4
    assert lay.adjacency_bytes() < dense_bytes
    # stored count tracks nnz tiles (padding bounded by the worst cell
    # plus the one-filler-per-empty-row invariant)
    stored = lay.tiles.shape[2]
    assert stored <= int(lay.nnz_tiles.max()) + lay.num_tile_rows
    assert lay.nnz_tiles.sum() == part.nnz_tile_counts(8, 8).sum()


def test_blocked_sparse_counts_match_materialized_layout():
    """The no-materialize accounting the memory guard prices must equal
    the shipped layout byte-for-byte (full and ring forms)."""
    g = rmat_graph(10, 4, seed=1)
    part = partition_2d(g, 2, 4)
    counts = part.blocked_sparse_counts(8, 8)
    assert counts["nnz_max"] == int(part.nnz_tile_counts(8, 8).max())
    for ring in (False, True):
        lay = part.blocked_sparse(8, 8, ring=ring)
        key = "ring" if ring else "full"
        assert counts[f"bytes_{key}"] == lay.adjacency_bytes()
        assert counts["nnz_total"] == int(lay.nnz_tiles.sum())
        arr = lay.ring_tiles if ring else lay.tiles
        stored = arr.shape[3] * arr.shape[2] if ring else arr.shape[2]
        assert counts[f"stored_tiles_{key}"] == stored


def test_footprint_prices_ring_layouts():
    """Under a ring overlap policy the guard must price the ring layouts
    (R padded slots / slices), which can only be larger than the flat
    forms it prices for the barrier schedule."""
    g = rmat_graph(10, 4, seed=1)
    part = partition_2d(g, 2, 4)
    for kind in ("sparse", "pallas_sparse"):
        flat = estimate_device_footprint(part, kind, 16, bm=8, bk=8)
        ring = estimate_device_footprint(
            part, kind, 16, bm=8, bk=8, overlap="expand"
        )
        assert ring["adjacency_bytes"] >= flat["adjacency_bytes"]
    # sparse arc ring pricing matches the materialized ring layout
    ring_src, _ = part.ring_arcs()
    want = 2 * ring_src.shape[2] * ring_src.shape[3] * 4
    got = estimate_device_footprint(part, "sparse", 16, overlap="expand")
    assert got["adjacency_bytes"] == want


# ---------------------------------------------------------------- kernels
@pytest.mark.parametrize("use_pallas", [True, False])
def test_sparse_kernels_match_dense_partials(rng, use_pallas):
    g = gnp_graph(26, 0.15, seed=0)
    part, lay, _ = _layout(g, 2, 4)
    dense = part.dense_blocks()
    chunk = part.chunk
    kdim, m = 2 * chunk, 4 * chunk
    sigma = jnp.asarray(rng.integers(0, 5, (kdim, S)), jnp.float32)
    depth = jnp.asarray(rng.integers(-1, 4, (kdim, S)), jnp.int32)
    delta = jnp.asarray(rng.normal(size=(kdim, S)), jnp.float32)
    omega = jnp.asarray(rng.integers(0, 3, kdim), jnp.float32)
    acc0 = jnp.asarray(rng.normal(size=(m, S)), jnp.float32)
    lvl = 2
    for i in range(2):
        for j in range(4):
            tiles, tr, tc = (
                jnp.asarray(a[i, j])
                for a in (lay.tiles, lay.tile_rows, lay.tile_cols)
            )
            a_dense = jnp.asarray(dense[i, j])
            want_f = ops.frontier_spmm_partial(a_dense, sigma, depth, lvl, interpret=True)
            got_f = ops.frontier_spmm_sparse(
                tiles, tr, tc, sigma, depth, lvl, m=m,
                use_pallas=use_pallas, interpret=True,
            )
            np.testing.assert_allclose(got_f, want_f, rtol=1e-5, atol=1e-6)
            # chunked-acc mode: the ring's running combine
            got_acc = ops.frontier_spmm_sparse(
                tiles, tr, tc, sigma, depth, lvl, m=m, acc=acc0,
                use_pallas=use_pallas, interpret=True,
            )
            np.testing.assert_allclose(got_acc, acc0 + want_f, rtol=1e-5, atol=1e-5)
            want_b = ops.dependency_spmm_partial(
                a_dense, sigma, depth, delta, omega, lvl, interpret=True
            )
            got_b = ops.dependency_spmm_sparse(
                tiles, tr, tc, sigma, depth, delta, omega, lvl, m=m,
                use_pallas=use_pallas, interpret=True,
            )
            np.testing.assert_allclose(got_b, want_b, rtol=1e-5, atol=1e-6)


def test_ring_chunk_composition_matches_full(rng):
    """R chunked-acc steps over the ring slices == one full-block call."""
    g = gnp_graph(26, 0.15, seed=0)
    part, lay, ring_lay = _layout(g, 2, 4)
    chunk = part.chunk
    kdim, m = 2 * chunk, 4 * chunk
    sigma = jnp.asarray(rng.integers(0, 5, (kdim, S)), jnp.float32)
    depth = jnp.asarray(rng.integers(-1, 4, (kdim, S)), jnp.int32)
    i, j = 1, 2
    tiles, tr, tc = (
        jnp.asarray(a[i, j]) for a in (lay.tiles, lay.tile_rows, lay.tile_cols)
    )
    want = ops.frontier_spmm_sparse(
        tiles, tr, tc, sigma, depth, 2, m=m, interpret=True
    )
    acc = jnp.zeros((m, S), jnp.float32)
    for r in range(2):
        acc = ops.frontier_spmm_sparse(
            jnp.asarray(ring_lay.ring_tiles[i, j, r]),
            jnp.asarray(ring_lay.ring_tile_rows[i, j, r]),
            jnp.asarray(ring_lay.ring_tile_cols[i, j, r]),
            sigma[r * chunk : (r + 1) * chunk],
            depth[r * chunk : (r + 1) * chunk],
            2,
            m=m,
            acc=acc,
            interpret=True,
        )
    np.testing.assert_allclose(acc, want, rtol=1e-5, atol=1e-6)


def test_empty_tiles_are_skipped(rng):
    """A block-diagonal graph stores ~1/num_chunks of the dense tiles,
    and filler tiles (empty rows / padding) do not perturb the product."""
    # two disjoint cliques → strongly block-structured adjacency
    from repro.graphs import disjoint_union, gnp_graph as gnp

    g = disjoint_union(gnp(16, 0.9, seed=1), gnp(16, 0.9, seed=2))
    part, lay, _ = _layout(g, 2, 4, bm=2, bk=2)
    dense_tiles = lay.num_tile_rows * lay.num_tile_cols
    assert int(lay.nnz_tiles.sum()) < dense_tiles * 8 // 2  # mostly empty
    chunk = part.chunk
    kdim, m = 2 * chunk, 4 * chunk
    sigma = jnp.asarray(rng.integers(0, 5, (kdim, S)), jnp.float32)
    depth = jnp.asarray(rng.integers(-1, 4, (kdim, S)), jnp.int32)
    dense = part.dense_blocks()
    for i in range(2):
        for j in range(4):
            want = ops.frontier_spmm_partial(
                jnp.asarray(dense[i, j]), sigma, depth, 2, interpret=True
            )
            got = ops.frontier_spmm_sparse(
                jnp.asarray(lay.tiles[i, j]),
                jnp.asarray(lay.tile_rows[i, j]),
                jnp.asarray(lay.tile_cols[i, j]),
                sigma,
                depth,
                2,
                m=m,
                interpret=True,
            )
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ----------------------------------------------------- distributed engine
needs_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices"
)


@needs_devices
@pytest.mark.parametrize("grid", [(2, 4), (4, 2)])
@pytest.mark.parametrize("overlap", ["none", "expand", "expand+fold", "auto"])
def test_pallas_sparse_matches_oracle(grid, overlap):
    from repro.launch.mesh import make_mesh

    g = gnp_graph(26, 0.15, seed=0)
    mesh = make_mesh(grid, ("data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g,
        mesh,
        heuristics="h3",
        batch_size=8,
        engine_kind="pallas_sparse",
        overlap=overlap,
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


@needs_devices
@pytest.mark.parametrize("overlap", ["expand", "expand+fold"])
def test_pallas_sparse_subcluster_divergent_depths(overlap):
    """Replicas with divergent data-dependent level counts (41-level path
    round vs 2-level G(n,p) round) must not deadlock the tile ring."""
    from repro.graphs import disjoint_union, path_graph
    from repro.launch.mesh import make_mesh

    g = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    bc, _ = distributed_betweenness_centrality(
        g,
        mesh,
        replica_axis="pod",
        batch_size=8,
        engine_kind="pallas_sparse",
        overlap=overlap,
    )
    np.testing.assert_allclose(bc, brandes_reference(g), rtol=1e-6, atol=1e-6)


def test_graph_arrays_layouts():
    g = gnp_graph(26, 0.15, seed=0)
    part = partition_2d(g, 2, 4)
    full = distributed_graph_arrays(part, "pallas_sparse", "none")
    assert len(full) == 3 and full[0].ndim == 5
    ring = distributed_graph_arrays(part, "pallas_sparse", "expand")
    assert len(ring) == 3 and ring[0].ndim == 6 and ring[0].shape[2] == part.R


# ------------------------------------------- memory guard + auto overlap
def test_footprint_sparse_below_dense_and_guard_fires():
    # 8x8 tiles: production-default 128 tiles are larger than this test
    # graph's whole chunk, so pick a tile that resolves its sparsity
    g = rmat_graph(10, 4, seed=1)
    part = partition_2d(g, 2, 4)
    dense = estimate_device_footprint(part, "pallas", 16)
    sparse = estimate_device_footprint(part, "pallas_sparse", 16, bm=8, bk=8)
    assert sparse["adjacency_bytes"] < dense["adjacency_bytes"]
    # budget between the two engines: dense errors and suggests sparse
    budget = (dense["total_bytes"] + sparse["total_bytes"]) / 2
    with pytest.raises(MemoryError, match="pallas_sparse"):
        check_device_memory(part, "pallas", 16, budget, bm=8, bk=8)
    check_device_memory(part, "pallas_sparse", 16, budget, bm=8, bk=8)  # fits
    check_device_memory(part, "pallas", 16, None)  # guard disarmed


def test_resolve_overlap_auto_and_passthrough():
    g = gnp_graph(26, 0.15, seed=0)
    part = partition_2d(g, 2, 4)
    for kind in ("sparse", "pallas", "pallas_sparse"):
        assert resolve_overlap("auto", part, kind, 8) in (
            "none",
            "expand",
            "expand+fold",
        )
    assert resolve_overlap("expand", part, "sparse", 8) == "expand"
    with pytest.raises(ValueError):
        resolve_overlap("ring", part, "sparse", 8)
