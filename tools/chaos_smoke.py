"""Chaos smoke: the fault matrix end-to-end on 8 fake host devices.

``make chaos-smoke`` / the distributed-overlap CI job run this to prove
the self-healing round loop survives every injectable fault class
(:data:`repro.distributed.chaos.FAULT_KINDS`) with BC parity against
the Brandes oracle:

  1. **grid mesh (2x4)** — transient dispatch failures + a NaN-poisoned
     block: the driver retries with backoff, quarantines the poisoned
     block and recomputes it via the chaos-supplied clean fallback.
  2. **replicated mesh (2x2x2)** — a replica killed mid-run: the
     multi-ledger loop re-meshes onto the survivor and finishes every
     round exactly once.
  3. **torn snapshot** — the run's final checkpoint write is truncated;
     the next run must warn, cold-start (no intact generation), redo the
     rounds, and still match — corruption costs recompute, never
     correctness (and never a traceback).
  4. **corrupted autotune cache** — every persisted cache put is
     garbled; the next run warm-starts the cache empty with a warning
     and simply re-measures.
  5. **silent data corruption (flip)** — a finite corruption of one
     dispatch's block output, invisible to the numeric guard; the
     ``integrity="checksum"`` audits catch it, quarantine the block and
     recompute it.
  6. **wedged dispatch (stall)** — a dispatch delayed past its
     ``dispatch_deadline_s``; the watchdog trips, re-dispatches, then
     escalates to a replica loss the elastic re-mesh absorbs — the run
     finishes instead of hanging.

Each leg asserts parity at the repo-standard smoke tolerance (1e-5,
f32 accumulation) plus the recovery telemetry the fault must produce.
"""
from __future__ import annotations

import os
import pathlib
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks.common import ensure_devices, make_mesh  # noqa: E402

ensure_devices(8)

import numpy as np  # noqa: E402


def main() -> int:
    if not ensure_devices(8):
        print("chaos-smoke: needs 8 host devices, skipping")
        return 0

    from repro.autotune import CostCache
    from repro.checkpoint import BCCheckpoint
    from repro.core.brandes_ref import brandes_reference
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.graphs import disjoint_union, gnp_graph, path_graph, rmat_graph

    def check(tag, result, expected):
        np.testing.assert_allclose(result.bc, expected, rtol=1e-5, atol=1e-5)
        err = float(np.abs(result.bc - expected).max())
        rec = result.recovery_stats
        print(
            f"chaos-smoke[{tag}]: parity ok (err {err:.2e}), "
            f"rounds {result.rounds_run}/{len(result.schedule.rounds)}, "
            f"recovery {({k: v for k, v in rec.items() if k != 'chaos'})}"
        )
        return rec

    # 1. transient + poison on the grid mesh (fr=1): retry + fallback
    g1 = rmat_graph(6, 4, seed=2)
    oracle1 = brandes_reference(g1)
    grid = make_mesh((2, 4), ("data", "model"))
    rec = check(
        "transient+poison",
        distributed_betweenness_centrality(
            g1, grid, batch_size=16,
            chaos="seed=5;transient@1x2;poison@3:nan",
            retry_backoff_s=1e-3, full_result=True,
        ),
        oracle1,
    )
    assert rec["transient_errors"] == 2, rec
    assert rec["quarantined_blocks"] >= 1, rec

    # 2. replica kill on the replicated mesh: elastic re-mesh
    g2 = disjoint_union(path_graph(40), gnp_graph(16, 0.3, seed=4))
    oracle2 = brandes_reference(g2)
    pods = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rec = check(
        "replica-kill",
        distributed_betweenness_centrality(
            g2, pods, replica_axis="pod", batch_size=8, overlap="expand",
            straggler="steal",
            chaos="seed=1;kill@1:r1",
            retry_backoff_s=1e-3, full_result=True,
        ),
        oracle2,
    )
    assert rec["remesh_events"] == 1 and rec["dead_replicas"] == [1], rec

    with tempfile.TemporaryDirectory() as tmp:
        # 3. torn snapshot: the corrupted checkpoint costs recompute,
        # never correctness (and never a traceback)
        snap = os.path.join(tmp, "bc.npz")
        rec = check(
            "torn-write",
            distributed_betweenness_centrality(
                g1, grid, batch_size=16,
                checkpoint=BCCheckpoint(snap),
                chaos="seed=9;torn@0",
                full_result=True,
            ),
            oracle1,
        )
        assert rec["chaos"]["files_corrupted"] == [snap], rec["chaos"]
        resumed = distributed_betweenness_centrality(
            g1, grid, batch_size=16,
            checkpoint=BCCheckpoint(snap),
            full_result=True,
        )
        rec = check("torn-resume", resumed, oracle1)
        assert rec["resumed_generation"] is None, rec  # cold start, warned
        assert resumed.rounds_run == len(resumed.schedule.rounds)

        # 4. corrupted autotune cache: warm-start empty + re-measure
        cache_path = os.path.join(tmp, "cache.json")
        rec = check(
            "cache-garble",
            distributed_betweenness_centrality(
                g1, grid, batch_size=16, overlap="auto",
                autotune="measure", autotune_cache=cache_path,
                chaos="seed=3;cache@0x999",
                full_result=True,
            ),
            oracle1,
        )
        assert rec["chaos"]["cache_puts"] > 0, rec["chaos"]
        assert cache_path in rec["chaos"]["files_corrupted"], rec["chaos"]
        fresh = CostCache(cache_path)  # warns + starts empty, no traceback
        assert fresh.num_records() == 0, fresh.stats()
        rec = check(
            "cache-remeasure",
            distributed_betweenness_centrality(
                g1, grid, batch_size=16, overlap="auto",
                autotune="measure", autotune_cache=cache_path,
                full_result=True,
            ),
            oracle1,
        )

    # 5. silent data corruption on the grid mesh: finite flip caught by
    # the checksum/claim audits, quarantined and recomputed
    rec = check(
        "flip-integrity",
        distributed_betweenness_centrality(
            g1, grid, batch_size=16, engine_kind="pallas", overlap="expand",
            integrity="checksum",
            chaos="seed=11;flip@1",
            retry_backoff_s=1e-3, full_result=True,
        ),
        oracle1,
    )
    integ = rec["integrity"]
    assert integ["checksum_failures"] + integ["audit_failures"] >= 1, integ
    assert rec["quarantined_blocks"] >= 1, rec
    assert integ["max_checksum_residual"] < 1e-3, integ

    # 6. wedged dispatch on the replicated mesh: watchdog trip ->
    # re-dispatch -> escalation -> re-mesh, no hang
    rec = check(
        "stall-watchdog",
        distributed_betweenness_centrality(
            g2, pods, replica_axis="pod", batch_size=8, straggler="steal",
            integrity="audit",
            chaos="seed=13;stall@0x3:200",
            dispatch_deadline_s=0.05, max_retries=2,
            retry_backoff_s=1e-3, full_result=True,
        ),
        oracle2,
    )
    integ = rec["integrity"]
    assert integ["watchdog_trips"] >= 3, integ
    assert integ["watchdog_escalations"] >= 1, integ
    assert rec["remesh_events"] >= 1, rec

    print(
        "chaos-smoke: all fault classes healed — transient retry, poison "
        "quarantine + fallback, replica re-mesh, torn-snapshot cold start, "
        "cache corruption re-measure, flip integrity quarantine, stall "
        "watchdog escalation"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
