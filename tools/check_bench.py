"""CI perf-regression gate over the BENCH_*.json baselines.

``make bench-smoke`` regenerates ``BENCH_overlap.json`` /
``BENCH_sparse.json`` / ``BENCH_subcluster.json`` in the working tree;
this tool compares those fresh records against the *committed* baselines
(``git show <ref>:<file>``) and fails on drift, so a kernel or layout
regression fails the PR instead of silently rewriting a baseline.  A
deliberate perf/structure change must commit the regenerated baseline in
the same PR — which is exactly the reviewable diff we want.

Four comparison classes, keyed on the metric path:

* **structural** — link bytes, ring steps, per-class collective counts,
  nnz/stored tile counts, A-stream bytes, the hybrid per-cell decision
  and host-bytes record, graph/mesh/tile identity, round counts: must
  match EXACTLY.  These are functions of the code, not the machine.
* **wall-clock** — any ``*wall*`` metric: measured seconds, machine-
  and load-dependent; must agree within a loose factor
  (``--wall-factor``, default 25x either way) so a CI runner can't fail
  the gate on speed alone, but a 100x pathology still trips.  A wall
  flipping between null and a value is likewise a timing artifact (the
  metric is recorded only behind opt-in measurement modes, e.g. fig9's
  ``--interp-wall``) — key presence is still enforced.
* **parity error** — ``max_abs_err*``: the oracle comparison, compared
  within the repo's standard 1e-6 tolerance (a jax/XLA version bump may
  legally change reduction order) — a real parity break still trips.
* **ignored** — scheduler artifacts that are *timing-dependent by
  design* (rounds stolen/re-dealt, duplicate dispatch counts,
  per-replica level attribution, idle-seconds estimates — signed
  differences of measured walls, for which a ratio test is
  meaningless): key presence is still checked, the value is not.

Run as ``make bench-check`` (regenerates, then compares) or standalone
``python tools/check_bench.py`` after a ``make bench-smoke``.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

BASELINES = ("BENCH_overlap.json", "BENCH_sparse.json", "BENCH_subcluster.json")

#: path components marking a measured-seconds metric (loose comparison);
#: idle_s* metrics are NOT here — they are signed differences/estimates
#: of walls that legitimately cross zero, so they fall through to
#: "ignored" (key presence still checked)
WALL_MARKERS = ("wall",)

#: path components marking a structural metric (exact comparison); every
#: other numeric leaf is a timing-dependent scheduler artifact (ignored)
STRUCTURAL_MARKERS = (
    "link_bytes",
    "ring_steps",
    "collectives_per_round",
    "collectives_per_round_by_class",
    "nnz_tiles",
    "a_stream_bytes",
    "adjacency_stored_bytes",
    "dense_tiles",
    "stored_tiles",
    "dense_cells",
    "cells_dense",
    "cells_sparse",
    "host_bytes",
    "threshold",
    "graph",
    "mesh",
    "tile",
    "num_levels",
    "overlap",
    "rounds",
    # the scheduler's deal comparison (table3 "deal" section): exact BFS
    # depths + deterministic schedules — total_levels is a code property
    "deal",
    "batch_size",
    "total_levels",
    # the weighted section's bucket width: auto_delta is a deterministic
    # function of the graph (weight statistics), a code property
    "delta",
)

#: parity-error metrics: near-exact floats (the oracle comparison is
#: deterministic per jax version, but a runner's jax/XLA bump may change
#: reduction order) — compared within the repo's standard 1e-6 tolerance
#: instead of bitwise, so the gate still catches a real parity break
ERR_MARKERS = ("max_abs_err",)
ERR_ATOL = 1e-6

#: leaves that merely *contain* "rounds" but count timing-dependent
#: scheduler decisions — never exact-matched
TIMING_LEAVES = ("rounds_stolen", "rounds_redealt")


def flatten(node, prefix="") -> dict:
    """dict/list tree -> {path: leaf} with '/'-joined path components."""
    out: dict = {}
    if isinstance(node, dict):
        items = ((str(k), v) for k, v in node.items())
    elif isinstance(node, list):
        items = ((str(i), v) for i, v in enumerate(node))
    else:
        return {prefix: node}
    for key, val in items:
        out.update(flatten(val, f"{prefix}/{key}" if prefix else key))
    return out


def classify(path: str) -> str:
    """'wall' | 'err' | 'structural' | 'ignored' for one metric path."""
    parts = path.split("/")
    if any(any(m in p for m in WALL_MARKERS) for p in parts):
        return "wall"
    if any(p.startswith(m) for m in ERR_MARKERS for p in parts):
        return "err"
    if any(p in TIMING_LEAVES for p in parts):
        return "ignored"
    if any(p.startswith(m) or p == m for m in STRUCTURAL_MARKERS for p in parts):
        return "structural"
    return "ignored"


def compare(baseline: dict, fresh: dict, name: str, wall_factor: float) -> list[str]:
    """Drift list (empty = pass) between one committed/fresh record pair."""
    base_flat, fresh_flat = flatten(baseline), flatten(fresh)
    failures: list[str] = []
    for path in sorted(set(base_flat) - set(fresh_flat)):
        failures.append(f"{name}: {path} missing from fresh record")
    for path in sorted(set(fresh_flat) - set(base_flat)):
        failures.append(
            f"{name}: {path} not in committed baseline (regenerate + commit it)"
        )
    for path in sorted(set(base_flat) & set(fresh_flat)):
        want, got = base_flat[path], fresh_flat[path]
        cls = classify(path)
        if cls == "ignored":
            continue
        if cls == "wall":
            if want == got:
                continue
            if want is None or got is None:
                # a wall flipping between null and a value is a timing
                # artifact, not structural drift: walls are recorded only
                # behind measurement opt-ins (fig9 --interp-wall for the
                # interpreted Pallas engines), so the same code measures
                # or skips depending on how the smoke was invoked.  The
                # key-set check above still fails if the key disappears.
                continue
            lo, hi = sorted((float(want), float(got)))
            if lo <= 0 or hi / max(lo, 1e-12) > wall_factor:
                failures.append(
                    f"{name}: {path} wall {want!r} -> {got!r} "
                    f"(outside {wall_factor}x)"
                )
            continue
        if cls == "err":
            if abs(float(want) - float(got)) > ERR_ATOL:
                failures.append(
                    f"{name}: {path} parity error {want!r} -> {got!r} "
                    f"(beyond {ERR_ATOL})"
                )
            continue
        # structural: exact (floats included — these are byte/count models)
        if want != got:
            failures.append(f"{name}: {path} drifted {want!r} -> {got!r}")
    return failures


def committed_json(path: str, ref: str) -> dict | None:
    try:
        text = subprocess.run(
            ["git", "show", f"{ref}:{path}"],
            cwd=ROOT,
            capture_output=True,
            text=True,
            check=True,
        ).stdout
    except (subprocess.CalledProcessError, FileNotFoundError):
        return None
    return json.loads(text)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*", default=None)
    ap.add_argument(
        "--baseline-ref",
        default="HEAD",
        help="git ref holding the committed baselines (default HEAD)",
    )
    ap.add_argument(
        "--wall-factor",
        type=float,
        default=25.0,
        help="allowed wall-clock ratio either way (machine-speed slack)",
    )
    args = ap.parse_args(argv)
    files = args.files or list(BASELINES)

    failures: list[str] = []
    checked = 0
    for name in files:
        fresh_path = ROOT / name
        if not fresh_path.exists():
            failures.append(f"{name}: no fresh record (run `make bench-smoke`)")
            continue
        baseline = committed_json(name, args.baseline_ref)
        if baseline is None:
            failures.append(
                f"{name}: not committed at {args.baseline_ref} "
                "(commit the generated baseline)"
            )
            continue
        fresh = json.loads(fresh_path.read_text())
        failures.extend(compare(baseline, fresh, name, args.wall_factor))
        checked += 1

    if failures:
        print("bench baseline drift detected:")
        for f in failures:
            print(f"  - {f}")
        print(
            "\nIf the change is intentional, regenerate with `make bench-smoke` "
            "and commit the updated BENCH_*.json in this PR."
        )
        return 1
    print(f"bench baselines in sync: {checked} records checked against HEAD")
    return 0


if __name__ == "__main__":
    sys.exit(main())
