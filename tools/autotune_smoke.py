"""Autotune smoke: cold-measure → cache-hit round trip on fake devices.

``make autotune-smoke`` / the distributed-overlap CI job run this to
prove the measure-once contract end to end on 8 fake host devices:

  1. **cold run** — ``distributed_betweenness_centrality`` with
     ``autotune="measure"`` against an empty cache file: candidate
     configs are micro-benched, recorded, and the result must match the
     Brandes oracle.
  2. **warm run** — the same graph/mesh with ``autotune="measure"``
     against the persisted file: every consult must HIT (zero fresh
     measurements, zero stores — the cache file is byte-identical
     after), and parity must hold again.
  3. **cache-only run** — ``autotune="cache"`` also serves fully from
     the file (no bench construction possible to need).

The cache file (``AUTOTUNE_CACHE_JSON``, default ``AUTOTUNE_cache.json``)
is left behind for CI to upload next to the BENCH baselines.
"""
from __future__ import annotations

import os
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks.common import ensure_devices, make_mesh  # noqa: E402

ensure_devices(8)

import numpy as np  # noqa: E402

CACHE_PATH = os.environ.get("AUTOTUNE_CACHE_JSON", "AUTOTUNE_cache.json")


def main() -> int:
    if not ensure_devices(8):
        print("autotune-smoke: needs 8 host devices, skipping")
        return 0

    from repro.autotune import CostCache
    from repro.core.brandes_ref import brandes_reference
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.graphs import rmat_graph

    cache_file = pathlib.Path(CACHE_PATH)
    if cache_file.exists():
        cache_file.unlink()  # a true cold start every smoke

    g = rmat_graph(6, 4, seed=2)
    expected = brandes_reference(g)
    mesh = make_mesh((2, 4), ("data", "model"))

    def run(mode: str) -> CostCache:
        cache = CostCache(CACHE_PATH)
        bc, _ = distributed_betweenness_centrality(
            g,
            mesh,
            batch_size=16,
            engine_kind="pallas_sparse",
            overlap="auto",
            autotune=mode,
            autotune_cache=cache,
        )
        # repo-standard distributed parity tolerance (f32 accumulation)
        np.testing.assert_allclose(bc, expected, rtol=1e-5, atol=1e-5)
        err = float(np.abs(bc - expected).max())
        print(
            f"autotune-smoke[{mode}]: parity ok (err {err:.2e}), "
            f"cache {cache.stats()}"
        )
        return cache

    # 1. cold: must measure and record
    cold = run("measure")
    assert cold.stores > 0, "cold run recorded nothing"
    assert cold.num_records() > 0
    assert cache_file.exists(), f"cache not persisted at {CACHE_PATH}"
    persisted = cache_file.read_bytes()

    # 2. warm measure: every consult hits, nothing re-measured
    warm = run("measure")
    assert warm.hits > 0, "warm run never consulted the cache"
    assert warm.stores == 0, (
        f"measure-once violated: warm run re-measured {warm.stores} configs"
    )
    assert cache_file.read_bytes() == persisted, "cache file changed on a warm run"

    # 3. cache-only mode serves from the file too
    cached = run("cache")
    assert cached.hits > 0 and cached.stores == 0

    print(
        f"autotune-smoke: measure-once round trip ok — "
        f"{cold.stores} configs measured cold, {warm.hits} served warm, "
        f"cache at {CACHE_PATH}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
