"""CI shard map: the tier-1 suite split into balanced parallel legs.

The distributed-overlap CI job used to run one 11-file pytest list that
drifted from the suite on disk whenever a test file was added — the new
file ran only in the slow everything-at-once tier1 job.  This map is the
single source of truth: every ``tests/test_*.py`` must belong to exactly
one shard, and ``--check`` fails CI when a file on disk appears in no
shard (or a shard lists a file that no longer exists).

Shards are balanced by measured wall time (local 8-fake-device run; the
per-shard figures below are from that measurement).  Rebalance by moving
files between lists — ``--check`` only cares about exact coverage.

Usage::

    python tools/ci_shards.py --list          # shard names, one per line
    python tools/ci_shards.py --files NAME    # space-separated file list
    python tools/ci_shards.py --check         # drift gate (exit 1 on drift)
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
TESTS = ROOT / "tests"

# Shard -> test files, every path relative to the repo root.  Keep the
# per-shard wall times (comments, local 8-fake-device measurement)
# roughly level when editing.
SHARDS: dict[str, tuple[str, ...]] = {
    "dist-core": (  # ~96s
        "tests/test_dist_bc.py",
        "tests/test_dist_overlap.py",
        "tests/test_dist_gnn2d.py",
    ),
    "dist-weighted": (  # ~97s
        "tests/test_weighted.py",
        "tests/test_dist_weighted.py",
        "tests/test_blocked_spmm.py",
        "tests/test_hybrid.py",
        "tests/test_serving.py",
        "tests/test_roofline.py",
    ),
    "engines": (  # ~103s
        "tests/test_operators.py",
        "tests/test_kernels.py",
        "tests/test_substrates.py",
        "tests/test_bc_core.py",
        "tests/test_properties.py",
        "tests/test_system.py",
    ),
    "system": (  # ~106s
        "tests/test_autotune.py",
        "tests/test_chaos.py",
        "tests/test_straggler.py",
        "tests/test_sampling.py",
        "tests/test_bench_check.py",
        "tests/test_arch_smoke.py",
    ),
}


def check() -> int:
    on_disk = {f"tests/{p.name}" for p in TESTS.glob("test_*.py")}
    listed: dict[str, str] = {}
    bad = 0
    for shard, files in SHARDS.items():
        for f in files:
            if f in listed:
                print(f"ci_shards: {f} listed in both {listed[f]!r} and {shard!r}")
                bad += 1
            listed[f] = shard
            if f not in on_disk:
                print(f"ci_shards: shard {shard!r} lists missing file {f}")
                bad += 1
    for f in sorted(on_disk - listed.keys()):
        print(f"ci_shards: {f} exists on disk but appears in no shard — "
              "add it to a shard list in tools/ci_shards.py")
        bad += 1
    if bad:
        return 1
    print(f"ci_shards: OK ({len(on_disk)} files across {len(SHARDS)} shards)")
    return 0


def main(argv: list[str]) -> int:
    if argv == ["--list"]:
        print("\n".join(SHARDS))
        return 0
    if len(argv) == 2 and argv[0] == "--files":
        files = SHARDS.get(argv[1])
        if files is None:
            print(f"ci_shards: unknown shard {argv[1]!r} "
                  f"(have: {', '.join(SHARDS)})", file=sys.stderr)
            return 2
        print(" ".join(files))
        return 0
    if argv == ["--check"]:
        return check()
    print(__doc__, file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
