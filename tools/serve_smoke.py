"""Serving smoke: snapshot-served BC query loop vs exact on fake devices.

``make serve-smoke`` / the distributed-overlap CI job run this to prove
the sampled-BC serving stack end to end on 8 fake host devices:

  1. **serve** — :func:`repro.launch.serve_bc.run_serving` on a 2x4 mesh
     with ``sampling="fixed", sample_frac=1.0``: a background refresher
     runs the exact schedule in block-budgeted slices over a shared
     BCCheckpoint while the foreground query loop polls ``top_k``.
  2. **accounting** — every query is exactly one of hit / stale_hit /
     miss; the cold query before any generation exists must miss, and
     the settled query after the refresher joins must hit.
  3. **parity** — the final generation is the full schedule, so its BC
     must match the Brandes oracle within 1e-6-scale f32 tolerance, and
     the served top-10 must equal the exact top-10.
  4. **resume** — a second ``run_serving`` over the same checkpoint
     republishes the committed snapshot at startup (no miss, no new
     rounds) — the killed-refresher replacement path.
"""
from __future__ import annotations

import os
import pathlib
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks.common import ensure_devices, make_mesh  # noqa: E402

ensure_devices(8)

import numpy as np  # noqa: E402


def main() -> int:
    if not ensure_devices(8):
        print("serve_smoke: needs 8 devices, have fewer — skipping")
        return 0

    from repro.core.brandes_ref import brandes_reference
    from repro.graphs import rmat_graph
    from repro.launch.serve_bc import run_serving
    from repro.serving.sampling import top_k_indices

    graph = rmat_graph(7, 8, seed=3)
    mesh = make_mesh((2, 4))
    exact = brandes_reference(graph)
    exact_top = set(int(v) for v in top_k_indices(exact, 10))

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "serve.npz")
        out = run_serving(
            graph,
            mesh,
            ckpt_path=ckpt,
            batch_size=16,
            sampling="fixed",
            sample_frac=1.0,
            refresh_blocks=2,
            generations=3,
            queries=8,
            top_k=10,
        )

        st = out["stats"]
        assert st["queries"] == st["hits"] + st["stale_hits"] + st["misses"], st
        assert st["misses"] >= 1, f"cold query should miss: {st}"
        assert st["hits"] >= 1, f"settled query should hit: {st}"
        assert st["stale_hits"] >= 1, f"mid-refresh queries should be stale: {st}"
        gens = [h["generation"] for h in out["history"]]
        assert gens == sorted(gens), f"generations regressed: {gens}"
        assert out["generations_published"] >= 2, out["generations_published"]

        err = float(np.abs(out["final_bc"] - exact).max())
        assert err < 1e-4, f"final-generation parity vs Brandes: {err}"
        served_top = set(out["final_top_k"])
        assert served_top == exact_top, (served_top, exact_top)

        # killed-refresher replacement: resumes (and serves) the
        # committed snapshot without recomputing any rounds
        out2 = run_serving(
            graph,
            mesh,
            ckpt_path=ckpt,
            batch_size=16,
            sampling="fixed",
            sample_frac=1.0,
            generations=1,
            queries=3,
            top_k=10,
        )
        assert out2["stats"]["misses"] == 0, out2["stats"]
        assert sum(r["rounds_run"] for r in out2["refresh_runs"]) == 0, (
            out2["refresh_runs"]
        )
        assert set(out2["final_top_k"]) == exact_top

    print(
        f"serve_smoke OK: {st['queries']} queries "
        f"({st['hits']} hit / {st['stale_hits']} stale / "
        f"{st['misses']} miss) across {out['generations_published']} "
        f"generations; final parity {err:.2e}; resume served "
        f"{out2['stats']['queries']} queries with 0 recomputed rounds"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
