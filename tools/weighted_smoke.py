"""Weighted-traversal smoke: bucketed BC vs the Dijkstra oracle.

``make weighted-smoke`` / the distributed-overlap CI job run this to
prove the weighted path end to end on 8 fake host devices:

  1. **single-device** — ``betweenness_centrality(weighted=True)`` on a
     dyadic-weighted R-MAT graph matches ``brandes_reference`` (which
     runs Dijkstra when the graph carries weights) for the dense and
     sparse engines.
  2. **distributed** — the same graph on a 2x4 mesh through the sparse
     and fused-dense (pallas) distributed engines, auto-derived delta.
  3. **unit-weight reduction** — weights all 1.0 at delta=1 must
     reproduce the unweighted engine's BC bitwise, single-device and
     distributed: the bucket loop degenerates to the level loop and the
     sigma/delta contractions are the same dot_generals.
"""
from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks.common import ensure_devices, make_mesh  # noqa: E402

ensure_devices(8)

import numpy as np  # noqa: E402


def _rel_err(got, oracle) -> float:
    """Max abs error scaled by the oracle's magnitude (BC grows ~n^2)."""
    scale = max(1.0, float(np.abs(oracle).max()))
    return float(np.abs(np.asarray(got) - oracle).max()) / scale


def main() -> int:
    if not ensure_devices(8):
        print("weighted_smoke: needs 8 devices, have fewer — skipping")
        return 0

    from repro.core.bc import betweenness_centrality
    from repro.core.brandes_ref import brandes_reference
    from repro.core.distributed import distributed_betweenness_centrality
    from repro.core.operators import auto_delta
    from repro.graphs import rmat_graph
    from repro.graphs.graph import Graph

    graph = rmat_graph(6, 4, seed=3, weights="dyadic")
    oracle = brandes_reference(graph)
    delta = auto_delta(graph)
    print(f"weighted_smoke: n={graph.n} arcs={graph.num_arcs} "
          f"auto_delta={delta:.4g}")

    for engine_kind in ("dense", "sparse"):
        got = betweenness_centrality(
            graph, engine_kind=engine_kind, weighted=True, batch_size=64
        )
        err = _rel_err(got.bc, oracle)
        print(f"weighted_smoke: single[{engine_kind}] rel_err={err:.3g}")
        assert err < 1e-5, f"single-device {engine_kind} diverged: {err}"

    mesh = make_mesh((2, 4))
    for engine_kind in ("sparse", "pallas"):
        bc, _ = distributed_betweenness_centrality(
            graph, mesh, engine_kind=engine_kind, weighted=True, batch_size=64
        )
        err = _rel_err(bc, oracle)
        print(f"weighted_smoke: dist[{engine_kind}] rel_err={err:.3g}")
        assert err < 1e-5, f"distributed {engine_kind} diverged: {err}"

    unit = rmat_graph(6, 4, seed=3, weights="unit")
    bare = Graph(n=unit.n, src=unit.src, dst=unit.dst)
    ref = betweenness_centrality(bare, engine_kind="sparse", batch_size=64)
    got = betweenness_centrality(
        unit, engine_kind="sparse", weighted=True, delta=1.0, batch_size=64
    )
    assert np.array_equal(np.asarray(ref.bc), np.asarray(got.bc)), (
        "unit weights must reproduce the unweighted engine bitwise"
    )
    bc_u, _ = distributed_betweenness_centrality(
        bare, mesh, engine_kind="sparse", batch_size=64
    )
    bc_w, _ = distributed_betweenness_centrality(
        unit, mesh, engine_kind="sparse", weighted=True, delta=1.0,
        batch_size=64,
    )
    assert np.array_equal(np.asarray(bc_u), np.asarray(bc_w)), (
        "distributed unit weights must reproduce the unweighted engine bitwise"
    )
    print("weighted_smoke: unit-weight bitwise reduction OK")
    print("weighted_smoke: PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
