"""Docs drift check: choice lists in the docs vs the source constants.

README.md and ARCHITECTURE.md document the engine × overlap × heuristics
× straggler configuration matrix.  Those lists have single sources of
truth in code (`ENGINE_KINDS`, `DIST_ENGINE_KINDS`, `OVERLAP_POLICIES`,
`HEURISTICS_MODES`, `STRAGGLER_POLICIES`, `AUTOTUNE_MODES`,
`FAULT_KINDS`, `INTEGRITY_MODES`, `WEIGHT_MODES`); this check
fails CI when a
constant gains a value the docs never mention — the failure mode where a
new engine/policy ships undocumented.  (The reverse — docs mentioning a
*removed* value — is not mechanically detectable here; on a rename,
update the docs in the same change and this check will at least demand
the new name appear.)

Run as ``make docs-check`` or ``python tools/check_docs.py``.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def _tokens(text: str) -> set[str]:
    # one token per word; keeps '+' so "expand+fold" survives intact
    return set(re.findall(r"[A-Za-z0-9_+]+", text))


def main() -> int:
    from repro.autotune import AUTOTUNE_MODES
    from repro.core.bc import ENGINE_KINDS
    from repro.core.distributed import DIST_ENGINE_KINDS
    from repro.core.driver import INTEGRITY_MODES, STRAGGLER_POLICIES
    from repro.core.operators import OVERLAP_POLICIES
    from repro.core.scheduler import HEURISTICS_MODES
    from repro.distributed.chaos import FAULT_KINDS
    from repro.graphs.generators import WEIGHT_MODES
    from repro.serving import SAMPLING_MODES

    overlap_choices = tuple(OVERLAP_POLICIES) + ("auto",)  # CLI surface
    required = {
        "README.md": {
            "engine_kind (single-device ENGINE_KINDS)": ENGINE_KINDS,
            "engine_kind (distributed DIST_ENGINE_KINDS)": DIST_ENGINE_KINDS,
            "overlap (OVERLAP_POLICIES + auto)": overlap_choices,
            "heuristics (HEURISTICS_MODES)": HEURISTICS_MODES,
            "straggler (STRAGGLER_POLICIES)": STRAGGLER_POLICIES,
            "autotune (AUTOTUNE_MODES)": AUTOTUNE_MODES,
            "chaos (FAULT_KINDS)": FAULT_KINDS,
            "integrity (INTEGRITY_MODES)": INTEGRITY_MODES,
            "sampling (SAMPLING_MODES)": SAMPLING_MODES,
            "weights (WEIGHT_MODES)": WEIGHT_MODES,
        },
        "ARCHITECTURE.md": {
            "engine_kind (distributed DIST_ENGINE_KINDS)": DIST_ENGINE_KINDS,
            "overlap (OVERLAP_POLICIES + auto)": overlap_choices,
            "straggler (STRAGGLER_POLICIES)": STRAGGLER_POLICIES,
            "autotune (AUTOTUNE_MODES)": AUTOTUNE_MODES,
            "chaos (FAULT_KINDS)": FAULT_KINDS,
            "integrity (INTEGRITY_MODES)": INTEGRITY_MODES,
            "sampling (SAMPLING_MODES)": SAMPLING_MODES,
            "weights (WEIGHT_MODES)": WEIGHT_MODES,
        },
    }
    failures: list[str] = []
    for doc, lists in required.items():
        path = ROOT / doc
        if not path.exists():
            failures.append(f"{doc}: missing")
            continue
        words = _tokens(path.read_text())
        for label, choices in lists.items():
            for choice in choices:
                if choice not in words:
                    failures.append(
                        f"{doc}: does not mention {label} choice {choice!r}"
                    )

    if failures:
        print("docs drift detected:")
        for f in failures:
            print(f"  - {f}")
        return 1
    n_lists = sum(len(v) for v in required.values())
    print(f"docs in sync: {n_lists} choice lists checked against constants")
    return 0


if __name__ == "__main__":
    sys.exit(main())
