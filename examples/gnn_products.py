"""GNN training with the paper's 2-D decomposition (models/gnn2d.py).

    PYTHONPATH=src python examples/gnn_products.py

Trains a reduced GraphCast-style processor on a synthetic products-like
graph, full-batch, with message passing distributed exactly like MGBC's
traversal (expand/fold collectives) over an 8-device mesh.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.data.graphs import full_graph_batch, to_2d_batch
from repro.graphs import rmat_graph
from repro.models import gnn as gnn_mod
from repro.models.gnn2d import make_gnn2d_loss_fn
from repro.optim import adamw

R, C = 2, 4
cfg = dataclasses.replace(get_arch("gin-tu").arch, n_layers=3, d_hidden=32)
graph = rmat_graph(10, 8, seed=3)
d_feat, n_classes = 32, 16

batch = full_graph_batch(cfg, graph, graph.n, 2 * graph.num_arcs, d_feat,
                         n_classes, n_classes, seed=0)
# learnable labels: a linear probe of the node features
probe = np.random.default_rng(1).standard_normal((d_feat, n_classes))
batch["labels"] = np.argmax(batch["node_feat"] @ probe, axis=1).astype(np.int32)
b2d = to_2d_batch(batch, graph.n, R, C)
chunk = b2d["node_feat"].shape[0] // (R * C)

from repro.launch.mesh import make_mesh

mesh = make_mesh((R, C), ("data", "model"))
loss_fn, _ = make_gnn2d_loss_fn(
    cfg, mesh, "full_graph", chunk=chunk, max_arcs=b2d["src_local"].shape[2]
)
params = gnn_mod.init_params(cfg, d_feat, n_classes, jax.random.PRNGKey(0))
opt = adamw(3e-3)
state = opt.init(params)

@jax.jit
def step(params, state, batch):
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
    params, state = opt.update(grads, state, params)
    return params, state, loss

jb = jax.tree.map(jnp.asarray, b2d)
t0 = time.time()
losses = []
for i in range(60):
    params, state, loss = step(params, state, jb)
    losses.append(float(loss))
    if i % 10 == 0 or i == 59:
        print(f"step {i:3d}  loss {losses[-1]:.4f}")
print(f"{time.time()-t0:.1f}s — node classification on n={graph.n}, "
      f"m={graph.num_edges} with 2-D distributed message passing ✓")
assert losses[-1] < 0.6 * losses[0], (losses[0], losses[-1])
