"""Distributed MGBC: 2-D decomposition + sub-clustering on a device mesh.

    PYTHONPATH=src python examples/bc_distributed.py

Runs the paper's full stack on 8 host devices: two sub-clusters (fr=2),
each a 2x2 grid (fd=4), R-MAT input, heuristics on, the expand/fold
collectives ring-pipelined against block compute (paper Fig. 2) — then
verifies against the oracle.  The same code drives the 16x16(x2)
production mesh.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np

from repro.core import brandes_reference
from repro.core.distributed import distributed_betweenness_centrality
from repro.graphs import rmat_graph

graph = rmat_graph(8, 8, seed=1)
print(f"R-MAT SCALE 8, EF 8: n={graph.n}, m={graph.num_edges}")

from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
print(f"mesh: {dict(mesh.shape)} — fr=2 sub-clusters of fd=4 (2x2 grids)")

bc, schedule = distributed_betweenness_centrality(
    graph,
    mesh,
    replica_axis="pod",
    batch_size=16,
    heuristics="h3",
    overlap="expand+fold",  # ppermute rings instead of barrier collectives
)
print(
    f"{len(schedule.rounds)} rounds "
    f"({schedule.num_explicit} explicit sources, "
    f"{schedule.num_derived} derived by the 2-degree heuristic, "
    f"{schedule.num_leaf_skipped} leaves removed)"
)
np.testing.assert_allclose(bc, brandes_reference(graph), rtol=1e-5, atol=1e-5)
print("distributed result matches Brandes oracle ✓")
