"""Quickstart: exact betweenness centrality in five lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import betweenness_centrality, brandes_reference
from repro.graphs import road_like_graph

# a road-network-like graph: long diameter, many 1-/2-degree vertices
graph = road_like_graph(10, 10, spur_fraction=0.5, seed=7)
print(f"graph: n={graph.n} vertices, m={graph.num_edges} edges")

# MGBC with all heuristics (H3 = 1-degree reduction + 2-degree DMF)
result = betweenness_centrality(graph, batch_size=32, heuristics="h3")

print(
    f"rounds: {result.rounds_run}; forward BFS columns: "
    f"{result.forward_columns} (of {graph.n} vertices — the rest were "
    f"handled by the heuristics)"
)
top = np.argsort(result.bc)[::-1][:5]
for v in top:
    print(f"  vertex {int(v):4d}   BC = {result.bc[int(v)]:9.1f}")

# exactness: identical to the textbook Brandes oracle
np.testing.assert_allclose(result.bc, brandes_reference(graph), rtol=1e-5, atol=1e-5)
print("matches Brandes oracle ✓")
