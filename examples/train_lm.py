"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-sized

Uses the full production substrate: config system, optimizer, synthetic
data pipeline with prefetch, async checkpointing + exact resume.
"""
import argparse
import sys

sys.argv0 = sys.argv[0]

from repro.configs.registry import get_arch
from repro.launch.train import reduced_lm, train_lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    base = get_arch("codeqwen1.5-7b").arch
    if args.tiny:
        cfg = reduced_lm(base, layers=2, d_model=128, vocab=1024)
        steps, batch, seq = args.steps or 30, 4, 128
    else:
        # ~100M params: 12 layers x d=768 (GPT-2-small-class).
        # batch 4 x seq 256 keeps a CPU step at seconds; on TPU raise both.
        cfg = reduced_lm(base, layers=12, d_model=768, vocab=32768)
        steps, batch, seq = args.steps or 200, 4, 256

    n_params = (
        cfg.vocab * cfg.d_model
        + cfg.n_layers
        * (
            2 * cfg.d_model * (cfg.n_heads + cfg.n_kv_heads) * cfg.head_dim
            + 3 * cfg.d_model * cfg.d_ff
        )
    )
    print(f"training ~{n_params/1e6:.0f}M-param LM for {steps} steps")
    out = train_lm(cfg, steps=steps, batch=batch, seq=seq, ckpt_dir=args.ckpt_dir)
    first = sum(out["losses"][:10]) / max(len(out["losses"][:10]), 1)
    print(f"loss: {first:.3f} (first 10 avg) -> {out['final_loss']:.3f} (final)")


if __name__ == "__main__":
    main()
