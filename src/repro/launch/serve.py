"""Deprecated alias of :mod:`repro.launch.serve_lm` (the LM decoder).

``repro.launch.serve`` historically named the LM serving launcher; the
BC snapshot-serving front end (:mod:`repro.launch.serve_bc`) made the
bare name ambiguous, so the LM launcher moved to ``serve_lm``.  This
shim keeps old imports and ``python -m repro.launch.serve`` invocations
working one release longer.
"""
from __future__ import annotations

import warnings

from repro.launch.serve_lm import main, serve_loop

__all__ = ["main", "serve_loop"]

warnings.warn(
    "repro.launch.serve is deprecated: the LM serving launcher moved to "
    "repro.launch.serve_lm (BC snapshot serving lives in "
    "repro.launch.serve_bc)",
    DeprecationWarning,
    stacklevel=2,
)

if __name__ == "__main__":
    main()
