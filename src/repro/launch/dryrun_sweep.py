"""Sequential per-cell dry-run sweep with subprocess isolation.

Each cell compiles in a fresh process (XLA's compile caches and SPMD
structures otherwise accumulate ~hundreds of MB per cell and OOM the
host after a few dozen cells).  Results merge into one JSON.

  PYTHONPATH=src python -m repro.launch.dryrun_sweep --out results/dryrun_all.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile


def list_cells():
    from repro.configs.registry import get_arch, list_archs

    cells = []
    for arch in list_archs():
        for shape in get_arch(arch).shapes:
            for mp in (False, True):
                cells.append((arch, shape, mp))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun_all.json")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--only", default=None, help="substring filter on arch:shape")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    records, failures = [], []
    cells = list_cells()
    for arch, shape, mp in cells:
        tag = f"{arch}:{shape}:{'multi' if mp else 'single'}"
        if args.only and args.only not in tag:
            continue
        with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as tmp:
            tmp_path = tmp.name
        cmd = [
            sys.executable,
            "-m",
            "repro.launch.dryrun",
            "--arch",
            arch,
            "--shape",
            shape,
            "--out",
            tmp_path,
        ]
        if mp:
            cmd.append("--multi-pod")
        if args.hlo_dir:
            os.makedirs(args.hlo_dir, exist_ok=True)
            cmd += ["--hlo-dir", args.hlo_dir]
        try:
            proc = subprocess.run(
                cmd,
                capture_output=True,
                text=True,
                timeout=args.timeout,
                env={**os.environ, "PYTHONPATH": "src"},
            )
            with open(tmp_path) as f:
                data = json.load(f)
            records += data.get("records", [])
            failures += data.get("failures", [])
            status = "ok" if proc.returncode == 0 else "FAIL"
            line = [l for l in proc.stdout.splitlines() if l.startswith("[")]
            print(line[-1] if line else f"[{status}] {tag}", flush=True)
        except subprocess.TimeoutExpired:
            failures.append({"cell": tag, "error": "timeout"})
            print(f"[FAIL] {tag}: timeout", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append({"cell": tag, "error": repr(e)})
            print(f"[FAIL] {tag}: {e!r}", flush=True)
        finally:
            if os.path.exists(tmp_path):
                os.unlink(tmp_path)
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)

    print(f"\n{len(records)} cells compiled, {len(failures)} failures")
    for f_ in failures:
        print("  FAIL", f_["cell"], f_["error"][:120])


if __name__ == "__main__":
    main()
