"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state.  Single pod = 16x16 = 256 chips (v5e pod);
multi-pod adds a leading "pod" axis (2 pods = 512 chips).

Axis roles:
  "pod"   — sub-cluster replication (MGBC fr; LM/GNN/recsys pure DP)
  "data"  — batch / MGBC grid rows (R)
  "model" — tensor/expert parallel / MGBC grid columns (C)

``make_mesh`` is the version-compat constructor (JAX 0.4.37 lacks
``jax.sharding.AxisType``); every mesh in tests, benchmarks, examples
and launchers goes through it.
"""
from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_mesh", "make_production_mesh", "make_bench_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_bench_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary meshes for scaling benchmarks (fr/fd sweeps)."""
    return make_mesh(shape, axes)
