import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first backend initialization).

import argparse
import json
import time
import traceback

import jax
from jax.sharding import PartitionSpec

from repro.configs.registry import get_arch, list_archs
from repro.distributed.sharding import named_sharding, use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell

__doc__ = """Multi-pod dry-run: lower + compile every (arch × shape × mesh)
cell with ShapeDtypeStruct inputs (no allocation) and record
memory_analysis / cost_analysis / collective bytes for the roofline.

  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --out dryrun.json
"""


def _shardings_for(mesh, logical_tree):
    is_spec = lambda x: isinstance(x, PartitionSpec)
    return jax.tree.map(
        lambda spec: named_sharding(mesh, spec), logical_tree, is_leaf=is_spec
    )


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, save_hlo: str | None = None):
    """Lower + compile one cell. Returns a result record dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = get_arch(arch_name)
    t0 = time.time()
    cell = build_cell(bundle, shape_name, mesh=mesh)

    # Fail-fast memory report (BC cells): per-engine adjacency + state
    # footprint, printed *before* the compile so an over-budget dense
    # engine is visible without waiting for (or OOMing in) compilation.
    footprints = cell.static_meta.get("hbm_footprint_bytes")
    if footprints:
        per_engine = ", ".join(
            f"{kind}={b/2**30:.2f} GiB" for kind, b in sorted(footprints.items())
        )
        print(f"[mem] {cell.name}: per-device footprint {per_engine}")

    # Measured-cost autotune status (BC cells): whether a run of this
    # graph/mesh key would hit the persistent cost cache, and with how
    # many measured configs — before any compile happens.
    tune = cell.static_meta.get("tune")
    if tune:
        source = (
            f"cache {tune['cache_path']} ({tune['cached_configs']} configs)"
            if tune["cached_configs"]
            else "no cached measurements (autotune=measure would record them)"
        )
        print(f"[tune] {cell.name}: key {tune['graph_key']} -> {source}")

    # Self-healing envelope (BC cells): the retry/backoff budget, the
    # checkpoint generation depth, and whether replica loss re-meshes —
    # what a production run of this cell survives without intervention.
    res = cell.static_meta.get("resilience")
    if res:
        print(
            f"[resilience] {cell.name}: {res['max_retries']} retries "
            f"(backoff {res['retry_backoff_s']}s), "
            f"{res['checkpoint_generations']} snapshot generations, "
            f"replica-loss re-mesh "
            f"{'on' if res['remesh_on_replica_loss'] else 'off (fr=1)'}; "
            f"injectable faults: {', '.join(res['fault_kinds'])}"
        )

    with use_mesh(mesh):
        if hasattr(cell.fn, "lower"):  # pre-jitted (BC round fn)
            jitted = cell.fn
        elif cell.needs_shardmap_mesh:  # shard_map carries the shardings
            jitted = jax.jit(cell.fn, donate_argnums=cell.donate_argnums)
        else:
            in_shardings = tuple(
                _shardings_for(mesh, logical) for logical in cell.args_logical
            )
            jitted = jax.jit(
                cell.fn,
                in_shardings=in_shardings,
                donate_argnums=cell.donate_argnums,
            )
        lowered = jitted.lower(*cell.args_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    from repro.roofline.hlo import analyze_hlo_module

    hlo_terms = analyze_hlo_module(hlo)

    record = {
        "cell": cell.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (
                peak := mem.argument_size_in_bytes
                + max(mem.output_size_in_bytes - mem.alias_size_in_bytes, 0)
                + mem.temp_size_in_bytes
            ),
            # x86-backend bf16->f32 shadow copies don't exist on TPU
            # (see roofline/hlo.py artifact accounting)
            "tpu_peak_bytes_per_device": max(
                peak - hlo_terms["bf16_upcast_artifact_bytes"],
                mem.argument_size_in_bytes,
            ),
        },
        "xla_cost": {
            "flops_per_device": cost.get("flops", 0.0),
            "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        },
        "hlo_terms": hlo_terms,
        "meta": cell.static_meta,
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--multi-pod", action="store_true", help="2x16x16 mesh")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--hlo-dir", default=None, help="dump per-cell HLO text")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    records, failures = [], []
    for arch_name in archs:
        bundle = get_arch(arch_name)
        shapes = [args.shape] if args.shape else list(bundle.shapes)
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch_name}:{shape_name}:{'multi' if mp else 'single'}"
                hlo_path = (
                    os.path.join(args.hlo_dir, tag.replace(":", "__") + ".hlo")
                    if args.hlo_dir
                    else None
                )
                try:
                    rec = run_cell(arch_name, shape_name, mp, save_hlo=hlo_path)
                    records.append(rec)
                    gb = rec["memory"]["peak_bytes_per_device"] / 2**30
                    tgb = rec["memory"]["tpu_peak_bytes_per_device"] / 2**30
                    print(
                        f"[ok] {tag:64s} compile={rec['compile_s']:7.1f}s "
                        f"peak/dev={gb:7.2f} GiB (tpu-adj {tgb:6.2f}) "
                        f"flops/dev={rec['hlo_terms']['flops']:.3e}"
                    )
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append({"cell": tag, "error": repr(e)})
                    print(f"[FAIL] {tag}: {e}")
                    if args.fail_fast:
                        traceback.print_exc()
                        raise

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"records": records, "failures": failures}, f, indent=1)
    print(f"\n{len(records)} cells compiled, {len(failures)} failures")
    if failures:
        for f_ in failures:
            print("  FAIL", f_["cell"])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
