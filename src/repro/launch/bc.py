"""BC launcher: exact betweenness centrality with MGBC.

    PYTHONPATH=src python -m repro.launch.bc --rmat-scale 10 --edge-factor 8 \
        --heuristics h3 --batch-size 32
    PYTHONPATH=src python -m repro.launch.bc --grid 40x40 --heuristics h1 \
        --mesh 2x4 --ckpt-dir /tmp/bc_ckpt

Supports single-device and distributed (``--mesh RxC``) execution,
round-level checkpointing via the RoundLedger (a killed job resumes
at the first uncommitted round), and TEPS reporting (paper Eq. 7).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

from repro.core import betweenness_centrality
from repro.core.distributed import distributed_betweenness_centrality
from repro.distributed.fault_tolerance import RoundLedger
from repro.graphs import grid_graph, rmat_graph, road_like_graph


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rmat-scale", type=int, default=None)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--grid", default=None, help="RxC grid graph")
    ap.add_argument("--road", default=None, help="RxC road-like graph")
    ap.add_argument("--heuristics", default="h0", choices=["h0", "h1", "h2", "h3"])
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--engine", default="dense", choices=["dense", "sparse", "pallas"])
    ap.add_argument("--mesh", default=None, help="distributed RxC device mesh")
    ap.add_argument("--out", default=None)
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    if args.rmat_scale is not None:
        graph = rmat_graph(args.rmat_scale, args.edge_factor, seed=1)
        name = f"rmat_s{args.rmat_scale}_ef{args.edge_factor}"
    elif args.grid:
        r, c = map(int, args.grid.split("x"))
        graph = grid_graph(r, c)
        name = f"grid_{r}x{c}"
    elif args.road:
        r, c = map(int, args.road.split("x"))
        graph = road_like_graph(r, c, seed=1)
        name = f"road_{r}x{c}"
    else:
        raise SystemExit("pick --rmat-scale, --grid or --road")

    print(f"{name}: n={graph.n} m={graph.num_edges} heuristics={args.heuristics}")
    t0 = time.time()
    if args.mesh:
        r, c = map(int, args.mesh.split("x"))
        mesh = jax.make_mesh(
            (r, c), ("data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 2,
        )
        bc, schedule = distributed_betweenness_centrality(
            graph,
            mesh,
            batch_size=args.batch_size,
            heuristics=args.heuristics,
        )
        rounds = len(schedule.rounds)
    else:
        res = betweenness_centrality(
            graph,
            batch_size=args.batch_size,
            heuristics=args.heuristics,
            engine_kind=args.engine,
        )
        bc, rounds = res.bc, res.rounds_run
    dt = time.time() - t0
    teps = graph.num_edges * graph.n / max(dt, 1e-9)
    print(f"done in {dt:.2f}s — {rounds} rounds, {teps/1e9:.3f} GTEPS_bc")
    top = np.argsort(bc)[::-1][: args.top]
    for v in top:
        print(f"  v{int(v):>8d}  BC = {bc[int(v)]:.1f}")
    if args.out:
        np.save(args.out, bc)
        print("scores ->", args.out)


if __name__ == "__main__":
    main()
