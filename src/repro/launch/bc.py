"""BC launcher: exact betweenness centrality with MGBC.

    PYTHONPATH=src python -m repro.launch.bc --rmat-scale 10 --edge-factor 8 \
        --heuristics h3 --batch-size 32
    PYTHONPATH=src python -m repro.launch.bc --grid 40x40 --heuristics h1 \
        --mesh 2x4 --engine pallas --ckpt-dir /tmp/bc_ckpt
    PYTHONPATH=src python -m repro.launch.bc --rmat-scale 8 --mesh 2x2x2 \
        --overlap expand --straggler redeal
    PYTHONPATH=src python -m repro.launch.bc --road 20x20 --weights dyadic \
        --weighted --mesh 2x4 --engine pallas

``--weighted`` swaps the level-synchronous traversal for the bucketed
weighted one (distance buckets of width ``--delta``, auto-derived when
unset); ``--weights unit|dyadic`` samples edge weights onto the
generated graph (dyadic = k/4, k=1..16 — exactly representable, so f32
distance sums are exact).  Weighted runs restrict ``--heuristics`` to
the weight-sound modes (h0/h1/h1t).

Supports single-device and distributed execution; every engine of the
unified traversal stack is selectable with ``--engine`` (single-device:
``dense | sparse | pallas | pallas_bf16``; distributed: the ``sparse``
arc-list engine, the Pallas dense-block engines, the blocked-sparse
``pallas_sparse`` engine for graphs whose dense blocks do not fit, or
``pallas_hybrid``, which picks dense vs BCSR *per device cell* from the
roofline's bytes-streamed threshold — ``--hybrid-threshold`` overrides
the break-even, the per-cell choice is logged).

``--mesh RxC`` runs one 2-D-decomposed traversal grid; ``--mesh FRxRxC``
(three dims) replicates that grid into ``FR`` sub-clusters (paper §3.3),
each processing different source rounds concurrently.

``--heuristics`` selects the preprocessing (paper §3.4 / Fig. 12 naming;
see core/heuristics/): ``h0`` none | ``h1`` 1-degree reduction |
``h2`` 2-degree DMF | ``h3`` both | ``h1t``/``h3t`` exhaustive
pendant-tree contraction (beyond-paper).

``--overlap`` selects the distributed collective schedule: ``none``
(barrier all_gather/psum_scatter), ``expand`` (ring-pipelined gather),
``expand+fold`` (both collectives decomposed into ppermute rings
overlapped with block compute — paper Fig. 2) or ``auto`` (picked from
the roofline's pipelining estimate and logged).

``--straggler`` selects the sub-cluster scheduling policy (needs a
three-dim ``--mesh``): ``none`` static deal | ``steal`` idle replicas
pull rounds from the heaviest backlog (+ speculative tail backups) |
``redeal`` pending rounds are re-packed across replicas when one
replica's EWMA per-round wall exceeds ``--straggler-factor ×`` the
fastest's.  Commits stay exactly-once across steals, re-deals and
kill-and-resume (per-replica round ledgers, first commit wins).

``--autotune`` swaps the roofline guesses behind the tile, hybrid-cell,
``--overlap auto`` and straggler-prior choices for cached measurements
(``off`` roofline-only | ``cache`` consult, never measure | ``measure``
micro-bench on a miss and record), persisted across runs via
``--autotune-cache PATH``; it also packs rounds by sampled root
eccentricity so depth-divergent roots stop sharing a batch.

``--chaos PLAN`` injects a deterministic fault plan at the round and
file-write seams (``kind@at[xcount][:arg]`` entries: ``transient``,
``poison``, ``kill:rI``, ``crash``, ``torn``, ``cache``, ``flip``
(finite silent corruption), ``stall`` (delay a dispatch) — see
distributed/chaos.py) so any failure is reproducible from the CLI; the
driver's self-healing (``--max-retries`` / ``--retry-backoff`` retry
budget, ``--numeric-guard`` non-finite quarantine, replica-loss re-mesh
under a straggler policy) recovers and reports what it did.
``--generations`` keeps that many rotated BCCheckpoint snapshots so a
torn newest write falls back instead of cold-starting.

``--integrity`` makes every round self-verifying (needs ``--mesh``):
``audit`` cross-checks each drained block against its in-graph claimed
sum plus output-domain invariants; ``checksum`` additionally threads an
ABFT column-sum lane through every level SpMM, catching silent data
corruption (e.g. ``--chaos 'flip@K'``) that is finite and so invisible
to the numeric guard.  A failed audit quarantines and re-dispatches the
block; under ``--straggler steal`` duplicated tail rounds are also
compared lane-vs-lane (duplicate-vote SDC detection) with a tie-breaker
re-dispatch on mismatch.  ``--dispatch-deadline SECONDS|auto`` arms the
dispatch watchdog: a block exceeding its deadline (``auto`` derives one
from the roofline/autotune round prior) is re-dispatched and, when the
retry budget is spent, escalated to a replica loss that the elastic
re-mesh absorbs — a wedged replica can no longer hang the job.

The per-device adjacency + state footprint is reported before
compiling; ``--hbm-gb <GiB>`` additionally arms the fail-fast memory
guard, turning an over-budget engine into an immediate error with a
suggestion (``pallas_sparse`` / a larger mesh) instead of an OOM
mid-round.  ``--ckpt-dir`` snapshots (partial BC, n_s, committed
rounds) through a BCCheckpoint — a killed job resumes at the first
uncommitted round — and TEPS is reported per paper Eq. 7.
"""
from __future__ import annotations

import argparse
import logging
import os
import time

import numpy as np

from repro.autotune import AUTOTUNE_MODES
from repro.core import betweenness_centrality
from repro.core.bc import ENGINE_KINDS
from repro.core.driver import INTEGRITY_MODES, STRAGGLER_POLICIES
from repro.core.operators import OVERLAP_POLICIES
from repro.core.scheduler import HEURISTICS_MODES
from repro.core.distributed import (
    DIST_ENGINE_KINDS,
    distributed_betweenness_centrality,
)
from repro.distributed.fault_tolerance import BCCheckpoint
from repro.graphs import grid_graph, rmat_graph, road_like_graph
from repro.graphs.generators import WEIGHT_MODES, weighted_copy
from repro.serving import SAMPLING_MODES


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rmat-scale", type=int, default=None)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--grid", default=None, help="RxC grid graph")
    ap.add_argument("--road", default=None, help="RxC road-like graph")
    ap.add_argument("--heuristics", default="h0", choices=list(HEURISTICS_MODES))
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument(
        "--engine",
        default="dense",
        choices=sorted(set(ENGINE_KINDS) | set(DIST_ENGINE_KINDS)),
    )
    ap.add_argument(
        "--mesh",
        default=None,
        help="distributed device mesh: RxC (one 2-D grid) or FRxRxC "
        "(FR sub-cluster replicas of an RxC grid, paper §3.3)",
    )
    ap.add_argument(
        "--overlap",
        default="none",
        choices=list(OVERLAP_POLICIES) + ["auto"],
        help="distributed collective schedule (ring pipelining; needs --mesh; "
        "'auto' picks from the roofline estimate)",
    )
    ap.add_argument(
        "--tile",
        default=None,
        help="blocked-sparse tile shape BM or BMxBK (pallas_sparse / "
        "pallas_hybrid; both must divide the partition chunk; default: "
        "largest lane-friendly divisor <= 128).  Coarser tiles push "
        "more hybrid cells over the dense break-even",
    )
    ap.add_argument(
        "--hybrid-threshold",
        type=float,
        default=1.0,
        help="pallas_hybrid break-even: a cell streams BCSR tiles when "
        "their bytes are under this fraction of its dense-block bytes "
        "(0 forces all cells dense, a large value all sparse; the "
        "per-cell choice is logged)",
    )
    ap.add_argument(
        "--hbm-gb",
        type=float,
        default=0.0,
        help="per-device HBM budget (GiB) arming the fail-fast memory "
        "guard (e.g. 16 for v5e); the footprint is always reported, but "
        "only an explicit budget turns it into a pre-compile error",
    )
    ap.add_argument(
        "--straggler",
        default="none",
        choices=list(STRAGGLER_POLICIES),
        help="sub-cluster straggler policy (needs a FRxRxC --mesh): "
        "'steal' pulls rounds into replicas whose queue ran dry; "
        "'redeal' re-packs all pending rounds when one replica's EWMA "
        "per-round wall exceeds --straggler-factor x the fastest's",
    )
    ap.add_argument(
        "--straggler-factor",
        type=float,
        default=2.0,
        help="EWMA per-round-wall ratio over the fastest replica that "
        "triggers a re-deal (straggler=redeal only; steal is "
        "queue-driven and ignores it)",
    )
    ap.add_argument(
        "--autotune",
        default="off",
        choices=list(AUTOTUNE_MODES),
        help="measured-cost autotuning (needs --mesh): 'cache' consults "
        "the measured-cost cache and falls back to the roofline on a "
        "miss; 'measure' micro-benches candidate configs on a miss and "
        "records them (measure-once — the next run with the same graph "
        "stats + mesh hits the cache).  Also switches the scheduler to "
        "eccentricity-packed rounds",
    )
    ap.add_argument(
        "--autotune-cache",
        default=None,
        help="path of the persistent measured-cost cache JSON "
        "(default: in-memory for this run only)",
    )
    ap.add_argument(
        "--chaos",
        default=None,
        help="deterministic fault-injection plan (needs --mesh): "
        "'kind@at[xcount][:arg]' entries separated by ';', plus 'seed=N' "
        "— kinds transient | poison[:nan|:inf] | kill:rI | crash | torn "
        "| cache | flip[:rI|:dI|:neg] (finite silent corruption; pair "
        "with --integrity) | stall[:MS] (delay a dispatch; pair with "
        "--dispatch-deadline), e.g. "
        "'seed=7;transient@1x2;poison@3:nan;kill@4:r1;flip@5'. "
        "Reproduces any failure from the CLI; recovery is reported "
        "(see distributed/chaos.py)",
    )
    ap.add_argument(
        "--integrity",
        default="off",
        choices=list(INTEGRITY_MODES),
        help="self-verifying rounds (needs --mesh): 'audit' cross-checks "
        "each drained block against its claimed sum + output-domain "
        "invariants; 'checksum' adds the ABFT column-sum lane through "
        "every level SpMM (catches finite silent corruption the "
        "numeric guard cannot see).  Failed blocks are quarantined and "
        "re-dispatched; detection counters are reported",
    )
    ap.add_argument(
        "--dispatch-deadline",
        default=None,
        help="dispatch watchdog deadline in seconds, or 'auto' to derive "
        "one from the roofline/autotune round prior (needs --mesh).  A "
        "block exceeding it is re-dispatched, then escalated to a "
        "replica loss the elastic re-mesh absorbs",
    )
    ap.add_argument(
        "--max-retries",
        type=int,
        default=None,
        help="self-healing retry budget per dispatch block (transient "
        "errors + quarantined non-finite blocks; default 2)",
    )
    ap.add_argument(
        "--retry-backoff",
        type=float,
        default=None,
        help="base seconds of the exponential backoff between transient "
        "retries (default 0.05)",
    )
    ap.add_argument(
        "--numeric-guard",
        action="store_true",
        help="force the post-block non-finite bc/ns guard on (adds a "
        "per-block host sync on the static fast path; it is automatic "
        "wherever the loop already syncs — profile/straggler modes — "
        "and whenever a fallback path exists)",
    )
    ap.add_argument(
        "--generations",
        type=int,
        default=None,
        help="BCCheckpoint snapshot generations to keep (default 3); "
        "load falls back to the newest intact one on a torn write",
    )
    ap.add_argument(
        "--sampling",
        default="off",
        choices=list(SAMPLING_MODES),
        help="source-sampled approximate BC: 'fixed' runs a seeded "
        "k-root subset and rescales by N/k; 'adaptive' additionally "
        "stops dispatching once the top-k rank set stabilizes across "
        "consecutive blocks.  Needs --heuristics h0 (per-root "
        "additivity); --sample-frac 1.0 reproduces the exact schedule",
    )
    ap.add_argument(
        "--sample-frac",
        type=float,
        default=None,
        help="sample size as a fraction of the eligible roots "
        "(mutually exclusive with --sample-k)",
    )
    ap.add_argument(
        "--sample-k",
        type=int,
        default=None,
        help="sample size as a root count (mutually exclusive with "
        "--sample-frac)",
    )
    ap.add_argument(
        "--sample-seed",
        type=int,
        default=0,
        help="seed of the root draw; the same seed gives nested "
        "samples as k grows (serving refinement extends evidence)",
    )
    ap.add_argument(
        "--weighted",
        action="store_true",
        help="weighted BC via the bucketed (delta-stepping-style) "
        "traversal instead of the level-synchronous loop.  Needs edge "
        "weights on the graph: pass --weights to sample them on the "
        "generated graph.  Restricts --heuristics to the weight-sound "
        "modes (h0/h1/h1t)",
    )
    ap.add_argument(
        "--weights",
        default="none",
        choices=list(WEIGHT_MODES),
        help="edge-weight mode of the generated graph: 'unit' (all 1.0; "
        "reproduces the unweighted run exactly at --delta 1) or 'dyadic' "
        "(k/4, k=1..16 — exactly representable, so distance sums are "
        "exact in f32).  Implies nothing by itself; pair with --weighted",
    )
    ap.add_argument(
        "--delta",
        type=float,
        default=None,
        help="bucket width of the weighted traversal (needs --weighted; "
        "default: derived from the weight distribution, see "
        "repro.core.operators.auto_delta)",
    )
    ap.add_argument("--ckpt-dir", default=None, help="round-ledger resume dir")
    ap.add_argument("--out", default=None)
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")

    if args.rmat_scale is not None:
        graph = rmat_graph(
            args.rmat_scale, args.edge_factor, seed=1, weights=args.weights
        )
        name = f"rmat_s{args.rmat_scale}_ef{args.edge_factor}"
    elif args.grid:
        r, c = map(int, args.grid.split("x"))
        graph = grid_graph(r, c)
        if args.weights != "none":
            graph = weighted_copy(graph, weights=args.weights, seed=1)
        name = f"grid_{r}x{c}"
    elif args.road:
        r, c = map(int, args.road.split("x"))
        graph = road_like_graph(r, c, seed=1, weights=args.weights)
        name = f"road_{r}x{c}"
    else:
        raise SystemExit("pick --rmat-scale, --grid or --road")

    if args.weighted and graph.w is None:
        raise SystemExit(
            "--weighted needs edge weights; pass --weights unit|dyadic"
        )
    if args.delta is not None and not args.weighted:
        raise SystemExit("--delta sizes the weighted buckets; pass --weighted")

    checkpoint = None
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        ckpt_kw = {} if args.generations is None else {"generations": args.generations}
        checkpoint = BCCheckpoint(
            os.path.join(args.ckpt_dir, f"{name}.npz"), **ckpt_kw
        )
        if checkpoint.exists():
            _, _, committed = checkpoint.load()
            gen = checkpoint.loaded_generation
            print(
                f"resuming: {len(committed)} rounds already committed"
                + ("" if not gen else f" (from fallback generation {gen})")
            )

    if args.overlap != "none" and not args.mesh:
        raise SystemExit("--overlap is a distributed schedule; pass --mesh RxC")
    if args.engine in ("pallas_sparse", "pallas_hybrid") and not args.mesh:
        raise SystemExit(
            f"{args.engine} is a distributed engine; pass --mesh RxC"
        )
    tile = None
    if args.tile:
        if not args.mesh:
            raise SystemExit(
                "--tile shapes the blocked-sparse/hybrid layouts; pass --mesh RxC"
            )
        try:
            dims = tuple(int(d) for d in args.tile.split("x"))
        except ValueError:
            dims = ()
        if len(dims) not in (1, 2) or any(d <= 0 for d in dims):
            raise SystemExit("--tile takes BM or BMxBK (positive integers)")
        tile = (dims[0], dims[-1])
    mesh_shape = tuple(map(int, args.mesh.split("x"))) if args.mesh else None
    if mesh_shape is not None and len(mesh_shape) not in (2, 3):
        raise SystemExit("--mesh takes RxC or FRxRxC")
    if args.straggler != "none" and (mesh_shape is None or len(mesh_shape) != 3):
        raise SystemExit(
            "--straggler re-deals rounds between sub-cluster replicas; "
            "pass a replicated --mesh FRxRxC"
        )
    if args.autotune != "off" and not args.mesh:
        raise SystemExit(
            "--autotune measures distributed round configs; pass --mesh RxC"
        )
    if args.chaos and not args.mesh:
        raise SystemExit(
            "--chaos injects faults at the distributed round seam; "
            "pass --mesh RxC"
        )
    if args.integrity != "off" and not args.mesh:
        raise SystemExit(
            "--integrity audits the distributed round loop; pass --mesh RxC"
        )
    deadline = None
    if args.dispatch_deadline is not None:
        if not args.mesh:
            raise SystemExit(
                "--dispatch-deadline arms the distributed dispatch "
                "watchdog; pass --mesh RxC"
            )
        if args.dispatch_deadline == "auto":
            deadline = "auto"
        else:
            try:
                deadline = float(args.dispatch_deadline)
            except ValueError:
                raise SystemExit("--dispatch-deadline takes seconds or 'auto'")

    sampling_kw: dict = {}
    if args.sampling != "off":
        sampling_kw = {
            "sampling": args.sampling,
            "sample_frac": args.sample_frac,
            "sample_k": args.sample_k,
            "sample_seed": args.sample_seed,
        }
    elif args.sample_frac is not None or args.sample_k is not None:
        raise SystemExit(
            "--sample-frac/--sample-k size a sampled run; pass "
            "--sampling fixed|adaptive"
        )

    print(
        f"{name}: n={graph.n} m={graph.num_edges} "
        f"heuristics={args.heuristics} engine={args.engine} "
        f"overlap={args.overlap} straggler={args.straggler} "
        f"sampling={args.sampling}"
        + (f" weighted(delta={args.delta or 'auto'})" if args.weighted else "")
    )
    t0 = time.time()
    if mesh_shape is not None:
        from repro.launch.mesh import make_mesh

        axes = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = make_mesh(mesh_shape, axes)
        # the distributed engine's arc-list local compute is the sparse
        # path; dense-block MXU compute is the pallas pair.
        engine_kind = "sparse" if args.engine in ("dense", "sparse") else args.engine
        robust_kw: dict = {}
        if args.max_retries is not None:
            robust_kw["max_retries"] = args.max_retries
        if args.retry_backoff is not None:
            robust_kw["retry_backoff_s"] = args.retry_backoff
        if args.numeric_guard:
            robust_kw["numeric_guard"] = True
        if args.integrity != "off":
            robust_kw["integrity"] = args.integrity
        if deadline is not None:
            robust_kw["dispatch_deadline_s"] = deadline
        result = distributed_betweenness_centrality(
            graph,
            mesh,
            replica_axis="pod" if len(mesh_shape) == 3 else None,
            batch_size=args.batch_size,
            heuristics=args.heuristics,
            engine_kind=engine_kind,
            overlap=args.overlap,
            tile=tile,
            hybrid_threshold=args.hybrid_threshold,
            hbm_limit_bytes=args.hbm_gb * 2**30 if args.hbm_gb > 0 else None,
            checkpoint=checkpoint,
            straggler=args.straggler,
            straggler_factor=args.straggler_factor,
            autotune=args.autotune,
            autotune_cache=args.autotune_cache,
            chaos=args.chaos,
            full_result=True,
            weighted=args.weighted,
            delta=args.delta,
            **robust_kw,
            **sampling_kw,
        )
        bc, schedule = result.bc, result.schedule
        rounds = len(schedule.rounds)
        samp = result.sampling_stats
        rec = result.recovery_stats or {}
        integ = rec.get("integrity") or {}
        # the integrity sub-dict is informational even when healthy (its
        # "mode" string and checksum residual are always truthy under
        # integrity=checksum) — only its detection counters are events
        integ_events = {
            k: v
            for k, v in integ.items()
            if k not in ("mode", "max_checksum_residual") and v
        }
        if args.chaos or any(
            v
            for k, v in rec.items()
            if k not in ("resumed_generation", "integrity") and v
        ) or integ_events or rec.get("resumed_generation"):
            print(
                "recovery: "
                f"{rec.get('retries', 0)} retries "
                f"({rec.get('transient_errors', 0)} transient), "
                f"{rec.get('quarantined_blocks', 0)} quarantined, "
                f"{rec.get('fallback_recomputes', 0)} fallback recomputes, "
                f"{rec.get('remesh_events', 0)} re-mesh events "
                f"(dead replicas {rec.get('dead_replicas', [])}), "
                f"resumed generation {rec.get('resumed_generation')}"
            )
        if integ and integ.get("mode", "off") != "off":
            print(
                f"integrity[{integ['mode']}]: "
                f"{integ.get('checksum_failures', 0)} checksum + "
                f"{integ.get('audit_failures', 0)} audit failures, "
                f"{integ.get('vote_mismatches', 0)}/{integ.get('votes', 0)} "
                f"duplicate-vote mismatches, "
                f"{integ.get('quarantined_rounds', 0)} quarantined rounds, "
                f"watchdog {integ.get('watchdog_trips', 0)} trips / "
                f"{integ.get('watchdog_escalations', 0)} escalations, "
                f"max checksum residual "
                f"{integ.get('max_checksum_residual', 0.0):.2e}"
            )
    else:
        res = betweenness_centrality(
            graph,
            batch_size=args.batch_size,
            heuristics=args.heuristics,
            engine_kind=args.engine,
            checkpoint=checkpoint,
            weighted=args.weighted,
            delta=args.delta,
            **sampling_kw,
        )
        bc, rounds = res.bc, res.rounds_run
        samp = res.sampling_stats
    dt = time.time() - t0
    teps = graph.num_edges * graph.n / max(dt, 1e-9)
    print(f"done in {dt:.2f}s — {rounds} rounds, {teps/1e9:.3f} GTEPS_bc")
    if samp:
        print(
            f"sampling[{samp['mode']}]: "
            f"{samp['roots_accumulated']}/{samp['num_eligible']} roots "
            f"(planned k={samp['k_planned']}, seed {samp['seed']}), "
            f"estimates rescaled x{samp['scale']:.3f}"
        )
    top = np.argsort(bc)[::-1][: args.top]
    for v in top:
        print(f"  v{int(v):>8d}  BC = {bc[int(v)]:.1f}")
    if args.out:
        np.save(args.out, bc)
        print("scores ->", args.out)


if __name__ == "__main__":
    main()
