"""Serving launcher: batched LM decoding with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Runs prefill then a decode loop — the real serving path the decode
dry-run cells lower.  ``--reduced`` shrinks the model for CPU.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.configs.registry import get_arch
from repro.launch.train import reduced_lm
from repro.models import transformer as tf


def serve_loop(cfg: LMArch, batch: int, prompt_len: int, gen: int, seed: int = 0):
    params = tf.init_params(cfg, jax.random.PRNGKey(seed))
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed + 1), (batch, prompt_len), 0, cfg.vocab
    )
    max_seq = prompt_len + gen

    prefill = jax.jit(lambda p, t: tf.prefill(cfg, p, t))
    decode = jax.jit(
        lambda p, c, t, pos: tf.decode_step(cfg, p, c, t, pos), donate_argnums=(1,)
    )

    t0 = time.time()
    logits, cache = prefill(params, prompts)
    cache = jax.tree.map(
        lambda c: jnp.pad(
            c, ((0, 0), (0, 0), (0, max_seq - c.shape[2]), (0, 0), (0, 0))
        ),
        cache,
    )
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits, axis=-1)
    generated = [tokens]
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tokens, jnp.int32(prompt_len + i))
        tokens = jnp.argmax(logits, axis=-1)
        generated.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0
    out = np.stack([np.asarray(t) for t in generated], axis=1)
    return out, t_prefill, t_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    bundle = get_arch(args.arch)
    cfg = bundle.arch
    if args.reduced:
        cfg = reduced_lm(cfg, layers=2, d_model=256, vocab=2048)
    out, t_p, t_d = serve_loop(cfg, args.batch, args.prompt_len, args.gen)
    tok_s = args.batch * (args.gen - 1) / max(t_d, 1e-9)
    print(f"prefill {t_p:.2f}s; decode {t_d:.2f}s ({tok_s:.1f} tok/s)")
    print("sample generations (token ids):")
    for row in out[:2]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
