"""BC snapshot-serving launcher: answer queries while sampling refines.

    PYTHONPATH=src python -m repro.launch.serve_bc --rmat-scale 8 \
        --mesh 2x4 --sample-frac 1.0 --refresh-blocks 2 --generations 3 \
        --ckpt-dir /tmp/bc_serve
    PYTHONPATH=src python -m repro.launch.serve_bc --grid 12x12 \
        --sampling adaptive --queries 20

Front end of the sampled-BC stack (repro/serving/): a foreground query
loop answers ``top_k`` / ``score`` requests from the current
:class:`~repro.serving.BCSnapshotStore` generation while a background
refresher thread runs the *same* sampled schedule in budgeted slices —
each slice is one ``distributed_betweenness_centrality`` (or
single-device) run over a shared :class:`BCCheckpoint` with a
:class:`~repro.serving.BlockBudgetStop` stop rule, so resume skips the
committed prefix and every generation strictly extends the evidence.
After each slice the store republishes from the checkpoint's committed
prefix (raw accumulator, rescaled N/k here) and atomically swaps the
generation; the last slice runs without a block budget, so the final
generation is the full sampled estimate (exact when
``--sample-frac 1.0``).

Queries issued mid-refresh are answered from the previous generation
and counted as ``stale_hits`` — the store's stats dict accounts every
query as exactly one of hit / stale_hit / miss.  A killed refresher's
replacement republishes the last *committed* generation at startup
(``publish_from_checkpoint``) before resuming, so serving never
regresses past durable state.
"""
from __future__ import annotations

import argparse
import logging
import os
import threading
import time

import numpy as np

from repro.core import betweenness_centrality
from repro.core.distributed import distributed_betweenness_centrality
from repro.distributed.fault_tolerance import BCCheckpoint
from repro.graphs import grid_graph, rmat_graph, road_like_graph
from repro.serving import (
    BCSnapshotStore,
    BlockBudgetStop,
    eligible_roots,
    plan_sampling,
)

logger = logging.getLogger(__name__)


def run_serving(
    graph,
    mesh=None,
    *,
    ckpt_path: str,
    batch_size: int = 8,
    engine: str = "sparse",
    overlap: str = "none",
    sampling: str = "fixed",
    sample_frac: float | None = None,
    sample_k: int | None = None,
    sample_seed: int = 0,
    refresh_blocks: int = 2,
    generations: int = 3,
    queries: int = 12,
    top_k: int = 10,
    poll_s: float = 0.02,
) -> dict:
    """Serve BC queries while a background refresher extends the sample.

    Args:
      graph:          input graph.
      mesh:           jax mesh for the distributed path, or None for the
                      single-device driver (same serving semantics).
      ckpt_path:      BCCheckpoint file the refresher slices share — the
                      durable state a replacement refresher resumes from.
      sampling / sample_frac / sample_k / sample_seed: the sampled
                      schedule (see :func:`repro.core.bc
                      .betweenness_centrality`).  ``"off"`` is rejected:
                      budgeted refresh slices are truncated runs, which
                      are only meaningful as rescaled estimates.
      refresh_blocks: dispatch blocks each non-final slice runs before
                      republishing (the refresh cadence).
      generations:    maximum refresher slices; the last runs without a
                      block budget so the final generation is the full
                      sampled estimate.  Slices after the schedule is
                      exhausted are skipped.
      queries:        minimum foreground ``top_k`` queries to issue.
      top_k:          k of the foreground query loop.
      poll_s:         sleep between foreground queries while refreshing.

    Returns a stats dict: per-slice telemetry (``refresh_runs``), the
    store's query accounting (``stats``), the generation history the
    query loop observed (``history``), and the final snapshot's top-k
    and full estimate (``final_top_k`` / ``final_bc``).
    """
    if sampling == "off":
        raise ValueError(
            "serving refreshes in budgeted slices, which are only "
            "meaningful as rescaled estimates; pass sampling='fixed' "
            "(sample_frac=1.0 for an exact final generation) or "
            "'adaptive'"
        )
    plan = plan_sampling(
        eligible_roots(graph), sampling, sample_frac, sample_k, sample_seed
    )
    checkpoint = BCCheckpoint(ckpt_path)
    store = BCSnapshotStore()
    refresh_runs: list[dict] = []
    refresh_errors: list[BaseException] = []

    def _publish(meta: dict) -> int | None:
        return store.publish_from_checkpoint(
            checkpoint, num_eligible=plan.num_eligible, meta=meta
        )

    def _run_slice(stop_rule):
        if mesh is not None:
            kind = "sparse" if engine in ("dense", "sparse") else engine
            return distributed_betweenness_centrality(
                graph,
                mesh,
                replica_axis="pod" if len(mesh.devices.shape) == 3 else None,
                batch_size=batch_size,
                heuristics="h0",
                engine_kind=kind,
                overlap=overlap,
                checkpoint=checkpoint,
                sampling=sampling,
                sample_frac=sample_frac,
                sample_k=sample_k,
                sample_seed=sample_seed,
                stop_rule=stop_rule,
                full_result=True,
            )
        return betweenness_centrality(
            graph,
            batch_size=batch_size,
            heuristics="h0",
            engine_kind=engine,
            checkpoint=checkpoint,
            sampling=sampling,
            sample_frac=sample_frac,
            sample_k=sample_k,
            sample_seed=sample_seed,
            stop_rule=stop_rule,
        )

    # resume path: a replacement refresher serves the last committed
    # generation immediately, before any new rounds run
    if checkpoint.exists():
        gen = _publish({"resumed": True})
        if gen is not None:
            logger.info("resumed serving from committed snapshot (gen %d)", gen)

    def _refresher():
        try:
            for i in range(generations):
                final = i == generations - 1
                store.begin_refresh()
                t0 = time.perf_counter()
                result = _run_slice(
                    None if final else BlockBudgetStop(refresh_blocks)
                )
                _publish(
                    {
                        "refresh_slice": i + 1,
                        "final": not result.stopped_early,
                    }
                )
                store.end_refresh()
                refresh_runs.append(
                    {
                        "slice": i + 1,
                        "rounds_run": result.rounds_run,
                        "roots_accumulated": result.roots_accumulated,
                        "stopped_early": result.stopped_early,
                        "wall_s": time.perf_counter() - t0,
                        "sampling": result.sampling_stats,
                    }
                )
                if not result.stopped_early:
                    break  # schedule exhausted — the estimate is final
        except BaseException as exc:  # surfaced to the caller after join
            refresh_errors.append(exc)
        finally:
            store.end_refresh()

    history: list[dict] = []

    def _query():
        res = store.top_k(top_k)
        if res is None:
            return
        snap, top = res
        if not history or history[-1]["generation"] != snap.generation:
            history.append(
                {
                    "generation": snap.generation,
                    "top_k": [v for v, _ in top],
                    "meta": dict(snap.meta),
                }
            )

    _query()  # cold query: a miss unless a committed snapshot resumed us
    refresher = threading.Thread(target=_refresher, name="bc-refresher")
    refresher.start()
    issued = 1
    while refresher.is_alive() or issued < queries:
        _query()
        issued += 1
        if refresher.is_alive():
            time.sleep(poll_s)
    refresher.join()
    if refresh_errors:
        raise refresh_errors[0]
    _query()  # settled query: always a hit against the final generation

    snap = store.snapshot()
    final_top = history[-1]["top_k"] if history else []
    return {
        "n": graph.n,
        "plan": {
            "mode": plan.mode,
            "num_eligible": plan.num_eligible,
            "k": plan.k,
            "seed": plan.seed,
        },
        "generations_published": store.generation,
        "refresh_runs": refresh_runs,
        "stats": dict(store.stats),
        "history": history,
        "final_top_k": final_top,
        "final_bc": None if snap is None else snap.bc,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rmat-scale", type=int, default=None)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--grid", default=None, help="RxC grid graph")
    ap.add_argument("--road", default=None, help="RxC road-like graph")
    ap.add_argument("--mesh", default=None, help="RxC or FRxRxC device mesh")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--engine", default="sparse")
    ap.add_argument("--overlap", default="none")
    ap.add_argument("--sampling", default="fixed", choices=["fixed", "adaptive"])
    ap.add_argument("--sample-frac", type=float, default=None)
    ap.add_argument("--sample-k", type=int, default=None)
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--refresh-blocks", type=int, default=2)
    ap.add_argument("--generations", type=int, default=3)
    ap.add_argument("--queries", type=int, default=12)
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None, help="shared refresher state")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")

    if args.rmat_scale is not None:
        graph = rmat_graph(args.rmat_scale, args.edge_factor, seed=1)
        name = f"rmat_s{args.rmat_scale}_ef{args.edge_factor}"
    elif args.grid:
        r, c = map(int, args.grid.split("x"))
        graph = grid_graph(r, c)
        name = f"grid_{r}x{c}"
    elif args.road:
        r, c = map(int, args.road.split("x"))
        graph = road_like_graph(r, c, seed=1)
        name = f"road_{r}x{c}"
    else:
        raise SystemExit("pick --rmat-scale, --grid or --road")

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh

        shape = tuple(map(int, args.mesh.split("x")))
        mesh = make_mesh(shape, ("pod", "data", "model")[-len(shape):])

    ckpt_dir = args.ckpt_dir or os.path.join("/tmp", "bc_serve")
    os.makedirs(ckpt_dir, exist_ok=True)
    out = run_serving(
        graph,
        mesh,
        ckpt_path=os.path.join(ckpt_dir, f"{name}.npz"),
        batch_size=args.batch_size,
        engine=args.engine,
        overlap=args.overlap,
        sampling=args.sampling,
        sample_frac=args.sample_frac,
        sample_k=args.sample_k,
        sample_seed=args.sample_seed,
        refresh_blocks=args.refresh_blocks,
        generations=args.generations,
        queries=args.queries,
        top_k=args.top,
    )

    print(
        f"{name}: n={out['n']} sampling={out['plan']['mode']} "
        f"k={out['plan']['k']}/{out['plan']['num_eligible']} roots"
    )
    for run in out["refresh_runs"]:
        print(
            f"  slice {run['slice']}: {run['rounds_run']} rounds, "
            f"{run['roots_accumulated']} roots committed, "
            f"{'stopped early' if run['stopped_early'] else 'final'}, "
            f"{run['wall_s']:.2f}s"
        )
    st = out["stats"]
    print(
        f"served {st['queries']} queries across "
        f"{out['generations_published']} generations: {st['hits']} hits, "
        f"{st['stale_hits']} stale, {st['misses']} misses"
    )
    bc = out["final_bc"]
    for v in out["final_top_k"]:
        print(f"  v{int(v):>8d}  BC = {bc[int(v)]:.1f}")


if __name__ == "__main__":
    main()
