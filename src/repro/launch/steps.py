"""Cell programs: (arch × shape) -> a jit-able step + abstract inputs.

A *cell* is one dry-run / benchmark unit: ``train_step`` for training
shapes, ``serve_step`` for inference shapes, one distributed MGBC round
for the BC configs.  ``build_cell`` returns everything the dry-run needs:

  fn          — the step function (state/batch in, state/outputs out)
  args_specs  — ShapeDtypeStruct PyTree per argument (no allocation)
  args_logical — logical partition tuples per argument (None = let the
                 shard_map handle it / replicate)
  static_meta — dict for reporting (param counts, model flops, ...)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import BCArch, DLRMArch, GNNArch, LMArch
from repro.configs.registry import ArchBundle
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf
from repro.optim import adafactor, adamw
from repro.optim.optimizers import AdafactorState, AdamWState

__all__ = ["CellProgram", "build_cell", "lm_model_flops", "opt_state_specs"]

PyTree = Any
SDS = jax.ShapeDtypeStruct
DEV_MULT = 512  # pad workload dims so input shardings divide on both meshes


def _pad_mult(x: int, m: int = DEV_MULT) -> int:
    return x + (-x) % m


@dataclasses.dataclass
class CellProgram:
    name: str
    fn: Callable
    args_specs: tuple
    args_logical: tuple  # logical axis tuples, or None per arg
    static_meta: dict
    needs_shardmap_mesh: bool = False  # BC cells build their own shard_map
    donate_argnums: tuple = ()  # in-place args (train state, KV cache)


def _tree_logical(tree, fn):
    return jax.tree.map(fn, tree)


# --------------------------------------------------------------------- LM
def lm_model_flops(cfg: LMArch, tokens: int) -> float:
    """6·N_active·D (MoE counts routed experts only)."""
    d, hhd, khd = cfg.d_model, cfg.n_heads * cfg.head_dim, cfg.n_kv_heads * cfg.head_dim
    per_layer = 2 * d * hhd + 2 * d * khd + hhd * d  # qkv + o
    if cfg.moe is None:
        per_layer += 3 * d * cfg.d_ff
    else:
        per_layer += 3 * d * cfg.moe.d_ff * cfg.moe.top_k
    n_active = cfg.n_layers * per_layer + cfg.vocab * d  # + embedding/head
    return 6.0 * n_active * tokens


def _lm_param_logical(cfg: LMArch) -> PyTree:
    return tf.param_partition_specs(cfg)


def opt_state_specs(opt_name: str, param_specs: PyTree, param_logical: PyTree):
    """(ShapeDtypeStruct tree, logical tree) for the optimizer state."""
    if opt_name == "adamw":
        f32 = lambda s: SDS(s.shape, jnp.float32)
        return (
            AdamWState(
                step=SDS((), jnp.int32),
                mu=jax.tree.map(f32, param_specs),
                nu=jax.tree.map(f32, param_specs),
            ),
            AdamWState(step=P(), mu=param_logical, nu=param_logical),
        )
    if opt_name == "adafactor":

        def vr_s(s):
            return SDS(s.shape[:-1] if len(s.shape) >= 2 else s.shape, jnp.float32)

        def vc_s(s):
            return SDS(
                s.shape[:-2] + s.shape[-1:] if len(s.shape) >= 2 else (1,), jnp.float32
            )

        def _padded(spec, rank):
            t = tuple(spec)
            return t + (None,) * (rank - len(t))

        def vr_l(spec, s):
            rank = len(s.shape)
            t = _padded(spec, rank)
            return P(*t[:-1]) if rank >= 2 else P(*t)

        def vc_l(spec, s):
            rank = len(s.shape)
            t = _padded(spec, rank)
            return P(*(t[:-2] + t[-1:])) if rank >= 2 else P(None)

        return (
            AdafactorState(
                step=SDS((), jnp.int32),
                vr=jax.tree.map(vr_s, param_specs),
                vc=jax.tree.map(vc_s, param_specs),
            ),
            AdafactorState(
                step=P(),
                vr=jax.tree.map(vr_l, param_logical, param_specs),
                vc=jax.tree.map(vc_l, param_logical, param_specs),
            ),
        )
    raise ValueError(opt_name)




def _tree_bytes(tree) -> float:
    return float(
        sum(np.prod(s.shape) * s.dtype.itemsize for s in jax.tree.leaves(tree))
    )


def _lm_analytic_bytes(cfg: LMArch, shape, p_specs, o_specs) -> float:
    """Analytic *global* HBM for the TPU target (fully-sharded params/
    grads/opt + remat carries + per-layer transient working set); the
    roofline report divides by the mesh size.  The x86 dry-run backend
    promotes bf16 internals to f32 around dots, so its memory_analysis
    overstates TPU peaks ~2x on bf16-heavy cells; this estimator is the
    standard MaxText-style bound reported alongside."""
    pb = _tree_bytes(p_specs)
    ob = _tree_bytes(o_specs) if o_specs is not None else 0.0
    d = cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        carries = cfg.n_layers * tokens * d * 2  # bf16 residual stack
        # per-layer transient (remat backward): qkv/o + mlp or moe slices
        if cfg.moe is None:
            trans = tokens * (2 * cfg.d_ff + 4 * d) * 2
        else:
            m = cfg.moe
            cap = int(m.capacity_factor * tokens * m.top_k / m.num_experts)
            trans = (
                m.num_experts * cap * (d + 2 * m.d_ff) * 2  # buf + h (E-sharded)
                + tokens * m.top_k * (d * 2 + 4 * m.num_experts)  # rows + router
            )
        logits = shape.global_batch * cfg.loss_chunk * tf.padded_vocab(cfg) * 4
        grads = pb
        return pb + grads + ob + carries + trans + logits
    cache = 2 * cfg.n_layers * shape.global_batch * shape.seq_len * (
        cfg.n_kv_heads * cfg.head_dim
    ) * 2
    if shape.kind == "decode":
        return pb + cache + 2 * shape.global_batch * cfg.n_heads * shape.seq_len * 4
    # prefill: cache is the output; transient = per-layer scores chunk
    tokens = shape.global_batch * shape.seq_len
    scores = shape.global_batch * cfg.n_heads * cfg.q_chunk * shape.seq_len * 4
    return pb + 2 * cache + tokens * d * 2 * 2 + scores

def _make_optimizer(cfg_optimizer: str, lr=1e-4):
    return adafactor(lr) if cfg_optimizer == "adafactor" else adamw(lr)


def _build_lm_cell(cfg: LMArch, shape) -> CellProgram:
    p_specs = tf.param_specs(cfg)
    p_logical = _lm_param_logical(cfg)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(p_specs))

    if shape.kind == "train":
        optimizer = _make_optimizer(cfg.optimizer)
        o_specs, o_logical = opt_state_specs(cfg.optimizer, p_specs, p_logical)

        def train_step(state, batch):
            def loss_fn(p):
                return tf.lm_loss(cfg, p, batch["tokens"])

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"]
            )
            new_p, new_o = optimizer.update(grads, state["opt"], state["params"])
            return {"params": new_p, "opt": new_o}, {"loss": loss, **metrics}

        tokens = shape.global_batch * shape.seq_len
        return CellProgram(
            name=f"{cfg.name}:{shape.name}",
            fn=train_step,
            args_specs=(
                {"params": p_specs, "opt": o_specs},
                {"tokens": SDS((shape.global_batch, shape.seq_len), jnp.int32)},
            ),
            args_logical=(
                {"params": p_logical, "opt": o_logical},
                {"tokens": P("data", None)},
            ),
            static_meta={
                "n_params": n_params,
                "model_flops": 3 * lm_model_flops(cfg, tokens),  # fwd+bwd
                "tokens": tokens,
                "analytic_bytes_global": _lm_analytic_bytes(
                    cfg, shape, p_specs, o_specs
                ),
            },
            donate_argnums=(0,),
        )

    if shape.kind == "prefill":

        def serve_step(params, batch):
            logits, cache = tf.prefill(cfg, params, batch["tokens"])
            return logits, cache

        tokens = shape.global_batch * shape.seq_len
        return CellProgram(
            name=f"{cfg.name}:{shape.name}",
            fn=serve_step,
            args_specs=(
                p_specs,
                {"tokens": SDS((shape.global_batch, shape.seq_len), jnp.int32)},
            ),
            args_logical=(p_logical, {"tokens": P("data", None)}),
            static_meta={
                "n_params": n_params,
                "model_flops": lm_model_flops(cfg, tokens),
                "tokens": tokens,
                "analytic_bytes_global": _lm_analytic_bytes(cfg, shape, p_specs, None),
            },
        )

    # decode: one new token against a seq_len cache
    b = shape.global_batch
    cache = tf.cache_specs(cfg, b, shape.seq_len)
    # batch over data when divisible, otherwise shard the cache sequence
    if b >= 16:
        cache_logical = P(None, "data", None, None, "model")
        tok_logical = P("data")
    else:  # long_500k: B=1 — sequence-sharded cache
        cache_logical = P(None, None, "data", None, "model")
        tok_logical = P(None)

    def decode(params, cache, batch):
        logits, new_cache = tf.decode_step(
            cfg, params, cache, batch["tokens"], batch["pos"]
        )
        return logits, new_cache

    return CellProgram(
        name=f"{cfg.name}:{shape.name}",
        fn=decode,
        args_specs=(
            p_specs,
            cache,
            {"tokens": SDS((b,), jnp.int32), "pos": SDS((), jnp.int32)},
        ),
        args_logical=(
            p_logical,
            {"k": cache_logical, "v": cache_logical},
            {"tokens": tok_logical, "pos": P()},
        ),
        static_meta={
            "n_params": n_params,
            # decode model-flops: 2·N_active per token + cache read ≈ bandwidth
            "model_flops": 2.0 * lm_model_flops(cfg, b) / 6.0,
            "tokens": b,
            "analytic_bytes_global": _lm_analytic_bytes(cfg, shape, p_specs, None),
        },
        donate_argnums=(1,),
    )


# -------------------------------------------------------------------- GNN
# GNN cells run the paper's 2-D decomposition (models/gnn2d.py): GSPMD's
# automatic gather/scatter partitioning replicates node state (X00 GB on
# ogb_products); the MGBC expand/fold structure keeps per-device state at
# O(n/sqrt(p) * d).  The flat GSPMD path remains in models/gnn.py for the
# single-device smoke tests and the A/B comparison in EXPERIMENTS.md.


def _gnn_workload(shape):
    if shape.kind == "minibatch":
        t = shape.batch_nodes
        n_nodes, n_edges, frontier = t, 0, t
        for f in shape.fanout:
            n_edges += frontier * f
            frontier *= f
            n_nodes += frontier
    else:
        n_nodes = shape.n_nodes * (shape.n_graphs or 1)
        n_edges = shape.n_edges * (shape.n_graphs or 1)
    return n_nodes, n_edges


def _build_gnn_cell(cfg: GNNArch, shape, mesh) -> CellProgram:
    from repro.models.gnn2d import gnn2d_batch_specs, make_gnn2d_loss_fn

    d_out = gnn_mod.output_dim(cfg, shape)
    n_nodes, n_edges = _gnn_workload(shape)
    d_feat = shape.d_feat

    R = mesh.shape["data"]
    C = mesh.shape["model"]
    n_dev = R * C
    chunk = -(-n_nodes // n_dev)
    n_pad = n_dev * chunk
    max_arcs = int(1.5 * n_edges / n_dev) + 8
    max_arcs += (-max_arcs) % 8

    p_specs = gnn_mod.param_specs(cfg, d_feat, d_out)
    p_logical = jax.tree.map(lambda s: P(), p_specs)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(p_specs))

    loss_fn, _ = make_gnn2d_loss_fn(
        cfg,
        mesh,
        shape.kind,
        chunk=chunk,
        max_arcs=max_arcs,
        n_graphs=shape.n_graphs or 0,
        gather_dtype=jnp.bfloat16,  # halve expand-collective bytes (§Perf)
        fold_dtype=jnp.bfloat16,  # halve the dominant fold reduce-scatter
    )
    batch_specs = gnn2d_batch_specs(
        cfg, shape.kind, n_pad, R, C, max_arcs, d_feat, d_out,
        n_graphs=shape.n_graphs or 0,
    )

    optimizer = adamw(1e-3)
    o_specs, o_logical = opt_state_specs("adamw", p_specs, p_logical)

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch))(
            state["params"]
        )
        new_p, new_o = optimizer.update(grads, state["opt"], state["params"])
        return {"params": new_p, "opt": new_o}, {"loss": loss}

    # model flops: message MLP (2d->d, d->d) per arc + update MLP per node
    d = cfg.d_hidden * (cfg.n_heads if cfg.kind == "gat" else 1)
    per_layer = 2 * n_edges * (2 * d) * d + 2 * n_edges * d * d
    per_layer += 2 * n_nodes * (2 * d) * d + 2 * n_nodes * d * d
    model_flops = 3.0 * (cfg.n_layers * per_layer + 2 * n_nodes * d_feat * d)

    return CellProgram(
        name=f"{cfg.name}:{shape.name}",
        fn=train_step,
        args_specs=({"params": p_specs, "opt": o_specs}, batch_specs),
        args_logical=(None, None),  # shard_map carries the shardings
        static_meta={
            "n_params": n_params,
            "model_flops": model_flops,
            "n_nodes": n_nodes,
            "n_edges": n_edges,
        },
        needs_shardmap_mesh=True,
        donate_argnums=(0,),
    )


# ------------------------------------------------------------------- DLRM
def _build_dlrm_cell(cfg: DLRMArch, shape) -> CellProgram:
    p_specs = dlrm_mod.param_specs(cfg)
    p_logical = jax.tree.map(lambda s: P(), p_specs)
    p_logical["tables"] = P(None, ("model", "data"), None)  # rows over all chips
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(p_specs))
    b = shape.batch

    base_batch = {
        "dense": SDS((b, cfg.n_dense), jnp.float32),
        "sparse": SDS((b, cfg.n_sparse, cfg.hot_size), jnp.int32),
    }
    bdata = "data" if b >= 16 else None  # retrieval has batch=1
    base_logical = {
        "dense": P(bdata, None),
        "sparse": P(bdata, None, None),
    }
    # MLP+interaction flops per example
    mlp_flops = 0
    dims = (cfg.n_dense,) + cfg.bot_mlp
    mlp_flops += sum(2 * a * bb for a, bb in zip(dims[:-1], dims[1:]))
    f = cfg.n_sparse + 1
    mlp_flops += 2 * f * f * cfg.embed_dim
    dims = (f * (f - 1) // 2 + cfg.embed_dim,) + cfg.top_mlp
    mlp_flops += sum(2 * a * bb for a, bb in zip(dims[:-1], dims[1:]))

    if shape.kind == "train":
        optimizer = adamw(1e-3)
        o_specs, o_logical = opt_state_specs("adamw", p_specs, p_logical)
        base_batch["labels"] = SDS((b,), jnp.float32)
        base_logical["labels"] = P("data")

        def train_step(state, batch):
            def loss_fn(p):
                return dlrm_mod.dlrm_loss(cfg, p, batch)

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"]
            )
            new_p, new_o = optimizer.update(grads, state["opt"], state["params"])
            return {"params": new_p, "opt": new_o}, {"loss": loss, **metrics}

        return CellProgram(
            name=f"{cfg.name}:{shape.name}",
            fn=train_step,
            args_specs=({"params": p_specs, "opt": o_specs}, base_batch),
            args_logical=({"params": p_logical, "opt": o_logical}, base_logical),
            static_meta={"n_params": n_params, "model_flops": 3.0 * b * mlp_flops},
            donate_argnums=(0,),
        )

    if shape.kind == "retrieval":
        base_batch["candidates"] = SDS(
            (_pad_mult(shape.n_candidates), cfg.embed_dim), jnp.float32
        )
        base_logical["candidates"] = P(("data", "model"), None)

        def retrieve(params, batch):
            return dlrm_mod.retrieval_scores(cfg, params, batch)

        flops = b * mlp_flops + 2.0 * b * shape.n_candidates * cfg.embed_dim
        return CellProgram(
            name=f"{cfg.name}:{shape.name}",
            fn=retrieve,
            args_specs=(p_specs, base_batch),
            args_logical=(p_logical, base_logical),
            static_meta={"n_params": n_params, "model_flops": flops},
        )

    def serve(params, batch):
        logit, _ = dlrm_mod.dlrm_forward(cfg, params, batch["dense"], batch["sparse"])
        return jax.nn.sigmoid(logit)

    return CellProgram(
        name=f"{cfg.name}:{shape.name}",
        fn=serve,
        args_specs=(p_specs, base_batch),
        args_logical=(p_logical, base_logical),
        static_meta={"n_params": n_params, "model_flops": 1.0 * b * mlp_flops},
    )


# --------------------------------------------------------------------- BC
def _build_bc_cell(cfg: BCArch, shape, mesh) -> CellProgram:
    """One distributed MGBC round on the production mesh (shard_map)."""
    from repro.core.distributed import make_distributed_round_fn
    from repro.graphs.partition import TwoDPartition

    axis = dict(zip(mesh.axis_names, mesh.shape.values()))  # ordered
    R = mesh.shape["data"]
    C = mesh.shape["model"]
    replica_axis = "pod" if "pod" in mesh.axis_names else None

    n = 1 << shape.scale
    chunk = -(-n // (R * C))
    m2 = 2 * shape.edge_factor * n
    max_arcs = int(1.5 * m2 / (R * C))  # imbalance headroom
    max_arcs += (-max_arcs) % 8

    part = TwoDPartition(
        R=R,
        C=C,
        n=n,
        chunk=chunk,
        src_local=np.zeros((1,), np.int32),  # placeholders; dry-run only
        dst_local=np.zeros((1,), np.int32),
        arc_counts=np.zeros((R, C), np.int64),
    )
    round_fn = make_distributed_round_fn(
        part,
        mesh,
        row_axis="data",
        col_axis="model",
        replica_axis=replica_axis,
        num_levels=cfg.max_levels,
    )
    # pre-compile per-device HBM footprint per engine (the dry-run's
    # fail-fast memory report; nnz tiles bounded by one tile per arc)
    from repro.graphs.partition import default_tile_dim
    from repro.roofline.model import device_hbm_footprint

    tile = default_tile_dim(chunk)
    tiles_per_dev = (C * chunk // tile) * (R * chunk // tile)
    footprints = {
        kind: device_hbm_footprint(
            kind,
            R=R,
            C=C,
            chunk=chunk,
            batch_size=cfg.batch_size,
            nnz_tiles=min(max_arcs, tiles_per_dev),
            bm=tile,
            bk=tile,
            max_arcs=max_arcs,
        )["total_bytes"]
        for kind in ("sparse", "pallas", "pallas_sparse")
    }

    fr = mesh.shape["pod"] if replica_axis else 1

    # [tune] report: would this cell's autotune key hit the measured-cost
    # cache?  (Read-only — the dry run never measures; the cache path
    # follows the smoke tool's AUTOTUNE_CACHE_JSON convention.)
    import os

    from repro.autotune import AUTOTUNE_MODES, CostCache, graph_key

    cache_path = os.environ.get("AUTOTUNE_CACHE_JSON", "AUTOTUNE_cache.json")
    tune_cache = CostCache(cache_path) if os.path.exists(cache_path) else None
    gkey = graph_key(n, m2, R=R, C=C, fr=fr)
    tune_meta = {
        "graph_key": gkey,
        "modes": list(AUTOTUNE_MODES),
        "cache_path": cache_path if tune_cache is not None else None,
        "cached_configs": (
            len(tune_cache.entries.get(gkey, {})) if tune_cache is not None else 0
        ),
    }

    # [resilience] report: the self-healing envelope a production run of
    # this cell gets from BCDriver + generational BCCheckpoint (values
    # from the single-source constants, so the report cannot drift).
    from repro.checkpoint.checkpointer import DEFAULT_GENERATIONS
    from repro.core.driver import DEFAULT_MAX_RETRIES, DEFAULT_RETRY_BACKOFF_S
    from repro.distributed.chaos import FAULT_KINDS

    resilience_meta = {
        "max_retries": DEFAULT_MAX_RETRIES,
        "retry_backoff_s": DEFAULT_RETRY_BACKOFF_S,
        "checkpoint_generations": DEFAULT_GENERATIONS,
        "remesh_on_replica_loss": fr > 1,
        "fault_kinds": list(FAULT_KINDS),
    }

    s, k = cfg.batch_size, max(1, cfg.batch_size // 2)
    args_specs = (
        SDS((R, C, max_arcs), jnp.int32),
        SDS((R, C, max_arcs), jnp.int32),
        SDS((R * C * chunk,), jnp.float32),
        SDS((fr, s), jnp.int32),
        SDS((fr, k, 3), jnp.int32),
    )
    # 2·m·s traversed-edge updates per direction, fwd+bwd, per replica round
    model_flops = 2.0 * (m2 / 2) * (s + k) * 2 * fr
    return CellProgram(
        name=f"{cfg.name}:{shape.name}",
        fn=round_fn,
        args_specs=args_specs,
        args_logical=(None, None, None, None, None),
        static_meta={
            "n_vertices": n,
            "n_arcs": m2,
            "sources_per_round": s + k,
            "model_flops": model_flops,
            "hbm_footprint_bytes": footprints,
            "tune": tune_meta,
            "resilience": resilience_meta,
        },
        needs_shardmap_mesh=True,
    )


def build_cell(bundle: ArchBundle, shape_name: str, mesh=None) -> CellProgram:
    shape = bundle.shapes[shape_name]
    arch = bundle.arch
    if isinstance(arch, LMArch):
        return _build_lm_cell(arch, shape)
    if isinstance(arch, GNNArch):
        if mesh is None:
            raise ValueError("GNN cells need the mesh at build time (shard_map)")
        return _build_gnn_cell(arch, shape, mesh)
    if isinstance(arch, DLRMArch):
        return _build_dlrm_cell(arch, shape)
    if isinstance(arch, BCArch):
        if mesh is None:
            raise ValueError("BC cells need the mesh at build time (shard_map)")
        return _build_bc_cell(arch, shape, mesh)
    raise TypeError(type(arch))
