"""Training launcher: real steps on real (synthetic) data.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

On this CPU container it runs reduced configs (the examples use it to
train a ~100M model for a few hundred steps); on a TPU cluster the same
driver runs the full configs — the mesh shape is the only difference.
Features exercised: sharded state, donation, checkpoint/resume (exact),
prefetching data pipeline, straggler/ledger bookkeeping.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import LMArch
from repro.configs.registry import get_arch
from repro.data.pipeline import Prefetcher
from repro.data.tokens import TokenStream
from repro.launch.steps import _make_optimizer
from repro.models import transformer as tf


def reduced_lm(arch: LMArch, layers: int, d_model: int, vocab: int) -> LMArch:
    """Shrink an LM config for CPU-scale runs, preserving its character
    (GQA ratio, MoE-ness, activation)."""
    head_dim = 64
    n_heads = max(2, d_model // 128)
    n_kv = max(1, min(arch.n_kv_heads, n_heads))
    moe = None
    if arch.moe is not None:
        moe = dataclasses.replace(
            arch.moe, num_experts=min(arch.moe.num_experts, 8), d_ff=d_model * 2
        )
    return dataclasses.replace(
        arch,
        n_layers=layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=d_model * 4,
        vocab=vocab,
        moe=moe,
        q_chunk=128,
        loss_chunk=128,
    )


def train_lm(
    cfg: LMArch,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: str | None = None,
    save_every: int = 50,
    log_every: int = 10,
    seed: int = 0,
) -> dict:
    optimizer = _make_optimizer(cfg.optimizer, lr=3e-3)
    params = tf.init_params(cfg, jax.random.PRNGKey(seed))
    state = {"params": params, "opt": optimizer.init(params)}

    @jax.jit
    def step_fn(state, tokens):
        def loss_fn(p):
            return tf.lm_loss(cfg, p, tokens)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"]
        )
        new_p, new_o = optimizer.update(grads, state["opt"], state["params"])
        return {"params": new_p, "opt": new_o}, loss

    stream = TokenStream(vocab=cfg.vocab, batch=batch, seq_len=seq, seed=seed)
    manager = (
        CheckpointManager(ckpt_dir, save_every=save_every, async_writes=True)
        if ckpt_dir
        else None
    )
    start_step = 0
    if manager is not None:
        state, meta, start_step = manager.restore_or_init(state)
        if start_step:
            print(f"resumed from step {start_step}")

    prefetch = Prefetcher(stream.batch_at, depth=2, start_step=start_step)
    losses = []
    t0 = time.time()
    try:
        for step in range(start_step, steps):
            _, tokens = prefetch.get()
            state, loss = step_fn(state, jnp.asarray(tokens))
            losses.append(float(loss))
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:8.4f} ({dt:6.1f}s)")
            if manager is not None:
                manager.maybe_save(step, state, {"stream_step": step + 1})
    finally:
        prefetch.close()
        if manager is not None:
            manager.ckpt.close()
    return {"losses": losses, "final_loss": losses[-1] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full-config", action="store_true", help="no reduction (TPU)")
    args = ap.parse_args()

    bundle = get_arch(args.arch)
    if not isinstance(bundle.arch, LMArch):
        raise SystemExit("train.py currently drives LM archs; see examples/ for GNN/DLRM")
    cfg = (
        bundle.arch
        if args.full_config
        else reduced_lm(bundle.arch, args.layers, args.d_model, args.vocab)
    )
    out = train_lm(cfg, args.steps, args.batch, args.seq, args.ckpt_dir)
    print("final loss:", out["final_loss"])


if __name__ == "__main__":
    main()
