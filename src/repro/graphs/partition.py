"""2-D decomposition of the adjacency matrix (paper §2.3).

The processor grid has R rows and C columns.  Vertices are padded to
``n_pad = R*C*chunk`` and assigned to chunks contiguously: chunk ``k``
owns vertices ``[k*chunk, (k+1)*chunk)``.  Device ``(i, j)`` owns chunk
``j*R + i`` — the paper's exact vertex assignment — which makes both
collectives of a traversal level land on contiguous memory:

* **expand** (vertical / paper's "gather Q and σ from column j"):
  ``all_gather`` of the owned chunks over the ``row`` axis yields the
  contiguous vertex range ``cols_j = [j*R*chunk, (j+1)*R*chunk)``.
* **fold** (horizontal / paper's "exchange Q_r and σ for row i"):
  device ``(i, j)`` accumulates partials for ``rows_i`` = chunks
  ``{i, R+i, ..., (C-1)R+i}``; reshaping to ``[C, chunk, ...]`` and
  ``psum_scatter`` over the ``col`` axis delivers block ``j`` — chunk
  ``j*R+i`` — exactly the device's own chunk.  No re-indexing traffic.

Arcs are stored on the device owning (source-column, destination-row):
arc (u, v) lives on grid cell ``(row_of(v), col_of(u))`` with local
indices precomputed here.  Padding arcs point at a sentinel destination
row (``C*chunk``) so they accumulate into a discarded slot.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph

__all__ = [
    "TwoDPartition",
    "BlockedSparseLayout",
    "partition_2d",
    "partition_arcs_2d",
    "default_tile_dim",
]


def default_tile_dim(chunk: int, preferred: int = 128) -> int:
    """Largest divisor of ``chunk`` ≤ ``preferred``, preferring MXU-lane
    multiples (8).  Tile dims must divide ``chunk`` so ring-chunk slicing
    lands exactly on chunk boundaries (see :meth:`TwoDPartition.blocked_sparse`)."""
    divisors = [d for d in range(1, min(chunk, preferred) + 1) if chunk % d == 0]
    lane_aligned = [d for d in divisors if d % 8 == 0]
    return max(lane_aligned or divisors)


@dataclasses.dataclass(frozen=True)
class BlockedSparseLayout:
    """Tiled block-compressed (BCSR-style) per-device adjacency layout.

    Each 2-D device block A[rows_i, cols_j] ([C·chunk, R·chunk]) is cut
    into a grid of (bm × bk) tiles and only nonzero tiles are stored —
    per-device adjacency memory and A-stream HBM traffic become
    O(nnz_tiles · bm · bk) instead of O(n_pad²/p).  Tiles are sorted by
    output tile-row so a flattened-nnz Pallas grid can accumulate one
    tile-row at a time (kernels/blocked_spmm.py); every tile-row holds at
    least one (possibly all-zero filler) tile so every output block is
    written, and cells are padded with trailing zero tiles on the last
    row to a uniform count for shard_map.

    Attributes:
      bm, bk:     tile shape (rows × cols); both divide ``chunk``.
      tiles:      [R, C, T, bm, bk] tile data (0/1 values).
      tile_rows:  i32 [R, C, T] output tile-row index of each stored tile
                  (into the [C·chunk/bm] grid), non-decreasing along T.
      tile_cols:  i32 [R, C, T] operand tile-col index (into [R·chunk/bk]).
      nnz_tiles:  i64 [R, C] true nonzero-tile count per cell (excludes
                  fillers/padding — the memory-model quantity).
      ring_*:     per-ring-chunk slices for the pipelined expand schedule
                  (``ring=True``): slot r of [R, C, R, Tr, ...] holds the
                  cell's tiles whose source columns lie in grid-row r's
                  chunk, ``ring_tile_cols`` re-based to [0, chunk/bk).
                  Same row-sorted / row-complete / padded invariants per
                  slot.  None when built with ``ring=False``.
    """

    bm: int
    bk: int
    R: int
    C: int
    chunk: int
    tiles: np.ndarray
    tile_rows: np.ndarray
    tile_cols: np.ndarray
    nnz_tiles: np.ndarray
    ring_tiles: np.ndarray | None = None
    ring_tile_rows: np.ndarray | None = None
    ring_tile_cols: np.ndarray | None = None

    @property
    def num_tile_rows(self) -> int:
        return self.C * self.chunk // self.bm

    @property
    def num_tile_cols(self) -> int:
        return self.R * self.chunk // self.bk

    def adjacency_bytes(self, dtype_bytes: int = 4) -> int:
        """Stored per-device adjacency bytes (tile data + index maps) —
        the layout actually materialized, padding included."""
        arrs = (
            (self.ring_tiles, self.ring_tile_rows, self.ring_tile_cols)
            if self.ring_tiles is not None
            else (self.tiles, self.tile_rows, self.tile_cols)
        )
        per_dev = arrs[0].size // (self.R * self.C) * dtype_bytes
        per_dev += sum(a.size // (self.R * self.C) * 4 for a in arrs[1:])
        return per_dev


@dataclasses.dataclass(frozen=True)
class TwoDPartition:
    """Host-side product of the 2-D partitioner.

    Attributes:
      R, C:      grid shape.
      n:         true vertex count.
      chunk:     vertices per chunk; ``n_pad = R*C*chunk``.
      src_local: int32 [R, C, max_arcs] — arc source index into the
                 column-gathered frontier (``[0, R*chunk)``).
      dst_local: int32 [R, C, max_arcs] — arc destination index into the
                 local partial accumulator (``[0, C*chunk]``; the value
                 ``C*chunk`` is the padding sentinel).
      arc_counts: int64 [R, C] true arc count per cell (diagnostics).
      arc_perm:  int64 [R, C, max_arcs] index of each slot in the
                 original arc list (-1 = padding) — lets callers carry
                 per-arc payloads (e.g. GNN edge features) into the
                 partitioned layout.
    """

    R: int
    C: int
    n: int
    chunk: int
    src_local: np.ndarray
    dst_local: np.ndarray
    arc_counts: np.ndarray
    arc_perm: np.ndarray | None = None

    @property
    def n_pad(self) -> int:
        return self.R * self.C * self.chunk

    def owned_vertex_base(self, i: int, j: int) -> int:
        return (j * self.R + i) * self.chunk

    def vertex_chunk_owner(self) -> np.ndarray:
        """int32 [n_pad] -> flat device id (i * C + j) of each vertex's owner."""
        chunks = np.arange(self.n_pad) // self.chunk
        i = chunks % self.R
        j = chunks // self.R
        return (i * self.C + j).astype(np.int32)

    def ring_arcs(self, arc_pad_multiple: int = 8) -> tuple[np.ndarray, np.ndarray]:
        """Ring-sliced arc layout for the pipelined expand schedule.

        The ring schedule replaces the vertical ``all_gather`` with R-1
        ``ppermute`` steps: at step t device (i, j) holds the frontier
        chunk of grid row ``r = (i - t) mod R`` in hand and must process
        exactly the arcs whose source lies in that chunk.  This method
        re-slices each cell's arc list by source row-chunk so a step is
        one dynamic-slice away from its arcs.

        Returns ``(ring_src, ring_dst)`` int32 [R, C, R, max_ring_arcs]:
        slot (i, j, r) holds cell (i, j)'s arcs sourced in global chunk
        ``j*R + r``.  ``ring_src`` is chunk-relative ([0, chunk)) —
        it indexes the single chunk in hand, not the gathered slice;
        ``ring_dst`` is unchanged ([0, C*chunk], sentinel-padded).
        Padding slots use src 0 / dst sentinel (discarded row).
        """
        R, C, chunk = self.R, self.C, self.chunk
        sentinel = C * chunk
        max_ring = 1
        sliced: list[list[list[tuple[np.ndarray, np.ndarray]]]] = []
        for i in range(R):
            row: list[list[tuple[np.ndarray, np.ndarray]]] = []
            for j in range(C):
                valid = self.dst_local[i, j] != sentinel
                s_all = self.src_local[i, j][valid]
                d_all = self.dst_local[i, j][valid]
                r_all = s_all // chunk
                slots = []
                for r in range(R):
                    sel = r_all == r
                    slots.append((s_all[sel] % chunk, d_all[sel]))
                    max_ring = max(max_ring, int(sel.sum()))
                row.append(slots)
            sliced.append(row)
        max_ring += (-max_ring) % arc_pad_multiple
        ring_src = np.zeros((R, C, R, max_ring), np.int32)
        ring_dst = np.full((R, C, R, max_ring), sentinel, np.int32)
        for i in range(R):
            for j in range(C):
                for r in range(R):
                    s_r, d_r = sliced[i][j][r]
                    ring_src[i, j, r, : s_r.size] = s_r
                    ring_dst[i, j, r, : d_r.size] = d_r
        return ring_src, ring_dst

    def dense_blocks(self, dtype=np.float32) -> np.ndarray:
        """Dense per-device adjacency blocks [R, C, C·chunk, R·chunk].

        Block (i, j) is A[rows_i, cols_j] in the local index spaces the
        collectives use: rows index the [C·chunk] fold partial, columns
        index the [R·chunk] row-gathered frontier.  This feeds the fused
        Pallas dense-block engine (operators.DistributedPallasOperator);
        memory is (n_pad²/p)·dtype per device, so it is the dense-regime
        counterpart of the arc-list layout, not a replacement.
        """
        sentinel = self.C * self.chunk
        blocks = np.zeros(
            (self.R, self.C, self.C * self.chunk, self.R * self.chunk), dtype
        )
        for i in range(self.R):
            for j in range(self.C):
                valid = self.dst_local[i, j] != sentinel
                blocks[i, j, self.dst_local[i, j, valid], self.src_local[i, j, valid]] = 1
        return blocks

    def _cell_arcs(self, i: int, j: int) -> tuple[np.ndarray, np.ndarray]:
        """True (dst_local, src_local) arc pairs of one grid cell."""
        valid = self.dst_local[i, j] != self.C * self.chunk
        return self.dst_local[i, j][valid], self.src_local[i, j][valid]

    def nnz_tile_counts(self, bm: int | None = None, bk: int | None = None) -> np.ndarray:
        """int64 [R, C] nonzero (bm × bk)-tile count per device block —
        the O(nnz-tiles) quantity of the blocked-sparse memory model,
        computable without materializing any tile data (memory guard /
        roofline path)."""
        bm = default_tile_dim(self.chunk) if bm is None else bm
        bk = default_tile_dim(self.chunk) if bk is None else bk
        num_tc = self.R * self.chunk // bk
        counts = np.zeros((self.R, self.C), np.int64)
        for i in range(self.R):
            for j in range(self.C):
                d, s = self._cell_arcs(i, j)
                counts[i, j] = np.unique((d // bm) * num_tc + (s // bk)).size
        return counts

    def ring_arcs_max(self, arc_pad_multiple: int = 8) -> int:
        """``max_ring_arcs`` of :meth:`ring_arcs` without materializing
        the layout — the worst (cell, slot) arc count, pad included.
        The ring arc layout allocates 2 · R · max_ring_arcs i32 per
        device (vs 2 · max_arcs flat), which is what the memory guard
        must price under a ring overlap policy."""
        max_ring = 1
        for i in range(self.R):
            for j in range(self.C):
                _, s = self._cell_arcs(i, j)
                if s.size:
                    slots = np.bincount(s // self.chunk, minlength=self.R)
                    max_ring = max(max_ring, int(slots.max()))
        return max_ring + (-max_ring) % arc_pad_multiple

    def blocked_sparse_counts(
        self, bm: int | None = None, bk: int | None = None
    ) -> dict:
        """Exact stored-tile accounting of :meth:`blocked_sparse` (both
        the full and ring forms, one pass) without materializing tile
        data (memory guard / roofline path).

        The shipped layout stores more than the true nonzero tiles: one
        zero filler per empty tile-row (row-complete invariant), padding
        to the worst cell's count (shard_map uniformity), and — in the
        ring form — R per-slot slices each carrying its own fillers and
        global padding.  ``bytes_full``/``bytes_ring`` match
        :meth:`BlockedSparseLayout.adjacency_bytes` exactly.
        """
        bm = default_tile_dim(self.chunk) if bm is None else bm
        bk = default_tile_dim(self.chunk) if bk is None else bk
        R, C, chunk = self.R, self.C, self.chunk
        num_tr = C * chunk // bm
        num_tc = R * chunk // bk
        cpk = chunk // bk
        nnz_max = nnz_total = full_max = ring_max = 0
        for i in range(R):
            for j in range(C):
                d, s = self._cell_arcs(i, j)
                key = (d // bm) * num_tc + (s // bk)
                uniq = np.unique(key)
                r_u, c_u = uniq // num_tc, uniq % num_tc
                nnz_max = max(nnz_max, uniq.size)
                nnz_total += uniq.size
                full_max = max(full_max, uniq.size + num_tr - np.unique(r_u).size)
                for r in range(R):
                    rows_r = r_u[(c_u // cpk) == r]
                    ring_max = max(
                        ring_max, rows_r.size + num_tr - np.unique(rows_r).size
                    )
        stored_full = max(full_max, 1)
        stored_ring = R * max(ring_max, 1)
        per_tile = bm * bk * 4 + 8
        return {
            "bm": bm,
            "bk": bk,
            "nnz_max": nnz_max,
            "nnz_total": nnz_total,
            "stored_tiles_full": stored_full,
            "stored_tiles_ring": stored_ring,
            "bytes_full": stored_full * per_tile,
            "bytes_ring": stored_ring * per_tile,
        }

    def blocked_sparse(
        self,
        bm: int | None = None,
        bk: int | None = None,
        *,
        ring: bool = False,
        dtype=np.float32,
    ) -> BlockedSparseLayout:
        """Build the tiled block-compressed layout (see BlockedSparseLayout).

        ``bm``/``bk`` must divide ``chunk`` (defaults: the largest
        lane-friendly divisor ≤ 128) so the tile grid is aligned with
        both the fold-partial rows ([C·chunk]) and — for ``ring=True`` —
        the per-ring-chunk source slicing of the pipelined expand.
        """
        bm = default_tile_dim(self.chunk) if bm is None else bm
        bk = default_tile_dim(self.chunk) if bk is None else bk
        if self.chunk % bm or self.chunk % bk:
            raise ValueError(
                f"tile dims ({bm}, {bk}) must divide chunk={self.chunk} "
                "(ring-chunk slicing needs tile-aligned chunk boundaries)"
            )
        R, C, chunk = self.R, self.C, self.chunk
        num_tr = C * chunk // bm
        num_tc = R * chunk // bk
        cpk = chunk // bk  # tile-cols per ring chunk

        def materialize(entries, t_max):
            """entries[i][j] = (rows, cols, data) sorted by row, row-complete.
            Pad each cell to t_max with zero tiles on the last tile-row."""
            rows = np.full((R, C, t_max), num_tr - 1, np.int32)
            cols = np.zeros((R, C, t_max), np.int32)
            data = np.zeros((R, C, t_max, bm, bk), dtype)
            for i in range(R):
                for j in range(C):
                    r_u, c_u, d_u = entries[i][j]
                    rows[i, j, : r_u.size] = r_u
                    cols[i, j, : c_u.size] = c_u
                    data[i, j, : d_u.shape[0]] = d_u
            return rows, cols, data

        def row_complete(r_u, c_u, d_u):
            """Insert one zero filler tile into every absent tile-row so
            each output block is visited (and, in acc mode, carries the
            ring accumulator through) — then re-sort by row."""
            missing = np.setdiff1d(np.arange(num_tr, dtype=np.int64), r_u)
            if missing.size:
                r_u = np.concatenate([r_u, missing])
                c_u = np.concatenate([c_u, np.zeros(missing.size, np.int64)])
                d_u = np.concatenate(
                    [d_u, np.zeros((missing.size, bm, bk), dtype)], axis=0
                )
                order = np.argsort(r_u, kind="stable")
                r_u, c_u, d_u = r_u[order], c_u[order], d_u[order]
            return r_u, c_u, d_u

        nnz = np.zeros((R, C), np.int64)
        full_entries: list[list[tuple]] = []
        ring_entries: list[list[list[tuple]]] = []
        full_max, ring_max = 1, 1
        for i in range(R):
            full_row, ring_row = [], []
            for j in range(C):
                d, s = self._cell_arcs(i, j)
                key = (d // bm) * num_tc + (s // bk)
                uniq, inv = np.unique(key, return_inverse=True)
                data = np.zeros((uniq.size, bm, bk), dtype)
                data[inv, d % bm, s % bk] = 1
                r_u, c_u = uniq // num_tc, uniq % num_tc
                nnz[i, j] = uniq.size
                cell = row_complete(r_u, c_u, data)
                full_max = max(full_max, cell[0].size)
                full_row.append(cell)
                if ring:
                    slots = []
                    for r in range(R):
                        sel = (c_u // cpk) == r
                        slot = row_complete(r_u[sel], c_u[sel] - r * cpk, data[sel])
                        ring_max = max(ring_max, slot[0].size)
                        slots.append(slot)
                    ring_row.append(slots)
            full_entries.append(full_row)
            ring_entries.append(ring_row)

        rows_a, cols_a, tiles_a = materialize(full_entries, full_max)
        ring_rows = ring_cols = ring_tiles = None
        if ring:
            ring_rows = np.full((R, C, R, ring_max), num_tr - 1, np.int32)
            ring_cols = np.zeros((R, C, R, ring_max), np.int32)
            ring_tiles = np.zeros((R, C, R, ring_max, bm, bk), dtype)
            for i in range(R):
                for j in range(C):
                    for r in range(R):
                        r_u, c_u, d_u = ring_entries[i][j][r]
                        ring_rows[i, j, r, : r_u.size] = r_u
                        ring_cols[i, j, r, : c_u.size] = c_u
                        ring_tiles[i, j, r, : d_u.shape[0]] = d_u
        return BlockedSparseLayout(
            bm=bm,
            bk=bk,
            R=R,
            C=C,
            chunk=chunk,
            tiles=tiles_a,
            tile_rows=rows_a,
            tile_cols=cols_a,
            nnz_tiles=nnz,
            ring_tiles=ring_tiles,
            ring_tile_rows=ring_rows,
            ring_tile_cols=ring_cols,
        )


def partition_2d(
    graph: Graph,
    R: int,
    C: int,
    arc_pad_multiple: int = 8,
) -> TwoDPartition:
    """Partition ``graph`` over an R×C grid (see module docstring)."""
    return partition_arcs_2d(
        graph.src, graph.dst, graph.n, R, C, arc_pad_multiple=arc_pad_multiple
    )


def partition_arcs_2d(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    R: int,
    C: int,
    arc_pad_multiple: int = 8,
    max_arcs: int | None = None,
) -> TwoDPartition:
    """2-D partition of an arbitrary (possibly asymmetric) arc list —
    used by both MGBC and the GNN message-passing substrate (the paper's
    decomposition applied verbatim to 'messages' instead of 'frontier
    expansions')."""
    chunk = -(-n // (R * C))  # ceil
    src, dst = np.asarray(src, np.int64), np.asarray(dst, np.int64)

    src_chunk = src // chunk
    dst_chunk = dst // chunk
    # grid cell of each arc: column owner of src, row owner of dst
    j_of_arc = src_chunk // R
    i_of_arc = dst_chunk % R

    # local indices
    src_local = (src - j_of_arc * R * chunk).astype(np.int32)  # within cols_j
    dst_block = dst_chunk // R  # block m of rows_i
    dst_local = (dst_block * chunk + dst % chunk).astype(np.int32)

    cell = i_of_arc * C + j_of_arc
    order = np.argsort(cell, kind="stable")
    cell_sorted = cell[order]
    counts = np.bincount(cell_sorted, minlength=R * C).reshape(R, C)

    if max_arcs is None:
        max_arcs = int(counts.max()) if counts.size else 0
        max_arcs = max(max_arcs, 1)
        max_arcs += (-max_arcs) % arc_pad_multiple
    elif counts.size and int(counts.max()) > max_arcs:
        raise ValueError(f"max_arcs={max_arcs} < worst cell {int(counts.max())}")

    sentinel_dst = C * chunk
    out_src = np.zeros((R, C, max_arcs), dtype=np.int32)
    out_dst = np.full((R, C, max_arcs), sentinel_dst, dtype=np.int32)
    out_perm = np.full((R, C, max_arcs), -1, dtype=np.int64)

    starts = np.zeros(R * C + 1, dtype=np.int64)
    np.cumsum(counts.ravel(), out=starts[1:])
    src_sorted = src_local[order]
    dst_sorted = dst_local[order]
    for flat in range(R * C):
        i, j = divmod(flat, C)
        s, e = starts[flat], starts[flat + 1]
        out_src[i, j, : e - s] = src_sorted[s:e]
        out_dst[i, j, : e - s] = dst_sorted[s:e]
        out_perm[i, j, : e - s] = order[s:e]

    return TwoDPartition(
        R=R,
        C=C,
        n=n,
        chunk=chunk,
        src_local=out_src,
        dst_local=out_dst,
        arc_counts=counts.astype(np.int64),
        arc_perm=out_perm,
    )
