"""2-D decomposition of the adjacency matrix (paper §2.3).

The processor grid has R rows and C columns.  Vertices are padded to
``n_pad = R*C*chunk`` and assigned to chunks contiguously: chunk ``k``
owns vertices ``[k*chunk, (k+1)*chunk)``.  Device ``(i, j)`` owns chunk
``j*R + i`` — the paper's exact vertex assignment — which makes both
collectives of a traversal level land on contiguous memory:

* **expand** (vertical / paper's "gather Q and σ from column j"):
  ``all_gather`` of the owned chunks over the ``row`` axis yields the
  contiguous vertex range ``cols_j = [j*R*chunk, (j+1)*R*chunk)``.
* **fold** (horizontal / paper's "exchange Q_r and σ for row i"):
  device ``(i, j)`` accumulates partials for ``rows_i`` = chunks
  ``{i, R+i, ..., (C-1)R+i}``; reshaping to ``[C, chunk, ...]`` and
  ``psum_scatter`` over the ``col`` axis delivers block ``j`` — chunk
  ``j*R+i`` — exactly the device's own chunk.  No re-indexing traffic.

Arcs are stored on the device owning (source-column, destination-row):
arc (u, v) lives on grid cell ``(row_of(v), col_of(u))`` with local
indices precomputed here.  Padding arcs point at a sentinel destination
row (``C*chunk``) so they accumulate into a discarded slot.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph

__all__ = ["TwoDPartition", "partition_2d", "partition_arcs_2d"]


@dataclasses.dataclass(frozen=True)
class TwoDPartition:
    """Host-side product of the 2-D partitioner.

    Attributes:
      R, C:      grid shape.
      n:         true vertex count.
      chunk:     vertices per chunk; ``n_pad = R*C*chunk``.
      src_local: int32 [R, C, max_arcs] — arc source index into the
                 column-gathered frontier (``[0, R*chunk)``).
      dst_local: int32 [R, C, max_arcs] — arc destination index into the
                 local partial accumulator (``[0, C*chunk]``; the value
                 ``C*chunk`` is the padding sentinel).
      arc_counts: int64 [R, C] true arc count per cell (diagnostics).
      arc_perm:  int64 [R, C, max_arcs] index of each slot in the
                 original arc list (-1 = padding) — lets callers carry
                 per-arc payloads (e.g. GNN edge features) into the
                 partitioned layout.
    """

    R: int
    C: int
    n: int
    chunk: int
    src_local: np.ndarray
    dst_local: np.ndarray
    arc_counts: np.ndarray
    arc_perm: np.ndarray | None = None

    @property
    def n_pad(self) -> int:
        return self.R * self.C * self.chunk

    def owned_vertex_base(self, i: int, j: int) -> int:
        return (j * self.R + i) * self.chunk

    def vertex_chunk_owner(self) -> np.ndarray:
        """int32 [n_pad] -> flat device id (i * C + j) of each vertex's owner."""
        chunks = np.arange(self.n_pad) // self.chunk
        i = chunks % self.R
        j = chunks // self.R
        return (i * self.C + j).astype(np.int32)

    def ring_arcs(self, arc_pad_multiple: int = 8) -> tuple[np.ndarray, np.ndarray]:
        """Ring-sliced arc layout for the pipelined expand schedule.

        The ring schedule replaces the vertical ``all_gather`` with R-1
        ``ppermute`` steps: at step t device (i, j) holds the frontier
        chunk of grid row ``r = (i - t) mod R`` in hand and must process
        exactly the arcs whose source lies in that chunk.  This method
        re-slices each cell's arc list by source row-chunk so a step is
        one dynamic-slice away from its arcs.

        Returns ``(ring_src, ring_dst)`` int32 [R, C, R, max_ring_arcs]:
        slot (i, j, r) holds cell (i, j)'s arcs sourced in global chunk
        ``j*R + r``.  ``ring_src`` is chunk-relative ([0, chunk)) —
        it indexes the single chunk in hand, not the gathered slice;
        ``ring_dst`` is unchanged ([0, C*chunk], sentinel-padded).
        Padding slots use src 0 / dst sentinel (discarded row).
        """
        R, C, chunk = self.R, self.C, self.chunk
        sentinel = C * chunk
        max_ring = 1
        sliced: list[list[list[tuple[np.ndarray, np.ndarray]]]] = []
        for i in range(R):
            row: list[list[tuple[np.ndarray, np.ndarray]]] = []
            for j in range(C):
                valid = self.dst_local[i, j] != sentinel
                s_all = self.src_local[i, j][valid]
                d_all = self.dst_local[i, j][valid]
                r_all = s_all // chunk
                slots = []
                for r in range(R):
                    sel = r_all == r
                    slots.append((s_all[sel] % chunk, d_all[sel]))
                    max_ring = max(max_ring, int(sel.sum()))
                row.append(slots)
            sliced.append(row)
        max_ring += (-max_ring) % arc_pad_multiple
        ring_src = np.zeros((R, C, R, max_ring), np.int32)
        ring_dst = np.full((R, C, R, max_ring), sentinel, np.int32)
        for i in range(R):
            for j in range(C):
                for r in range(R):
                    s_r, d_r = sliced[i][j][r]
                    ring_src[i, j, r, : s_r.size] = s_r
                    ring_dst[i, j, r, : d_r.size] = d_r
        return ring_src, ring_dst

    def dense_blocks(self, dtype=np.float32) -> np.ndarray:
        """Dense per-device adjacency blocks [R, C, C·chunk, R·chunk].

        Block (i, j) is A[rows_i, cols_j] in the local index spaces the
        collectives use: rows index the [C·chunk] fold partial, columns
        index the [R·chunk] row-gathered frontier.  This feeds the fused
        Pallas dense-block engine (operators.DistributedPallasOperator);
        memory is (n_pad²/p)·dtype per device, so it is the dense-regime
        counterpart of the arc-list layout, not a replacement.
        """
        sentinel = self.C * self.chunk
        blocks = np.zeros(
            (self.R, self.C, self.C * self.chunk, self.R * self.chunk), dtype
        )
        for i in range(self.R):
            for j in range(self.C):
                valid = self.dst_local[i, j] != sentinel
                blocks[i, j, self.dst_local[i, j, valid], self.src_local[i, j, valid]] = 1
        return blocks


def partition_2d(
    graph: Graph,
    R: int,
    C: int,
    arc_pad_multiple: int = 8,
) -> TwoDPartition:
    """Partition ``graph`` over an R×C grid (see module docstring)."""
    return partition_arcs_2d(
        graph.src, graph.dst, graph.n, R, C, arc_pad_multiple=arc_pad_multiple
    )


def partition_arcs_2d(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    R: int,
    C: int,
    arc_pad_multiple: int = 8,
    max_arcs: int | None = None,
) -> TwoDPartition:
    """2-D partition of an arbitrary (possibly asymmetric) arc list —
    used by both MGBC and the GNN message-passing substrate (the paper's
    decomposition applied verbatim to 'messages' instead of 'frontier
    expansions')."""
    chunk = -(-n // (R * C))  # ceil
    src, dst = np.asarray(src, np.int64), np.asarray(dst, np.int64)

    src_chunk = src // chunk
    dst_chunk = dst // chunk
    # grid cell of each arc: column owner of src, row owner of dst
    j_of_arc = src_chunk // R
    i_of_arc = dst_chunk % R

    # local indices
    src_local = (src - j_of_arc * R * chunk).astype(np.int32)  # within cols_j
    dst_block = dst_chunk // R  # block m of rows_i
    dst_local = (dst_block * chunk + dst % chunk).astype(np.int32)

    cell = i_of_arc * C + j_of_arc
    order = np.argsort(cell, kind="stable")
    cell_sorted = cell[order]
    counts = np.bincount(cell_sorted, minlength=R * C).reshape(R, C)

    if max_arcs is None:
        max_arcs = int(counts.max()) if counts.size else 0
        max_arcs = max(max_arcs, 1)
        max_arcs += (-max_arcs) % arc_pad_multiple
    elif counts.size and int(counts.max()) > max_arcs:
        raise ValueError(f"max_arcs={max_arcs} < worst cell {int(counts.max())}")

    sentinel_dst = C * chunk
    out_src = np.zeros((R, C, max_arcs), dtype=np.int32)
    out_dst = np.full((R, C, max_arcs), sentinel_dst, dtype=np.int32)
    out_perm = np.full((R, C, max_arcs), -1, dtype=np.int64)

    starts = np.zeros(R * C + 1, dtype=np.int64)
    np.cumsum(counts.ravel(), out=starts[1:])
    src_sorted = src_local[order]
    dst_sorted = dst_local[order]
    for flat in range(R * C):
        i, j = divmod(flat, C)
        s, e = starts[flat], starts[flat + 1]
        out_src[i, j, : e - s] = src_sorted[s:e]
        out_dst[i, j, : e - s] = dst_sorted[s:e]
        out_perm[i, j, : e - s] = order[s:e]

    return TwoDPartition(
        R=R,
        C=C,
        n=n,
        chunk=chunk,
        src_local=out_src,
        dst_local=out_dst,
        arc_counts=counts.astype(np.int64),
        arc_perm=out_perm,
    )
