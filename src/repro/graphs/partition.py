"""2-D decomposition of the adjacency matrix (paper §2.3).

The processor grid has R rows and C columns.  Vertices are padded to
``n_pad = R*C*chunk`` and assigned to chunks contiguously: chunk ``k``
owns vertices ``[k*chunk, (k+1)*chunk)``.  Device ``(i, j)`` owns chunk
``j*R + i`` — the paper's exact vertex assignment — which makes both
collectives of a traversal level land on contiguous memory:

* **expand** (vertical / paper's "gather Q and σ from column j"):
  ``all_gather`` of the owned chunks over the ``row`` axis yields the
  contiguous vertex range ``cols_j = [j*R*chunk, (j+1)*R*chunk)``.
* **fold** (horizontal / paper's "exchange Q_r and σ for row i"):
  device ``(i, j)`` accumulates partials for ``rows_i`` = chunks
  ``{i, R+i, ..., (C-1)R+i}``; reshaping to ``[C, chunk, ...]`` and
  ``psum_scatter`` over the ``col`` axis delivers block ``j`` — chunk
  ``j*R+i`` — exactly the device's own chunk.  No re-indexing traffic.

Arcs are stored on the device owning (source-column, destination-row):
arc (u, v) lives on grid cell ``(row_of(v), col_of(u))`` with local
indices precomputed here.  Padding arcs point at a sentinel destination
row (``C*chunk``) so they accumulate into a discarded slot.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph

__all__ = [
    "TwoDPartition",
    "BlockedSparseLayout",
    "HybridLayout",
    "partition_2d",
    "partition_arcs_2d",
    "default_tile_dim",
]


def _arc_tile_unique(d: np.ndarray, s: np.ndarray, bm: int, bk: int, num_tc: int):
    """The arc→tile unique pass of one grid cell.

    Maps a cell's (dst_local, src_local) arc pairs onto the (bm × bk)
    tile grid and deduplicates: returns ``(r_u, c_u, inv)`` — the unique
    tile row/col ids (row-major-key sorted, i64) and the arc→unique-tile
    inverse map.  This is the single expensive sort of the host-side
    tile build; :meth:`TwoDPartition._tile_pass` caches its result per
    (bm, bk) so the counting path (memory guard / roofline), the kernel
    choice, and the layout build all share ONE pass.  Tests spy on this
    seam to pin the no-duplicate-pass property.
    """
    key = (d // bm) * num_tc + (s // bk)
    uniq, inv = np.unique(key, return_inverse=True)
    return uniq // num_tc, uniq % num_tc, inv


def default_tile_dim(chunk: int, preferred: int = 128) -> int:
    """Largest divisor of ``chunk`` ≤ ``preferred``, preferring MXU-lane
    multiples (8).  Tile dims must divide ``chunk`` so ring-chunk slicing
    lands exactly on chunk boundaries (see :meth:`TwoDPartition.blocked_sparse`)."""
    divisors = [d for d in range(1, min(chunk, preferred) + 1) if chunk % d == 0]
    lane_aligned = [d for d in divisors if d % 8 == 0]
    return max(lane_aligned or divisors)


@dataclasses.dataclass(frozen=True)
class BlockedSparseLayout:
    """Tiled block-compressed (BCSR-style) per-device adjacency layout.

    Each 2-D device block A[rows_i, cols_j] ([C·chunk, R·chunk]) is cut
    into a grid of (bm × bk) tiles and only nonzero tiles are stored —
    per-device adjacency memory and A-stream HBM traffic become
    O(nnz_tiles · bm · bk) instead of O(n_pad²/p).  Tiles are sorted by
    output tile-row so a flattened-nnz Pallas grid can accumulate one
    tile-row at a time (kernels/blocked_spmm.py); every tile-row holds at
    least one (possibly all-zero filler) tile so every output block is
    written, and cells are padded with trailing zero tiles on the last
    row to a uniform count for shard_map.

    Attributes:
      bm, bk:     tile shape (rows × cols); both divide ``chunk``.
      tiles:      [R, C, T, bm, bk] tile data (0/1 values).
      tile_rows:  i32 [R, C, T] output tile-row index of each stored tile
                  (into the [C·chunk/bm] grid), non-decreasing along T.
      tile_cols:  i32 [R, C, T] operand tile-col index (into [R·chunk/bk]).
      nnz_tiles:  i64 [R, C] true nonzero-tile count per cell (excludes
                  fillers/padding — the memory-model quantity).
      ring_*:     per-ring-chunk slices for the pipelined expand schedule
                  (``ring=True``): slot r of [R, C, R, Tr, ...] holds the
                  cell's tiles whose source columns lie in grid-row r's
                  chunk, ``ring_tile_cols`` re-based to [0, chunk/bk).
                  Same row-sorted / row-complete / padded invariants per
                  slot.  None when built with ``ring=False``.

    Exactly one of the two forms is materialized: ``ring=False`` fills
    ``tiles``/``tile_rows``/``tile_cols`` and leaves the ring arrays
    None; ``ring=True`` fills only the ring arrays (the full tile array
    used to be built alongside and thrown away — double host memory at
    RMAT scale).
    """

    bm: int
    bk: int
    R: int
    C: int
    chunk: int
    nnz_tiles: np.ndarray
    tiles: np.ndarray | None = None
    tile_rows: np.ndarray | None = None
    tile_cols: np.ndarray | None = None
    ring_tiles: np.ndarray | None = None
    ring_tile_rows: np.ndarray | None = None
    ring_tile_cols: np.ndarray | None = None

    @property
    def num_tile_rows(self) -> int:
        return self.C * self.chunk // self.bm

    @property
    def num_tile_cols(self) -> int:
        return self.R * self.chunk // self.bk

    def adjacency_bytes(self, dtype_bytes: int = 4) -> int:
        """Stored per-device adjacency bytes (tile data + index maps) —
        the layout actually materialized, padding included."""
        arrs = (
            (self.ring_tiles, self.ring_tile_rows, self.ring_tile_cols)
            if self.ring_tiles is not None
            else (self.tiles, self.tile_rows, self.tile_cols)
        )
        per_dev = arrs[0].size // (self.R * self.C) * dtype_bytes
        per_dev += sum(a.size // (self.R * self.C) * 4 for a in arrs[1:])
        return per_dev


@dataclasses.dataclass(frozen=True)
class HybridLayout:
    """Mixed dense/sparse per-cell layout (``engine_kind="pallas_hybrid"``).

    The roofline's per-cell kernel choice
    (:func:`repro.roofline.model.cell_kernel_choice`) marks each device
    cell dense or BCSR; the layout ships both operand sets with
    shard_map-uniform shapes but materializes each cell's data only in
    its chosen representation:

      dense_cells: bool [R, C] — True where the cell streams its dense
                   block through the dense partial kernels.
      blocks:      f32 [R, C, C·chunk, R·chunk] — dense adjacency data
                   for the dense-chosen cells; sparse-chosen slots are
                   never written (np.zeros calloc pages stay untouched),
                   so *materialized* host memory scales with the
                   dense-chosen area, not the mesh.
      sparse:      :class:`BlockedSparseLayout` holding tile data only
                   for the sparse-chosen cells — dense-chosen cells
                   carry the minimal row-complete filler list, so the
                   tile-count padding is set by the sparse cells alone.
    """

    dense_cells: np.ndarray
    blocks: np.ndarray
    sparse: BlockedSparseLayout

    def host_bytes(self) -> int:
        """Materialized host bytes of the mixed layout: dense block data
        for the dense-chosen cells only (untouched zero pages of the
        sparse-chosen slots excluded), all cells' tile arrays (padded —
        the shipped quantity), and the choice mask."""
        m, k = self.blocks.shape[2:]
        dense = int(self.dense_cells.sum()) * m * k * self.blocks.itemsize
        n_cells = self.dense_cells.size
        return dense + n_cells * self.sparse.adjacency_bytes() + n_cells


@dataclasses.dataclass(frozen=True)
class TwoDPartition:
    """Host-side product of the 2-D partitioner.

    Attributes:
      R, C:      grid shape.
      n:         true vertex count.
      chunk:     vertices per chunk; ``n_pad = R*C*chunk``.
      src_local: int32 [R, C, max_arcs] — arc source index into the
                 column-gathered frontier (``[0, R*chunk)``).
      dst_local: int32 [R, C, max_arcs] — arc destination index into the
                 local partial accumulator (``[0, C*chunk]``; the value
                 ``C*chunk`` is the padding sentinel).
      arc_counts: int64 [R, C] true arc count per cell (diagnostics).
      arc_perm:  int64 [R, C, max_arcs] index of each slot in the
                 original arc list (-1 = padding) — lets callers carry
                 per-arc payloads (e.g. GNN edge features) into the
                 partitioned layout.
    """

    R: int
    C: int
    n: int
    chunk: int
    src_local: np.ndarray
    dst_local: np.ndarray
    arc_counts: np.ndarray
    arc_perm: np.ndarray | None = None

    @property
    def n_pad(self) -> int:
        return self.R * self.C * self.chunk

    def owned_vertex_base(self, i: int, j: int) -> int:
        return (j * self.R + i) * self.chunk

    def vertex_chunk_owner(self) -> np.ndarray:
        """int32 [n_pad] -> flat device id (i * C + j) of each vertex's owner."""
        chunks = np.arange(self.n_pad) // self.chunk
        i = chunks % self.R
        j = chunks // self.R
        return (i * self.C + j).astype(np.int32)

    def ring_arcs(self, arc_pad_multiple: int = 8) -> tuple[np.ndarray, np.ndarray]:
        """Ring-sliced arc layout for the pipelined expand schedule.

        The ring schedule replaces the vertical ``all_gather`` with R-1
        ``ppermute`` steps: at step t device (i, j) holds the frontier
        chunk of grid row ``r = (i - t) mod R`` in hand and must process
        exactly the arcs whose source lies in that chunk.  This method
        re-slices each cell's arc list by source row-chunk so a step is
        one dynamic-slice away from its arcs.

        Returns ``(ring_src, ring_dst)`` int32 [R, C, R, max_ring_arcs]:
        slot (i, j, r) holds cell (i, j)'s arcs sourced in global chunk
        ``j*R + r``.  ``ring_src`` is chunk-relative ([0, chunk)) —
        it indexes the single chunk in hand, not the gathered slice;
        ``ring_dst`` is unchanged ([0, C*chunk], sentinel-padded).
        Padding slots use src 0 / dst sentinel (discarded row).
        """
        R, C, chunk = self.R, self.C, self.chunk
        sentinel = C * chunk
        max_ring = 1
        sliced: list[list[list[tuple[np.ndarray, np.ndarray]]]] = []
        for i in range(R):
            row: list[list[tuple[np.ndarray, np.ndarray]]] = []
            for j in range(C):
                valid = self.dst_local[i, j] != sentinel
                s_all = self.src_local[i, j][valid]
                d_all = self.dst_local[i, j][valid]
                r_all = s_all // chunk
                slots = []
                for r in range(R):
                    sel = r_all == r
                    slots.append((s_all[sel] % chunk, d_all[sel]))
                    max_ring = max(max_ring, int(sel.sum()))
                row.append(slots)
            sliced.append(row)
        max_ring += (-max_ring) % arc_pad_multiple
        ring_src = np.zeros((R, C, R, max_ring), np.int32)
        ring_dst = np.full((R, C, R, max_ring), sentinel, np.int32)
        for i in range(R):
            for j in range(C):
                for r in range(R):
                    s_r, d_r = sliced[i][j][r]
                    ring_src[i, j, r, : s_r.size] = s_r
                    ring_dst[i, j, r, : d_r.size] = d_r
        return ring_src, ring_dst

    def arc_weights(self, w: np.ndarray) -> np.ndarray:
        """Per-arc weight payload in the partitioned slot layout.

        ``w`` is the graph's f32 [num_arcs] weight array; the result is
        f32 [R, C, max_arcs] aligned with ``src_local``/``dst_local``,
        with weight 0 at padding slots — the same "0 = no arc" encoding
        the dense layouts use, so the distributed weighted operators can
        mask on ``w > 0`` uniformly.  Requires ``arc_perm``.
        """
        if self.arc_perm is None:
            raise ValueError("arc_weights needs arc_perm (partition_arcs_2d output)")
        w = np.asarray(w, np.float32)
        valid = self.arc_perm >= 0
        return np.where(
            valid, w[np.clip(self.arc_perm, 0, None)], np.float32(0)
        ).astype(np.float32)

    def dense_blocks(self, dtype=np.float32, weights: np.ndarray | None = None) -> np.ndarray:
        """Dense per-device adjacency blocks [R, C, C·chunk, R·chunk].

        Block (i, j) is A[rows_i, cols_j] in the local index spaces the
        collectives use: rows index the [C·chunk] fold partial, columns
        index the [R·chunk] row-gathered frontier.  This feeds the fused
        Pallas dense-block engine (operators.DistributedPallasOperator);
        memory is (n_pad²/p)·dtype per device, so it is the dense-regime
        counterpart of the arc-list layout, not a replacement.

        With ``weights`` (f32 [num_arcs], graph arc order) the blocks
        hold edge weights instead of 0/1 — the bucketed-traversal
        operand, where 0 encodes "no arc" (weights are validated > 0 at
        graph construction).
        """
        sentinel = self.C * self.chunk
        blocks = np.zeros(
            (self.R, self.C, self.C * self.chunk, self.R * self.chunk), dtype
        )
        wrc = None if weights is None else self.arc_weights(weights)
        for i in range(self.R):
            for j in range(self.C):
                valid = self.dst_local[i, j] != sentinel
                val = 1 if wrc is None else wrc[i, j, valid]
                blocks[i, j, self.dst_local[i, j, valid], self.src_local[i, j, valid]] = val
        return blocks

    def _cell_arcs(self, i: int, j: int) -> tuple[np.ndarray, np.ndarray]:
        """True (dst_local, src_local) arc pairs of one grid cell."""
        valid = self.dst_local[i, j] != self.C * self.chunk
        return self.dst_local[i, j][valid], self.src_local[i, j][valid]

    def tile_candidates(self, limit: int = 3) -> list[tuple[int, int]]:
        """Candidate square BCSR (bm, bk) tile shapes for the autotuner.

        Divisors of ``chunk`` ≤ 128 (the ring-chunk alignment
        :meth:`_tile_dims` enforces), lane-aligned (multiples of 8) when
        any exist, largest first, capped at ``limit`` — a bounded menu
        the measured-cost planner can afford to time exhaustively.  The
        first entry is always the legacy :func:`default_tile_dim` pick,
        so autotune-off and roofline-fallback behavior are unchanged.
        """
        divisors = [
            d for d in range(1, min(self.chunk, 128) + 1) if self.chunk % d == 0
        ]
        lane = [d for d in divisors if d % 8 == 0] or divisors
        picks = sorted(lane, reverse=True)[: max(1, limit)]
        return [(d, d) for d in picks]

    def _tile_dims(self, bm: int | None, bk: int | None) -> tuple[int, int]:
        bm = default_tile_dim(self.chunk) if bm is None else bm
        bk = default_tile_dim(self.chunk) if bk is None else bk
        if self.chunk % bm or self.chunk % bk:
            raise ValueError(
                f"tile dims ({bm}, {bk}) must divide chunk={self.chunk} "
                "(ring-chunk slicing needs tile-aligned chunk boundaries)"
            )
        return bm, bk

    def _tile_pass(self, bm: int, bk: int) -> list[list[tuple]]:
        """The ONE arc→tile counting pass per (bm, bk), cached.

        ``result[i][j] = (r_u, c_u, inv)`` from :func:`_arc_tile_unique`.
        Every consumer of the tile grid — :meth:`nnz_tile_counts`,
        :meth:`blocked_sparse_counts` (memory guard / roofline / kernel
        choice) and the :meth:`blocked_sparse` layout build — reads this
        cache, so resolve → guard → build runs the per-cell unique pass
        exactly once per tile shape, not once per consumer.
        """
        cache = self.__dict__.setdefault("_tile_pass_cache", {})
        if (bm, bk) not in cache:
            num_tc = self.R * self.chunk // bk
            cache[(bm, bk)] = [
                [
                    _arc_tile_unique(*self._cell_arcs(i, j), bm, bk, num_tc)
                    for j in range(self.C)
                ]
                for i in range(self.R)
            ]
        return cache[(bm, bk)]

    def nnz_tile_counts(self, bm: int | None = None, bk: int | None = None) -> np.ndarray:
        """int64 [R, C] nonzero (bm × bk)-tile count per device block —
        the O(nnz-tiles) quantity of the blocked-sparse memory model,
        computable without materializing any tile data (memory guard /
        roofline path)."""
        bm, bk = self._tile_dims(bm, bk)
        cells = self._tile_pass(bm, bk)
        return np.array(
            [[cells[i][j][0].size for j in range(self.C)] for i in range(self.R)],
            np.int64,
        )

    def ring_arcs_max(self, arc_pad_multiple: int = 8) -> int:
        """``max_ring_arcs`` of :meth:`ring_arcs` without materializing
        the layout — the worst (cell, slot) arc count, pad included.
        The ring arc layout allocates 2 · R · max_ring_arcs i32 per
        device (vs 2 · max_arcs flat), which is what the memory guard
        must price under a ring overlap policy."""
        max_ring = 1
        for i in range(self.R):
            for j in range(self.C):
                _, s = self._cell_arcs(i, j)
                if s.size:
                    slots = np.bincount(s // self.chunk, minlength=self.R)
                    max_ring = max(max_ring, int(slots.max()))
        return max_ring + (-max_ring) % arc_pad_multiple

    def blocked_sparse_counts(
        self,
        bm: int | None = None,
        bk: int | None = None,
        cells: np.ndarray | None = None,
    ) -> dict:
        """Exact stored-tile accounting of :meth:`blocked_sparse` (both
        the full and ring forms) without materializing tile data (memory
        guard / roofline / kernel-choice path) — served from the shared
        :meth:`_tile_pass` cache, so calling this before the layout
        build adds zero extra arc→tile passes.

        The shipped layout stores more than the true nonzero tiles: one
        zero filler per empty tile-row (row-complete invariant), padding
        to the worst cell's count (shard_map uniformity), and — in the
        ring form — R per-slot slices each carrying its own fillers and
        global padding.  ``bytes_full``/``bytes_ring`` match
        :meth:`BlockedSparseLayout.adjacency_bytes` exactly.

        ``cells`` (bool [R, C], default all-True) restricts which cells'
        tiles count as stored — the hybrid engine prices its sparse side
        with ``cells=~dense_cells``; deselected cells are accounted as
        the filler-only lists the masked layout actually materializes.

        The per-cell arrays (``nnz_cell``/``stored_full_cell``/
        ``stored_ring_slot_cell``, masked like the aggregates) feed the
        roofline's per-cell dense-vs-BCSR choice
        (:func:`repro.roofline.model.cell_kernel_choice`).
        """
        bm, bk = self._tile_dims(bm, bk)
        R, C, chunk = self.R, self.C, self.chunk
        num_tr = C * chunk // bm
        cpk = chunk // bk
        sel = (
            np.ones((R, C), bool) if cells is None else np.asarray(cells, bool)
        )
        pass_cells = self._tile_pass(bm, bk)
        nnz_cell = np.zeros((R, C), np.int64)
        full_cell = np.zeros((R, C), np.int64)
        ring_slot_cell = np.zeros((R, C), np.int64)
        for i in range(R):
            for j in range(C):
                # a deselected cell materializes like an empty one: num_tr
                # row-complete fillers, no data tiles
                r_u, c_u, _ = (
                    pass_cells[i][j]
                    if sel[i, j]
                    else (np.zeros(0, np.int64), np.zeros(0, np.int64), None)
                )
                nnz_cell[i, j] = r_u.size
                full_cell[i, j] = r_u.size + num_tr - np.unique(r_u).size
                slot_max = 0
                for r in range(R):
                    rows_r = r_u[(c_u // cpk) == r]
                    slot_max = max(
                        slot_max, rows_r.size + num_tr - np.unique(rows_r).size
                    )
                ring_slot_cell[i, j] = slot_max
        stored_full = max(int(full_cell.max()), 1)
        stored_ring = R * max(int(ring_slot_cell.max()), 1)
        per_tile = bm * bk * 4 + 8
        return {
            "bm": bm,
            "bk": bk,
            "nnz_max": int(nnz_cell.max()),
            "nnz_total": int(nnz_cell.sum()),
            "stored_tiles_full": stored_full,
            "stored_tiles_ring": stored_ring,
            "bytes_full": stored_full * per_tile,
            "bytes_ring": stored_ring * per_tile,
            "nnz_cell": nnz_cell,
            "stored_full_cell": full_cell,
            "stored_ring_slot_cell": ring_slot_cell,
        }

    def blocked_sparse(
        self,
        bm: int | None = None,
        bk: int | None = None,
        *,
        ring: bool = False,
        dtype=np.float32,
        cells: np.ndarray | None = None,
        weights: np.ndarray | None = None,
    ) -> BlockedSparseLayout:
        """Build the tiled block-compressed layout (see BlockedSparseLayout).

        ``bm``/``bk`` must divide ``chunk`` (defaults: the largest
        lane-friendly divisor ≤ 128) so the tile grid is aligned with
        both the fold-partial rows ([C·chunk]) and — for ``ring=True`` —
        the per-ring-chunk source slicing of the pipelined expand.

        Only the requested form is materialized: ``ring=True`` builds
        the per-ring-chunk slices and leaves ``tiles`` None.  The tile
        ids come from the shared :meth:`_tile_pass` cache, so a
        preceding :meth:`blocked_sparse_counts` (guard / roofline) costs
        no second arc→tile pass.

        ``cells`` (bool [R, C]) stores tile data only for the selected
        cells; deselected cells materialize like empty ones (the minimal
        row-complete filler list) — the hybrid engine's sparse side,
        where dense-chosen cells must not inflate the tile padding.

        ``weights`` (f32 [num_arcs], graph arc order) stores edge
        weights instead of 0/1 tile values (0 = no arc) — the bucketed
        traversal operand.  Only the full form carries weights; the
        ring-sliced form belongs to the pipelined unweighted expand
        (weighted rounds run the barrier schedule).
        """
        if weights is not None and ring:
            raise ValueError(
                "weighted tiles are barrier-schedule only (ring pipelining of "
                "the bucketed relaxation is not implemented); build with ring=False"
            )
        bm, bk = self._tile_dims(bm, bk)
        R, C, chunk = self.R, self.C, self.chunk
        num_tr = C * chunk // bm
        cpk = chunk // bk  # tile-cols per ring chunk
        sel = (
            np.ones((R, C), bool) if cells is None else np.asarray(cells, bool)
        )
        pass_cells = self._tile_pass(bm, bk)
        wrc = None if weights is None else self.arc_weights(weights)

        def row_complete(r_u, c_u, d_u):
            """Insert one zero filler tile into every absent tile-row so
            each output block is visited (and, in acc mode, carries the
            ring accumulator through) — then re-sort by row."""
            missing = np.setdiff1d(np.arange(num_tr, dtype=np.int64), r_u)
            if missing.size:
                r_u = np.concatenate([r_u, missing])
                c_u = np.concatenate([c_u, np.zeros(missing.size, np.int64)])
                d_u = np.concatenate(
                    [d_u, np.zeros((missing.size, bm, bk), dtype)], axis=0
                )
                order = np.argsort(r_u, kind="stable")
                r_u, c_u, d_u = r_u[order], c_u[order], d_u[order]
            return r_u, c_u, d_u

        nnz = np.zeros((R, C), np.int64)
        entries: list = []  # [i][j] = cell tuple, or [i][j][r] = slot tuple
        t_max = 1
        for i in range(R):
            row = []
            for j in range(C):
                if sel[i, j]:
                    r_u, c_u, inv = pass_cells[i][j]
                    d, s = self._cell_arcs(i, j)
                    data = np.zeros((r_u.size, bm, bk), dtype)
                    valid = self.dst_local[i, j] != C * chunk
                    data[inv, d % bm, s % bk] = 1 if wrc is None else wrc[i, j, valid]
                    nnz[i, j] = r_u.size
                else:
                    r_u = c_u = np.zeros(0, np.int64)
                    data = np.zeros((0, bm, bk), dtype)
                if ring:
                    slots = []
                    for r in range(R):
                        pick = (c_u // cpk) == r
                        slot = row_complete(r_u[pick], c_u[pick] - r * cpk, data[pick])
                        t_max = max(t_max, slot[0].size)
                        slots.append(slot)
                    row.append(slots)
                else:
                    cell = row_complete(r_u, c_u, data)
                    t_max = max(t_max, cell[0].size)
                    row.append(cell)
            entries.append(row)

        # materialize (pad each cell/slot to t_max with zero tiles on the
        # last tile-row); only the requested form is allocated
        lead = (R, C, R) if ring else (R, C)
        rows_a = np.full(lead + (t_max,), num_tr - 1, np.int32)
        cols_a = np.zeros(lead + (t_max,), np.int32)
        tiles_a = np.zeros(lead + (t_max, bm, bk), dtype)
        for i in range(R):
            for j in range(C):
                slots = entries[i][j] if ring else [entries[i][j]]
                for r, (r_u, c_u, d_u) in enumerate(slots):
                    at = (i, j, r) if ring else (i, j)
                    rows_a[at][: r_u.size] = r_u
                    cols_a[at][: c_u.size] = c_u
                    tiles_a[at][: d_u.shape[0]] = d_u
        kw = (
            dict(ring_tiles=tiles_a, ring_tile_rows=rows_a, ring_tile_cols=cols_a)
            if ring
            else dict(tiles=tiles_a, tile_rows=rows_a, tile_cols=cols_a)
        )
        return BlockedSparseLayout(
            bm=bm, bk=bk, R=R, C=C, chunk=chunk, nnz_tiles=nnz, **kw
        )

    def blocked_hybrid(
        self,
        bm: int | None = None,
        bk: int | None = None,
        *,
        dense_cells: np.ndarray,
        ring: bool = False,
        dtype=np.float32,
        weights: np.ndarray | None = None,
    ) -> HybridLayout:
        """Build the mixed dense/sparse per-cell layout (see HybridLayout).

        ``dense_cells`` (bool [R, C]) is the roofline's per-cell kernel
        choice (:func:`repro.roofline.model.cell_kernel_choice`).  Dense
        data is written only into the dense-chosen cells' block slots;
        the sparse side is :meth:`blocked_sparse` restricted to the
        complementary cells, so each representation is materialized
        exactly where it is streamed.  ``weights`` threads the bucketed
        traversal's edge weights into both sides (0 = no arc).
        """
        dense_cells = np.asarray(dense_cells, bool)
        if dense_cells.shape != (self.R, self.C):
            raise ValueError(
                f"dense_cells shape {dense_cells.shape} != grid {(self.R, self.C)}"
            )
        sparse = self.blocked_sparse(
            bm, bk, ring=ring, dtype=dtype, cells=~dense_cells, weights=weights
        )
        wrc = None if weights is None else self.arc_weights(weights)
        m, k = self.C * self.chunk, self.R * self.chunk
        blocks = np.zeros((self.R, self.C, m, k), np.float32)
        for i in range(self.R):
            for j in range(self.C):
                if dense_cells[i, j]:
                    d, s = self._cell_arcs(i, j)
                    if wrc is None:
                        blocks[i, j, d, s] = 1
                    else:
                        valid = self.dst_local[i, j] != self.C * self.chunk
                        blocks[i, j, d, s] = wrc[i, j, valid]
        return HybridLayout(dense_cells=dense_cells, blocks=blocks, sparse=sparse)


def partition_2d(
    graph: Graph,
    R: int,
    C: int,
    arc_pad_multiple: int = 8,
) -> TwoDPartition:
    """Partition ``graph`` over an R×C grid (see module docstring)."""
    return partition_arcs_2d(
        graph.src, graph.dst, graph.n, R, C, arc_pad_multiple=arc_pad_multiple
    )


def partition_arcs_2d(
    src: np.ndarray,
    dst: np.ndarray,
    n: int,
    R: int,
    C: int,
    arc_pad_multiple: int = 8,
    max_arcs: int | None = None,
) -> TwoDPartition:
    """2-D partition of an arbitrary (possibly asymmetric) arc list —
    used by both MGBC and the GNN message-passing substrate (the paper's
    decomposition applied verbatim to 'messages' instead of 'frontier
    expansions')."""
    chunk = -(-n // (R * C))  # ceil
    src, dst = np.asarray(src, np.int64), np.asarray(dst, np.int64)

    src_chunk = src // chunk
    dst_chunk = dst // chunk
    # grid cell of each arc: column owner of src, row owner of dst
    j_of_arc = src_chunk // R
    i_of_arc = dst_chunk % R

    # local indices
    src_local = (src - j_of_arc * R * chunk).astype(np.int32)  # within cols_j
    dst_block = dst_chunk // R  # block m of rows_i
    dst_local = (dst_block * chunk + dst % chunk).astype(np.int32)

    cell = i_of_arc * C + j_of_arc
    order = np.argsort(cell, kind="stable")
    cell_sorted = cell[order]
    counts = np.bincount(cell_sorted, minlength=R * C).reshape(R, C)

    if max_arcs is None:
        max_arcs = int(counts.max()) if counts.size else 0
        max_arcs = max(max_arcs, 1)
        max_arcs += (-max_arcs) % arc_pad_multiple
    elif counts.size and int(counts.max()) > max_arcs:
        raise ValueError(f"max_arcs={max_arcs} < worst cell {int(counts.max())}")

    sentinel_dst = C * chunk
    out_src = np.zeros((R, C, max_arcs), dtype=np.int32)
    out_dst = np.full((R, C, max_arcs), sentinel_dst, dtype=np.int32)
    out_perm = np.full((R, C, max_arcs), -1, dtype=np.int64)

    starts = np.zeros(R * C + 1, dtype=np.int64)
    np.cumsum(counts.ravel(), out=starts[1:])
    src_sorted = src_local[order]
    dst_sorted = dst_local[order]
    for flat in range(R * C):
        i, j = divmod(flat, C)
        s, e = starts[flat], starts[flat + 1]
        out_src[i, j, : e - s] = src_sorted[s:e]
        out_dst[i, j, : e - s] = dst_sorted[s:e]
        out_perm[i, j, : e - s] = order[s:e]

    return TwoDPartition(
        R=R,
        C=C,
        n=n,
        chunk=chunk,
        src_local=out_src,
        dst_local=out_dst,
        arc_counts=counts.astype(np.int64),
        arc_perm=out_perm,
    )
