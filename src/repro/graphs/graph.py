"""Undirected graph container (optionally edge-weighted).

The paper (and therefore this framework) works on undirected graphs.  We
store the graph as a *symmetric directed edge list*: every undirected
edge {u, v} appears as both (u, v) and (v, u).  This is the layout
consumed by every traversal formulation in :mod:`repro.core`:

* dense path      — ``graph.dense_adjacency()`` (small n, MXU-friendly)
* sparse path     — ``graph.src / graph.dst`` + ``jax.ops.segment_sum``
* distributed 2-D — :func:`repro.graphs.partition.partition_2d`

Edge weights (``w``, float32 per arc, symmetric like the arc list) feed
the bucketed weighted traversal (`weighted=` on the BC entry points).
Weights must be strictly positive and finite: the delta-stepping bucket
loop relies on ``w > 0`` for its settled-mask invariant, and the dense
layouts encode "no edge" as weight 0.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Graph"]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable undirected graph.

    Attributes:
      n:    number of vertices (vertex ids are ``0 .. n-1``).
      src:  int32 [m2] source endpoint of each directed arc.
      dst:  int32 [m2] destination endpoint of each directed arc.
            ``m2 == 2 * num_undirected_edges``; the arc list is symmetric
            and sorted by (src, dst).
      w:    optional float32 [m2] arc weights, aligned with src/dst and
            symmetric (both arcs of an undirected edge share one weight).
            ``None`` means unweighted; weights are strictly positive.
    """

    n: int
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray | None = None

    # ------------------------------------------------------------- build
    @staticmethod
    def from_edges(
        n: int, edges: np.ndarray, weights: np.ndarray | None = None
    ) -> "Graph":
        """Build from an [e, 2] array of (possibly duplicated, possibly
        self-looped, possibly one-directional) undirected edge pairs.

        ``weights`` (optional [e] floats, one per input edge row) must be
        strictly positive and finite; duplicate undirected pairs keep the
        weight of the first occurrence.
        """
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if weights is not None:
            weights = np.asarray(weights, dtype=np.float32).reshape(-1)
            if weights.shape[0] != edges.shape[0]:
                raise ValueError(
                    f"weights has {weights.shape[0]} entries for "
                    f"{edges.shape[0]} edges"
                )
            if weights.size and (not np.all(np.isfinite(weights)) or weights.min() <= 0):
                raise ValueError(
                    "edge weights must be strictly positive and finite: the "
                    "bucketed weighted traversal relies on w > 0 (a zero-"
                    "weight edge would put its endpoints in the same bucket "
                    "forever and the dense layouts reserve 0 for 'no edge')"
                )
        if edges.size:
            if edges.min() < 0 or edges.max() >= n:
                raise ValueError("edge endpoint out of range")
        # drop self loops
        keep = edges[:, 0] != edges[:, 1]
        edges = edges[keep]
        if weights is not None:
            weights = weights[keep]
        # canonicalize + dedupe undirected pairs
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n + hi
        _, idx = np.unique(key, return_index=True)
        lo, hi = lo[idx], hi[idx]
        # symmetrize
        src = np.concatenate([lo, hi]).astype(np.int32)
        dst = np.concatenate([hi, lo]).astype(np.int32)
        order = np.lexsort((dst, src))
        if weights is None:
            return Graph(n=n, src=src[order], dst=dst[order])
        wu = weights[idx]
        w = np.concatenate([wu, wu]).astype(np.float32)
        return Graph(n=n, src=src[order], dst=dst[order], w=w[order])

    # ---------------------------------------------------------- derived
    @property
    def num_arcs(self) -> int:
        """Number of directed arcs (= 2x undirected edges)."""
        return int(self.src.shape[0])

    @property
    def num_edges(self) -> int:
        """Number of undirected edges."""
        return self.num_arcs // 2

    @property
    def weighted(self) -> bool:
        """True when the graph carries per-arc weights."""
        return self.w is not None

    def degrees(self) -> np.ndarray:
        """int64 [n] vertex degrees."""
        return np.bincount(self.src, minlength=self.n).astype(np.int64)

    def dense_adjacency(self, dtype=np.float32) -> np.ndarray:
        """[n, n] symmetric 0/1 adjacency matrix (small graphs only)."""
        a = np.zeros((self.n, self.n), dtype=dtype)
        a[self.src, self.dst] = 1
        return a

    def dense_weights(self, dtype=np.float32) -> np.ndarray:
        """[n, n] symmetric weight matrix; 0 encodes "no edge" (sound
        because weights are strictly positive).  Weighted graphs only."""
        if self.w is None:
            raise ValueError("dense_weights() requires a weighted graph")
        a = np.zeros((self.n, self.n), dtype=dtype)
        a[self.src, self.dst] = self.w
        return a

    def adjacency_lists(self) -> list[np.ndarray]:
        """Per-vertex sorted neighbor arrays (oracle / sampler use)."""
        order = np.argsort(self.src, kind="stable")
        src, dst = self.src[order], self.dst[order]
        starts = np.searchsorted(src, np.arange(self.n))
        ends = np.searchsorted(src, np.arange(self.n), side="right")
        return [dst[s:e] for s, e in zip(starts, ends)]

    def weighted_adjacency_lists(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """Per-vertex (neighbors, weights) pairs (Dijkstra oracle use)."""
        if self.w is None:
            raise ValueError("weighted_adjacency_lists() requires a weighted graph")
        order = np.argsort(self.src, kind="stable")
        src, dst, w = self.src[order], self.dst[order], self.w[order]
        starts = np.searchsorted(src, np.arange(self.n))
        ends = np.searchsorted(src, np.arange(self.n), side="right")
        return [(dst[s:e], w[s:e]) for s, e in zip(starts, ends)]

    def csr(self) -> tuple[np.ndarray, np.ndarray]:
        """(row_ptr int64 [n+1], col_idx int32 [m2]) CSR view."""
        order = np.argsort(self.src, kind="stable")
        col = self.dst[order]
        counts = np.bincount(self.src, minlength=self.n)
        row_ptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=row_ptr[1:])
        return row_ptr, col.astype(np.int32)

    def connected_components(self) -> np.ndarray:
        """int64 [n] component label per vertex (host-side union-find)."""
        parent = np.arange(self.n, dtype=np.int64)

        def find(x: int) -> int:
            root = x
            while parent[root] != root:
                root = parent[root]
            while parent[x] != root:
                parent[x], x = root, parent[x]
            return root

        for u, v in zip(self.src, self.dst):
            ru, rv = find(int(u)), find(int(v))
            if ru != rv:
                parent[max(ru, rv)] = min(ru, rv)
        labels = np.array([find(i) for i in range(self.n)], dtype=np.int64)
        return labels

    def subgraph_mask(self, keep_arc: np.ndarray) -> "Graph":
        """Graph with only the arcs where ``keep_arc`` is True (the arc
        list must stay symmetric — caller's responsibility)."""
        w = None if self.w is None else self.w[keep_arc]
        return Graph(n=self.n, src=self.src[keep_arc], dst=self.dst[keep_arc], w=w)

    def padded_arcs(self, multiple: int) -> tuple[np.ndarray, np.ndarray, int]:
        """Arc list padded to a multiple with self-referencing sentinel
        arcs pointing at vertex slot ``n`` (callers allocate n+1 slots so
        the sentinel accumulates into a discarded row)."""
        m2 = self.num_arcs
        pad = (-m2) % multiple
        src = np.concatenate([self.src, np.full(pad, self.n, np.int32)])
        dst = np.concatenate([self.dst, np.full(pad, self.n, np.int32)])
        return src, dst, m2

    def padded_arc_weights(self, multiple: int) -> np.ndarray:
        """Weights aligned with :meth:`padded_arcs`; sentinel arcs get
        weight 0 (their dst row is discarded anyway)."""
        if self.w is None:
            raise ValueError("padded_arc_weights() requires a weighted graph")
        pad = (-self.num_arcs) % multiple
        return np.concatenate([self.w, np.zeros(pad, np.float32)]).astype(np.float32)
