"""Graph substrate: containers, generators, partitioners, samplers.

Everything here is host-side (numpy) construction logic; the arrays it
produces are consumed by the JAX programs in :mod:`repro.core` and
:mod:`repro.models.gnn`.
"""
from repro.graphs.graph import Graph
from repro.graphs.generators import (
    rmat_graph,
    path_graph,
    cycle_graph,
    star_graph,
    complete_graph,
    grid_graph,
    gnp_graph,
    disjoint_union,
    road_like_graph,
    suburb_graph,
    skewed_depth_graph,
)
from repro.graphs.partition import TwoDPartition, partition_2d

__all__ = [
    "Graph",
    "rmat_graph",
    "path_graph",
    "cycle_graph",
    "star_graph",
    "complete_graph",
    "grid_graph",
    "gnp_graph",
    "disjoint_union",
    "road_like_graph",
    "suburb_graph",
    "skewed_depth_graph",
    "TwoDPartition",
    "partition_2d",
]
