"""Graph generators.

``rmat_graph`` reproduces the paper's synthetic workload (R-MAT with
a=0.57, b=0.19, c=0.19, d=0.05; SCALE/EF parameterization, §4.1).  The
structured generators (path/cycle/star/complete/grid) have closed-form
betweenness scores and anchor the property tests; ``road_like_graph``
mimics the road-network regime (long diameter, many 1- and 2-degree
vertices) that the paper's heuristics target.

Weighted variants: ``rmat_graph(..., weights=)`` and
``road_like_graph(..., weights=)`` sample per-edge weights (and any graph
can be weighted after the fact with :func:`weighted_copy`).  The weight
modes live in :data:`WEIGHT_MODES`:

* ``"none"``   — unweighted (``Graph.w is None``)
* ``"unit"``   — every edge weight exactly 1.0 (the reduction check:
  unit weights must reproduce the unweighted result)
* ``"dyadic"`` — seeded draws from {0.25, 0.5, …, 4.0}.  Dyadic weights
  make float32 distance sums *exact*, so the engines' bucket/equality
  masks agree bit-for-bit with the float64 Dijkstra oracle.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import Graph

__all__ = [
    "WEIGHT_MODES",
    "rmat_graph",
    "path_graph",
    "cycle_graph",
    "star_graph",
    "complete_graph",
    "grid_graph",
    "gnp_graph",
    "disjoint_union",
    "road_like_graph",
    "suburb_graph",
    "skewed_depth_graph",
    "weighted_copy",
]

WEIGHT_MODES = ("none", "unit", "dyadic")


def sample_weights(
    rng: np.random.Generator, count: int, weights: str
) -> np.ndarray | None:
    """Draw ``count`` edge weights for a :data:`WEIGHT_MODES` mode."""
    if weights not in WEIGHT_MODES:
        raise ValueError(f"weights must be one of {WEIGHT_MODES}, got {weights!r}")
    if weights == "none":
        return None
    if weights == "unit":
        return np.ones(count, dtype=np.float32)
    # dyadic: k/4 for k in 1..16 — exactly representable, exact f32 sums
    return (rng.integers(1, 17, size=count) * 0.25).astype(np.float32)


def weighted_copy(graph: Graph, weights: str = "dyadic", seed: int = 0) -> Graph:
    """Attach sampled edge weights to an existing (unweighted) graph.

    Deterministic in ``seed``; both arcs of each undirected edge share
    one weight.
    """
    keep = graph.src < graph.dst  # each undirected edge once
    edges = np.stack([graph.src[keep], graph.dst[keep]], axis=1)
    rng = np.random.default_rng(seed)
    w = sample_weights(rng, edges.shape[0], weights)
    return Graph.from_edges(graph.n, edges, weights=w)


def rmat_graph(
    scale: int,
    edge_factor: int,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    weights: str = "none",
) -> Graph:
    """R-MAT generator (Chakrabarti et al.), paper parameters by default.

    n = 2**scale vertices, m = edge_factor * n undirected edge samples
    (duplicates / self-loops dropped, as in Graph500 practice).
    ``weights`` picks a :data:`WEIGHT_MODES` mode; duplicate samples keep
    the first draw's weight.
    """
    n = 1 << scale
    m = edge_factor * n
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # quadrant probabilities: a | b / c | d
        src_bit = r >= a + b
        dst_bit = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    # permute vertex ids so degree is not correlated with id
    perm = rng.permutation(n)
    w = sample_weights(rng, m, weights)
    return Graph.from_edges(n, np.stack([perm[src], perm[dst]], axis=1), weights=w)


def path_graph(n: int) -> Graph:
    e = np.stack([np.arange(n - 1), np.arange(1, n)], axis=1)
    return Graph.from_edges(n, e)


def cycle_graph(n: int) -> Graph:
    e = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    return Graph.from_edges(n, e)


def star_graph(n_leaves: int) -> Graph:
    """Vertex 0 is the hub; 1..n_leaves are leaves."""
    e = np.stack([np.zeros(n_leaves, np.int64), np.arange(1, n_leaves + 1)], axis=1)
    return Graph.from_edges(n_leaves + 1, e)


def complete_graph(n: int) -> Graph:
    iu = np.triu_indices(n, k=1)
    return Graph.from_edges(n, np.stack(iu, axis=1))


def grid_graph(rows: int, cols: int) -> Graph:
    """2-D lattice — the canonical long-diameter road-like topology."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    horiz = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    vert = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    return Graph.from_edges(rows * cols, np.concatenate([horiz, vert]))


def gnp_graph(n: int, p: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < p
    mask = np.triu(mask, k=1)
    u, v = np.nonzero(mask)
    return Graph.from_edges(n, np.stack([u, v], axis=1))


def disjoint_union(*graphs: Graph) -> Graph:
    """Multi-component graphs (the 1-degree heuristic's hard case)."""
    offset = 0
    parts = []
    for g in graphs:
        parts.append(np.stack([g.src + offset, g.dst + offset], axis=1))
        offset += g.n
    edges = np.concatenate(parts) if parts else np.zeros((0, 2), np.int64)
    return Graph.from_edges(offset, edges)


def skewed_depth_graph(pairs: int, block: int) -> Graph:
    """Alternating deep/shallow components aligned to the round deal.

    ``2 · pairs`` components of ``block`` vertices each, in alternating
    vertex-id order: even blocks are *paths* (traversal depth ≈ block),
    odd blocks are *complete graphs* (depth 1).  With
    ``batch_size=block`` the source scheduler packs each component into
    exactly one round, so under a two-replica interleaved deal one
    replica draws every deep-diameter root batch and the other every
    shallow one — the maximally skewed workload the straggler scheduler
    (``BCDriver(straggler=...)``) exists to re-balance, used by
    ``benchmarks/table3_subcluster.py`` and the forced-straggler tests.
    """
    parts = []
    for i in range(2 * pairs):
        parts.append(path_graph(block) if i % 2 == 0 else complete_graph(block))
    return disjoint_union(*parts)


def road_like_graph(
    rows: int,
    cols: int,
    spur_fraction: float = 0.3,
    seed: int = 0,
    weights: str = "none",
) -> Graph:
    """Grid backbone + dangling spur paths: long diameter, rich in
    1-degree (spur tips) and 2-degree (spur interior, grid edges) vertices
    — the regime of Table 5 / Fig. 12 in the paper.  With ``weights`` a
    non-"none" :data:`WEIGHT_MODES` mode this is the weighted road-network
    regime (varying segment lengths over a long-diameter backbone)."""
    rng = np.random.default_rng(seed)
    base = grid_graph(rows, cols)
    n = base.n
    n_spurs = int(spur_fraction * n)
    anchors = rng.integers(0, n, size=n_spurs)
    lengths = rng.integers(1, 4, size=n_spurs)
    edges = [np.stack([base.src, base.dst], axis=1)]
    nxt = n
    for anchor, length in zip(anchors, lengths):
        prev = int(anchor)
        for _ in range(int(length)):
            edges.append(np.array([[prev, nxt]]))
            prev = nxt
            nxt += 1
    all_edges = np.concatenate(edges)
    w = sample_weights(rng, all_edges.shape[0], weights)
    return Graph.from_edges(nxt, all_edges, weights=w)


def suburb_graph(rows: int, cols: int, leaf_fraction: float = 0.5, seed: int = 0) -> Graph:
    """Grid with every edge subdivided (chain vertices of degree 2) and
    single leaves attached to a fraction of the chain vertices (degree 3).

    This is the paper's §4.4 H3 regime: 1-degree removal turns those
    3-degree chain vertices back into 2-degree vertices, so the combined
    heuristic derives strictly more than H2 alone ("basically 3-degree
    vertices which have a 1-degree neighbor become 2-degree").
    """
    rng = np.random.default_rng(seed)
    base = grid_graph(rows, cols)
    nxt = base.n
    edges = []
    mids = []
    for u, v in zip(base.src, base.dst):
        if u < v:  # each undirected edge once
            edges.append([int(u), nxt])
            edges.append([nxt, int(v)])
            mids.append(nxt)
            nxt += 1
    for m in mids:
        if rng.random() < leaf_fraction:
            edges.append([m, nxt])
            nxt += 1
    return Graph.from_edges(nxt, np.array(edges))
