"""Optimizers: AdamW, Adafactor (factored second moments), SGD+momentum.

Adafactor is the memory plan for the 400B-class MoE cells (DESIGN.md §6):
its second-moment statistics are O(rows + cols) instead of O(rows·cols),
which is the difference between fitting and not fitting 512 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "sgd_momentum",
    "global_norm",
    "clip_by_global_norm",
]

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def _layerwise(fn):
    """Apply a per-leaf update one leading-dim slice at a time for big
    stacked leaves (scan-over-layers params, DLRM table stacks): the
    optimizer's f32 elementwise chains otherwise materialize several
    full-stack temporaries at once (tens of GB on the 400B cells)."""

    def wrapped(p, *rest):
        if p.ndim >= 3 and p.shape[0] > 1:
            return jax.lax.map(lambda args: fn(*args), (p, *rest))
        return fn(p, *rest)

    return wrapped


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object  # PyTree like params
    nu: object


def adamw(
    lr=1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip_norm: float | None = None,
) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def update(grads, state, params):
        if grad_clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip_norm)
        step = state.step + 1
        lr_t = sched(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m, v

        out = jax.tree.map(_layerwise(upd), params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: object  # row second moments (or full v for <2D params)
    vc: object  # col second moments (zeros-placeholder for <2D)


def adafactor(
    lr=1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Factored Adafactor (Shazeer & Stern).  Params with ndim >= 2 factor
    their last two dims; smaller params keep a full second moment in vr."""
    sched = _as_schedule(lr)

    def init(params):
        def vr_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-1], jnp.float32)  # row stats
            return jnp.zeros(p.shape, jnp.float32)

        def vc_init(p):
            if p.ndim >= 2:
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return AdafactorState(
            step=jnp.zeros((), jnp.int32),
            vr=jax.tree.map(vr_init, params),
            vc=jax.tree.map(vc_init, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
                r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                u = g / (
                    jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :] + eps
                )
            else:
                vr = beta * vr + (1 - beta) * g2
                u = g / (jnp.sqrt(vr) + eps)
                vc = vc
            # update clipping (RMS-based, Adafactor eq. 6)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), vr, vc

        out = jax.tree.map(_layerwise(upd), params, grads, state.vr, state.vc)
        istup = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=istup)
        vr = jax.tree.map(lambda t: t[1], out, is_leaf=istup)
        vc = jax.tree.map(lambda t: t[2], out, is_leaf=istup)
        return new_params, AdafactorState(step=step, vr=vr, vc=vc)

    return Optimizer(init=init, update=update)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: object


def sgd_momentum(lr=1e-2, momentum: float = 0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(step)

        def upd(p, g, m):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), m

        out = jax.tree.map(_layerwise(upd), params, grads, state.momentum)
        istup = lambda t: isinstance(t, tuple)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=istup)
        mom = jax.tree.map(lambda t: t[1], out, is_leaf=istup)
        return new_params, SGDState(step=step, momentum=mom)

    return Optimizer(init=init, update=update)
