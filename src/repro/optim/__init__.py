"""Hand-rolled optimizers (no optax dependency in this environment).

Optax-style pure-function API:  ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (new_params, new_state)``.
All states are PyTrees of arrays so they shard/checkpoint like params.
"""
from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    sgd_momentum,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import constant, cosine_with_warmup, linear_warmup

__all__ = [
    "Optimizer",
    "adamw",
    "adafactor",
    "sgd_momentum",
    "global_norm",
    "clip_by_global_norm",
    "constant",
    "cosine_with_warmup",
    "linear_warmup",
]
