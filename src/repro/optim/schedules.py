"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup", "cosine_with_warmup"]


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup(peak: float, warmup_steps: int):
    def fn(step):
        step = step.astype(jnp.float32)
        return peak * jnp.minimum(1.0, step / max(warmup_steps, 1))

    return fn


def cosine_with_warmup(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * jnp.minimum(1.0, step / max(warmup_steps, 1))
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)

    return fn
