"""Fault tolerance & elasticity planning (pure functions → unit-testable).

At thousand-node scale the framework must survive pod/host loss without
operator intervention.  The moving parts:

* **Work units.**  MGBC's source *rounds* (core/scheduler.py) and LM
  *steps* are idempotent and additive, so recovery = re-issue, never
  partial-state repair.
* **Elastic re-mesh.**  ``plan_elastic_remesh`` maps a device loss to a
  new mesh shape (shrink the replica/data axis first — the model axes
  encode weight layouts and are expensive to change) and emits the
  checkpoint-reload plan.
* **Straggler mitigation.**  ``StragglerPolicy`` tracks per-worker round
  times and flags rounds for speculative re-execution (backup tasks)
  when a worker exceeds ``factor``× the running median.  Because BC
  accumulation is additive per-round, duplicate completions are resolved
  by a "first result wins" commit in the round ledger.  The *integrated*
  version of this idea — per-replica ledgers, EWMA-threshold detection,
  steal/re-deal of pending rounds — is the shared round loop's
  ``straggler=`` policy (:data:`repro.core.driver.STRAGGLER_POLICIES`).
* **Round ledger.**  ``RoundLedger`` records committed rounds so a
  restart (or a duplicated speculative execution) never double-counts —
  this is what makes BC exact across failures.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics

__all__ = [
    "MeshPlan",
    "plan_elastic_remesh",
    "StragglerPolicy",
    "RoundLedger",
    "BCCheckpoint",
    "schedule_fingerprint",
    "TransientRoundError",
    "ReplicaLostError",
    "IntegrityError",
    "is_transient_error",
]


class IntegrityError(RuntimeError):
    """A round output failed its integrity audit beyond recovery.

    Raised by the driver when a block keeps failing the ABFT checksum /
    claim / output-domain audits (``integrity="audit"|"checksum"``) after
    the re-dispatch budget and the clean-fallback recompute are both
    exhausted — finite-but-wrong data that would otherwise silently enter
    the BC accumulator.  Never retryable: by construction every retry
    path was already tried.
    """


class TransientRoundError(RuntimeError):
    """A round failure worth retrying on the same device set.

    Raised by the chaos harness (:mod:`repro.distributed.chaos`) to model
    the transient XLA/runtime failures a long-lived service sees; the
    driver's per-block retry loop (:class:`repro.core.driver.BCDriver`)
    treats it — and runtime error types named in
    :data:`TRANSIENT_ERROR_NAMES` — as retryable within the retry budget.
    Any other exception propagates immediately.
    """


class ReplicaLostError(RuntimeError):
    """A sub-cluster replica's devices are gone (preemption, host loss).

    Carries the lost ``replica`` index.  Not retryable in place: the
    driver's multi-ledger loop consults :func:`plan_elastic_remesh`,
    merges the dead replica's ledger into a survivor's, re-deals its
    pending rounds and continues on the surviving lanes (the dead lane
    is dealt only padding from then on).
    """

    def __init__(self, replica: int, message: str | None = None):
        super().__init__(message or f"replica {replica} lost")
        self.replica = int(replica)


#: Exception type *names* treated as transient alongside
#: :class:`TransientRoundError` — matched by name so the check never
#: imports backend-private modules.  XLA surfaces preemption/rendezvous
#: hiccups as these; a retry budget bounds the damage when one is
#: actually permanent.
TRANSIENT_ERROR_NAMES = ("XlaRuntimeError", "UnavailableError", "InternalError")


def is_transient_error(exc: BaseException) -> bool:
    """True when a round failure should be retried in place."""
    if isinstance(exc, TransientRoundError):
        return True
    if isinstance(exc, ReplicaLostError):
        return False
    return type(exc).__name__ in TRANSIENT_ERROR_NAMES


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    reload_from_checkpoint: bool
    reshard_params: bool
    note: str


def plan_elastic_remesh(
    current_shape: tuple[int, ...],
    axes: tuple[str, ...],
    devices_lost: int,
) -> MeshPlan:
    """Shrink policy: drop whole replica ('pod') groups first, then halve
    the 'data' axis; never touch 'model' (weight layout)."""
    shape = list(current_shape)
    n = 1
    for s in shape:
        n *= s
    remaining = n - devices_lost
    if remaining <= 0:
        raise ValueError("no devices left")

    # drop pods while a whole pod is gone
    if "pod" in axes:
        pod_ax = axes.index("pod")
        per_pod = n // shape[pod_ax]
        pods_left = remaining // per_pod
        if pods_left >= 1:
            if pods_left != shape[pod_ax]:
                shape[pod_ax] = pods_left
                return MeshPlan(
                    shape=tuple(shape),
                    axes=axes,
                    reload_from_checkpoint=False,  # replicas hold full state
                    reshard_params=False,
                    note=f"dropped to {pods_left} pods; surviving replicas "
                    f"re-deal the remaining source rounds",
                )
            return MeshPlan(tuple(shape), axes, False, False, "no change")
    # halve data axis until it fits
    data_ax = axes.index("data")
    while True:
        prod = 1
        for s in shape:
            prod *= s
        if prod <= remaining:
            break
        if shape[data_ax] % 2 != 0 or shape[data_ax] == 1:
            raise ValueError(f"cannot shrink mesh {current_shape} to {remaining}")
        shape[data_ax] //= 2
    return MeshPlan(
        shape=tuple(shape),
        axes=axes,
        reload_from_checkpoint=True,
        reshard_params=True,
        note="data axis halved; params resharded from checkpoint, "
        "global batch rescaled",
    )


class StragglerPolicy:
    """Median-based speculative re-execution (MapReduce backup tasks).

    Standalone detector for external orchestration; the BC round loop
    itself uses the integrated multi-ledger scheduler
    (``BCDriver(straggler="steal"|"redeal")``, core/driver.py)."""

    def __init__(self, factor: float = 2.0, min_samples: int = 5, window: int = 512):
        self.factor = factor
        self.min_samples = min_samples
        # bounded history: a long-lived service observes millions of
        # rounds and the median only needs the recent regime anyway
        self.times: collections.deque[float] = collections.deque(maxlen=window)

    def observe(self, seconds: float) -> None:
        self.times.append(seconds)

    def should_speculate(self, elapsed: float) -> bool:
        if len(self.times) < self.min_samples:
            return False
        return elapsed > self.factor * statistics.median(self.times)


class RoundLedger:
    """Exactly-once commit of additive work units (BC rounds / steps).

    The shared round loop (:class:`repro.core.driver.BCDriver`) consumes
    a ledger directly: committed rounds are re-dealt as inert padding
    columns, so a speculatively duplicated round is accumulated exactly
    once.  The ledger is deliberately *in-memory only* — a round is
    marked committed at dispatch, before its contribution is anywhere
    durable, so persisting the ledger alone would drop work on a crash.
    Durable kill-and-resume is :class:`BCCheckpoint`, which snapshots
    the committed set together with the matching partial BC sums.
    """

    def __init__(self):
        self._committed: set[int] = set()

    def try_commit(self, round_id: int) -> bool:
        """True if this result should be accumulated (first completion)."""
        if round_id in self._committed:
            return False
        self._committed.add(round_id)
        return True

    def is_committed(self, round_id: int) -> bool:
        """Read-only commit check (the multi-ledger driver consults every
        replica's ledger before committing into one — first commit wins)."""
        return round_id in self._committed

    def merge(self, other: "RoundLedger") -> int:
        """Absorb (move) another ledger's committed set into this one.

        The replica-loss re-mesh path: the dead replica's commits must
        stay committed (exactly-once), so a survivor's ledger takes them
        over and the dead ledger is emptied — the committed *union*
        across ledgers is unchanged, only the attribution moves.
        Returns the number of rounds newly committed here.
        """
        added = len(other._committed - self._committed)
        self._committed |= other._committed
        other._committed = set()
        return added

    def pending(self, total_rounds: int) -> list[int]:
        return [r for r in range(total_rounds) if r not in self._committed]

    def state(self) -> list[int]:
        return sorted(self._committed)

    @classmethod
    def from_state(cls, committed: list[int]) -> "RoundLedger":
        led = cls()
        led._committed = set(committed)
        return led


# BCCheckpoint — the durable (partial BC, n_s, committed rounds) triple —
# lives with the rest of the durable-state code in
# repro/checkpoint/checkpointer.py since it grew per-replica ledger
# namespacing; re-exported here because this is where the ledger protocol
# it completes is defined (and where existing callers import it from).
from repro.checkpoint.checkpointer import BCCheckpoint  # noqa: E402,F401


def schedule_fingerprint(n: int, schedule) -> str:
    """Content hash tying a checkpoint to one (graph, schedule) pair."""
    import zlib

    crc = 0
    for rnd in schedule.rounds:
        crc = zlib.crc32(rnd.sources.tobytes(), crc)
        crc = zlib.crc32(rnd.derived.tobytes(), crc)
    return (
        f"n{n}_b{schedule.batch_size}_k{schedule.derived_per_round}_"
        f"r{len(schedule.rounds)}_{crc:08x}"
    )
