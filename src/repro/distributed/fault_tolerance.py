"""Fault tolerance & elasticity planning (pure functions → unit-testable).

At thousand-node scale the framework must survive pod/host loss without
operator intervention.  The moving parts:

* **Work units.**  MGBC's source *rounds* (core/scheduler.py) and LM
  *steps* are idempotent and additive, so recovery = re-issue, never
  partial-state repair.
* **Elastic re-mesh.**  ``plan_elastic_remesh`` maps a device loss to a
  new mesh shape (shrink the replica/data axis first — the model axes
  encode weight layouts and are expensive to change) and emits the
  checkpoint-reload plan.
* **Straggler mitigation.**  ``StragglerPolicy`` tracks per-worker round
  times and flags rounds for speculative re-execution (backup tasks)
  when a worker exceeds ``factor``× the running median.  Because BC
  accumulation is additive per-round, duplicate completions are resolved
  by a "first result wins" commit in the round ledger.
* **Round ledger.**  ``RoundLedger`` records committed rounds so a
  restart (or a duplicated speculative execution) never double-counts —
  this is what makes BC exact across failures.
"""
from __future__ import annotations

import dataclasses
import statistics

__all__ = ["MeshPlan", "plan_elastic_remesh", "StragglerPolicy", "RoundLedger"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    reload_from_checkpoint: bool
    reshard_params: bool
    note: str


def plan_elastic_remesh(
    current_shape: tuple[int, ...],
    axes: tuple[str, ...],
    devices_lost: int,
) -> MeshPlan:
    """Shrink policy: drop whole replica ('pod') groups first, then halve
    the 'data' axis; never touch 'model' (weight layout)."""
    shape = list(current_shape)
    n = 1
    for s in shape:
        n *= s
    remaining = n - devices_lost
    if remaining <= 0:
        raise ValueError("no devices left")

    # drop pods while a whole pod is gone
    if "pod" in axes:
        pod_ax = axes.index("pod")
        per_pod = n // shape[pod_ax]
        pods_left = remaining // per_pod
        if pods_left >= 1:
            if pods_left != shape[pod_ax]:
                shape[pod_ax] = pods_left
                return MeshPlan(
                    shape=tuple(shape),
                    axes=axes,
                    reload_from_checkpoint=False,  # replicas hold full state
                    reshard_params=False,
                    note=f"dropped to {pods_left} pods; surviving replicas "
                    f"re-deal the remaining source rounds",
                )
            return MeshPlan(tuple(shape), axes, False, False, "no change")
    # halve data axis until it fits
    data_ax = axes.index("data")
    while True:
        prod = 1
        for s in shape:
            prod *= s
        if prod <= remaining:
            break
        if shape[data_ax] % 2 != 0 or shape[data_ax] == 1:
            raise ValueError(f"cannot shrink mesh {current_shape} to {remaining}")
        shape[data_ax] //= 2
    return MeshPlan(
        shape=tuple(shape),
        axes=axes,
        reload_from_checkpoint=True,
        reshard_params=True,
        note="data axis halved; params resharded from checkpoint, "
        "global batch rescaled",
    )


class StragglerPolicy:
    """Median-based speculative re-execution (MapReduce backup tasks)."""

    def __init__(self, factor: float = 2.0, min_samples: int = 5):
        self.factor = factor
        self.min_samples = min_samples
        self.times: list[float] = []

    def observe(self, seconds: float) -> None:
        self.times.append(seconds)

    def should_speculate(self, elapsed: float) -> bool:
        if len(self.times) < self.min_samples:
            return False
        return elapsed > self.factor * statistics.median(self.times)


class RoundLedger:
    """Exactly-once commit of additive work units (BC rounds / steps)."""

    def __init__(self):
        self._committed: set[int] = set()

    def try_commit(self, round_id: int) -> bool:
        """True if this result should be accumulated (first completion)."""
        if round_id in self._committed:
            return False
        self._committed.add(round_id)
        return True

    def pending(self, total_rounds: int) -> list[int]:
        return [r for r in range(total_rounds) if r not in self._committed]

    def state(self) -> list[int]:
        return sorted(self._committed)

    @classmethod
    def from_state(cls, committed: list[int]) -> "RoundLedger":
        led = cls()
        led._committed = set(committed)
        return led
