"""Ambient-mesh sharding helpers.

Model code stays mesh-agnostic: it calls ``constrain(x, "data", None)``
with logical axis names; when a mesh is installed (launch layer) this
becomes ``with_sharding_constraint``; without one it is a no-op, so the
same model runs single-device in tests.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["constrain", "current_mesh", "set_current_mesh", "use_mesh", "named_sharding"]

_state = threading.local()


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def set_current_mesh(mesh: Mesh | None) -> None:
    _state.mesh = mesh


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = current_mesh()
    set_current_mesh(mesh)
    try:
        yield mesh
    finally:
        set_current_mesh(prev)


def _filter_spec(mesh: Mesh, spec) -> P:
    """Drop axis names the mesh does not have (e.g. 'pod' on single-pod)."""

    def keep(name):
        if name is None:
            return None
        if isinstance(name, tuple):
            kept = tuple(n for n in name if n in mesh.axis_names)
            return kept if kept else None
        return name if name in mesh.axis_names else None

    return P(*(keep(s) for s in tuple(spec)))


def named_sharding(mesh: Mesh, *spec) -> NamedSharding:
    """named_sharding(mesh, "data", None) or named_sharding(mesh, P(...))."""
    if len(spec) == 1 and isinstance(spec[0], P):
        spec = tuple(spec[0])
    return NamedSharding(mesh, _filter_spec(mesh, spec))


def constrain(x, *spec):
    """with_sharding_constraint against the ambient mesh (no-op if none)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, named_sharding(mesh, *spec))
