"""Gradient compression: int8 block quantization with error feedback.

For data-parallel all-reduces the gradient payload dominates the
collective term; int8 + per-block scales cuts it 4x.  Error feedback
(Seide et al. / EF-SGD) accumulates the quantization residual locally
and re-adds it the next step, which preserves convergence.

Usage (train loop):
    carrier, residual = compress_tree(grads, residual)
    grads = decompress_tree(carrier)              # after the all-reduce
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["QuantizedTensor", "quantize", "dequantize", "compress_tree", "decompress_tree", "init_residual"]

BLOCK = 256


class QuantizedTensor(NamedTuple):
    q: jnp.ndarray  # int8 payload, padded flat [ceil(n/B), B]
    scale: jnp.ndarray  # f32 per-block scales [ceil(n/B)]
    shape: tuple  # static original shape


def quantize(x: jnp.ndarray) -> QuantizedTensor:
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    return QuantizedTensor(q=q, scale=scale, shape=shape)


def dequantize(t: QuantizedTensor) -> jnp.ndarray:
    flat = (t.q.astype(jnp.float32) * t.scale[:, None]).reshape(-1)
    n = 1
    for d in t.shape:
        n *= d
    return flat[:n].reshape(t.shape)


def init_residual(tree: Any) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def compress_tree(grads: Any, residual: Any) -> tuple[Any, Any]:
    """Returns (quantized tree, new residual).  Error feedback: the next
    step's gradient carries this step's quantization error."""

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        qt = quantize(corrected)
        back = dequantize(qt)
        return qt, corrected - back

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    qs, rs = [], []
    for g, r in zip(flat_g, flat_r):
        qt, nr = one(g, r)
        qs.append(qt)
        rs.append(nr)
    return (
        jax.tree_util.tree_unflatten(treedef, qs),
        jax.tree_util.tree_unflatten(treedef, rs),
    )


def decompress_tree(qtree: Any) -> Any:
    return jax.tree_util.tree_map(
        dequantize, qtree, is_leaf=lambda t: isinstance(t, QuantizedTensor)
    )
