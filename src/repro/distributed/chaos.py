"""Deterministic fault injection for the BC driver (the chaos harness).

The paper's scale argument — graphs "too large to fit in the memory of a
single computational node" — implies runs long and wide enough that
transient runtime failures, replica (pod/host) loss and torn snapshot
writes are the *normal* case.  The driver's self-healing round loop
(:class:`repro.core.driver.BCDriver`: retry/backoff, numeric quarantine,
elastic re-mesh, generational snapshots) exists to survive them; this
module makes every one of those failure modes reproducible on demand so
the recovery paths are testable, debuggable from the CLI, and gated in
CI (``make chaos-smoke``).

Design: faults are *declared* up front in a seeded :class:`FaultPlan`
and *injected* by wrappers at exactly two seams — the ``round_fn`` call
boundary (:class:`ChaosRoundFn`) and the durable-file writes
(:class:`ChaosFS` via :class:`ChaosCheckpoint` /
:class:`ChaosCostCache`).  Production code paths are never patched or
branched; a chaos run is the production run with wrapped callables, so
whatever survives chaos is exactly what runs clean.

Fault classes (:data:`FAULT_KINDS`), all keyed on deterministic
counters (dispatch-call index, checkpoint-save index, cache-put index):

  ``transient``  raise :class:`TransientRoundError` for ``count``
                 consecutive dispatch calls starting at ``at`` — the
                 driver must retry with backoff and succeed.
  ``poison``     multiply the block's ``bc``/``ns`` outputs by NaN (or
                 Inf, ``:inf``) — the driver's numeric guard must
                 quarantine the block, re-dispatch it, and fall back to
                 the clean round fn if the poison persists.
  ``kill``       replica ``:rI`` is lost from call ``at`` on — the
                 wrapper raises :class:`ReplicaLostError` whenever that
                 lane is dispatched live (non-padding) columns, exactly
                 like a device set that fails when used; after the
                 driver re-meshes, the dead lane receives only padding
                 and the wrapper stays silent.
  ``crash``      raise :class:`ChaosCrash` at call ``at`` — a simulated
                 process death (never retried) for kill-and-resume
                 tests.
  ``torn``       tear (truncate) the snapshot file the ``at``-th
                 checkpoint save just wrote — the next load must fall
                 back to an older intact generation.
  ``cache``      garble the autotune cache JSON after its ``at``-th
                 persisted put — the next run must warm-start empty
                 with a warning, never traceback.
  ``flip``       *finite* corruption of the block the ``at``-th dispatch
                 returned (silent data corruption: a bit flip or bad
                 reduction that the numeric guard cannot see).  Arg
                 ``:rI`` scales lane I's bc (``2x+1``); ``:neg`` negates
                 it (``-(x+1)``); ``:dI`` is the *deep* variant — lane
                 I's bc is scaled (``2x``) AND the in-round bc-sum claim
                 is recomputed to match, so only the duplicate-vote
                 compare can catch it.  The driver's ``integrity`` audits
                 must detect, quarantine and re-dispatch.
  ``stall``      sleep ``:MS`` milliseconds (default 50) inside the
                 ``at``-th dispatch call, through the driver-shared
                 injectable sleeper — a wedged collective / hung
                 participant.  Past ``dispatch_deadline_s`` the driver's
                 watchdog must re-dispatch, then escalate to a re-mesh.

A plan is constructed programmatically or parsed from the compact CLI
spec of ``launch/bc.py --chaos``::

    --chaos "seed=7;transient@1x2;poison@3:nan;kill@4:r1;flip@5;stall@6:200"

entries are ``kind@at[xcount][:arg]`` separated by ``;`` or ``,``.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.distributed.fault_tolerance import (
    ReplicaLostError,
    TransientRoundError,
)

__all__ = [
    "FAULT_KINDS",
    "FaultPlan",
    "FaultEvent",
    "ChaosCrash",
    "ChaosRoundFn",
    "ChaosFS",
    "ChaosCheckpoint",
    "ChaosCostCache",
]

#: The injectable fault classes — the single source of truth for the
#: ``--chaos`` spec grammar and the docs drift check (tools/check_docs.py):
#: "transient" retryable raise | "poison" NaN/Inf block outputs |
#: "kill" permanent replica loss | "crash" simulated process death |
#: "torn" truncated snapshot write | "cache" corrupted autotune cache |
#: "flip" finite (silent) corruption of a round output |
#: "stall" delay a dispatch past its watchdog deadline.
FAULT_KINDS = (
    "transient", "poison", "kill", "crash", "torn", "cache", "flip", "stall"
)

#: Default injected stall, milliseconds (``stall@K`` with no ``:MS`` arg).
DEFAULT_STALL_MS = 50.0

_ENTRY_RE = re.compile(
    r"^(?P<kind>[a-z]+)@(?P<at>\d+)(?:x(?P<count>\d+))?(?::(?P<arg>[A-Za-z0-9_]+))?$"
)


class ChaosCrash(BaseException):
    """Simulated process death (kill-and-resume tests).

    Deliberately NOT an ``Exception`` subclass: nothing in the driver —
    not the transient retry, not the numeric fallback — may swallow it,
    exactly like a SIGKILL.
    """


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One declared fault: ``kind`` fires at counter value ``at`` for
    ``count`` consecutive ticks; ``arg`` carries the kind-specific
    payload (poison mode, killed replica index)."""

    kind: str
    at: int
    count: int = 1
    arg: str | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}"
            )
        if self.at < 0 or self.count < 1:
            raise ValueError(f"fault {self.kind!r} needs at >= 0 and count >= 1")
        if self.kind == "poison" and self.arg not in (None, "nan", "inf"):
            raise ValueError(f"poison arg must be 'nan' or 'inf', got {self.arg!r}")
        if self.kind == "kill":
            if self.arg is None or not re.fullmatch(r"r\d+", self.arg):
                raise ValueError(
                    f"kill needs a replica arg like ':r1', got {self.arg!r}"
                )
        if self.kind == "flip":
            if self.arg is not None and not re.fullmatch(
                r"r\d+|d\d+|neg", self.arg
            ):
                raise ValueError(
                    f"flip arg must be ':rI' (scale lane I), ':dI' (deep: "
                    f"claim fixed up too) or ':neg', got {self.arg!r}"
                )
        if self.kind == "stall":
            if self.arg is not None and not re.fullmatch(r"\d+", self.arg):
                raise ValueError(
                    f"stall arg is a delay in milliseconds, got {self.arg!r}"
                )

    def covers(self, tick: int) -> bool:
        return self.at <= tick < self.at + self.count


class FaultPlan:
    """Seeded, declarative fault schedule (see module docstring)."""

    def __init__(self, events: list[FaultEvent] | tuple = (), seed: int = 0):
        self.events = tuple(events)
        self.seed = int(seed)

    # ------------------------------------------------------------ parse
    @classmethod
    def parse(cls, spec: "str | FaultPlan | None") -> "FaultPlan":
        """Parse a ``--chaos`` spec string (idempotent on FaultPlan/None)."""
        if spec is None:
            return cls()
        if isinstance(spec, FaultPlan):
            return spec
        seed = 0
        events: list[FaultEvent] = []
        for raw in re.split(r"[;,]", spec):
            entry = raw.strip()
            if not entry:
                continue
            if entry.startswith("seed="):
                seed = int(entry[len("seed="):])
                continue
            m = _ENTRY_RE.match(entry)
            if m is None:
                raise ValueError(
                    f"bad --chaos entry {entry!r}; expected "
                    f"'kind@at[xcount][:arg]' with kind in {FAULT_KINDS} "
                    f"(or 'seed=N')"
                )
            events.append(
                FaultEvent(
                    kind=m["kind"],
                    at=int(m["at"]),
                    count=int(m["count"] or 1),
                    arg=m["arg"],
                )
            )
        return cls(events, seed=seed)

    def __repr__(self) -> str:
        parts = [f"seed={self.seed}"] + [
            f"{e.kind}@{e.at}"
            + (f"x{e.count}" if e.count != 1 else "")
            + (f":{e.arg}" if e.arg is not None else "")
            for e in self.events
        ]
        return f"FaultPlan({';'.join(parts)})"

    def __bool__(self) -> bool:
        return bool(self.events)

    # ---------------------------------------------------------- queries
    def _of(self, kind: str):
        return (e for e in self.events if e.kind == kind)

    def transient_at(self, call: int) -> bool:
        return any(e.covers(call) for e in self._of("transient"))

    def poison_at(self, call: int) -> str | None:
        for e in self._of("poison"):
            if e.covers(call):
                return e.arg or "nan"
        return None

    def crash_at(self, call: int) -> bool:
        return any(e.covers(call) for e in self._of("crash"))

    def killed_replicas(self, call: int) -> set[int]:
        """Replicas permanently dead as of dispatch ``call`` (a kill has
        no end: ``count`` is ignored — loss is loss)."""
        return {int(e.arg[1:]) for e in self._of("kill") if call >= e.at}

    def flip_at(self, call: int) -> tuple[str, int] | None:
        """(mode, lane) of the finite corruption injected after dispatch
        ``call`` returned — mode "scale" (``:rI``, the default lane 0),
        "neg" (``:neg``), or "deep" (``:dI`` — the claim is fixed up so
        only duplicate voting catches it) — or None."""
        for e in self._of("flip"):
            if e.covers(call):
                arg = e.arg or "r0"
                if arg == "neg":
                    return ("neg", 0)
                return ("deep" if arg[0] == "d" else "scale", int(arg[1:]))
        return None

    def stall_ms(self, call: int) -> float | None:
        """Milliseconds to stall dispatch ``call`` (None = no stall)."""
        for e in self._of("stall"):
            if e.covers(call):
                return float(e.arg) if e.arg is not None else DEFAULT_STALL_MS
        return None

    def torn_save(self, save_idx: int) -> bool:
        return any(e.covers(save_idx) for e in self._of("torn"))

    def corrupt_cache_put(self, put_idx: int) -> bool:
        return any(e.covers(put_idx) for e in self._of("cache"))


class ChaosRoundFn:
    """Wrap a driver ``round_fn`` with the plan's dispatch-seam faults.

    Counts every invocation (retries advance the counter too, so a
    ``transient@KxN`` entry models N consecutive failed attempts) and
    injects in a fixed order: crash, replica loss, stall (a sleep
    through the injectable ``sleeper``, before the wrapped call),
    transient raise, then — after the call — output poison and the
    finite ``flip`` corruption.  Replica loss fires only when the dead
    lane carries live (non-padding) columns — after the driver's
    re-mesh deals the dead lane padding only, the wrapper stays silent,
    like hardware that fails when addressed.
    """

    def __init__(self, round_fn, plan: FaultPlan, sleeper=None):
        import time

        self.round_fn = round_fn
        self.plan = FaultPlan.parse(plan)
        self.calls = 0
        self._sleep = sleeper if sleeper is not None else time.sleep

    def __call__(self, sources, derived):
        import jax.numpy as jnp

        call = self.calls
        self.calls += 1
        if self.plan.crash_at(call):
            raise ChaosCrash(f"chaos: simulated process death at dispatch {call}")
        src_np = np.asarray(sources)
        for r in sorted(self.plan.killed_replicas(call)):
            if r < src_np.shape[0] and bool((src_np[r] >= 0).any()):
                raise ReplicaLostError(
                    r, f"chaos: replica {r} lost (dispatch {call})"
                )
        ms = self.plan.stall_ms(call)
        if ms is not None:
            self._sleep(ms / 1000.0)
        if self.plan.transient_at(call):
            raise TransientRoundError(
                f"chaos: transient round failure at dispatch {call}"
            )
        out = self.round_fn(sources, derived)
        mode = self.plan.poison_at(call)
        if mode is not None:
            bad = jnp.float32(jnp.nan if mode == "nan" else jnp.inf)
            out = (out[0] * bad, out[1] * bad) + tuple(out[2:])
        flip = self.plan.flip_at(call)
        if flip is not None:
            out = self._apply_flip(out, *flip)
        return out

    @staticmethod
    def _apply_flip(out, mode: str, lane: int):
        """Finitely corrupt lane ``lane`` of the block's bc output.

        "scale" → ``2x + 1`` (sum and values move — the claim audit or
        the ABFT residual catches it); "neg" → ``-(x + 1)`` (guaranteed
        negative values — the non-negativity audit's showcase); "deep"
        → ``2x`` AND the integrity record's claim is recomputed from the
        corrupted block, modeling corruption *upstream* of the claim —
        invisible to the block audits, detectable only by comparing
        duplicate lanes.
        """
        import jax.numpy as jnp

        bc = out[0]
        lanes = bc.shape[0] if bc.ndim > 1 else 1
        if lane >= lanes:
            return out
        if mode == "neg":
            def upd(x):
                return -(x + 1.0)
        elif mode == "deep":
            def upd(x):
                return 2.0 * x
        else:
            def upd(x):
                return 2.0 * x + 1.0
        bc = bc.at[lane].set(upd(bc[lane])) if bc.ndim > 1 else upd(bc)
        out = (bc,) + tuple(out[1:])
        if mode == "deep" and len(out) >= 5 and out[4] is not None:
            integ = out[4]
            claim = jnp.sum(bc[lane]) if bc.ndim > 1 else jnp.sum(bc)
            integ = (
                integ.at[lane, 1].set(claim)
                if integ.ndim > 1
                else integ.at[1].set(claim)
            )
            out = out[:4] + (integ,) + tuple(out[5:])
        return out


class ChaosFS:
    """The file-write seam: tears/garbles durable files per the plan.

    Holds the per-run save/put counters and the seeded RNG, so the same
    plan tears the same byte offset every run (reproducible from the
    CLI).  Wrap concrete writers with :class:`ChaosCheckpoint` /
    :class:`ChaosCostCache`; both call back into this object after each
    successful write.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = FaultPlan.parse(plan)
        self._rng = np.random.default_rng(self.plan.seed)
        self.checkpoint_saves = 0
        self.cache_puts = 0
        self.files_corrupted: list[str] = []

    def tear_file(self, path) -> None:
        """Truncate ``path`` at a seeded interior offset — the classic
        torn write (power loss / kill mid-flush)."""
        path = str(path)
        with open(path, "rb") as f:
            data = f.read()
        cut = max(1, int(len(data) * self._rng.uniform(0.2, 0.8)))
        with open(path, "wb") as f:
            f.write(data[:cut])
        self.files_corrupted.append(path)

    def garble_file(self, path) -> None:
        """Overwrite ``path`` with seeded garbage bytes (bit rot / a
        concurrent writer) — unreadable rather than merely short."""
        path = str(path)
        with open(path, "wb") as f:
            f.write(self._rng.bytes(64))
        self.files_corrupted.append(path)

    def after_checkpoint_save(self, path) -> None:
        idx = self.checkpoint_saves
        self.checkpoint_saves += 1
        if self.plan.torn_save(idx):
            self.tear_file(path)

    def after_cache_save(self, path) -> None:
        idx = self.cache_puts
        self.cache_puts += 1
        if self.plan.corrupt_cache_put(idx):
            self.garble_file(path)


class ChaosCheckpoint:
    """BCCheckpoint proxy: delegates everything, tears the snapshot file
    after the saves the plan names (the *newest* generation — the file
    the next resume tries first)."""

    def __init__(self, inner, fs: ChaosFS):
        self._inner = inner
        self._fs = fs

    def save(self, *args, **kwargs):
        out = self._inner.save(*args, **kwargs)
        self._fs.after_checkpoint_save(self._inner.path)
        return out

    def __getattr__(self, name):
        return getattr(self._inner, name)


def ChaosCostCache(path, fs: ChaosFS):
    """A :class:`repro.autotune.CostCache` whose persisted JSON the plan
    garbles after the puts it names (factory — returns a CostCache
    subclass instance, so ``isinstance(..., CostCache)`` holds and the
    autotune planner accepts it unchanged)."""
    from repro.autotune.cache import CostCache

    class _ChaosCostCache(CostCache):
        def save(self):
            super().save()
            if self.path is not None:
                fs.after_cache_save(self.path)

    return _ChaosCostCache(path)
