"""Deterministic fault injection for the BC driver (the chaos harness).

The paper's scale argument — graphs "too large to fit in the memory of a
single computational node" — implies runs long and wide enough that
transient runtime failures, replica (pod/host) loss and torn snapshot
writes are the *normal* case.  The driver's self-healing round loop
(:class:`repro.core.driver.BCDriver`: retry/backoff, numeric quarantine,
elastic re-mesh, generational snapshots) exists to survive them; this
module makes every one of those failure modes reproducible on demand so
the recovery paths are testable, debuggable from the CLI, and gated in
CI (``make chaos-smoke``).

Design: faults are *declared* up front in a seeded :class:`FaultPlan`
and *injected* by wrappers at exactly two seams — the ``round_fn`` call
boundary (:class:`ChaosRoundFn`) and the durable-file writes
(:class:`ChaosFS` via :class:`ChaosCheckpoint` /
:class:`ChaosCostCache`).  Production code paths are never patched or
branched; a chaos run is the production run with wrapped callables, so
whatever survives chaos is exactly what runs clean.

Fault classes (:data:`FAULT_KINDS`), all keyed on deterministic
counters (dispatch-call index, checkpoint-save index, cache-put index):

  ``transient``  raise :class:`TransientRoundError` for ``count``
                 consecutive dispatch calls starting at ``at`` — the
                 driver must retry with backoff and succeed.
  ``poison``     multiply the block's ``bc``/``ns`` outputs by NaN (or
                 Inf, ``:inf``) — the driver's numeric guard must
                 quarantine the block, re-dispatch it, and fall back to
                 the clean round fn if the poison persists.
  ``kill``       replica ``:rI`` is lost from call ``at`` on — the
                 wrapper raises :class:`ReplicaLostError` whenever that
                 lane is dispatched live (non-padding) columns, exactly
                 like a device set that fails when used; after the
                 driver re-meshes, the dead lane receives only padding
                 and the wrapper stays silent.
  ``crash``      raise :class:`ChaosCrash` at call ``at`` — a simulated
                 process death (never retried) for kill-and-resume
                 tests.
  ``torn``       tear (truncate) the snapshot file the ``at``-th
                 checkpoint save just wrote — the next load must fall
                 back to an older intact generation.
  ``cache``      garble the autotune cache JSON after its ``at``-th
                 persisted put — the next run must warm-start empty
                 with a warning, never traceback.

A plan is constructed programmatically or parsed from the compact CLI
spec of ``launch/bc.py --chaos``::

    --chaos "seed=7;transient@1x2;poison@3:nan;kill@4:r1;torn@0;cache@0"

entries are ``kind@at[xcount][:arg]`` separated by ``;`` or ``,``.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np

from repro.distributed.fault_tolerance import (
    ReplicaLostError,
    TransientRoundError,
)

__all__ = [
    "FAULT_KINDS",
    "FaultPlan",
    "FaultEvent",
    "ChaosCrash",
    "ChaosRoundFn",
    "ChaosFS",
    "ChaosCheckpoint",
    "ChaosCostCache",
]

#: The injectable fault classes — the single source of truth for the
#: ``--chaos`` spec grammar and the docs drift check (tools/check_docs.py):
#: "transient" retryable raise | "poison" NaN/Inf block outputs |
#: "kill" permanent replica loss | "crash" simulated process death |
#: "torn" truncated snapshot write | "cache" corrupted autotune cache.
FAULT_KINDS = ("transient", "poison", "kill", "crash", "torn", "cache")

_ENTRY_RE = re.compile(
    r"^(?P<kind>[a-z]+)@(?P<at>\d+)(?:x(?P<count>\d+))?(?::(?P<arg>[A-Za-z0-9_]+))?$"
)


class ChaosCrash(BaseException):
    """Simulated process death (kill-and-resume tests).

    Deliberately NOT an ``Exception`` subclass: nothing in the driver —
    not the transient retry, not the numeric fallback — may swallow it,
    exactly like a SIGKILL.
    """


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One declared fault: ``kind`` fires at counter value ``at`` for
    ``count`` consecutive ticks; ``arg`` carries the kind-specific
    payload (poison mode, killed replica index)."""

    kind: str
    at: int
    count: int = 1
    arg: str | None = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}"
            )
        if self.at < 0 or self.count < 1:
            raise ValueError(f"fault {self.kind!r} needs at >= 0 and count >= 1")
        if self.kind == "poison" and self.arg not in (None, "nan", "inf"):
            raise ValueError(f"poison arg must be 'nan' or 'inf', got {self.arg!r}")
        if self.kind == "kill":
            if self.arg is None or not re.fullmatch(r"r\d+", self.arg):
                raise ValueError(
                    f"kill needs a replica arg like ':r1', got {self.arg!r}"
                )

    def covers(self, tick: int) -> bool:
        return self.at <= tick < self.at + self.count


class FaultPlan:
    """Seeded, declarative fault schedule (see module docstring)."""

    def __init__(self, events: list[FaultEvent] | tuple = (), seed: int = 0):
        self.events = tuple(events)
        self.seed = int(seed)

    # ------------------------------------------------------------ parse
    @classmethod
    def parse(cls, spec: "str | FaultPlan | None") -> "FaultPlan":
        """Parse a ``--chaos`` spec string (idempotent on FaultPlan/None)."""
        if spec is None:
            return cls()
        if isinstance(spec, FaultPlan):
            return spec
        seed = 0
        events: list[FaultEvent] = []
        for raw in re.split(r"[;,]", spec):
            entry = raw.strip()
            if not entry:
                continue
            if entry.startswith("seed="):
                seed = int(entry[len("seed="):])
                continue
            m = _ENTRY_RE.match(entry)
            if m is None:
                raise ValueError(
                    f"bad --chaos entry {entry!r}; expected "
                    f"'kind@at[xcount][:arg]' with kind in {FAULT_KINDS} "
                    f"(or 'seed=N')"
                )
            events.append(
                FaultEvent(
                    kind=m["kind"],
                    at=int(m["at"]),
                    count=int(m["count"] or 1),
                    arg=m["arg"],
                )
            )
        return cls(events, seed=seed)

    def __repr__(self) -> str:
        parts = [f"seed={self.seed}"] + [
            f"{e.kind}@{e.at}"
            + (f"x{e.count}" if e.count != 1 else "")
            + (f":{e.arg}" if e.arg is not None else "")
            for e in self.events
        ]
        return f"FaultPlan({';'.join(parts)})"

    def __bool__(self) -> bool:
        return bool(self.events)

    # ---------------------------------------------------------- queries
    def _of(self, kind: str):
        return (e for e in self.events if e.kind == kind)

    def transient_at(self, call: int) -> bool:
        return any(e.covers(call) for e in self._of("transient"))

    def poison_at(self, call: int) -> str | None:
        for e in self._of("poison"):
            if e.covers(call):
                return e.arg or "nan"
        return None

    def crash_at(self, call: int) -> bool:
        return any(e.covers(call) for e in self._of("crash"))

    def killed_replicas(self, call: int) -> set[int]:
        """Replicas permanently dead as of dispatch ``call`` (a kill has
        no end: ``count`` is ignored — loss is loss)."""
        return {int(e.arg[1:]) for e in self._of("kill") if call >= e.at}

    def torn_save(self, save_idx: int) -> bool:
        return any(e.covers(save_idx) for e in self._of("torn"))

    def corrupt_cache_put(self, put_idx: int) -> bool:
        return any(e.covers(put_idx) for e in self._of("cache"))


class ChaosRoundFn:
    """Wrap a driver ``round_fn`` with the plan's dispatch-seam faults.

    Counts every invocation (retries advance the counter too, so a
    ``transient@KxN`` entry models N consecutive failed attempts) and
    injects in a fixed order: crash, replica loss, transient raise,
    output poison.  Replica loss fires only when the dead lane carries
    live (non-padding) columns — after the driver's re-mesh deals the
    dead lane padding only, the wrapper stays silent, like hardware
    that fails when addressed.
    """

    def __init__(self, round_fn, plan: FaultPlan):
        self.round_fn = round_fn
        self.plan = FaultPlan.parse(plan)
        self.calls = 0

    def __call__(self, sources, derived):
        import jax.numpy as jnp

        call = self.calls
        self.calls += 1
        if self.plan.crash_at(call):
            raise ChaosCrash(f"chaos: simulated process death at dispatch {call}")
        src_np = np.asarray(sources)
        for r in sorted(self.plan.killed_replicas(call)):
            if r < src_np.shape[0] and bool((src_np[r] >= 0).any()):
                raise ReplicaLostError(
                    r, f"chaos: replica {r} lost (dispatch {call})"
                )
        if self.plan.transient_at(call):
            raise TransientRoundError(
                f"chaos: transient round failure at dispatch {call}"
            )
        out = self.round_fn(sources, derived)
        mode = self.plan.poison_at(call)
        if mode is not None:
            bad = jnp.float32(jnp.nan if mode == "nan" else jnp.inf)
            out = (out[0] * bad, out[1] * bad) + tuple(out[2:])
        return out


class ChaosFS:
    """The file-write seam: tears/garbles durable files per the plan.

    Holds the per-run save/put counters and the seeded RNG, so the same
    plan tears the same byte offset every run (reproducible from the
    CLI).  Wrap concrete writers with :class:`ChaosCheckpoint` /
    :class:`ChaosCostCache`; both call back into this object after each
    successful write.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = FaultPlan.parse(plan)
        self._rng = np.random.default_rng(self.plan.seed)
        self.checkpoint_saves = 0
        self.cache_puts = 0
        self.files_corrupted: list[str] = []

    def tear_file(self, path) -> None:
        """Truncate ``path`` at a seeded interior offset — the classic
        torn write (power loss / kill mid-flush)."""
        path = str(path)
        with open(path, "rb") as f:
            data = f.read()
        cut = max(1, int(len(data) * self._rng.uniform(0.2, 0.8)))
        with open(path, "wb") as f:
            f.write(data[:cut])
        self.files_corrupted.append(path)

    def garble_file(self, path) -> None:
        """Overwrite ``path`` with seeded garbage bytes (bit rot / a
        concurrent writer) — unreadable rather than merely short."""
        path = str(path)
        with open(path, "wb") as f:
            f.write(self._rng.bytes(64))
        self.files_corrupted.append(path)

    def after_checkpoint_save(self, path) -> None:
        idx = self.checkpoint_saves
        self.checkpoint_saves += 1
        if self.plan.torn_save(idx):
            self.tear_file(path)

    def after_cache_save(self, path) -> None:
        idx = self.cache_puts
        self.cache_puts += 1
        if self.plan.corrupt_cache_put(idx):
            self.garble_file(path)


class ChaosCheckpoint:
    """BCCheckpoint proxy: delegates everything, tears the snapshot file
    after the saves the plan names (the *newest* generation — the file
    the next resume tries first)."""

    def __init__(self, inner, fs: ChaosFS):
        self._inner = inner
        self._fs = fs

    def save(self, *args, **kwargs):
        out = self._inner.save(*args, **kwargs)
        self._fs.after_checkpoint_save(self._inner.path)
        return out

    def __getattr__(self, name):
        return getattr(self._inner, name)


def ChaosCostCache(path, fs: ChaosFS):
    """A :class:`repro.autotune.CostCache` whose persisted JSON the plan
    garbles after the puts it names (factory — returns a CostCache
    subclass instance, so ``isinstance(..., CostCache)`` holds and the
    autotune planner accepts it unchanged)."""
    from repro.autotune.cache import CostCache

    class _ChaosCostCache(CostCache):
        def save(self):
            super().save()
            if self.path is not None:
                fs.after_cache_save(self.path)

    return _ChaosCostCache(path)
