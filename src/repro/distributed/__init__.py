"""Distribution substrate: mesh-aware sharding helpers, gradient
compression, fault tolerance / elasticity planning."""
from repro.distributed.sharding import (
    constrain,
    current_mesh,
    set_current_mesh,
    use_mesh,
    named_sharding,
)

__all__ = [
    "constrain",
    "current_mesh",
    "set_current_mesh",
    "use_mesh",
    "named_sharding",
]
