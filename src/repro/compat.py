"""JAX version compatibility shims.

The codebase targets the modern JAX API (``jax.make_mesh`` with
``axis_types``, ``jax.shard_map`` with ``check_vma``); the pinned
container ships JAX 0.4.37 where

* ``jax.sharding.AxisType`` does not exist (meshes are implicitly
  "auto" — the only mode this code uses),
* ``shard_map`` lives in ``jax.experimental.shard_map`` and spells the
  replication-check flag ``check_rep``.

Everything that touches either API routes through here so the rest of
the tree stays written against the current surface.
"""
from __future__ import annotations

from typing import Sequence

import jax

__all__ = ["make_mesh", "shard_map"]

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(
    shape: Sequence[int], axis_names: Sequence[str], *, devices=None
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with explicit Auto axis types when supported.

    JAX 0.4.37 has no ``axis_types`` kwarg (every axis is Auto); newer
    versions default collective-manual code paths differently, so there
    we pass ``AxisType.Auto`` explicitly.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _HAS_AXIS_TYPE:
        kwargs["axis_types"] = (jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(shape), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` where available, else the 0.4.x experimental one.

    ``check_vma`` (new name) maps onto ``check_rep`` (old name); both
    disable the same replication/varying-manual-axes verification.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
