"""Measured-cost autotuning: persistent cost cache + micro-bench planner.

Replaces roofline guesswork at the four choice seams (hybrid per-cell
kernel choice, ``overlap="auto"``, straggler EWMA prior, BCSR tile
pick) with cached measurements — see :mod:`repro.autotune.cache` for
the key schema and :mod:`repro.autotune.measure` for the measure-once
lifecycle.
"""
from repro.autotune.cache import (
    AUTOTUNE_MODES,
    CostCache,
    CostRecord,
    as_cache,
    config_key,
    graph_key,
    graph_key_for,
    normalize_autotune,
)
from repro.autotune.measure import (
    MEASURE_LEVELS,
    Candidate,
    TunePlan,
    default_bench,
    measure_walls,
    plan_autotune,
    sample_batch,
)

__all__ = [
    "AUTOTUNE_MODES",
    "Candidate",
    "CostCache",
    "CostRecord",
    "MEASURE_LEVELS",
    "TunePlan",
    "as_cache",
    "config_key",
    "default_bench",
    "graph_key",
    "graph_key_for",
    "measure_walls",
    "normalize_autotune",
    "plan_autotune",
    "sample_batch",
]
