"""Micro-bench harness + staged planner for the measured-cost cache.

``plan_autotune`` is the one entry: given a partition/mesh and the
requested run config, it resolves measured per-level costs for every
choice the run is about to make, consulting the :class:`CostCache`
first and (in ``"measure"`` mode) micro-benching on a miss.  Three
bounded stages keep a cold run to a handful of timings instead of a
cross product:

  1. **tile** — candidate BCSR tile shapes
     (:meth:`TwoDPartition.tile_candidates`), each timed as a pure
     ``pallas_sparse`` round at ``overlap="none"`` (the tile shape
     prices the BCSR side regardless of the surrounding engine).
  2. **hybrid calibration** — for ``pallas_hybrid``, one pure dense
     (``pallas``) and one pure BCSR (``pallas_sparse``) timing: the
     (dense_level_s, sparse_level_s) pair
     :func:`repro.roofline.model.cell_kernel_choice` consumes.
  3. **overlap** — the requested policy (or all of
     ``OVERLAP_POLICIES`` under ``overlap="auto"``) timed on the final
     engine/tile; these seed :func:`auto_overlap_policy` and the
     straggler prior (:func:`distributed.prior_round_seconds`).

Each timing runs the *real* distributed round function for a few
representative levels (``MEASURE_LEVELS``), 1 warm-up + ``MEASURE_ITERS``
timed calls, and records ``min(walls) / (2 · levels)`` — forward +
backward both sweep the level loop, hence the 2.  The wall clock and
the whole bench callable are injectable, so on CPU fake devices unit
tests drive the path with deterministic fake clocks (the
``tests/test_straggler.py`` trick).

When measured and roofline costs would otherwise mix (some candidates
cached, others not, in ``"cache"`` mode), comparisons restrict to the
measured candidates only — CPU-interpreter walls and model seconds are
not on the same scale, so a measured-vs-modelled comparison would be
meaningless.  ``"measure"`` mode never mixes: every candidate it
compares, it measures.
"""
from __future__ import annotations

import dataclasses
import logging
import time

import numpy as np

from repro.autotune.cache import (
    CostCache,
    CostRecord,
    config_key,
    graph_key_for,
    normalize_autotune,
)
from repro.core.operators import OVERLAP_POLICIES, normalize_overlap

logger = logging.getLogger(__name__)

#: static level bound of a micro-bench round: deep enough to amortize
#: per-round dispatch overhead, shallow enough that a cold autotune adds
#: only a few round-equivalents of work
MEASURE_LEVELS = 4
MEASURE_ITERS = 2
MEASURE_WARMUP = 1

#: engines whose graph operands are BCSR-tiled (tile stage applies)
TILED_ENGINES = ("pallas_sparse", "pallas_hybrid")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One measurable config (the cache's config-key tuple)."""

    engine_kind: str
    overlap: str
    batch_size: int
    tile: tuple[int, int] | None = None

    def key(self) -> str:
        return config_key(self.engine_kind, self.overlap, self.batch_size, self.tile)


def measure_walls(run, *, clock=time.perf_counter, warmup: int = MEASURE_WARMUP,
                  iters: int = MEASURE_ITERS) -> list[float]:
    """Time ``run()``: ``warmup`` untimed calls (compile), then ``iters``
    timed calls.  Returns the raw walls; callers take the min (the
    least-interfered sample) as the cost."""
    for _ in range(warmup):
        run()
    walls = []
    for _ in range(iters):
        t0 = clock()
        run()
        walls.append(clock() - t0)
    return walls


def default_bench(
    partition,
    mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    sources: np.ndarray,
    derived: np.ndarray,
    hybrid_threshold: float = 1.0,
    clock=time.perf_counter,
):
    """Build the production bench callable: Candidate -> CostRecord.

    Lowers the real distributed round function at ``MEASURE_LEVELS``
    static levels with the candidate's engine/overlap/tile operands and
    times it on the mesh.  Imports the distributed module lazily — the
    autotune package is imported *by* it.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.distributed import (
        distributed_graph_arrays,
        hybrid_cell_choice,
        make_distributed_round_fn,
    )

    omega = jnp.zeros(partition.R * partition.C * partition.chunk, jnp.float32)
    sources = jnp.asarray(sources)
    derived = jnp.asarray(derived)

    def bench(cand: Candidate) -> CostRecord:
        bm, bk = cand.tile if cand.tile is not None else (None, None)
        dense_cells = None
        if cand.engine_kind == "pallas_hybrid":
            dense_cells, _ = hybrid_cell_choice(
                partition, bm, bk, threshold=hybrid_threshold
            )
        round_fn = make_distributed_round_fn(
            partition,
            mesh,
            row_axis=row_axis,
            col_axis=col_axis,
            replica_axis=replica_axis,
            num_levels=MEASURE_LEVELS,
            engine_kind=cand.engine_kind,
            overlap=cand.overlap,
        )
        graph_args = distributed_graph_arrays(
            partition,
            cand.engine_kind,
            cand.overlap,
            tile=cand.tile,
            dense_cells=dense_cells,
        )

        def run():
            jax.block_until_ready(round_fn(*graph_args, omega, sources, derived))

        walls = measure_walls(run, clock=clock)
        return CostRecord(
            level_s=min(walls) / (2.0 * MEASURE_LEVELS),
            levels=MEASURE_LEVELS,
            walls=tuple(walls),
        )

    return bench


def sample_batch(schedule, fr: int) -> tuple[np.ndarray, np.ndarray]:
    """A representative (sources, derived) block for the micro-bench:
    the schedule's first round, replicated across the ``fr`` lanes."""
    r0 = schedule.rounds[0]
    sources = np.tile(np.asarray(r0.sources, np.int32), (fr, 1))
    derived = np.tile(np.asarray(r0.derived, np.int32), (fr, 1, 1))
    return sources, derived


@dataclasses.dataclass
class TunePlan:
    """Resolved measured costs for one run (what the seams consume)."""

    mode: str
    graph_key: str
    engine_kind: str
    batch_size: int
    #: resolved BCSR tile (None for untiled engines / no candidates)
    tile: tuple[int, int] | None = None
    #: "explicit" | "measured" | "roofline" | "default"
    tile_source: str = "default"
    #: measured (dense_level_s, sparse_level_s) hybrid calibration pair,
    #: None when either half is unmeasured (seam falls back to roofline)
    cell_costs: tuple[float, float] | None = None
    #: measured per-level seconds per overlap policy (only policies with
    #: a cache hit or fresh measurement appear)
    overlap_level_s: dict = dataclasses.field(default_factory=dict)
    hits: int = 0
    misses: int = 0
    measured: int = 0

    def level_s_for(self, policy: str) -> float | None:
        """Measured per-level cost of the (resolved) overlap policy —
        the straggler EWMA prior's seed."""
        return self.overlap_level_s.get(normalize_overlap(policy))

    def report(self) -> dict:
        """The dryrun/CLI ``[tune]`` record."""
        return {
            "mode": self.mode,
            "graph_key": self.graph_key,
            "tile": list(self.tile) if self.tile else None,
            "tile_source": self.tile_source,
            "overlap_level_s": {
                k: round(v, 9) for k, v in sorted(self.overlap_level_s.items())
            },
            "cell_costs_measured": self.cell_costs is not None,
            "hits": self.hits,
            "misses": self.misses,
            "measured": self.measured,
        }


def plan_autotune(
    partition,
    mesh=None,
    *,
    engine_kind: str,
    overlap: str,
    batch_size: int,
    tile: tuple[int, int] | None = None,
    mode: str = "measure",
    cache: CostCache | None = None,
    graph=None,
    nnz_tiles: int = 0,
    fr: int = 1,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    sources: np.ndarray | None = None,
    derived: np.ndarray | None = None,
    hybrid_threshold: float = 1.0,
    bench=None,
    clock=time.perf_counter,
) -> TunePlan:
    """Resolve measured costs for a run (see module docstring).

    ``bench`` overrides the measurement callable (Candidate ->
    CostRecord) — fake-clock unit tests inject a deterministic one; the
    default lowers and times real round functions on ``mesh``.
    """
    mode = normalize_autotune(mode)
    cache = cache if cache is not None else CostCache(None)
    gkey = graph_key_for(partition, graph, fr=fr, nnz_tiles=nnz_tiles)
    plan = TunePlan(
        mode=mode, graph_key=gkey, engine_kind=engine_kind, batch_size=batch_size
    )
    if mode == "off":
        return plan

    _bench = bench

    def get_bench():
        nonlocal _bench
        if _bench is None:
            if mesh is None:
                raise ValueError(
                    "autotune='measure' needs a mesh (or an injected bench) "
                    "to time candidate configs"
                )
            if sources is None or derived is None:
                raise ValueError("autotune measurement needs a sample batch")
            _bench = default_bench(
                partition,
                mesh,
                row_axis=row_axis,
                col_axis=col_axis,
                replica_axis=replica_axis,
                sources=sources,
                derived=derived,
                hybrid_threshold=hybrid_threshold,
                clock=clock,
            )
        return _bench

    def cost_of(cand: Candidate) -> float | None:
        """Measured per-level seconds of ``cand``: cache hit, else (in
        "measure" mode) a fresh micro-bench recorded under measure-once
        keys; None in "cache" mode on a miss (roofline fallback)."""
        ckey = cand.key()
        rec = cache.get(gkey, ckey)
        if rec is not None:
            plan.hits += 1
            return rec.level_s
        plan.misses += 1
        if mode != "measure":
            return None
        rec = get_bench()(cand)
        cache.put(gkey, ckey, rec)
        plan.measured += 1
        logger.info(
            "autotune measured %s @ %s: %.3es/level (walls %s)",
            ckey, gkey, rec.level_s, [f"{w:.3e}" for w in rec.walls],
        )
        return rec.level_s

    # ---- stage 1: BCSR tile shape (tiled engines, tile not forced) ----
    tiled = engine_kind in TILED_ENGINES
    if tile is not None:
        plan.tile, plan.tile_source = tile, "explicit"
    elif tiled:
        cands = partition.tile_candidates()
        costs = {t: cost_of(Candidate("pallas_sparse", "none", batch_size, t))
                 for t in cands}
        measured = {t: c for t, c in costs.items() if c is not None}
        if measured:
            plan.tile = min(measured, key=measured.get)
            plan.tile_source = "measured"
        else:
            plan.tile = _roofline_tile(partition, batch_size, cands)
            plan.tile_source = "roofline"

    # ---- stage 2: hybrid dense/sparse calibration --------------------
    if engine_kind == "pallas_hybrid":
        dense_s = cost_of(Candidate("pallas", "none", batch_size, None))
        sparse_s = cost_of(Candidate("pallas_sparse", "none", batch_size, plan.tile))
        if dense_s is not None and sparse_s is not None:
            plan.cell_costs = (dense_s, sparse_s)

    # ---- stage 3: overlap policies on the final engine/tile ----------
    policies = (
        list(OVERLAP_POLICIES) if overlap == "auto" else [normalize_overlap(overlap)]
    )
    for policy in policies:
        c = cost_of(Candidate(engine_kind, policy, batch_size, plan.tile))
        if c is not None:
            plan.overlap_level_s[policy] = c
    return plan


def _roofline_tile(partition, batch_size, candidates):
    """Roofline fallback for the tile pick: price each candidate's
    compute term and take the cheapest (lazy import — see module)."""
    from repro.core.distributed import level_time_estimates

    def price(t):
        compute_s, _, _ = level_time_estimates(
            partition, "pallas_sparse", batch_size, bm=t[0], bk=t[1]
        )
        return compute_s

    return min(candidates, key=price) if candidates else None
