"""Persistent measured-cost cache behind the engine/tile/overlap/prior picks.

The roofline model (:mod:`repro.roofline.model`) prices every candidate
config analytically; this cache stores what a config actually *measured*
(:mod:`repro.autotune.measure`) so the four choice seams — the hybrid
per-cell kernel choice, ``overlap="auto"``, the straggler EWMA prior,
and the BCSR tile-shape pick — can consult a measurement before falling
back to the model.

Keying (measure-once semantics):

  graph key  — graph stats + mesh shape: ``n{n}_m{m}_r{R}x{C}x{fr}_``
               ``t{nnz_tiles}_k{skew}`` where ``skew`` is the degree
               skew ``max(deg)/mean(deg)`` rounded to one decimal (a
               topology signature: RMAT vs uniform graphs land on
               different keys, re-runs of the same graph on the same
               mesh land on the same one).
  config key — candidate config: ``{engine}|{overlap}|b{batch}|``
               ``t{bm}x{bk}`` (``t-`` for untiled engines).

A record under (graph key, config key) is the measured per-level wall
seconds of that config.  Same keys on a later run ⇒ cache hit ⇒ no
re-measurement; the hit/miss/measured counters make that auditable
(``tools/autotune_smoke.py`` asserts the round trip).

The JSON file is versioned and corrupt-tolerant: an unreadable or
wrong-version file is treated as empty rather than crashing the run.
``path=None`` keeps the cache in-memory (unit tests, one-shot runs).
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import pathlib
import tempfile

import numpy as np

logger = logging.getLogger(__name__)

#: autotune modes (single source of truth — check_docs enforces that the
#: README/ARCHITECTURE flag tables mention every value):
#:   "off"     — roofline-only status quo (default; no cache, no timing)
#:   "cache"   — consult the cache; on a miss fall back to the roofline,
#:               never measure (safe for dry-runs and CI gates)
#:   "measure" — consult the cache; on a miss micro-bench the candidate
#:               and record it (measure-once: the next run hits)
AUTOTUNE_MODES = ("off", "cache", "measure")

CACHE_VERSION = 1


def normalize_autotune(mode: str | None) -> str:
    """Validate an ``autotune=`` mode (None ⇒ "off")."""
    if mode is None:
        return "off"
    if mode not in AUTOTUNE_MODES:
        raise ValueError(
            f"autotune must be one of {AUTOTUNE_MODES}, got {mode!r}"
        )
    return mode


def graph_key(
    n: int,
    m: int,
    *,
    R: int,
    C: int,
    fr: int = 1,
    nnz_tiles: int = 0,
    degree_skew: float = 1.0,
) -> str:
    """Graph-stats + mesh-shape cache key (see module docstring)."""
    return (
        f"n{int(n)}_m{int(m)}_r{int(R)}x{int(C)}x{int(fr)}"
        f"_t{int(nnz_tiles)}_k{float(degree_skew):.1f}"
    )


def graph_key_for(
    partition, graph=None, *, fr: int = 1, nnz_tiles: int = 0
) -> str:
    """Graph key from a :class:`TwoDPartition` (+ the graph for degree
    stats; without it the skew falls back to 1).  ``nnz_tiles`` is the
    caller's tile count when a tile pass already ran (tiled engines);
    untiled engines key on 0 — the key only needs to be stable across
    runs of the same configuration."""
    m = int(partition.arc_counts.sum())
    if graph is not None and graph.n > 0:
        deg = graph.degrees().astype(np.float64)
        skew = float(deg.max() / max(deg.mean(), 1.0))
    else:
        skew = 1.0
    return graph_key(
        partition.n, m, R=partition.R, C=partition.C, fr=fr,
        nnz_tiles=nnz_tiles, degree_skew=skew,
    )


def config_key(
    engine_kind: str,
    overlap: str,
    batch_size: int,
    tile: tuple[int, int] | None = None,
) -> str:
    """Candidate-config cache key (see module docstring)."""
    t = f"t{int(tile[0])}x{int(tile[1])}" if tile is not None else "t-"
    return f"{engine_kind}|{overlap}|b{int(batch_size)}|{t}"


@dataclasses.dataclass(frozen=True)
class CostRecord:
    """One measured config: per-level wall seconds + raw evidence."""

    level_s: float
    levels: int = 0
    walls: tuple[float, ...] = ()

    def to_json(self) -> dict:
        return {
            "level_s": self.level_s,
            "levels": self.levels,
            "walls": list(self.walls),
        }

    @classmethod
    def from_json(cls, obj: dict) -> "CostRecord":
        return cls(
            level_s=float(obj["level_s"]),
            levels=int(obj.get("levels", 0)),
            walls=tuple(float(w) for w in obj.get("walls", ())),
        )


class CostCache:
    """Persistent JSON cost cache with hit/miss/store accounting.

    ``path=None`` ⇒ in-memory only.  Loads eagerly (corrupt or
    wrong-version files are treated as empty), saves atomically
    (write-temp + rename) on every :meth:`put` so a killed run never
    loses or corrupts earlier measurements.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path is not None else None
        self.entries: dict[str, dict[str, CostRecord]] = {}
        self.hits = 0
        self.misses = 0
        self.stores = 0
        self._load()

    def _load(self) -> None:
        if self.path is None or not self.path.exists():
            return
        try:
            obj = json.loads(self.path.read_text())
        except (OSError, ValueError) as e:  # bad JSON / non-UTF-8 bytes
            # corrupt-tolerant, but never silent: a garbled cache means
            # every measurement is gone and the run re-measures cold
            logger.warning(
                "autotune cache %s is unreadable (%s: %s); starting empty",
                self.path, type(e).__name__, e,
            )
            return
        if not isinstance(obj, dict) or obj.get("version") != CACHE_VERSION:
            logger.warning(
                "autotune cache %s has an unexpected version/shape "
                "(want version %s); starting empty",
                self.path, CACHE_VERSION,
            )
            return
        for gkey, configs in obj.get("entries", {}).items():
            try:
                self.entries[gkey] = {
                    ckey: CostRecord.from_json(rec)
                    for ckey, rec in configs.items()
                }
            except (KeyError, TypeError, ValueError):
                logger.warning(
                    "autotune cache %s: malformed record group %s skipped",
                    self.path, gkey,
                )
                continue  # skip a malformed group, keep the rest

    def save(self) -> None:
        if self.path is None:
            return
        obj = {
            "version": CACHE_VERSION,
            "entries": {
                gkey: {ckey: rec.to_json() for ckey, rec in configs.items()}
                for gkey, configs in self.entries.items()
            },
        }
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=str(self.path.parent), prefix=self.path.name, suffix=".tmp"
        )
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(obj, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def get(self, gkey: str, ckey: str) -> CostRecord | None:
        rec = self.entries.get(gkey, {}).get(ckey)
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    def put(self, gkey: str, ckey: str, record: CostRecord) -> None:
        self.entries.setdefault(gkey, {})[ckey] = record
        self.stores += 1
        self.save()

    def num_records(self) -> int:
        return sum(len(c) for c in self.entries.values())

    def stats(self) -> dict:
        return {
            "path": str(self.path) if self.path else None,
            "records": self.num_records(),
            "hits": self.hits,
            "misses": self.misses,
            "stores": self.stores,
        }


def as_cache(cache) -> "CostCache":
    """Coerce a ``CostCache | path | None`` into a CostCache."""
    if isinstance(cache, CostCache):
        return cache
    return CostCache(cache)
