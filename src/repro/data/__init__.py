"""Data substrate: deterministic synthetic pipelines per family +
neighbor sampler + host-side prefetch."""
from repro.data.pipeline import Prefetcher
from repro.data.sampler import NeighborSampler

__all__ = ["Prefetcher", "NeighborSampler"]
