"""Deterministic synthetic LM token stream with an exact-resume cursor.

Tokens follow a seeded order-0 Markov-ish mixture (so the loss actually
decreases during the example runs, unlike uniform noise).  The stream is
a pure function of (seed, step), so resuming from a checkpoint at step k
reproduces exactly the batches a non-interrupted run would have seen —
the property tests/test_substrates.py checks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenStream"]


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    n_modes: int = 32

    def batch_at(self, step: int) -> np.ndarray:
        """i32 [batch, seq_len] — pure function of (seed, step)."""
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # each sequence draws from a small per-sequence token set → learnable
        modes = rng.integers(0, self.n_modes, size=(self.batch, 1))
        base = (modes * 97 + 13) % max(self.vocab - 64, 1)
        offsets = rng.integers(0, 64, size=(self.batch, self.seq_len))
        return ((base + offsets) % self.vocab).astype(np.int32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
