"""Host-side prefetching: overlap batch construction with device steps."""
from __future__ import annotations

import queue
import threading
from typing import Callable

__all__ = ["Prefetcher"]


class Prefetcher:
    """Runs ``producer(step)`` in a background thread, ``depth`` ahead."""

    def __init__(self, producer: Callable[[int], object], depth: int = 2, start_step: int = 0):
        self.producer = producer
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                item = (step, self.producer(step))
            except Exception as e:  # surface in get()
                self._q.put((step, e))
                return
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self):
        step, item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return step, item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
