"""Graph batch construction matching the GNN cell tensor formats.

Pads nodes/edges to the shape the step was compiled for, using the
sentinel conventions of models/gnn.py (edge endpoints = n_nodes index
into the sentinel row).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import GNNArch
from repro.data.sampler import NeighborSampler
from repro.graphs.graph import Graph

__all__ = ["full_graph_batch", "molecule_batch", "minibatch_batch", "synth_features"]


def synth_features(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _pad_edges(src, dst, n_edges_pad, sentinel):
    pad = n_edges_pad - len(src)
    if pad < 0:
        raise ValueError(f"edge budget too small: {len(src)} > {n_edges_pad}")
    src = np.concatenate([src, np.full(pad, sentinel, np.int32)])
    dst = np.concatenate([dst, np.full(pad, sentinel, np.int32)])
    return src, dst


def full_graph_batch(
    cfg: GNNArch,
    graph: Graph,
    n_nodes_pad: int,
    n_edges_pad: int,
    d_feat: int,
    d_out: int,
    n_classes: int,
    seed: int = 0,
) -> dict:
    rng = np.random.default_rng(seed)
    feat = np.zeros((n_nodes_pad, d_feat), np.float32)
    feat[: graph.n] = synth_features(graph.n, d_feat, seed)
    src, dst = _pad_edges(
        graph.src.astype(np.int32), graph.dst.astype(np.int32), n_edges_pad, n_nodes_pad
    )
    batch = {"node_feat": feat, "edge_src": src, "edge_dst": dst}
    if cfg.kind in ("graphcast", "meshgraphnet"):
        batch["target"] = rng.standard_normal((n_nodes_pad, d_out)).astype(np.float32)
        mask = np.zeros(n_nodes_pad, np.float32)
        mask[: graph.n] = 1.0
        batch["label_mask"] = mask
        if cfg.kind == "meshgraphnet":
            batch["edge_feat"] = rng.standard_normal((n_edges_pad, d_feat)).astype(
                np.float32
            )
    else:
        labels = rng.integers(0, n_classes, size=n_nodes_pad).astype(np.int32)
        mask = np.zeros(n_nodes_pad, np.float32)
        mask[: graph.n] = 1.0
        batch["labels"] = labels
        batch["label_mask"] = mask
    return batch


def molecule_batch(
    cfg: GNNArch,
    n_graphs: int,
    nodes_per: int,
    edges_per: int,
    n_nodes_pad: int,
    n_edges_pad: int,
    d_feat: int,
    d_out: int,
    n_classes: int,
    seed: int = 0,
) -> dict:
    """Batched small graphs as one disjoint union (segment-pooled)."""
    rng = np.random.default_rng(seed)
    srcs, dsts, gids = [], [], []
    for g in range(n_graphs):
        off = g * nodes_per
        u = rng.integers(0, nodes_per, size=edges_per // 2)
        v = rng.integers(0, nodes_per, size=edges_per // 2)
        srcs.append(np.concatenate([u, v]) + off)
        dsts.append(np.concatenate([v, u]) + off)
        gids.append(np.full(nodes_per, g, np.int32))
    n_used = n_graphs * nodes_per
    feat = np.zeros((n_nodes_pad, d_feat), np.float32)
    feat[:n_used] = synth_features(n_used, d_feat, seed)
    src, dst = _pad_edges(
        np.concatenate(srcs).astype(np.int32),
        np.concatenate(dsts).astype(np.int32),
        n_edges_pad,
        n_nodes_pad,
    )
    gid = np.concatenate(gids + [np.zeros(n_nodes_pad - n_used, np.int32)])
    mask = np.zeros(n_nodes_pad, np.float32)
    mask[:n_used] = 1.0
    return {
        "node_feat": feat,
        "edge_src": src,
        "edge_dst": dst,
        "graph_ids": gid,
        "labels": rng.integers(0, n_classes, size=n_graphs).astype(np.int32),
        "label_mask": mask,
    }


def minibatch_batch(
    cfg: GNNArch,
    graph: Graph,
    features: np.ndarray,
    sampler: NeighborSampler,
    targets: np.ndarray,
    n_nodes_pad: int,
    n_edges_pad: int,
    n_classes: int,
    labels: np.ndarray | None = None,
    seed: int = 0,
) -> dict:
    block = sampler.sample(targets)
    n_blk = len(block.node_ids)
    d_feat = features.shape[1]
    feat = np.zeros((n_nodes_pad, d_feat), np.float32)
    feat[:n_blk] = features[block.node_ids]
    src, dst = _pad_edges(block.edge_src, block.edge_dst, n_edges_pad, n_nodes_pad)
    rng = np.random.default_rng(seed)
    lab = (
        labels[targets]
        if labels is not None
        else rng.integers(0, n_classes, size=len(targets))
    ).astype(np.int32)
    return {
        "node_feat": feat,
        "edge_src": src,
        "edge_dst": dst,
        "labels": lab,
        "target_idx": block.target_idx,
    }


def to_2d_batch(batch: dict, n_true_pad: int, R: int, C: int, max_arcs: int | None = None) -> dict:
    """Convert a flat GNN batch (models/gnn.py format) into the 2-D
    chunk layout consumed by models/gnn2d.py.

    Node arrays stay in vertex order (the chunk layout is the identity
    on contiguous vertex ranges); arcs are re-dealt by the paper's 2-D
    rule, and per-arc payloads follow via ``arc_perm``.
    """
    from repro.graphs.partition import partition_arcs_2d

    n_nodes = batch["node_feat"].shape[0]
    chunk = -(-n_nodes // (R * C))
    n_pad = R * C * chunk
    src, dst = batch["edge_src"], batch["edge_dst"]
    real = (src < n_nodes) & (dst < n_nodes)  # drop flat-format sentinels
    part = partition_arcs_2d(
        src[real].astype(np.int64), dst[real].astype(np.int64), n_pad, R, C,
        max_arcs=max_arcs,
    )

    def pad_nodes(a, fill=0):
        if a.shape[0] == n_pad:
            return a
        widths = [(0, n_pad - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
        return np.pad(a, widths, constant_values=fill)

    out = {
        "node_feat": pad_nodes(batch["node_feat"]),
        "src_local": part.src_local,
        "dst_local": part.dst_local,
    }
    if "target" in batch:
        out["target"] = pad_nodes(batch["target"])
        out["label_mask"] = pad_nodes(
            batch.get("label_mask", np.ones(n_nodes, np.float32))
        )
    if "edge_feat" in batch:
        ef = batch["edge_feat"][real]
        d = ef.shape[1]
        gathered = np.zeros((part.R, part.C, part.src_local.shape[2], d), np.float32)
        valid = part.arc_perm >= 0
        gathered[valid] = ef[part.arc_perm[valid]]
        out["edge_feat"] = gathered
    if "graph_ids" in batch:
        out["graph_ids"] = pad_nodes(batch["graph_ids"], fill=0)
        out["labels"] = batch["labels"]
        out["label_mask"] = pad_nodes(batch["label_mask"])
    elif "labels" in batch and "target" not in batch:
        if "target_idx" in batch:  # minibatch: scatter labels to targets
            labels_full = np.full(n_pad, 0, np.int32)
            mask = np.zeros(n_pad, np.float32)
            labels_full[batch["target_idx"]] = batch["labels"]
            mask[batch["target_idx"]] = 1.0
            out["labels"] = labels_full
            out["label_mask"] = mask
        else:
            out["labels"] = pad_nodes(batch["labels"])
            out["label_mask"] = pad_nodes(batch["label_mask"])
    return out
