"""Synthetic click-log batches for the DLRM cells (deterministic)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import DLRMArch

__all__ = ["ClickLogStream"]


@dataclasses.dataclass
class ClickLogStream:
    cfg: DLRMArch
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        cfg = self.cfg
        dense = rng.standard_normal((self.batch, cfg.n_dense)).astype(np.float32)
        # zipf-ish sparse ids (hot rows dominate, like real logs)
        raw = rng.zipf(1.2, size=(self.batch, cfg.n_sparse, cfg.hot_size))
        sparse = ((raw - 1) % cfg.rows_per_table).astype(np.int32)
        # clickiness correlated with a linear probe of dense features
        p = 1.0 / (1.0 + np.exp(-(dense[:, :4].sum(axis=1))))
        labels = (rng.random(self.batch) < p).astype(np.float32)
        return {"dense": dense, "sparse": sparse, "labels": labels}
