"""Layer-wise fanout neighbor sampler (GraphSAGE-style), host side.

``minibatch_lg`` needs a *real* sampler: given target vertices, sample
``fanout[0]`` neighbors of each, then ``fanout[1]`` of those, etc., and
emit a fixed-shape block (padded with sentinel nodes/edges) matching the
tensor shapes the jitted GNN train step was compiled for.

Duplicates are kept (standard with-replacement sampling) so the shapes
are static: block node count = T·(1 + f0 + f0·f1 + ...) exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph

__all__ = ["SampledBlock", "NeighborSampler", "block_budget"]


def block_budget(batch_nodes: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    """(n_nodes, n_edges) of the fixed-shape sampled block."""
    nodes = batch_nodes
    edges = 0
    frontier = batch_nodes
    for f in fanout:
        edges += frontier * f
        frontier *= f
        nodes += frontier
    return nodes, edges


@dataclasses.dataclass
class SampledBlock:
    node_ids: np.ndarray  # i32 [n_nodes] global ids (may repeat)
    node_feat_rows: np.ndarray  # = node_ids (feature gather happens outside)
    edge_src: np.ndarray  # i32 [n_edges] local indices into node_ids
    edge_dst: np.ndarray  # i32 [n_edges]
    target_idx: np.ndarray  # i32 [batch] local indices of the targets


class NeighborSampler:
    def __init__(self, graph: Graph, fanout: tuple[int, ...], seed: int = 0):
        self.fanout = tuple(fanout)
        self.rng = np.random.default_rng(seed)
        self.row_ptr, self.col = graph.csr()
        self.n = graph.n
        self.deg = (self.row_ptr[1:] - self.row_ptr[:-1]).astype(np.int64)

    def _sample_neighbors(self, vertices: np.ndarray, k: int) -> np.ndarray:
        """[V] -> [V, k] sampled neighbor ids (self-loop for isolated)."""
        deg = self.deg[vertices]
        r = self.rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(vertices), k))
        idx = self.row_ptr[vertices][:, None] + r
        out = self.col[np.minimum(idx, len(self.col) - 1)]
        # isolated vertices self-loop (keeps shapes static, adds no info)
        out = np.where(deg[:, None] > 0, out, vertices[:, None])
        return out.astype(np.int32)

    def sample(self, targets: np.ndarray) -> SampledBlock:
        targets = np.asarray(targets, np.int32)
        nodes = [targets]
        srcs, dsts = [], []
        frontier = targets
        offset = 0
        for f in self.fanout:
            nbrs = self._sample_neighbors(frontier, f)  # [V, f]
            new_offset = offset + len(frontier)
            dst_local = np.repeat(np.arange(offset, new_offset, dtype=np.int32), f)
            src_local = np.arange(
                new_offset, new_offset + nbrs.size, dtype=np.int32
            )
            nodes.append(nbrs.reshape(-1))
            # message flows neighbor -> center
            srcs.append(src_local)
            dsts.append(dst_local)
            frontier = nbrs.reshape(-1)
            offset = new_offset
        node_ids = np.concatenate(nodes)
        return SampledBlock(
            node_ids=node_ids,
            node_feat_rows=node_ids,
            edge_src=np.concatenate(srcs),
            edge_dst=np.concatenate(dsts),
            target_idx=np.arange(len(targets), dtype=np.int32),
        )
