"""Post-SPMD HLO cost parser.

Why not ``compiled.cost_analysis()``: XLA counts ``while`` bodies once,
and every model here wraps layers/levels in scan/fori loops.  This
parser walks the computation graph, multiplies loop bodies by the
``known_trip_count`` XLA records in ``backend_config``, and classifies
collective operands — the three quantities §Roofline needs.

Cost model (per device — the partitioned module has local shapes):
  flops  — dot ops: 2 · numel(out) · contracted-dim product
           (+ matmul-shaped custom-calls, 2-D heuristic)
  bytes  — per fusion/op at computation top level: operand bytes +
           output bytes (post-fusion HLO ⇒ values between instructions
           live in HBM); free ops (tuple/GTE/parameter/bitcast/constant)
           excluded
  colls  — per collective: operand bytes, group size g, class —
           link-byte weighting happens in model.py
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

__all__ = ["analyze_hlo_module"]

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8\w*|pred|s64|s32|s16|s8|u64|u32|u16|u8|c64|c128)\[([\d,]*)\]")

_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
    "all-gather-start", "all-reduce-start", "collective-permute-start",
}

_INSTR_RE = re.compile(r"^\s*(?:ROOT )?%([\w\.\-]+) = (.*)$")
_HEADER_RE = re.compile(r"^(ENTRY )?%?([\w\.\-]+) \((.*)\) -> (.+) \{$")
_PARAM_RE = re.compile(r"([\w\.\-]+): ((?:\([^)]*\))|(?:[\w\[\]{},\/]+))")
_TRIP_RE = re.compile(r'known_trip_count[\\"]*:?\s*{[\\"]*n[\\"]*:[\\"]*(\d+)')
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes_numel(type_str: str) -> tuple[float, float]:
    """Total (bytes, numel) over every array shape in the type string."""
    total_b = 0.0
    total_n = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        numel = 1.0
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total_n += numel
        total_b += numel * _DTYPE_BYTES[dtype]
    return total_b, total_n


def _split_type_and_rest(s: str) -> tuple[str, str]:
    """'f32[2,3]{1,0} dot(%a, %b), ...' -> ('f32[2,3]{1,0}', 'dot(...)...')."""
    s = s.strip()
    if s.startswith("("):
        depth = 0
        for i, ch in enumerate(s):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                return s[: i + 1], s[i + 1 :].strip()
    i = s.find(" ")
    if i < 0:
        return s, ""
    return s[:i], s[i + 1 :].strip()


def _parse_call(rest: str) -> tuple[str, list[str], str]:
    """'dot(%a, %b), attrs' -> ('dot', ['%a','%b'], ', attrs')."""
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return rest.split(",")[0].strip(), [], ""
    opcode = m.group(1)
    depth = 0
    start = m.end() - 1
    for i in range(start, len(rest)):
        depth += rest[i] == "("
        depth -= rest[i] == ")"
        if depth == 0:
            args = rest[start + 1 : i]
            attrs = rest[i + 1 :]
            operands = re.findall(r"%([\w\.\-]+)", args)
            return opcode, operands, attrs
    return opcode, [], ""


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    operands: list[str]
    attrs: str
    line: str


def _parse_computations(text: str):
    comps: dict[str, list[_Instr]] = {}
    params: dict[str, dict[str, str]] = {}
    param_order: dict[str, list[str]] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        h = _HEADER_RE.match(line.strip())
        if h and ("=" not in line.split("(")[0]):
            is_entry, name, paramlist, _ret = h.groups()
            cur = name
            comps[cur] = []
            params[cur] = {}
            param_order[cur] = []
            if is_entry:
                entry = name
            for pname, pshape in _PARAM_RE.findall(paramlist):
                params[cur][pname] = pshape
                param_order[cur].append(pname)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m and cur is not None:
            name, rhs = m.groups()
            type_str, rest = _split_type_and_rest(rhs)
            opcode, operands, attrs = _parse_call(rest)
            comps[cur].append(
                _Instr(name=name, type_str=type_str, opcode=opcode, operands=operands, attrs=attrs, line=line)
            )
    return comps, params, entry, param_order


def _dot_flops(instr: _Instr, symtab: dict[str, str]) -> float:
    _, out_numel = _shape_bytes_numel(instr.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs + instr.line)
    contract = 1.0
    if m and instr.operands:
        lhs_shape = symtab.get(instr.operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * out_numel * contract


def _custom_call_flops(instr: _Instr, symtab: dict[str, str]) -> float:
    if not re.search(r"matmul|dot|gemm", instr.line, re.I):
        return 0.0
    out_b, out_n = _shape_bytes_numel(instr.type_str)
    if len(instr.operands) >= 2:
        _, ln = _shape_bytes_numel(symtab.get(instr.operands[0], ""))
        _, rn = _shape_bytes_numel(symtab.get(instr.operands[1], ""))
        if out_n > 0:
            k = math.sqrt(max(ln * rn / out_n, 1.0))
            return 2.0 * out_n * k
    return 0.0


def _collective_group_size(instr: _Instr, n_partitions_hint: int) -> int:
    m = _GROUPS_BRACKET_RE.search(instr.line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_BRACE_RE.search(instr.line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return max(n_partitions_hint, 1)


_SLICE_OPS = {"dynamic-slice", "gather", "slice"}


def _slice_aware_read_bytes(
    ins: _Instr, symtab: dict[str, str], comps, params, param_order
) -> float:
    """Read traffic of an instruction, counting only the *touched* bytes
    of sliced/gathered operands.

    dynamic-slice/slice/gather read only output-sized data from their
    big operand; dynamic-update-slice reads the update (the buffer is
    updated in place).  For fusions, each fused-computation parameter
    whose every internal use is a slice-like op contributes only those
    slices' bytes (this is what makes scan-over-layers weight reads
    count as per-layer slices instead of whole-stack reads)."""
    op = ins.opcode
    out_b, _ = _shape_bytes_numel(ins.type_str)
    if op in _SLICE_OPS:
        # indices operands are negligible; big operand read = output
        return out_b
    if op == "dynamic-update-slice":
        upd = ins.operands[1] if len(ins.operands) > 1 else None
        upd_b = _shape_bytes_numel(symtab.get(upd, ""))[0] if upd else 0.0
        return upd_b
    if op == "fusion":
        called = re.search(r"calls=%([\w\.\-]+)", ins.line)
        if not called or called.group(1) not in comps:
            return sum(_shape_bytes_numel(symtab.get(o, ""))[0] for o in ins.operands)
        cname = called.group(1)
        order = param_order.get(cname, [])
        uses: dict[str, list[_Instr]] = {p: [] for p in order}
        for sub in comps[cname]:
            for o in sub.operands:
                if o in uses:
                    uses[o].append(sub)
        total = 0.0
        for i, pname in enumerate(order):
            full = (
                _shape_bytes_numel(symtab.get(ins.operands[i], ""))[0]
                if i < len(ins.operands)
                else _shape_bytes_numel(params[cname].get(pname, ""))[0]
            )
            puses = uses.get(pname, [])
            if puses and all(u.opcode in _SLICE_OPS for u in puses):
                total += sum(_shape_bytes_numel(u.type_str)[0] for u in puses)
            elif puses and all(
                u.opcode in _SLICE_OPS or u.opcode == "dynamic-update-slice"
                for u in puses
            ):
                # in-place update pattern: read slices + the update only
                total += sum(
                    _shape_bytes_numel(u.type_str)[0]
                    for u in puses
                    if u.opcode in _SLICE_OPS
                )
            else:
                total += full
        return total
    return sum(_shape_bytes_numel(symtab.get(o, ""))[0] for o in ins.operands)


def _write_bytes(ins: _Instr, symtabs: dict, comps, cur: str) -> float:
    out_b, _ = _shape_bytes_numel(ins.type_str)
    if ins.opcode == "dynamic-update-slice":
        upd = ins.operands[1] if len(ins.operands) > 1 else None
        b = _shape_bytes_numel(symtabs[cur].get(upd, ""))[0] if upd else 0.0
        return b or out_b
    if ins.opcode == "fusion":
        called = re.search(r"calls=%([\w\.\-]+)", ins.line)
        if called and called.group(1) in comps and comps[called.group(1)]:
            cname = called.group(1)
            root = comps[cname][-1]
            if root.opcode == "dynamic-update-slice":
                return _write_bytes(root, symtabs, comps, cname) or out_b
    return out_b


def analyze_hlo_module(text: str, n_partitions_hint: int = 1) -> dict:
    """Returns per-device cost terms (see module docstring)."""
    comps, params, entry, param_order = _parse_computations(text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")

    memo: dict[str, dict] = {}
    symtabs: dict[str, dict[str, str]] = {}
    _instr_index: dict[str, dict[str, _Instr]] = {}
    for cname in comps:
        tab = dict(params.get(cname, {}))
        for ins in comps[cname]:
            tab[ins.name] = ins.type_str
        symtabs[cname] = tab
        _instr_index[cname] = {ins.name: ins for ins in comps[cname]}

    def comp_cost(name: str) -> dict:
        if name in memo:
            return memo[name]
        result = {
            "flops": 0.0,
            "bytes": 0.0,
            # (class, g) -> [operand bytes, instruction-site count]; the
            # count keeps ring-step accounting honest after aggregation
            # (trip-count-multiplied like the bytes — see model.ring_steps)
            "colls": defaultdict(lambda: [0.0, 0]),
            "unknown_trip_whiles": 0,
        }
        memo[name] = result  # pre-insert (cycles impossible, but cheap)
        symtab = symtabs[name]
        instrs = comps.get(name, [])
        for ins in instrs:
            op = ins.opcode
            if op in _FREE_OPS:
                continue
            out_b = _write_bytes(ins, symtabs, comps, name)
            opnd_b = _slice_aware_read_bytes(ins, symtab, comps, params, param_order)
            if op == "while":
                body = re.search(r"body=%([\w\.\-]+)", ins.line)
                cond = re.search(r"condition=%([\w\.\-]+)", ins.line)
                trip_m = _TRIP_RE.search(ins.line)
                trip = int(trip_m.group(1)) if trip_m else 1
                if not trip_m:
                    result["unknown_trip_whiles"] += 1
                for sub in (body, cond):
                    if sub:
                        c = comp_cost(sub.group(1))
                        result["flops"] += trip * c["flops"]
                        result["bytes"] += trip * c["bytes"]
                        for k, v in c["colls"].items():
                            ent = result["colls"][k]
                            ent[0] += trip * v[0]
                            ent[1] += trip * v[1]
                        result["unknown_trip_whiles"] += c["unknown_trip_whiles"]
                continue
            if op == "conditional":
                branches = re.findall(r"%([\w\.\-]+)", ins.attrs)
                sub_costs = [comp_cost(b) for b in branches if b in comps]
                if sub_costs:
                    best = max(sub_costs, key=lambda c: c["flops"] + c["bytes"])
                    result["flops"] += best["flops"]
                    result["bytes"] += best["bytes"]
                    for k, v in best["colls"].items():
                        ent = result["colls"][k]
                        ent[0] += v[0]
                        ent[1] += v[1]
                continue
            if op in _COLLECTIVES:
                g = _collective_group_size(ins, n_partitions_hint)
                cls = op.replace("-start", "")
                # x86 promotes bf16 collectives to f32 (convert fusions
                # feeding the op); TPU moves bf16 on the wire — count
                # the bf16 payload when the operand is a pure upcast.
                link_b = opnd_b
                if ins.operands:
                    prod = _instr_index.get(name, {}).get(ins.operands[0])
                    if prod is not None and "convert" in (prod.opcode + prod.name):
                        _, op_n = _shape_bytes_numel(
                            symtab.get(ins.operands[0], ins.type_str)
                        )
                        srcs = [symtab.get(o2, "") for o2 in prod.operands]
                        called = re.search(r"calls=%([\w\.\-]+)", prod.line)
                        if called and called.group(1) in comps:
                            srcs += [
                                sub.type_str for sub in comps[called.group(1)]
                            ]
                        for st in srcs:
                            m2 = _SHAPE_RE.search(st)
                            _, n2 = _shape_bytes_numel(st)
                            if m2 and m2.group(1) == "bf16" and n2 >= 0.9 * op_n > 0:
                                link_b = opnd_b / 2.0
                                break
                ent = result["colls"][(cls, g)]
                ent[0] += link_b
                ent[1] += 1
                result["bytes"] += opnd_b + out_b  # local HBM touch
                continue
            if op == "fusion":
                called = re.search(r"calls=%([\w\.\-]+)", ins.line)
                if called and called.group(1) in comps:
                    inner = comp_cost(called.group(1))
                    result["flops"] += inner["flops"]  # dots inside fusions
                result["bytes"] += opnd_b + out_b
                continue
            if op == "dot":
                result["flops"] += _dot_flops(ins, symtab)
            elif op == "custom-call":
                result["flops"] += _custom_call_flops(ins, symtab)
            elif op == "call":
                called = re.search(r"to_apply=%([\w\.\-]+)", ins.line)
                if called and called.group(1) in comps:
                    c = comp_cost(called.group(1))
                    result["flops"] += c["flops"]
                    result["bytes"] += c["bytes"]
                    for k, v in c["colls"].items():
                        ent = result["colls"][k]
                        ent[0] += v[0]
                        ent[1] += v[1]
            result["bytes"] += opnd_b + out_b
        return result

    cost = comp_cost(entry)
    colls_flat = defaultdict(float)
    coll_records = []
    for (cls, g), (b, cnt) in cost["colls"].items():
        colls_flat[cls] += b
        coll_records.append(
            {"class": cls, "group_size": g, "operand_bytes": b, "count": cnt}
        )
    return {
        "flops": cost["flops"],
        "bytes": cost["bytes"],
        "collective_operand_bytes": dict(colls_flat),
        "collectives": coll_records,
        "unknown_trip_whiles": cost["unknown_trip_whiles"],
        "bf16_upcast_artifact_bytes": _bf16_upcast_artifacts(comps, params, entry),
    }


def _bf16_upcast_artifacts(comps, params, entry, min_bytes: float = 64e6) -> float:
    """CPU-backend artifact accounting: x86 oneDNN has no bf16 GEMM, so
    XLA materializes f32 shadows of large bf16 loop state / parameters
    that feed dots (e.g. an f32 copy of the entire KV cache).  On the TPU
    target bf16 dot operands are MXU-native and these copies do not
    exist.  Heuristic: for every large bf16 ENTRY parameter whose dims
    also appear as an f32 convert output somewhere, count one f32 shadow
    (2x the bf16 bytes).  Reported separately so the dry-run can show
    both raw and TPU-adjusted peak memory."""
    f32_convert_dims: set[str] = set()
    for name, instrs in comps.items():
        for ins in instrs:
            if ins.opcode != "convert" and "convert" not in ins.name:
                continue
            m = _SHAPE_RE.search(ins.type_str)
            if m and m.group(1) == "f32":
                f32_convert_dims.add(m.group(2))
    total = 0.0
    for pname, pshape in params.get(entry, {}).items():
        m = _SHAPE_RE.search(pshape)
        if not m or m.group(1) != "bf16":
            continue
        b, _ = _shape_bytes_numel(pshape)
        if b >= min_bytes and m.group(2) in f32_convert_dims:
            total += 2.0 * b  # the f32 twin
    return total
