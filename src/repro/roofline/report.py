"""Roofline report: dryrun_all.json -> EXPERIMENTS.md tables.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun_all.json
"""
from __future__ import annotations

import argparse
import json

from repro.roofline.model import roofline_terms

HBM_PER_CHIP = 16 * 2**30  # v5e


def build_rows(records: list[dict]) -> list[dict]:
    rows = []
    for rec in records:
        terms = roofline_terms(
            rec["hlo_terms"],
            n_devices=rec["n_devices"],
            model_flops_total=rec["meta"].get("model_flops", 0.0),
        )
        rows.append(
            {
                "cell": rec["cell"],
                "mesh": rec["mesh"],
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "bottleneck": terms.bottleneck,
                "step_s": terms.step_time_s,
                "useful": terms.useful_fraction,
                "peak_gb": rec["memory"]["tpu_peak_bytes_per_device"] / 2**30,
                "raw_peak_gb": rec["memory"]["peak_bytes_per_device"] / 2**30,
                "analytic_gb": (
                    rec["meta"]["analytic_bytes_global"] / rec["n_devices"] / 2**30
                    if rec["meta"].get("analytic_bytes_global")
                    else None
                ),
                "fits": (
                    rec["meta"]["analytic_bytes_global"] / rec["n_devices"]
                    if rec["meta"].get("analytic_bytes_global")
                    else rec["memory"]["tpu_peak_bytes_per_device"]
                )
                <= HBM_PER_CHIP,
                "flops": rec["hlo_terms"]["flops"],
                "bytes": rec["hlo_terms"]["bytes"],
                "link_bytes": terms.link_bytes,
                "model_flops": rec["meta"].get("model_flops", 0.0),
            }
        )
    return rows


def advice(row: dict) -> str:
    b = row["bottleneck"]
    if b == "compute":
        if row["useful"] < 0.5:
            return "compute-bound with low useful fraction: cut remat recompute / padding waste"
        return "compute-bound near model flops: healthy; only sharding-waste left"
    if b == "memory":
        return "HBM-bound: fuse level ops (Pallas kernels), shrink dtypes, re-tile"
    return "collective-bound: reshard to cut gather/scatter volume or overlap with compute"


def to_markdown(rows: list[dict]) -> str:
    hdr = (
        "| cell | mesh | compute s | memory s | collective s | bottleneck | "
        "useful frac | peak GiB (tpu-adj) | fits 16G |\n|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in sorted(rows, key=lambda r: r["cell"]):
        mem_s = (
            f"{r['peak_gb']:.2f}"
            if r.get("analytic_gb") is None
            else f"{r['analytic_gb']:.2f}ᵃ"
        )
        lines.append(
            f"| {r['cell']} | {r['mesh']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['bottleneck']}** | {r['useful']:.2f} "
            f"| {mem_s} | {'yes' if r['fits'] else 'NO'} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("json_path")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    with open(args.json_path) as f:
        data = json.load(f)
    rows = build_rows(data["records"])
    print(to_markdown(rows))
    # summary
    doms = {}
    for r in rows:
        doms[r["bottleneck"]] = doms.get(r["bottleneck"], 0) + 1
    fits = sum(r["fits"] for r in rows)
    print(f"\n{len(rows)} cells; bottlenecks: {doms}; fit 16G: {fits}/{len(rows)}")


if __name__ == "__main__":
    main()
