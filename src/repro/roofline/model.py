"""TPU v5e roofline model: three terms per (arch × mesh) cell.

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = link_bytes_per_device / ICI_link_bandwidth

Link bytes apply the standard ring-algorithm weights to the collective
operand bytes the HLO parser recorded (g = participant group size):

    all-gather          (g-1)   · operand        (tiled operand = shard)
    reduce-scatter      (g-1)/g · operand
    all-reduce        2·(g-1)/g · operand
    all-to-all          (g-1)/g · operand
    collective-permute            operand
"""
from __future__ import annotations

import dataclasses

__all__ = ["V5E", "RooflineTerms", "roofline_terms", "link_bytes"]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_bf16_flops: float  # per chip
    hbm_bandwidth: float  # bytes/s per chip
    ici_link_bandwidth: float  # bytes/s per link


V5E = HardwareSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    link_bytes: float
    bottleneck: str
    model_flops_total: float
    useful_fraction: float  # MODEL_FLOPS / (HLO flops × devices)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Dominant-term share of the no-overlap ideal (1.0 = the step is
        exactly its dominant roofline term; <1 impossible here — reported
        as dominant/sum to show overlap headroom)."""
        total = self.compute_s + self.memory_s + self.collective_s
        return self.step_time_s / total if total > 0 else 0.0


def link_bytes(coll_records: list[dict]) -> float:
    total = 0.0
    for rec in coll_records:
        g = max(rec.get("group_size", 1), 1)
        b = rec["operand_bytes"]
        cls = rec["class"]
        if cls == "all-gather":
            total += (g - 1) * b
        elif cls == "reduce-scatter":
            total += (g - 1) / g * b
        elif cls == "all-reduce":
            total += 2 * (g - 1) / g * b
        elif cls == "all-to-all":
            total += (g - 1) / g * b
        else:  # collective-permute, broadcast
            total += b
    return total


def roofline_terms(
    hlo_terms: dict,
    n_devices: int,
    model_flops_total: float = 0.0,
    hw: HardwareSpec = V5E,
) -> RooflineTerms:
    """hlo_terms: output of analyze_hlo_module (per-device quantities)."""
    flops = hlo_terms["flops"]
    mem_bytes = hlo_terms["bytes"]
    lb = link_bytes(hlo_terms.get("collectives", []))
    compute_s = flops / hw.peak_bf16_flops
    memory_s = mem_bytes / hw.hbm_bandwidth
    collective_s = lb / hw.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = (
        model_flops_total / (flops * n_devices) if flops > 0 and model_flops_total else 0.0
    )
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        flops=flops,
        bytes=mem_bytes,
        link_bytes=lb,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_fraction=useful,
    )
