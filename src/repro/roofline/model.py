"""TPU v5e roofline model: three terms per (arch × mesh) cell.

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = link_bytes_per_device / ICI_link_bandwidth

Link bytes apply the standard ring-algorithm weights to the collective
operand bytes the HLO parser recorded (g = participant group size):

    all-gather          (g-1)   · operand        (tiled operand = shard)
    reduce-scatter      (g-1)/g · operand
    all-reduce        2·(g-1)/g · operand
    all-to-all          (g-1)/g · operand
    collective-permute            operand

Ring schedules additionally pay a per-step launch latency (α in the
α-β model): every ring hop is a ppermute with its own synchronization,
so a collective decomposed into k steps costs k·α + bytes/β.
``ring_steps`` counts the hops each collective class implies,
``ring_latency_s`` prices them, and ``overlap_step_time`` estimates the
pipelined level time max(T_comm, T_comp) + min(T_comm, T_comp)/k that
the ring-pipelined expand/fold schedule converges to (the barrier
schedule pays T_comm + T_comp).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "V5E",
    "RooflineTerms",
    "roofline_terms",
    "link_bytes",
    "ring_steps",
    "ring_latency_s",
    "overlap_step_time",
    "adjacency_stream_bytes",
    "sparse_tile_bytes",
    "cell_kernel_choice",
    "device_hbm_footprint",
    "auto_overlap_policy",
    "exchange_operands",
    "sampled_run_seconds",
    "TILE_OVERHEAD_BYTES",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_bf16_flops: float  # per chip
    hbm_bandwidth: float  # bytes/s per chip
    ici_link_bandwidth: float  # bytes/s per link
    ici_step_latency_s: float = 1e-6  # per ring-hop launch/sync latency (α)


V5E = HardwareSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    link_bytes: float
    bottleneck: str
    model_flops_total: float
    useful_fraction: float  # MODEL_FLOPS / (HLO flops × devices)
    ring_steps: int = 0  # total ring hops implied by the collectives
    ring_latency_s: float = 0.0  # α term: ring_steps · per-hop latency

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Dominant-term share of the no-overlap ideal (1.0 = the step is
        exactly its dominant roofline term; <1 impossible here — reported
        as dominant/sum to show overlap headroom)."""
        total = self.compute_s + self.memory_s + self.collective_s
        return self.step_time_s / total if total > 0 else 0.0


def link_bytes(coll_records: list[dict]) -> float:
    total = 0.0
    for rec in coll_records:
        g = max(rec.get("group_size", 1), 1)
        b = rec["operand_bytes"]
        cls = rec["class"]
        if cls == "all-gather":
            total += (g - 1) * b
        elif cls == "reduce-scatter":
            total += (g - 1) / g * b
        elif cls == "all-reduce":
            total += 2 * (g - 1) / g * b
        elif cls == "all-to-all":
            total += (g - 1) / g * b
        else:  # collective-permute, broadcast
            total += b
    return total


def ring_steps(coll_records: list[dict]) -> int:
    """Total ring hops the recorded collectives imply (α-model step count).

    A monolithic collective over a group of g devices runs a g-1-hop
    ring internally (2·(g-1) for all-reduce = reduce-scatter +
    all-gather); an explicit collective-permute IS one hop.  Records
    carry a ``count`` when they aggregate several instruction sites
    (roofline/hlo.py multiplies it by loop trip counts).  Comparing
    this count between the barrier and pipelined lowerings of the same
    level shows the latency-term price of the overlap schedule.
    """
    total = 0
    for rec in coll_records:
        g = max(rec.get("group_size", 1), 1)
        sites = max(rec.get("count", 1), 1)
        cls = rec["class"]
        if cls == "all-reduce":
            total += sites * 2 * (g - 1)
        elif cls in ("all-gather", "reduce-scatter", "all-to-all"):
            total += sites * (g - 1)
        else:  # collective-permute, broadcast: a single hop each
            total += sites
    return total


def ring_latency_s(coll_records: list[dict], hw: HardwareSpec = V5E) -> float:
    """α term: per-hop launch latency summed over every implied ring hop."""
    return ring_steps(coll_records) * hw.ici_step_latency_s


def overlap_step_time(compute_s: float, collective_s: float, k: int) -> float:
    """Pipelined level-time estimate for a k-step ring schedule.

    The barrier schedule pays compute + collective in sequence.  A ring
    schedule splits both into k per-chunk slices and overlaps slice i's
    transfer with slice i-1's compute, so only the first (or last) slice
    of the minor term is exposed:

        max(T_comp, T_comm) + min(T_comp, T_comm) / k
    """
    if k <= 1:
        return compute_s + collective_s
    lo, hi = sorted((compute_s, collective_s))
    return hi + lo / k


def sampled_run_seconds(num_rounds: int, fr: int, round_s: float) -> float:
    """Wall estimate of a (sampled) run: dispatch blocks × per-round wall.

    The sampled-cost bridge between the per-round prior
    (:func:`repro.core.distributed.prior_round_seconds`) and the serving
    layer: a k-root sample schedules ``ceil(k / batch)`` rounds dealt
    ``fr`` per dispatch block, so its cost is the block count times the
    same per-round prior the straggler EWMA is seeded from — which is
    what ``launch/serve_bc.py`` uses to budget refresh slices and what
    the entrypoints log as the expected sampled-run wall.
    """
    if num_rounds <= 0:
        return 0.0
    blocks = -(-int(num_rounds) // max(1, int(fr)))  # ceil division
    return blocks * float(round_s)


# ---------------------------------------------------------------------------
# Per-engine adjacency model for the 2-D distributed path.  The roofline
# historically priced the A-stream dense — O(n_pad²/p) per device per
# level — which is wrong by orders of magnitude for the blocked-sparse
# engine on RMAT-scale graphs; ``adjacency_stream_bytes`` is the
# per-engine quantity (dense block, arc list, or nnz-tile list) used by
# both the memory guard and the sparse benchmark record.
# ---------------------------------------------------------------------------

#: payload tensors per exchanged direction: the arc-list engine ships a
#: single pre-masked tensor; the fused Pallas engines (dense-block,
#: blocked-sparse, and the per-cell hybrid of the two) ship (σ, d)
#: forward and (σ, d, δ, ω) backward (paper §3.2 exchange set).
_EXCHANGE_OPERANDS = {
    "sparse": (1, 1),
    "pallas": (2, 4),
    "pallas_bf16": (2, 4),
    "pallas_sparse": (2, 4),
    "pallas_hybrid": (2, 4),
}

#: per-stored-tile scalar-prefetch/grid-step overhead allowance of the
#: blocked-sparse kernels, in equivalent HBM bytes: the 8 B row/col
#: index maps each tile DMAs plus a flat allowance for the per-grid-step
#: control cost (index-map evaluation, accumulator init/flush bookkeeping)
#: that the dense kernels amortize over whole 128-blocks.  Used only by
#: the per-cell dense-vs-BCSR choice (:func:`cell_kernel_choice`) — the
#: memory guard prices the stored bytes (:func:`sparse_tile_bytes`)
#: without the allowance.
TILE_OVERHEAD_BYTES = 32.0


def sparse_tile_bytes(bm: int, bk: int, elem: int = 4) -> int:
    """Stored bytes of one blocked-sparse tile: data + 8 B index maps."""
    return bm * bk * elem + 8


def cell_kernel_choice(
    stored_tiles_cell: np.ndarray,
    *,
    R: int,
    C: int,
    chunk: int,
    bm: int,
    bk: int,
    threshold: float = 1.0,
    elem: int = 4,
    measured: tuple[float, float] | None = None,
) -> np.ndarray:
    """Per-device-cell dense-vs-BCSR kernel pick (bool [R, C], True = dense).

    On skewed (RMAT-like) graphs the 2-D decomposition hands each device
    a block whose density varies wildly across the mesh — the
    community-structured cells are near-dense while the off-diagonal
    cells are hyper-sparse — so a single global engine choice always
    wastes either HBM bandwidth (dense streaming of near-empty blocks)
    or tile-index overhead (BCSR streaming of near-full blocks).  This
    prices what each cell actually streams per traversal level:

        dense:  (C·chunk)·(R·chunk)·elem          — the cell's n_pad²/p share
        BCSR:   stored · (bm·bk·elem + 8 + TILE_OVERHEAD_BYTES)

    and picks dense where ``bcsr >= threshold · dense``.
    ``stored_tiles_cell`` is the per-cell *stored* tile count (true
    nonzero tiles + row-complete fillers —
    ``TwoDPartition.blocked_sparse_counts()["stored_full_cell"]``), the
    count the kernel's grid actually iterates.  ``threshold`` is the
    ``--hybrid-threshold`` knob: 0 forces every cell dense, a huge value
    forces every cell sparse, 1.0 is the break-even default.

    ``measured`` replaces the bytes model with a measured calibration
    pair ``(dense_level_s, sparse_level_s)`` from the autotune cache
    (:mod:`repro.autotune`): the pure-dense per-level wall prices every
    cell's dense cost, the pure-BCSR wall divided by the total stored
    tiles prices one tile, and a cell goes dense where
    ``stored · per_tile_s >= threshold · dense_level_s`` — same
    break-even rule, measured seconds instead of modelled bytes.
    """
    stored = np.asarray(stored_tiles_cell, np.float64)
    if stored.shape != (R, C):
        raise ValueError(f"stored_tiles_cell shape {stored.shape} != {(R, C)}")
    if measured is not None:
        dense_level_s, sparse_level_s = (float(x) for x in measured)
        per_tile_s = sparse_level_s / max(float(stored.max()), 1.0)
        return stored * per_tile_s >= threshold * dense_level_s
    dense_bytes = float(C * chunk) * (R * chunk) * elem
    bcsr_bytes = stored * (sparse_tile_bytes(bm, bk, elem) + TILE_OVERHEAD_BYTES)
    return bcsr_bytes >= threshold * dense_bytes


def exchange_operands(engine_kind: str) -> tuple[int, int]:
    """(forward, backward) per-level exchange-operand counts of an engine.

    The single source of the §3.2 exchange-set table above: the arc-list
    engine gathers one pre-masked tensor per direction; the fused-kernel
    engines exchange (σ, d) forward and (σ, d, δ, ω) backward.  Consumed
    by the state-footprint model here and the per-level collective
    pricing in :func:`repro.core.distributed.level_time_estimates`.
    """
    return _EXCHANGE_OPERANDS[engine_kind]


def adjacency_stream_bytes(
    engine_kind: str,
    *,
    R: int,
    C: int,
    chunk: int,
    nnz_tiles: int | None = None,
    bm: int | None = None,
    bk: int | None = None,
    max_arcs: int | None = None,
) -> float:
    """Per-device A-stream bytes of one traversal level.

    dense Pallas engines   (C·chunk)·(R·chunk)·elem   — the full block
    blocked-sparse engine  nnz_tiles·bm·bk·elem + index maps
    arc-list engine        2·max_arcs·4               — (src, dst) i32
    hybrid engine          dense block + the sparse tile list — the
                           *resident* union the mixed layout ships with
                           shard_map-uniform shapes (the guard's
                           quantity); what one cell actually streams per
                           level is its chosen representation
                           (:func:`cell_kernel_choice`), priced per cell
                           in ``repro.core.distributed.level_time_estimates``.

    ``nnz_tiles`` is whatever tile count the caller wants priced: the
    true nonzero count for a best-case stream model, or the layout's
    *stored* count (fillers + padding + ring slots,
    ``TwoDPartition.blocked_sparse_counts``) for the bytes actually
    allocated/streamed — the memory guard passes the latter (for the
    hybrid engine: the sparse-chosen cells' masked counts, so the guard
    prices the actually-shipped mixed layout).
    """
    if engine_kind in ("pallas", "pallas_bf16"):
        elem = 2 if engine_kind == "pallas_bf16" else 4
        return float(C * chunk) * (R * chunk) * elem
    if engine_kind in ("pallas_sparse", "pallas_hybrid"):
        if None in (nnz_tiles, bm, bk):
            raise ValueError(f"{engine_kind} needs nnz_tiles, bm, bk")
        tiles = float(nnz_tiles) * sparse_tile_bytes(bm, bk)
        if engine_kind == "pallas_sparse":
            return tiles
        # dense-block operand + sparse tile list + the i32 cell choice
        return float(C * chunk) * (R * chunk) * 4 + tiles + 4
    if engine_kind == "sparse":
        if max_arcs is None:
            raise ValueError("sparse needs max_arcs")
        return float(2 * max_arcs) * 4
    raise ValueError(f"unknown distributed engine {engine_kind!r}")


def device_hbm_footprint(
    engine_kind: str,
    *,
    R: int,
    C: int,
    chunk: int,
    batch_size: int,
    nnz_tiles: int | None = None,
    bm: int | None = None,
    bk: int | None = None,
    max_arcs: int | None = None,
) -> dict:
    """Per-device HBM footprint (bytes) of one distributed BC round.

    ``adjacency``: the resident graph operand (engine-dependent — the
    quantity that decides dense-vs-sparse feasibility).  ``state``: owned
    (σ, δ f32 + d i32 + ω, bc f32) columns, the worst-case gathered
    operand slice ([R·chunk, s] × exchanged tensors), and the [C·chunk, s]
    fold partial.  An estimate for fail-fast guarding — XLA temp buffers
    add a constant factor, but the dense-block OOM this guard exists to
    catch is orders of magnitude, not percent.
    """
    s = batch_size
    adjacency = adjacency_stream_bytes(
        engine_kind,
        R=R,
        C=C,
        chunk=chunk,
        nnz_tiles=nnz_tiles,
        bm=bm,
        bk=bk,
        max_arcs=max_arcs,
    )
    _, bwd_operands = _EXCHANGE_OPERANDS[engine_kind]
    state = (
        3 * chunk * s * 4  # owned σ, d, δ
        + 2 * chunk * 4  # ω, bc accumulator
        + bwd_operands * R * chunk * s * 4  # gathered operand slice (worst: bwd)
        + C * chunk * s * 4  # pre-fold partial
    )
    return {
        "engine_kind": engine_kind,
        "adjacency_bytes": float(adjacency),
        "state_bytes": float(state),
        "total_bytes": float(adjacency + state),
    }


def auto_overlap_policy(
    compute_s: float,
    expand_s: float,
    fold_s: float,
    R: int,
    C: int,
    hw: HardwareSpec = V5E,
    measured: dict | None = None,
) -> tuple[str, dict]:
    """Pick the ring policy from the ``overlap_step_time`` estimate.

    Prices one traversal level under the three schedules — barrier
    (compute + both collectives in sequence), ``expand`` (gather
    pipelined into R hops, fold still a barrier), ``expand+fold`` (both
    collectives ring-decomposed) — each ring hop paying the α launch
    latency on top of the pipelined β term.  Returns the winning policy
    and the per-policy estimates (logged by the caller so the choice is
    auditable and overridable).

    ``measured`` maps policy -> measured per-level seconds from the
    autotune cache (:mod:`repro.autotune`).  When any policy has a
    measurement, the pick compares *measured policies only* (measured
    walls and model seconds are not on the same scale) and the returned
    estimates dict carries the measured values in place of the modelled
    ones, so the caller's audit log shows what the choice actually
    compared.
    """
    alpha = hw.ici_step_latency_s
    estimates = {
        "none": compute_s + expand_s + fold_s,
        "expand": overlap_step_time(compute_s, expand_s, R)
        + fold_s
        + (R - 1) * alpha,
        "expand+fold": overlap_step_time(compute_s, expand_s + fold_s, R)
        + (R - 1 + C - 1) * alpha,
    }
    if measured:
        known = {
            p: float(s) for p, s in measured.items()
            if p in estimates and s is not None
        }
        if known:
            estimates.update(known)
            return min(known, key=known.get), estimates
    return min(estimates, key=estimates.get), estimates


def roofline_terms(
    hlo_terms: dict,
    n_devices: int,
    model_flops_total: float = 0.0,
    hw: HardwareSpec = V5E,
) -> RooflineTerms:
    """hlo_terms: output of analyze_hlo_module (per-device quantities)."""
    flops = hlo_terms["flops"]
    mem_bytes = hlo_terms["bytes"]
    colls = hlo_terms.get("collectives", [])
    lb = link_bytes(colls)
    steps = ring_steps(colls)
    compute_s = flops / hw.peak_bf16_flops
    memory_s = mem_bytes / hw.hbm_bandwidth
    collective_s = lb / hw.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = (
        model_flops_total / (flops * n_devices) if flops > 0 and model_flops_total else 0.0
    )
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        flops=flops,
        bytes=mem_bytes,
        link_bytes=lb,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_fraction=useful,
        ring_steps=steps,
        ring_latency_s=steps * hw.ici_step_latency_s,
    )
