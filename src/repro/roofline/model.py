"""TPU v5e roofline model: three terms per (arch × mesh) cell.

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bandwidth
    collective = link_bytes_per_device / ICI_link_bandwidth

Link bytes apply the standard ring-algorithm weights to the collective
operand bytes the HLO parser recorded (g = participant group size):

    all-gather          (g-1)   · operand        (tiled operand = shard)
    reduce-scatter      (g-1)/g · operand
    all-reduce        2·(g-1)/g · operand
    all-to-all          (g-1)/g · operand
    collective-permute            operand

Ring schedules additionally pay a per-step launch latency (α in the
α-β model): every ring hop is a ppermute with its own synchronization,
so a collective decomposed into k steps costs k·α + bytes/β.
``ring_steps`` counts the hops each collective class implies,
``ring_latency_s`` prices them, and ``overlap_step_time`` estimates the
pipelined level time max(T_comm, T_comp) + min(T_comm, T_comp)/k that
the ring-pipelined expand/fold schedule converges to (the barrier
schedule pays T_comm + T_comp).
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "V5E",
    "RooflineTerms",
    "roofline_terms",
    "link_bytes",
    "ring_steps",
    "ring_latency_s",
    "overlap_step_time",
]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_bf16_flops: float  # per chip
    hbm_bandwidth: float  # bytes/s per chip
    ici_link_bandwidth: float  # bytes/s per link
    ici_step_latency_s: float = 1e-6  # per ring-hop launch/sync latency (α)


V5E = HardwareSpec(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes: float
    link_bytes: float
    bottleneck: str
    model_flops_total: float
    useful_fraction: float  # MODEL_FLOPS / (HLO flops × devices)
    ring_steps: int = 0  # total ring hops implied by the collectives
    ring_latency_s: float = 0.0  # α term: ring_steps · per-hop latency

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Dominant-term share of the no-overlap ideal (1.0 = the step is
        exactly its dominant roofline term; <1 impossible here — reported
        as dominant/sum to show overlap headroom)."""
        total = self.compute_s + self.memory_s + self.collective_s
        return self.step_time_s / total if total > 0 else 0.0


def link_bytes(coll_records: list[dict]) -> float:
    total = 0.0
    for rec in coll_records:
        g = max(rec.get("group_size", 1), 1)
        b = rec["operand_bytes"]
        cls = rec["class"]
        if cls == "all-gather":
            total += (g - 1) * b
        elif cls == "reduce-scatter":
            total += (g - 1) / g * b
        elif cls == "all-reduce":
            total += 2 * (g - 1) / g * b
        elif cls == "all-to-all":
            total += (g - 1) / g * b
        else:  # collective-permute, broadcast
            total += b
    return total


def ring_steps(coll_records: list[dict]) -> int:
    """Total ring hops the recorded collectives imply (α-model step count).

    A monolithic collective over a group of g devices runs a g-1-hop
    ring internally (2·(g-1) for all-reduce = reduce-scatter +
    all-gather); an explicit collective-permute IS one hop.  Records
    carry a ``count`` when they aggregate several instruction sites
    (roofline/hlo.py multiplies it by loop trip counts).  Comparing
    this count between the barrier and pipelined lowerings of the same
    level shows the latency-term price of the overlap schedule.
    """
    total = 0
    for rec in coll_records:
        g = max(rec.get("group_size", 1), 1)
        sites = max(rec.get("count", 1), 1)
        cls = rec["class"]
        if cls == "all-reduce":
            total += sites * 2 * (g - 1)
        elif cls in ("all-gather", "reduce-scatter", "all-to-all"):
            total += sites * (g - 1)
        else:  # collective-permute, broadcast: a single hop each
            total += sites
    return total


def ring_latency_s(coll_records: list[dict], hw: HardwareSpec = V5E) -> float:
    """α term: per-hop launch latency summed over every implied ring hop."""
    return ring_steps(coll_records) * hw.ici_step_latency_s


def overlap_step_time(compute_s: float, collective_s: float, k: int) -> float:
    """Pipelined level-time estimate for a k-step ring schedule.

    The barrier schedule pays compute + collective in sequence.  A ring
    schedule splits both into k per-chunk slices and overlaps slice i's
    transfer with slice i-1's compute, so only the first (or last) slice
    of the minor term is exposed:

        max(T_comp, T_comm) + min(T_comp, T_comm) / k
    """
    if k <= 1:
        return compute_s + collective_s
    lo, hi = sorted((compute_s, collective_s))
    return hi + lo / k


def roofline_terms(
    hlo_terms: dict,
    n_devices: int,
    model_flops_total: float = 0.0,
    hw: HardwareSpec = V5E,
) -> RooflineTerms:
    """hlo_terms: output of analyze_hlo_module (per-device quantities)."""
    flops = hlo_terms["flops"]
    mem_bytes = hlo_terms["bytes"]
    colls = hlo_terms.get("collectives", [])
    lb = link_bytes(colls)
    steps = ring_steps(colls)
    compute_s = flops / hw.peak_bf16_flops
    memory_s = mem_bytes / hw.hbm_bandwidth
    collective_s = lb / hw.ici_link_bandwidth
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = (
        model_flops_total / (flops * n_devices) if flops > 0 and model_flops_total else 0.0
    )
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        flops=flops,
        bytes=mem_bytes,
        link_bytes=lb,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_fraction=useful,
        ring_steps=steps,
        ring_latency_s=steps * hw.ici_step_latency_s,
    )
