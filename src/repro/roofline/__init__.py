"""Roofline analysis from compiled HLO (no hardware required).

hlo.py   — parses ``compiled.as_text()`` (post-SPMD, local shapes):
           dot FLOPs, HBM bytes, collective bytes — multiplying loop
           bodies by XLA's recorded ``known_trip_count`` (XLA's own
           cost_analysis counts while bodies once; see DESIGN.md §7).
model.py — TPU v5e constants + the three roofline terms.
"""
from repro.roofline.hlo import analyze_hlo_module
from repro.roofline.model import RooflineTerms, roofline_terms, V5E

__all__ = ["analyze_hlo_module", "roofline_terms", "RooflineTerms", "V5E"]
