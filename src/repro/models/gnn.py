"""Graph neural networks on the segment-sum message-passing substrate.

All four assigned GNN archs (graphcast, gat-cora, gin-tu, meshgraphnet)
share one edge-list substrate: messages are gathered from ``x[src]``,
optionally combined with edge features, and scatter-reduced to ``dst``
with ``jax.ops.segment_sum`` / ``segment_max`` — exactly the paper's
SpMM traversal structure (DESIGN.md §5), so the distributed layout is
the MGBC one: edge arrays sharded over the flattened mesh, node states
sharded by owner chunk, accumulations psum'd by XLA.

Input batch format (see data/graphs.py and launch/dryrun.py):
  node_feat [N, d_feat] f32   edge_src/edge_dst [E] i32 (sentinel N = pad)
  full_graph:     labels [N] i32, label_mask [N] f32
  minibatch:      labels [T] i32, target_idx [T] i32
  batched_graphs: graph_ids [N] i32, labels [G] i32
  regression (graphcast/meshgraphnet): target [N, d_out] f32
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import GNNArch
from repro.distributed.sharding import constrain
from repro.models.layers import dense_init

__all__ = ["param_specs", "init_params", "gnn_forward", "gnn_loss", "output_dim"]

PyTree = Any
MESH_AXES = ("data", "model")  # flattened over both for edge/node arrays


def _mlp_shapes(dims: tuple[int, ...]) -> list[tuple[int, int]]:
    return list(zip(dims[:-1], dims[1:]))


def output_dim(cfg: GNNArch, shape) -> int:
    if cfg.kind in ("graphcast", "meshgraphnet"):
        return cfg.n_vars if cfg.kind == "graphcast" else 3
    return shape.n_classes


def _arch_dims(cfg: GNNArch, d_feat: int, d_out: int):
    d = cfg.d_hidden * (cfg.n_heads if cfg.kind == "gat" else 1)
    return d


def param_specs(cfg: GNNArch, d_feat: int, d_out: int) -> PyTree:
    return jax.eval_shape(
        lambda: init_params(cfg, d_feat, d_out, jax.random.PRNGKey(0), abstract=True)
    )


def init_params(cfg: GNNArch, d_feat: int, d_out: int, key, abstract: bool = False):
    """Parameter tree; ``abstract`` skips RNG (ShapeDtypeStruct source)."""
    d = _arch_dims(cfg, d_feat, d_out)
    L = cfg.n_layers
    idx = [0]

    def mk(shape, in_axis=-2):
        if abstract:
            return jnp.zeros(shape, jnp.float32)
        idx[0] += 1
        return dense_init(jax.random.fold_in(key, idx[0]), shape, in_axis=in_axis)

    params: dict[str, Any] = {
        "enc_w": mk((d_feat, d)),
        "enc_b": jnp.zeros((d,), jnp.float32),
        "dec_w": mk((d, d_out)),
        "dec_b": jnp.zeros((d_out,), jnp.float32),
    }
    if cfg.kind == "gat":
        dh, H = cfg.d_hidden, cfg.n_heads
        params["layers"] = {
            "w": mk((L, d, H, dh)),
            "a_src": mk((L, H, dh), in_axis=-1),
            "a_dst": mk((L, H, dh), in_axis=-1),
        }
    elif cfg.kind == "gin":
        params["layers"] = {
            "eps": jnp.zeros((L,), jnp.float32),
            "w1": mk((L, d, d)),
            "b1": jnp.zeros((L, d), jnp.float32),
            "w2": mk((L, d, d)),
            "b2": jnp.zeros((L, d), jnp.float32),
        }
    elif cfg.kind == "meshgraphnet":
        params["edge_enc_w"] = mk((d_feat, d))  # edge features same width
        params["edge_enc_b"] = jnp.zeros((d,), jnp.float32)
        params["layers"] = {
            "we1": mk((L, 3 * d, d)),
            "be1": jnp.zeros((L, d), jnp.float32),
            "we2": mk((L, d, d)),
            "be2": jnp.zeros((L, d), jnp.float32),
            "wn1": mk((L, 2 * d, d)),
            "bn1": jnp.zeros((L, d), jnp.float32),
            "wn2": mk((L, d, d)),
            "bn2": jnp.zeros((L, d), jnp.float32),
        }
    else:  # graphcast: interaction-network processor (node messages)
        params["layers"] = {
            "wm1": mk((L, 2 * d, d)),
            "bm1": jnp.zeros((L, d), jnp.float32),
            "wm2": mk((L, d, d)),
            "bm2": jnp.zeros((L, d), jnp.float32),
            "wu1": mk((L, 2 * d, d)),
            "bu1": jnp.zeros((L, d), jnp.float32),
            "wu2": mk((L, d, d)),
            "bu2": jnp.zeros((L, d), jnp.float32),
        }
    return params


def _seg_sum(msgs, dst, n):
    return jax.ops.segment_sum(msgs, dst, num_segments=n)


def gnn_forward(cfg: GNNArch, params, batch) -> jnp.ndarray:
    """Returns per-node outputs [N, d_out]."""
    x = batch["node_feat"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    n = x.shape[0] + 1  # +1 sentinel row for padding arcs
    x = constrain(x, (MESH_AXES,), None)

    h = jnp.tanh(x @ params["enc_w"] + params["enc_b"])

    def pad(z):  # sentinel row
        return jnp.concatenate([z, jnp.zeros((1,) + z.shape[1:], z.dtype)], axis=0)

    def shard_nodes(z):
        return constrain(z, (MESH_AXES,), *([None] * (z.ndim - 1)))

    def shard_edges(z):
        return constrain(z, (MESH_AXES,), *([None] * (z.ndim - 1)))

    remat = jax.checkpoint  # full recompute in backward: node states only

    if cfg.kind == "gat":
        @remat
        def layer(h, lp):
            h = shard_nodes(h)
            hw = jnp.einsum("nd,dhk->nhk", h, lp["w"])  # [N, H, dh]
            hp = pad(hw)
            e_src = (hp[src] * lp["a_src"]).sum(-1)  # [E, H]
            e_dst = (hp[dst] * lp["a_dst"]).sum(-1)
            logit = jax.nn.leaky_relu(e_src + e_dst, 0.2)
            # segment softmax over incoming edges of dst
            mx = jax.ops.segment_max(logit, dst, num_segments=n)
            mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
            ex = jnp.exp(logit - mx[dst])
            denom = _seg_sum(ex, dst, n)
            alpha = ex / jnp.maximum(denom[dst], 1e-9)  # [E, H]
            msgs = shard_edges(hp[src] * alpha[..., None])  # [E, H, dh]
            agg = _seg_sum(msgs, dst, n)[:-1]  # [N, H, dh]
            return shard_nodes(jax.nn.elu(agg.reshape(h.shape[0], -1))), None

        h, _ = jax.lax.scan(layer, h, params["layers"])
    elif cfg.kind == "gin":
        @remat
        def layer(h, lp):
            h = shard_nodes(h)
            agg = _seg_sum(shard_edges(pad(h)[src]), dst, n)[:-1]
            z = (1.0 + lp["eps"]) * h + agg
            z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
            z = jax.nn.relu(z @ lp["w2"] + lp["b2"])
            return shard_nodes(z), None

        h, _ = jax.lax.scan(layer, h, params["layers"])
    elif cfg.kind == "meshgraphnet":
        e = jnp.tanh(batch["edge_feat"] @ params["edge_enc_w"] + params["edge_enc_b"])

        @remat
        def layer(carry, lp):
            h, e = carry
            h, e = shard_nodes(h), shard_edges(e)
            hp = pad(h)
            cat = shard_edges(jnp.concatenate([e, hp[src], hp[dst]], axis=-1))
            e2 = jax.nn.relu(cat @ lp["we1"] + lp["be1"]) @ lp["we2"] + lp["be2"]
            e = e + e2  # residual edge update
            agg = _seg_sum(e, dst, n)[:-1]
            cat_n = jnp.concatenate([h, agg], axis=-1)
            h2 = jax.nn.relu(cat_n @ lp["wn1"] + lp["bn1"]) @ lp["wn2"] + lp["bn2"]
            return (shard_nodes(h + h2), e), None

        (h, _), _ = jax.lax.scan(layer, (h, e), params["layers"])
    else:  # graphcast
        @remat
        def layer(h, lp):
            h = shard_nodes(h)
            hp = pad(h)
            cat = shard_edges(jnp.concatenate([hp[src], hp[dst]], axis=-1))
            m = jax.nn.relu(cat @ lp["wm1"] + lp["bm1"]) @ lp["wm2"] + lp["bm2"]
            agg = _seg_sum(m, dst, n)[:-1]
            cat_n = jnp.concatenate([h, agg], axis=-1)
            u = jax.nn.relu(cat_n @ lp["wu1"] + lp["bu1"]) @ lp["wu2"] + lp["bu2"]
            return shard_nodes(h + u), None

        h, _ = jax.lax.scan(layer, h, params["layers"])

    h = constrain(h, (MESH_AXES,), None)
    return h @ params["dec_w"] + params["dec_b"]


def gnn_loss(cfg: GNNArch, params, batch, shape_kind: str):
    out = gnn_forward(cfg, params, batch)  # [N, d_out]
    node_mask = batch.get("label_mask")
    if cfg.kind in ("graphcast", "meshgraphnet"):
        err = (out - batch["target"]).astype(jnp.float32)
        if node_mask is not None:
            sse = jnp.sum(jnp.square(err) * node_mask[:, None])
            cnt = jnp.maximum(node_mask.sum() * out.shape[1], 1.0)
            loss = sse / cnt
        else:
            loss = jnp.mean(jnp.square(err))
        return loss, {"mse": loss}
    if shape_kind == "batched_graphs":
        n_graphs = batch["labels"].shape[0]
        masked = out * node_mask[:, None] if node_mask is not None else out
        pooled = jax.ops.segment_sum(masked, batch["graph_ids"], num_segments=n_graphs)
        logits = pooled.astype(jnp.float32)
        labels = batch["labels"]
        mask = jnp.ones((n_graphs,), jnp.float32)
    elif shape_kind == "minibatch":
        logits = out[batch["target_idx"]].astype(jnp.float32)
        labels = batch["labels"]
        mask = jnp.ones_like(labels, jnp.float32)
    else:  # full_graph
        logits = out.astype(jnp.float32)
        labels = batch["labels"]
        mask = batch["label_mask"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss, {"ce": loss}
