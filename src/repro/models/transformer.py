"""Decoder-only transformer LM (dense or MoE) — pure functions.

Design points for the 256–512-chip cells:
  * layers are stacked on a leading L axis and executed with
    ``lax.scan`` (+ per-layer ``jax.checkpoint``): small HLO, fast SPMD
    partitioning, ``known_trip_count`` for the roofline parser;
  * attention is q-chunked (models/attention.py) so no [S, S] score
    tensor ever materializes;
  * the CE loss is sequence-chunked so the f32 [B, S, V] logits tensor
    never materializes (vocab up to 202k);
  * logits use the tied embedding transpose;
  * sharding: weights/activations carry logical constraints via
    ``distributed.constrain`` — "data" = batch, "model" = TP axis.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LMArch
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models.layers import ACTIVATIONS, dense_init, rms_norm, rope
from repro.models.moe import moe_ffn

__all__ = [
    "param_specs",
    "init_params",
    "param_partition_specs",
    "lm_loss",
    "prefill",
    "decode_step",
    "cache_specs",
]

PyTree = Any


def padded_vocab(cfg: LMArch) -> int:
    """Vocab rounded to 256 so the embedding shards on any mesh axis
    (MaxText-style padding; pad ids are never produced by the tokenizer)."""
    return cfg.vocab + (-cfg.vocab) % 256


def _layer_shapes(cfg: LMArch) -> dict[str, tuple[tuple[int, ...], Any]]:
    d, hhd, khd = cfg.d_model, cfg.n_heads * cfg.head_dim, cfg.n_kv_heads * cfg.head_dim
    L = cfg.n_layers
    shapes = {
        "ln1": ((L, d), jnp.float32),
        "ln2": ((L, d), jnp.float32),
        "wq": ((L, d, hhd), jnp.bfloat16),
        "wk": ((L, d, khd), jnp.bfloat16),
        "wv": ((L, d, khd), jnp.bfloat16),
        "wo": ((L, hhd, d), jnp.bfloat16),
    }
    if cfg.moe is None:
        shapes["wi"] = ((L, d, 2 * cfg.d_ff), jnp.bfloat16)
        shapes["wo_mlp"] = ((L, cfg.d_ff, d), jnp.bfloat16)
    else:
        m = cfg.moe
        shapes["router"] = ((L, d, m.num_experts), jnp.float32)
        shapes["wi_e"] = ((L, m.num_experts, d, 2 * m.d_ff), jnp.bfloat16)
        shapes["wo_e"] = ((L, m.num_experts, m.d_ff, d), jnp.bfloat16)
    return shapes


def param_specs(cfg: LMArch) -> PyTree:
    """ShapeDtypeStruct tree (dry-run input)."""
    specs = {
        "embed": jax.ShapeDtypeStruct((padded_vocab(cfg), cfg.d_model), jnp.bfloat16),
        "ln_f": jax.ShapeDtypeStruct((cfg.d_model,), jnp.float32),
        "layers": {
            k: jax.ShapeDtypeStruct(shape, dt)
            for k, (shape, dt) in _layer_shapes(cfg).items()
        },
    }
    return specs


def param_partition_specs(cfg: LMArch) -> PyTree:
    """Logical PartitionSpecs per parameter (filtered by mesh later).

    2-D "fully sharded" layout: every big tensor shards its output
    feature dim over "model" and its input dim over "data" (ZeRO-3-ish),
    so per-chip bytes scale 1/(data*model).
    """
    from jax.sharding import PartitionSpec as P
    specs = {
        "embed": P("model", "data"),
        "ln_f": P(None),
        "layers": {
            "ln1": P(None, None),
            "ln2": P(None, None),
            # Megatron TP: column-parallel qkv, row-parallel o; weights
            # replicated over "data" (dense attn weights are small — the
            # §Perf iteration log shows why ZeRO-sharding them over
            # "data" forced 1.25 GiB activation regathers per site)
            "wq": P(None, None, "model"),
            "wk": P(None, None, "model"),
            "wv": P(None, None, "model"),
            "wo": P(None, "model", None),
        },
    }
    if cfg.moe is None:
        specs["layers"]["wi"] = P(None, None, "model")
        specs["layers"]["wo_mlp"] = P(None, "model", None)
    else:
        specs["layers"]["router"] = P(None, None, None)
        # experts resident: E over "model", ffn dim over "data" (TP
        # within expert) — no weight gathering, dispatch via a2a
        specs["layers"]["wi_e"] = P(None, "model", None, "data")
        specs["layers"]["wo_e"] = P(None, "model", "data", None)
    return specs


def init_params(cfg: LMArch, key) -> PyTree:
    keys = jax.random.split(key, 16)
    shapes = _layer_shapes(cfg)
    layers = {}
    for i, (k, (shape, dt)) in enumerate(sorted(shapes.items())):
        if k.startswith("ln"):
            layers[k] = jnp.zeros(shape, dt)
        else:
            layers[k] = dense_init(keys[i], shape, in_axis=-2, dtype=dt)
    return {
        "embed": dense_init(
            keys[14], (padded_vocab(cfg), cfg.d_model), in_axis=1, dtype=jnp.bfloat16
        ),
        "ln_f": jnp.zeros((cfg.d_model,), jnp.float32),
        "layers": layers,
    }


# ----------------------------------------------------------------- forward
def _layer_fwd(cfg: LMArch, x, lp, positions):
    """One decoder layer. x: [B, S, d]."""
    b, s, d = x.shape
    # constrain at entry: the scan's saved residual carries (the remat
    # checkpoint) inherit this sharding — without it XLA replicates the
    # [L, B, S, d] stack over "model" (21 GiB/device on gemma train_4k).
    # Sequence-parallel layout (batch over "data", seq over "model"):
    # the saved carry is 1/(data*model) per device and the layer-boundary
    # collectives become all-gather/reduce-scatter pairs over seq.
    x = constrain(x, "data", "model", None)
    h = rms_norm(x, lp["ln1"])
    q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    o = attn.causal_attention(
        q, k, v, q_chunk=cfg.q_chunk, window=cfg.attn_window
    )
    x = x + (o.reshape(b, s, -1) @ lp["wo"])
    x = constrain(x, "data", "model", None)

    h = rms_norm(x, lp["ln2"])
    if cfg.moe is None:
        act = ACTIVATIONS[cfg.activation]
        y = act(h @ lp["wi"]) @ lp["wo_mlp"]
        aux = jnp.zeros((), jnp.float32)
    else:
        y, aux = moe_ffn(
            h.reshape(b * s, d),
            lp["router"],
            lp["wi_e"],
            lp["wo_e"],
            top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            activation=cfg.activation,
        )
        y = y.reshape(b, s, d)
    x = x + y
    x = constrain(x, "data", "model", None)
    return x, (k, v, aux)


def _backbone(cfg: LMArch, params, tokens, positions, collect_kv: bool):
    """tokens [B, S] -> final hidden [B, S, d] (+ per-layer kv, aux)."""
    x = params["embed"][tokens].astype(jnp.bfloat16)
    x = constrain(x, "data", "model", None)

    def body(x, lp):
        x, (k, v, aux) = _layer_fwd(cfg, x, lp, positions)
        out = (k, v, aux) if collect_kv else (None, None, aux)
        return x, out

    if cfg.remat:
        # full remat: save only the bf16 residual carry per layer;
        # everything else (incl. f32 norm upcasts) recomputes in backward
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (ks, vs, auxs) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["ln_f"])
    return x, ks, vs, auxs


def lm_loss(cfg: LMArch, params, tokens, aux_weight: float = 0.01):
    """Next-token CE, sequence-chunked logits. tokens: [B, S] int32."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, _, _, auxs = _backbone(cfg, params, tokens, positions, collect_kv=False)

    inputs = x[:, :-1]
    targets = tokens[:, 1:]
    chunk = min(cfg.loss_chunk, inputs.shape[1])
    n_tok = inputs.shape[1]
    n_chunks = max(n_tok // chunk, 1)
    usable = n_chunks * chunk
    inputs_c = inputs[:, :usable].reshape(b, n_chunks, chunk, -1).swapaxes(0, 1)
    targets_c = targets[:, :usable].reshape(b, n_chunks, chunk).swapaxes(0, 1)
    embed = params["embed"]

    def chunk_loss(carry, xt):
        xc, tc = xt  # [B, chunk, d], [B, chunk]
        logits = (xc @ embed.T).astype(jnp.float32)  # [B, chunk, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32), (inputs_c, targets_c))
    # ragged tail (only in smoke shapes where chunk doesn't divide)
    if usable < n_tok:
        logits = (inputs[:, usable:] @ embed.T).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, targets[:, usable:, None], axis=-1
        )[..., 0]
        total = total + jnp.sum(logz - gold)

    loss = total / (b * n_tok)
    aux = jnp.mean(auxs) if cfg.moe is not None else jnp.zeros((), jnp.float32)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ------------------------------------------------------------------ serving
def cache_specs(cfg: LMArch, batch: int, max_seq: int) -> PyTree:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
        "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
    }


def prefill(cfg: LMArch, params, tokens):
    """tokens [B, S] -> (logits_last [B, V], cache)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x, ks, vs, _ = _backbone(cfg, params, tokens, positions, collect_kv=True)
    logits = (x[:, -1] @ params["embed"].T).astype(jnp.float32)
    return logits, {"k": ks, "v": vs}


def decode_step(cfg: LMArch, params, cache, tokens, pos):
    """One decode step.

    Args:
      cache:  {"k","v"}: [L, B, S_max, K, hd] (bf16).
      tokens: i32 [B] — the tokens emitted at position ``pos``.
      pos:    i32 [] — their position (cache valid for [0, pos]).

    Returns (logits f32 [B, V], new cache).
    """
    b = tokens.shape[0]
    x = params["embed"][tokens].astype(jnp.bfloat16)  # [B, d]
    positions = jnp.full((b, 1), pos)
    s_max = cache["k"].shape[2]

    # fori over layers with the cache as *carry* (not scan xs/ys): the
    # dynamic_update_slice then updates in place (no stacked ys copy) and
    # the per-layer cache slice is loop-variant, so the CPU backend's
    # bf16->f32 dot-operand convert cannot be hoisted into a full-cache
    # f32 copy (a 2x cache-memory artifact; TPU dots are bf16-native).
    def layer_body(l, carry):
        x, k_all, v_all = carry
        lp = jax.tree.map(lambda w: w[l], params["layers"])
        h = rms_norm(x, lp["ln1"])
        q = (h @ lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, positions, cfg.rope_theta)[:, 0]
        k = rope(k, positions, cfg.rope_theta)[:, 0]
        v = v[:, 0]
        # match the cache sharding before the in-place update (see
        # decode_attention note on avoiding cache rematerialization)
        k = constrain(k, "data", None, "model")
        v = constrain(v, "data", None, "model")
        k_all = jax.lax.dynamic_update_slice(
            k_all, k[None, :, None], (l, 0, pos, 0, 0)
        )
        v_all = jax.lax.dynamic_update_slice(
            v_all, v[None, :, None], (l, 0, pos, 0, 0)
        )
        k_c = jax.lax.dynamic_slice(
            k_all, (l, 0, 0, 0, 0), (1,) + k_all.shape[1:]
        )[0]
        v_c = jax.lax.dynamic_slice(
            v_all, (l, 0, 0, 0, 0), (1,) + v_all.shape[1:]
        )[0]
        o = attn.decode_attention(q, k_c, v_c, pos, window=cfg.attn_window)
        x = x + o.reshape(b, -1) @ lp["wo"]

        h = rms_norm(x, lp["ln2"])
        if cfg.moe is None:
            act = ACTIVATIONS[cfg.activation]
            y = act(h @ lp["wi"]) @ lp["wo_mlp"]
        else:
            y, _ = moe_ffn(
                h,
                lp["router"],
                lp["wi_e"],
                lp["wo_e"],
                top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor,
                activation=cfg.activation,
            )
        x = x + y
        return (x, k_all, v_all)

    x, k_new, v_new = jax.lax.fori_loop(
        0, cfg.n_layers, layer_body, (x, cache["k"], cache["v"])
    )
    x = rms_norm(x, params["ln_f"])
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, {"k": k_new, "v": v_new}
