"""Mixture-of-Experts FFN with capacity-based token dispatch.

Token-choice top-k routing (Switch/GShard style) with a static-shape
``[E, capacity, d]`` dispatch buffer so every shape is jit/SPMD friendly:

  1. router logits → top-k experts + normalized gates per token;
  2. position-in-expert via a cumulative one-hot rank (no sort — the
     [T·k, E] cumsum shards cleanly over the data axis);
  3. scatter-add tokens into the expert buffer (drops beyond capacity,
     exactly like GShard's capacity factor semantics);
  4. per-expert FFN as a single batched einsum over [E, cap, ·] —
     sharding the E axis over "model" makes this expert parallelism and
     XLA materializes the dispatch/return as all-to-all-style traffic;
  5. gather + gate-weighted combine back to token order.

An auxiliary load-balancing loss (Switch eq. 4) is returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import ACTIVATIONS

__all__ = ["moe_ffn"]


def moe_ffn(
    x: jnp.ndarray,  # [T, d] flattened tokens
    router_w: jnp.ndarray,  # [d, E]
    wi: jnp.ndarray,  # [E, d, 2*ff]  (fused gate+up)
    wo: jnp.ndarray,  # [E, ff, d]
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    activation: str = "silu",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out [T, d], aux_loss scalar)."""
    t, d = x.shape
    e = router_w.shape[1]
    act = ACTIVATIONS[activation]

    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # load-balance aux loss (fraction routed vs mean prob, Switch eq. 4)
    me = probs.mean(axis=0)  # [E]
    onehot_top1 = jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32)
    ce = onehot_top1.mean(axis=0)
    aux = e * jnp.sum(me * ce)

    flat_e = expert_ids.reshape(-1)  # [T*k]
    flat_gate = gate_vals.reshape(-1)
    tok_of = jnp.arange(t * top_k, dtype=jnp.int32) // top_k

    # rank of each assignment within its expert (stable, no sort)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [T*k, E]
    ranks_all = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(ranks_all, flat_e[:, None], axis=1)[:, 0]  # [T*k]

    cap = max(8, int(capacity_factor * t * top_k / e))
    cap += (-cap) % 8
    keep = (pos < cap).astype(x.dtype)
    slot = jnp.minimum(pos, cap - 1)

    src_rows = x[tok_of] * keep[:, None]  # dropped rows contribute 0
    buf = jnp.zeros((e, cap, d), x.dtype).at[flat_e, slot].add(src_rows)
    buf = constrain(buf, "model", None, None)  # expert-parallel home

    h = act(jnp.einsum("ecd,edf->ecf", buf, wi))
    # keep h in the ff-sharded layout of wi/wo (ff over "data"): XLA then
    # psums y partials instead of re-gathering h to the full ff width
    # (a 258 GB/step gather on the 400B cell — §Perf iteration 2)
    h = constrain(h, "model", None, "data")
    y = jnp.einsum("ecf,efd->ecd", h, wo)
    y = constrain(y, "model", None, None)

    out_rows = y[flat_e, slot] * (flat_gate.astype(x.dtype) * keep)[:, None]
    out = jax.ops.segment_sum(out_rows, tok_of, num_segments=t)
    # NOTE §Perf iteration 3 (refuted): constraining this to the
    # sequence-parallel (("data","model")) layout doubled the collective
    # term — the combine scatter then needs cross-axis resharding of its
    # (token-order-scrambled) updates.  Kept token-major over "data".
    out = constrain(out, ("data",), None)
    return out.astype(x.dtype), aux
