"""Shared neural-net building blocks (pure jnp)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rms_norm", "rope", "swiglu", "geglu", "dense_init", "ACTIVATIONS"]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(
        dtype
    )


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """Rotary embedding.  x: [..., seq, n_heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(gate_up: jnp.ndarray) -> jnp.ndarray:
    gate, up = jnp.split(gate_up, 2, axis=-1)
    return jax.nn.silu(gate) * up


def geglu(gate_up: jnp.ndarray) -> jnp.ndarray:
    gate, up = jnp.split(gate_up, 2, axis=-1)
    return jax.nn.gelu(gate, approximate=True) * up


ACTIVATIONS = {"silu": swiglu, "gelu": geglu}


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis]
    std = fan_in**-0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(
        dtype
    )
