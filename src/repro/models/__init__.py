"""Model definitions for the assigned architectures.

Pure-function style (params are explicit PyTrees of arrays); every model
provides param_specs / init_params / forward (+ decode for LMs), and the
launch layer builds train_step / serve_step from them.
"""
