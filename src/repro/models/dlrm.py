"""DLRM (Naumov et al., arXiv:1906.00091) — RM2-class config.

The embedding lookup is the hot path: JAX has no native EmbeddingBag, so
it is built here from ``jnp.take`` + segment reduction (pure-XLA path)
with an optional Pallas kernel (kernels/segment_bag.py) that streams
table rows through VMEM.  Tables are row-sharded over "model"
(the paper's 1-D vertex partition, DESIGN.md §5); batch over "data".

Batch format:
  dense  f32 [B, n_dense]       sparse i32 [B, n_sparse, hot]
  train:     labels f32 [B]
  retrieval: candidates f32 [n_candidates, embed_dim]
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import DLRMArch
from repro.distributed.sharding import constrain
from repro.models.layers import dense_init

__all__ = [
    "param_specs",
    "init_params",
    "dlrm_forward",
    "dlrm_loss",
    "retrieval_scores",
]

PyTree = Any


def _mlp_params(dims, key, tag: str, abstract: bool):
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        if abstract:
            out[f"{tag}_w{i}"] = jnp.zeros((a, b), jnp.float32)
        else:
            out[f"{tag}_w{i}"] = dense_init(jax.random.fold_in(key, i), (a, b))
        out[f"{tag}_b{i}"] = jnp.zeros((b,), jnp.float32)
    return out


def _mlp_apply(params, tag: str, x, n: int, final_act: bool):
    for i in range(n):
        x = x @ params[f"{tag}_w{i}"] + params[f"{tag}_b{i}"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _interaction_dims(cfg: DLRMArch) -> int:
    f = cfg.n_sparse + 1  # sparse fields + bottom output
    return f * (f - 1) // 2 + cfg.embed_dim


def init_params(cfg: DLRMArch, key=None, abstract: bool = False) -> PyTree:
    key = key if key is not None else jax.random.PRNGKey(0)
    bot_dims = (cfg.n_dense,) + cfg.bot_mlp
    top_dims = (_interaction_dims(cfg),) + cfg.top_mlp
    if abstract:
        tables = jnp.zeros((cfg.n_sparse, cfg.rows_per_table, cfg.embed_dim), jnp.float32)
    else:
        tables = (
            jax.random.normal(
                jax.random.fold_in(key, 99),
                (cfg.n_sparse, cfg.rows_per_table, cfg.embed_dim),
                jnp.float32,
            )
            * cfg.embed_dim**-0.5
        )
    params = {"tables": tables}
    params.update(_mlp_params(bot_dims, jax.random.fold_in(key, 1), "bot", abstract))
    params.update(_mlp_params(top_dims, jax.random.fold_in(key, 2), "top", abstract))
    return params


def param_specs(cfg: DLRMArch) -> PyTree:
    # eval_shape: no allocation (the tables alone are tens of GB)
    return jax.eval_shape(lambda: init_params(cfg, abstract=True))


def embedding_bag_lookup(cfg: DLRMArch, tables, sparse_idx, use_pallas: bool = False):
    """tables [F, V, D], sparse_idx i32 [B, F, L] (−1 pad) -> [B, F, D]."""
    b, f, l = sparse_idx.shape
    v, d = tables.shape[1], tables.shape[2]
    if use_pallas:
        from repro.kernels.ops import segment_bag

        flat_table = tables.reshape(f * v, d)
        offs = (jnp.arange(f, dtype=jnp.int32) * v)[None, :, None]
        flat_idx = jnp.where(sparse_idx >= 0, sparse_idx + offs, -1)
        bags = flat_idx.reshape(b * f, l)
        out = segment_bag(flat_table, bags)
        return out.reshape(b, f, d)
    mask = (sparse_idx >= 0).astype(jnp.float32)
    safe = jnp.maximum(sparse_idx, 0)
    gathered = tables[jnp.arange(f)[None, :, None], safe]  # [B, F, L, D]
    return (gathered * mask[..., None]).sum(axis=2)


def dlrm_forward(cfg: DLRMArch, params, dense, sparse_idx, use_pallas: bool = False):
    """Returns (logit [B], feature vectors [B, F+1, D])."""
    dense = constrain(dense, "data", None)
    bot = _mlp_apply(params, "bot", dense, len(cfg.bot_mlp), final_act=True)  # [B, D]
    emb = embedding_bag_lookup(cfg, params["tables"], sparse_idx, use_pallas)
    emb = constrain(emb, "data", None, None)
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)  # [B, F+1, D]

    # pairwise dot interaction (upper triangle)
    dots = jnp.einsum("bif,bjf->bij", feats, feats)
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    z = jnp.concatenate([bot, dots[:, iu, ju]], axis=-1)
    logit = _mlp_apply(params, "top", z, len(cfg.top_mlp), final_act=False)
    return logit[:, 0], feats


def dlrm_loss(cfg: DLRMArch, params, batch, use_pallas: bool = False):
    logit, _ = dlrm_forward(cfg, params, batch["dense"], batch["sparse"], use_pallas)
    labels = batch["labels"]
    loss = jnp.mean(
        jnp.maximum(logit, 0.0) - logit * labels + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    )
    return loss, {"bce": loss}


def retrieval_scores(cfg: DLRMArch, params, batch, top_k: int = 100):
    """Score one query against n_candidates item embeddings (batched dot,
    not a loop): user vector = bottom output + pooled sparse embeddings."""
    _, feats = dlrm_forward(cfg, params, batch["dense"], batch["sparse"])
    user = feats.sum(axis=1)  # [B, D]
    cands = constrain(batch["candidates"], ("data", "model"), None)
    scores = user @ cands.T  # [B, Nc]
    return jax.lax.top_k(scores, top_k)
