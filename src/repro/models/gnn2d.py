"""2-D distributed GNN message passing — MGBC's decomposition applied
to GNN training (the paper's technique as a first-class framework
feature, DESIGN.md §5).

GSPMD's automatic partitioning of ``gather + segment_sum`` replicates
node state around the scatter (hundreds of GB/device on ogb_products).
This module instead expresses one message-passing layer with the exact
communication structure of the paper's traversal level:

  expand (vertical):    all_gather(h chunks, axis=row) → h[cols_j]
                        all_gather(h chunks, axis=col) → h[rows_i]
                        (the second gather feeds messages that read the
                        *destination* features — BC's frontier only
                        needed sources)
  local compute:        per-arc message MLP + local segment_sum
  fold (horizontal):    psum_scatter(partials, axis=col) → owner chunks

Per-device memory is O(n/√p · d + arcs/p · d) instead of O(n·d) — the
paper's scalability argument, inherited verbatim.

Node arrays use the BC chunk layout (chunk jR+i on device (i,j), i.e.
``P((col, row))`` on the flat vertex dim); arc arrays come from
graphs/partition.partition_arcs_2d.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import GNNArch

__all__ = ["make_gnn2d_loss_fn", "gnn2d_batch_specs"]

PyTree = Any


def make_gnn2d_loss_fn(
    cfg: GNNArch,
    mesh: Mesh,
    shape_kind: str,
    chunk: int,
    max_arcs: int,
    n_graphs: int = 0,
    row_axis: str = "data",
    col_axis: str = "model",
    gather_dtype=None,
    fold_dtype=None,
):
    """Builds loss_fn(params, batch) as a shard_map program.

    Batch (global shapes; n_pad = R*C*chunk):
      node_feat [n_pad, d_feat]      — P((col, row)) chunk layout
      src_local/dst_local [R, C, max_arcs] — P(row, col)
      edge_feat [R, C, max_arcs, d_feat]   — meshgraphnet only
      target [n_pad, d_out] | labels [n_pad] + label_mask [n_pad]
      graph_ids [n_pad] + labels [n_graphs] (batched_graphs)
    """
    R = mesh.shape[row_axis]
    C = mesh.shape[col_axis]
    grid = (row_axis, col_axis)
    n_acc = C * chunk + 1  # + sentinel row

    def body(params, batch):
        src_l = batch["src_local"][0, 0]
        dst_l = batch["dst_local"][0, 0]
        x = batch["node_feat"]  # [chunk, d_feat] owned
        h = jnp.tanh(x @ params["enc_w"] + params["enc_b"])

        e_loc = None
        if cfg.kind == "meshgraphnet":
            e_loc = jnp.tanh(
                batch["edge_feat"][0, 0] @ params["edge_enc_w"] + params["edge_enc_b"]
            )

        gd = gather_dtype

        def gather(z, axis):
            """Expand collective; optional low-precision payload
            (bf16 halves the gather bytes — §Perf graphcast iteration 2)."""
            if gd is not None and z.dtype != gd:
                return jax.lax.all_gather(z.astype(gd), axis, tiled=True).astype(
                    z.dtype
                )
            return jax.lax.all_gather(z, axis, tiled=True)

        def mp(h, e_loc, lp):
            if cfg.kind == "gat":
                H, dh = cfg.n_heads, cfg.d_hidden
                hw_own = jnp.einsum("nd,dhk->nhk", h, lp["w"])  # [chunk, H, dh]
                hw_col = gather(hw_own, row_axis)
                hw_row = gather(hw_own, col_axis)
                hwc = jnp.concatenate(
                    [hw_col, jnp.zeros((1, H, dh), hw_col.dtype)], axis=0
                )
                hwr = jnp.concatenate(
                    [hw_row, jnp.zeros((1, H, dh), hw_row.dtype)], axis=0
                )
                e_src = (hwc[src_l] * lp["a_src"]).sum(-1)  # [A, H]
                e_dst = (hwr[jnp.minimum(dst_l, C * chunk - 1)] * lp["a_dst"]).sum(-1)
                valid = (dst_l < C * chunk)[:, None]
                logit = jax.nn.leaky_relu(e_src + e_dst, 0.2)
                logit = jnp.where(valid, logit, -jnp.inf)
                # segment softmax: stats psum'd across the row group
                mx_l = jax.ops.segment_max(logit, dst_l, num_segments=n_acc)
                # softmax is shift-invariant: the cross-device max is a
                # constant for AD (pmax has no differentiation rule)
                mx = jax.lax.stop_gradient(
                    jax.lax.pmax(jax.lax.stop_gradient(mx_l), col_axis)
                )
                mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
                ex = jnp.where(valid, jnp.exp(logit - mx[dst_l]), 0.0)
                denom = jax.lax.psum(
                    jax.ops.segment_sum(ex, dst_l, num_segments=n_acc), col_axis
                )
                alpha = ex / jnp.maximum(denom[dst_l], 1e-9)
                msgs = hwc[src_l] * alpha[..., None]  # [A, H, dh]
                partial = jax.ops.segment_sum(msgs, dst_l, num_segments=n_acc)
                folded = jax.lax.psum_scatter(
                    partial[: C * chunk].reshape(C * chunk, H * dh),
                    col_axis,
                    scatter_dimension=0,
                    tiled=True,
                )
                return jax.nn.elu(folded), e_loc

            h_col = gather(h, row_axis)  # [R*chunk, d]
            h_row = (
                gather(h, col_axis)  # [C*chunk, d]
                if cfg.kind in ("graphcast", "meshgraphnet")
                else None
            )
            hc = jnp.concatenate([h_col, jnp.zeros((1,) + h_col.shape[1:], h_col.dtype)], 0)
            hr = (
                jnp.concatenate([h_row, jnp.zeros((1,) + h_row.shape[1:], h_row.dtype)], 0)
                if h_row is not None
                else None
            )
            src_i = src_l
            dst_i = dst_l  # sentinel C*chunk lands in the dropped row
            if cfg.kind == "gin":
                partial, e2 = (
                    jax.ops.segment_sum(hc[src_i], dst_i, num_segments=n_acc),
                    e_loc,
                )
            elif cfg.kind == "meshgraphnet":
                cat = jnp.concatenate(
                    [e_loc, hc[src_i], hr[jnp.minimum(dst_i, C * chunk - 1)]], axis=-1
                )
                upd = jax.nn.relu(cat @ lp["we1"] + lp["be1"]) @ lp["we2"] + lp["be2"]
                e2 = e_loc + upd * (dst_i < C * chunk)[:, None]
                partial = jax.ops.segment_sum(e2, dst_i, num_segments=n_acc)
            else:  # graphcast
                cat = jnp.concatenate(
                    [hc[src_i], hr[jnp.minimum(dst_i, C * chunk - 1)]], axis=-1
                )
                m = jax.nn.relu(cat @ lp["wm1"] + lp["bm1"]) @ lp["wm2"] + lp["bm2"]
                m = m * (dst_i < C * chunk)[:, None]
                partial = jax.ops.segment_sum(m, dst_i, num_segments=n_acc)
                e2 = e_loc
            if fold_dtype is not None:
                partial = partial.astype(fold_dtype)
            agg = jax.lax.psum_scatter(
                partial[: C * chunk], col_axis, scatter_dimension=0, tiled=True
            ).astype(h.dtype)  # [chunk, d]
            if cfg.kind == "gin":
                z = (1.0 + lp["eps"]) * h + agg
                z = jax.nn.relu(z @ lp["w1"] + lp["b1"])
                z = jax.nn.relu(z @ lp["w2"] + lp["b2"])
                return z, e2
            if cfg.kind == "meshgraphnet":
                cat_n = jnp.concatenate([h, agg], axis=-1)
                h2 = jax.nn.relu(cat_n @ lp["wn1"] + lp["bn1"]) @ lp["wn2"] + lp["bn2"]
                return h + h2, e2
            cat_n = jnp.concatenate([h, agg], axis=-1)
            u = jax.nn.relu(cat_n @ lp["wu1"] + lp["bu1"]) @ lp["wu2"] + lp["bu2"]
            return h + u, e2

        def scan_body(carry, lp):
            h, e = carry
            h2, e2 = jax.checkpoint(mp)(h, e, lp)
            return (h2, e2), None

        (h, _), _ = jax.lax.scan(scan_body, (h, e_loc), params["layers"])
        out = h @ params["dec_w"] + params["dec_b"]  # [chunk, d_out]

        # ------------------------------------------------------- losses
        if cfg.kind in ("graphcast", "meshgraphnet"):
            err = (out - batch["target"]).astype(jnp.float32)
            mask = batch["label_mask"][:, None]
            sse = jax.lax.psum(jnp.sum(jnp.square(err) * mask), grid)
            cnt = jax.lax.psum(jnp.sum(mask) * out.shape[1], grid)
            loss = sse / jnp.maximum(cnt, 1.0)
        elif shape_kind == "batched_graphs":
            masked = out * batch["label_mask"][:, None]
            pooled = jax.ops.segment_sum(
                masked, batch["graph_ids"], num_segments=n_graphs
            )
            logits = jax.lax.psum(pooled, grid).astype(jnp.float32)  # [G, d_out]
            labels = batch["labels"]  # replicated [G]
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
            loss = jnp.mean(logz - gold)
        else:  # full_graph / minibatch via label_mask
            logits = out.astype(jnp.float32)
            labels = batch["labels"]
            mask = batch["label_mask"]
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(labels, 0)[:, None], axis=-1
            )[:, 0]
            num = jax.lax.psum(jnp.sum((logz - gold) * mask), grid)
            den = jax.lax.psum(jnp.sum(mask), grid)
            loss = num / jnp.maximum(den, 1.0)
        return loss

    # sharding specs for shard_map
    owner = P((col_axis, row_axis))
    batch_specs_in = {
        "node_feat": P((col_axis, row_axis), None),
        "src_local": P(row_axis, col_axis, None),
        "dst_local": P(row_axis, col_axis, None),
    }
    if cfg.kind in ("graphcast", "meshgraphnet"):
        batch_specs_in["target"] = P((col_axis, row_axis), None)
        batch_specs_in["label_mask"] = owner
        if cfg.kind == "meshgraphnet":
            batch_specs_in["edge_feat"] = P(row_axis, col_axis, None, None)
    elif shape_kind == "batched_graphs":
        batch_specs_in["graph_ids"] = owner
        batch_specs_in["labels"] = P()
        batch_specs_in["label_mask"] = owner
    else:
        batch_specs_in["labels"] = owner
        batch_specs_in["label_mask"] = owner

    from repro.compat import shard_map

    shmapped = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), batch_specs_in),  # params replicated
        out_specs=P(),
        check_vma=False,
    )
    return shmapped, batch_specs_in


def gnn2d_batch_specs(cfg: GNNArch, shape_kind, n_pad, R, C, max_arcs, d_feat, d_out, n_graphs=0):
    """ShapeDtypeStruct tree for the 2-D batch."""
    SDS = jax.ShapeDtypeStruct
    specs = {
        "node_feat": SDS((n_pad, d_feat), jnp.float32),
        "src_local": SDS((R, C, max_arcs), jnp.int32),
        "dst_local": SDS((R, C, max_arcs), jnp.int32),
    }
    if cfg.kind in ("graphcast", "meshgraphnet"):
        specs["target"] = SDS((n_pad, d_out), jnp.float32)
        specs["label_mask"] = SDS((n_pad,), jnp.float32)
        if cfg.kind == "meshgraphnet":
            specs["edge_feat"] = SDS((R, C, max_arcs, d_feat), jnp.float32)
    elif shape_kind == "batched_graphs":
        specs["graph_ids"] = SDS((n_pad,), jnp.int32)
        specs["labels"] = SDS((n_graphs,), jnp.int32)
        specs["label_mask"] = SDS((n_pad,), jnp.float32)
    else:
        specs["labels"] = SDS((n_pad,), jnp.int32)
        specs["label_mask"] = SDS((n_pad,), jnp.float32)
    return specs
