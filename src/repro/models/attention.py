"""Grouped-query attention: training (q-chunked full causal), prefill,
and single-token decode against a KV cache.

The q-chunked formulation bounds the materialized score tensor to
[B, H, q_chunk, S] — the pure-JAX stand-in for a flash kernel (exact
same FLOPs; XLA fuses mask+softmax per chunk).  An optional sliding
window turns it into genuinely sub-quadratic local attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["causal_attention", "decode_attention"]

NEG_INF = -1e30


def _chunk_scores_to_out(q, k, v, q_start, causal, window, scale):
    """q: [B, qc, K, G, hd]; k/v: [B, S, K, hd] -> out [B, qc, K, G, hd]."""
    s = k.shape[1]
    qc = q.shape[1]
    # bf16 operands, f32 accumulation (MXU-native; no f32 copy of K/V)
    scores = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    q_pos = q_start + jnp.arange(qc)
    k_pos = jnp.arange(s)
    mask = jnp.ones((qc, s), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out


def causal_attention(
    q: jnp.ndarray,  # [B, S, H, hd]
    k: jnp.ndarray,  # [B, S, K, hd]
    v: jnp.ndarray,  # [B, S, K, hd]
    *,
    q_chunk: int = 512,
    window: int | None = None,
) -> jnp.ndarray:
    """Full (or windowed) causal GQA for training/prefill."""
    b, s, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = hd**-0.5
    qg = q.reshape(b, s, kh, g, hd)

    q_chunk = min(q_chunk, s)
    if s % q_chunk != 0:  # fall back to one chunk for ragged smoke shapes
        q_chunk = s
    n_chunks = s // q_chunk

    if n_chunks == 1:
        out = _chunk_scores_to_out(qg, k, v, 0, True, window, scale)
        return out.reshape(b, s, h, hd)

    def body(carry, qi):
        q_blk, idx = qi
        out = _chunk_scores_to_out(q_blk, k, v, idx * q_chunk, True, window, scale)
        return carry, out

    q_blocks = qg.reshape(b, n_chunks, q_chunk, kh, g, hd).transpose(1, 0, 2, 3, 4, 5)
    _, outs = jax.lax.scan(body, None, (q_blocks, jnp.arange(n_chunks)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, hd)
    return out


def decode_attention(
    q: jnp.ndarray,  # [B, H, hd] — one new token per sequence
    k_cache: jnp.ndarray,  # [B, S_max, K, hd]
    v_cache: jnp.ndarray,  # [B, S_max, K, hd]
    pos: jnp.ndarray,  # i32 [] — number of valid cache positions
    *,
    window: int | None = None,
) -> jnp.ndarray:
    """Single-step GQA decode over the cache (O(S) per token)."""
    from repro.distributed.sharding import constrain

    b, h, hd = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = hd**-0.5
    qg = q.reshape(b, kh, g, hd)
    # match the cache layout (head_dim over "model") so XLA reshards the
    # tiny q instead of fully rematerializing the multi-GB cache
    qg = constrain(qg, "data", None, None, "model")
    # bf16 cache operand + f32 accumulation: upcasting the cache would
    # materialize an f32 copy of the largest tensor in the system
    scores = jnp.einsum(
        "bkgh,bskh->bkgs",
        qg.astype(k_cache.dtype),
        k_cache,
        preferred_element_type=jnp.float32,
    ) * scale
    scores = constrain(scores, "data", None, None, None)
    k_pos = jnp.arange(k_cache.shape[1])
    mask = k_pos[None] <= pos
    if window is not None:
        mask &= k_pos[None] > pos - window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, h, hd)
