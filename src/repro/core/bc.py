"""Single-device betweenness centrality entry point (MGBC without the mesh).

Composes the round scheduler, the operator layer and the shared driver
(:mod:`repro.core.driver`) into the full exact-BC computation.  The
distributed version (:mod:`repro.core.distributed`) is the same
driver/round body over the 2-D-partitioned operators; this module is
both the small-graph production path and the semantic reference for it.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.driver import (
    BCDriver,
    BCResult,
    apply_reduction_corrections,
    normalize_straggler,
    traversal_round,
)
from repro.core.operators import (
    PallasDenseOperator,
    WeightedDenseOperator,
    WeightedSparseOperator,
    auto_delta,
    normalize_overlap,
)
from repro.core.scheduler import build_schedule
from repro.graphs.graph import Graph

# heuristics usable under weighted traversal: the 1-degree reduction (and
# its tree-contraction variant) is purely combinatorial — every path
# to/through a pendant subtree crosses its anchor whatever the edge
# weights — but the 2-degree derivation (h2/h3/h3t) rewrites *levels*
# (lvl_c = min(lvl_a, lvl_b) + 1), which assumes unit edge lengths.
WEIGHTED_HEURISTICS = ("h0", "h1", "h1t")

__all__ = [
    "BCResult",
    "betweenness_centrality",
    "make_round_fn",
    "apply_reduction_corrections",
    "apply_sampling_rescale",
    "ENGINE_KINDS",
]

# the single source of truth for --engine choices (launch/bc.py, benchmarks)
ENGINE_KINDS = ("dense", "sparse", "pallas", "pallas_bf16")


def make_round_fn(
    operator_fn,
    n: int,
    num_levels: int | None = None,
    fused_adjacency=None,
    interpret: bool | None = None,
):
    """Build the jit-able per-round function.

    Args:
      operator_fn:     closure () -> TraversalOperator (captures adjacency).
      n:               vertex count (kept for signature stability).
      num_levels:      static level bound (dry-run) or None (early exit).
      fused_adjacency: when given, run the fused Pallas kernel path on
                       this dense adjacency instead of ``operator_fn``.
      interpret:       Pallas interpret-mode override (CPU validation).

    The returned function maps
      (sources i32 [s], derived i32 [k, 3], omega f32 [n])
        -> (bc_round f32 [n], ns f32 [s+k], roots i32 [s+k], levels i32 [])
    """
    del n  # the operator knows its own row count

    def round_fn(sources, derived, omega):
        if fused_adjacency is not None:
            op = PallasDenseOperator(fused_adjacency, interpret=interpret)
        else:
            op = operator_fn()
        return traversal_round(op, sources, derived, omega, num_levels=num_levels)

    return round_fn


def _make_operator_fn(graph_residual, n, engine_kind):
    """Operator factory + fused-path config for an engine kind."""
    if engine_kind == "dense":
        adjacency = jnp.asarray(graph_residual.dense_adjacency(np.float32))
        return (lambda: engine.make_dense_operator(adjacency)), None, None
    if engine_kind == "sparse":
        src_p, dst_p, _ = graph_residual.padded_arcs(multiple=8)
        src_j, dst_j = jnp.asarray(src_p), jnp.asarray(dst_p)
        return (lambda: engine.make_sparse_operator(src_j, dst_j, n)), None, None
    if engine_kind in ("pallas", "pallas_bf16"):
        from repro.kernels.ops import on_tpu

        dt = np.float32 if engine_kind == "pallas" else jnp.bfloat16
        fused = jnp.asarray(graph_residual.dense_adjacency(np.float32), dt)
        return None, fused, (not on_tpu())
    raise ValueError(f"unknown engine {engine_kind!r}")


def _make_weighted_operator_fn(graph_residual, n, engine_kind, delta):
    """Weighted operator factory (bucketed traversal, all engine kinds).

    "sparse" keeps the arc-list layout; "dense"/"pallas"/"pallas_bf16"
    share the dense float32 weight-matrix operator — the weighted bucket
    steps are XLA contractions (no fused Pallas bucket kernels yet; see
    operators.py), and weights stay float32 even under pallas_bf16
    because distances feed exact equality masks.
    """
    if engine_kind == "sparse":
        src_p, dst_p, _ = graph_residual.padded_arcs(multiple=8)
        w_p = graph_residual.padded_arc_weights(multiple=8)
        src_j, dst_j, w_j = jnp.asarray(src_p), jnp.asarray(dst_p), jnp.asarray(w_p)
        return lambda: WeightedSparseOperator(src_j, dst_j, w_j, n, delta)
    if engine_kind in ("dense", "pallas", "pallas_bf16"):
        weights = jnp.asarray(graph_residual.dense_weights(np.float32))
        return lambda: WeightedDenseOperator(weights, delta)
    raise ValueError(f"unknown engine {engine_kind!r}")


def betweenness_centrality(
    graph: Graph,
    batch_size: int = 32,
    heuristics: str = "h0",
    engine_kind: str = "dense",
    num_levels: int | None = None,
    jit: bool = True,
    ledger=None,
    checkpoint=None,
    overlap: str = "none",
    straggler: str = "none",
    sampling: str = "off",
    sample_frac: float | None = None,
    sample_k: int | None = None,
    sample_seed: int = 0,
    stop_rule=None,
    weighted: bool = False,
    delta: float | None = None,
) -> BCResult:
    """Exact or source-sampled BC of an undirected graph
    (paper conventions: unnormalized, both traversal directions counted).

    Args:
      graph:       input graph.
      batch_size:  concurrent sources per round (multi-source width).
      heuristics:  "h0" | "h1" | "h2" | "h3" (paper Fig. 12 naming).
      weighted:    run the bucketed (delta-stepping) weighted traversal;
                   requires ``graph.w`` (``Graph.from_edges(weights=)``)
                   and restricts ``heuristics`` to
                   :data:`WEIGHTED_HEURISTICS`.  False on a weighted
                   graph ignores the weights (unit-distance BC).
      delta:       bucket width Δ for the weighted traversal; None derives
                   it from the edge-weight statistics
                   (:func:`repro.core.operators.auto_delta`).
      engine_kind: "dense" (n×n matmul) | "sparse" (segment-sum) |
                   "pallas" / "pallas_bf16" (fused level kernels).
      num_levels:  optional static level bound (compile-friendly); must be
                   ≥ graph diameter + 1 when given.
      jit:         wrap the round function in jax.jit (disable to debug).
      ledger:      optional RoundLedger — committed rounds are skipped
                   (in-memory exactly-once, e.g. speculative re-execution).
      checkpoint:  optional fault_tolerance.BCCheckpoint — durable
                   kill-and-resume (launch/bc.py --ckpt-dir).
      overlap:     collective-schedule policy, accepted for protocol
                   uniformity with the distributed entry point; a single
                   device has no collectives to overlap, so only "none"
                   is valid here.
      straggler:   sub-cluster scheduling policy, accepted for protocol
                   uniformity; a single device has no replicas to steal
                   from or re-deal to, so only "none" is valid here.
      sampling:    :data:`repro.serving.SAMPLING_MODES` — "off" (exact),
                   "fixed" (seeded k-root subset, result rescaled by
                   N/k) or "adaptive" (additionally stops dispatching
                   once top-k ranks stabilize; see
                   :class:`repro.serving.AdaptiveStopRule`).  Sampling
                   requires ``heuristics="h0"`` (per-root additivity).
      sample_frac / sample_k: sample size as a fraction of — or count
                   within — the eligible roots (at most one of the two;
                   ``sample_frac=1.0`` reproduces the unsampled schedule
                   exactly).
      sample_seed: RNG seed of the root draw (same seed ⇒ nested samples
                   in k).
      stop_rule:   explicit ``BCDriver`` stop-rule override, e.g.
                   :class:`repro.serving.BlockBudgetStop` for serving
                   refresh slices; default under "adaptive" is
                   ``AdaptiveStopRule()``.  Requires ``sampling != "off"``
                   — a truncated run is only meaningful as a rescaled
                   estimate.
    """
    from repro.serving.sampling import (
        AdaptiveStopRule,
        eligible_roots,
        plan_sampling,
    )

    if normalize_overlap(overlap) != "none":
        raise ValueError(
            "overlap schedules are a distributed-engine feature; "
            "single-device engines have no collectives to pipeline"
        )
    if normalize_straggler(straggler) != "none":
        raise ValueError(
            "straggler scheduling is a sub-cluster feature; a single "
            "device has no replicas to steal rounds from or re-deal to"
        )
    plan = plan_sampling(
        eligible_roots(graph), sampling, sample_frac, sample_k, sample_seed
    )
    if plan.mode != "off" and heuristics != "h0":
        raise ValueError(
            "sampling requires heuristics='h0': the 1-/2-degree analytic "
            "corrections are not per-root additive, so a sampled run "
            "could not be rescaled into an unbiased estimator"
        )
    if stop_rule is not None and plan.mode == "off":
        raise ValueError(
            "a stop_rule truncates the schedule, which is only meaningful "
            "as a rescaled estimate; pass sampling='fixed' or 'adaptive'"
        )
    if plan.mode == "adaptive" and stop_rule is None:
        stop_rule = AdaptiveStopRule()
    if weighted:
        if graph.w is None:
            raise ValueError(
                "weighted=True needs edge weights: build the graph with "
                "Graph.from_edges(..., weights=) or a weighted generator "
                "(graphs.generators WEIGHT_MODES)"
            )
        if heuristics not in WEIGHTED_HEURISTICS:
            raise ValueError(
                f"heuristics={heuristics!r} is level-based (2-degree "
                f"derivation assumes unit edge lengths); weighted runs "
                f"accept {WEIGHTED_HEURISTICS}"
            )
        if num_levels is not None:
            raise ValueError(
                "num_levels is a static level bound for the level-"
                "synchronous engine; the weighted bucket loop's trip "
                "count is data-dependent"
            )
        if delta is None:
            delta = auto_delta(graph)
        if not (float(delta) > 0 and np.isfinite(delta)):
            raise ValueError(f"delta must be positive and finite, got {delta}")
    elif delta is not None:
        raise ValueError("delta is only meaningful with weighted=True")
    n = graph.n
    schedule, prep, residual, omega_i = build_schedule(
        graph, batch_size=batch_size, heuristics=heuristics, roots=plan.roots
    )
    omega = jnp.asarray(omega_i, jnp.float32)

    if weighted:
        operator_fn = _make_weighted_operator_fn(
            residual, n, engine_kind, float(delta)
        )
        fused_adjacency, interpret = None, None
    else:
        operator_fn, fused_adjacency, interpret = _make_operator_fn(
            residual, n, engine_kind
        )
    round_fn = make_round_fn(
        operator_fn,
        n,
        num_levels=num_levels,
        fused_adjacency=fused_adjacency,
        interpret=interpret,
    )

    def block_fn(sources, derived):  # [1, s], [1, k, 3] -> block-dim outputs
        bc_r, ns, roots, levels = round_fn(sources[0], derived[0], omega)
        return bc_r, ns[None], roots[None], levels[None]

    if jit:
        block_fn = jax.jit(block_fn)

    driver = BCDriver(
        block_fn, schedule, n=n, prep=prep, ledger=ledger,
        checkpoint=checkpoint, stop_rule=stop_rule,
    )
    result = driver.run()
    return apply_sampling_rescale(result, plan)


def apply_sampling_rescale(result: BCResult, plan) -> BCResult:
    """Rescale a sampled run's BC by N / roots_accumulated (in place).

    Shared by both entrypoints.  The denominator is what the driver
    *committed* — an adaptive stop truncates it below ``plan.k``, a full
    fixed run equals it — so fixed and adaptive share one calibration.
    Checkpoints always store the raw accumulator (the driver snapshots
    before this runs), so a resumed run re-applies the then-current
    scale to the grown prefix — rescale and resume commute.
    """
    if plan.mode == "off":
        return result
    denom = result.roots_accumulated
    scale = plan.num_eligible / denom if denom else 1.0
    if scale != 1.0:
        result.bc = result.bc * scale
    result.sampling_stats = {
        "mode": plan.mode,
        "seed": plan.seed,
        "num_eligible": plan.num_eligible,
        "k_planned": plan.k,
        "roots_accumulated": denom,
        "scale": scale,
    }
    return result
