"""Single-device betweenness centrality entry point (MGBC without the mesh).

Composes the round scheduler, the operator layer and the shared driver
(:mod:`repro.core.driver`) into the full exact-BC computation.  The
distributed version (:mod:`repro.core.distributed`) is the same
driver/round body over the 2-D-partitioned operators; this module is
both the small-graph production path and the semantic reference for it.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.driver import (
    BCDriver,
    BCResult,
    apply_reduction_corrections,
    normalize_straggler,
    traversal_round,
)
from repro.core.operators import PallasDenseOperator, normalize_overlap
from repro.core.scheduler import build_schedule
from repro.graphs.graph import Graph

__all__ = [
    "BCResult",
    "betweenness_centrality",
    "make_round_fn",
    "apply_reduction_corrections",
    "ENGINE_KINDS",
]

# the single source of truth for --engine choices (launch/bc.py, benchmarks)
ENGINE_KINDS = ("dense", "sparse", "pallas", "pallas_bf16")


def make_round_fn(
    operator_fn,
    n: int,
    num_levels: int | None = None,
    fused_adjacency=None,
    interpret: bool | None = None,
):
    """Build the jit-able per-round function.

    Args:
      operator_fn:     closure () -> TraversalOperator (captures adjacency).
      n:               vertex count (kept for signature stability).
      num_levels:      static level bound (dry-run) or None (early exit).
      fused_adjacency: when given, run the fused Pallas kernel path on
                       this dense adjacency instead of ``operator_fn``.
      interpret:       Pallas interpret-mode override (CPU validation).

    The returned function maps
      (sources i32 [s], derived i32 [k, 3], omega f32 [n])
        -> (bc_round f32 [n], ns f32 [s+k], roots i32 [s+k], levels i32 [])
    """
    del n  # the operator knows its own row count

    def round_fn(sources, derived, omega):
        if fused_adjacency is not None:
            op = PallasDenseOperator(fused_adjacency, interpret=interpret)
        else:
            op = operator_fn()
        return traversal_round(op, sources, derived, omega, num_levels=num_levels)

    return round_fn


def _make_operator_fn(graph_residual, n, engine_kind):
    """Operator factory + fused-path config for an engine kind."""
    if engine_kind == "dense":
        adjacency = jnp.asarray(graph_residual.dense_adjacency(np.float32))
        return (lambda: engine.make_dense_operator(adjacency)), None, None
    if engine_kind == "sparse":
        src_p, dst_p, _ = graph_residual.padded_arcs(multiple=8)
        src_j, dst_j = jnp.asarray(src_p), jnp.asarray(dst_p)
        return (lambda: engine.make_sparse_operator(src_j, dst_j, n)), None, None
    if engine_kind in ("pallas", "pallas_bf16"):
        from repro.kernels.ops import on_tpu

        dt = np.float32 if engine_kind == "pallas" else jnp.bfloat16
        fused = jnp.asarray(graph_residual.dense_adjacency(np.float32), dt)
        return None, fused, (not on_tpu())
    raise ValueError(f"unknown engine {engine_kind!r}")


def betweenness_centrality(
    graph: Graph,
    batch_size: int = 32,
    heuristics: str = "h0",
    engine_kind: str = "dense",
    num_levels: int | None = None,
    jit: bool = True,
    ledger=None,
    checkpoint=None,
    overlap: str = "none",
    straggler: str = "none",
) -> BCResult:
    """Exact BC of an undirected, unweighted graph (paper conventions:
    unnormalized, both traversal directions counted).

    Args:
      graph:       input graph.
      batch_size:  concurrent sources per round (multi-source width).
      heuristics:  "h0" | "h1" | "h2" | "h3" (paper Fig. 12 naming).
      engine_kind: "dense" (n×n matmul) | "sparse" (segment-sum) |
                   "pallas" / "pallas_bf16" (fused level kernels).
      num_levels:  optional static level bound (compile-friendly); must be
                   ≥ graph diameter + 1 when given.
      jit:         wrap the round function in jax.jit (disable to debug).
      ledger:      optional RoundLedger — committed rounds are skipped
                   (in-memory exactly-once, e.g. speculative re-execution).
      checkpoint:  optional fault_tolerance.BCCheckpoint — durable
                   kill-and-resume (launch/bc.py --ckpt-dir).
      overlap:     collective-schedule policy, accepted for protocol
                   uniformity with the distributed entry point; a single
                   device has no collectives to overlap, so only "none"
                   is valid here.
      straggler:   sub-cluster scheduling policy, accepted for protocol
                   uniformity; a single device has no replicas to steal
                   from or re-deal to, so only "none" is valid here.
    """
    if normalize_overlap(overlap) != "none":
        raise ValueError(
            "overlap schedules are a distributed-engine feature; "
            "single-device engines have no collectives to pipeline"
        )
    if normalize_straggler(straggler) != "none":
        raise ValueError(
            "straggler scheduling is a sub-cluster feature; a single "
            "device has no replicas to steal rounds from or re-deal to"
        )
    n = graph.n
    schedule, prep, residual, omega_i = build_schedule(
        graph, batch_size=batch_size, heuristics=heuristics
    )
    omega = jnp.asarray(omega_i, jnp.float32)

    operator_fn, fused_adjacency, interpret = _make_operator_fn(
        residual, n, engine_kind
    )
    round_fn = make_round_fn(
        operator_fn,
        n,
        num_levels=num_levels,
        fused_adjacency=fused_adjacency,
        interpret=interpret,
    )

    def block_fn(sources, derived):  # [1, s], [1, k, 3] -> block-dim outputs
        bc_r, ns, roots, levels = round_fn(sources[0], derived[0], omega)
        return bc_r, ns[None], roots[None], levels[None]

    if jit:
        block_fn = jax.jit(block_fn)

    driver = BCDriver(
        block_fn, schedule, n=n, prep=prep, ledger=ledger, checkpoint=checkpoint
    )
    return driver.run()
