"""Single-device betweenness centrality driver (MGBC without the mesh).

Composes the round scheduler, the traversal engine and the heuristics
into the full exact-BC computation.  The distributed version
(:mod:`repro.core.distributed`) reuses the same schedule/round structure
with the 2-D partitioned engine; this module is both the small-graph
production path and the semantic reference for it.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.heuristics.one_degree import OneDegreeReduction, leaf_correction
from repro.core.heuristics.two_degree import derive_two_degree_columns
from repro.core.scheduler import Schedule, build_schedule
from repro.graphs.graph import Graph

__all__ = [
    "BCResult",
    "betweenness_centrality",
    "make_round_fn",
    "apply_reduction_corrections",
]


def apply_reduction_corrections(
    bc: np.ndarray,
    prep: OneDegreeReduction,
    schedule,
    ns_by_root: dict[int, float],
) -> None:
    """Add the analytic BC credits of the 1-degree/tree reduction.

    Every vertex x with removed branches (S(x) > 0) — residual or removed
    interior — gets 2·S·(n_comp−1−S) + 2·P (heuristics/one_degree.py).
    n_comp comes from x's own round, the isolated-residual analytic size,
    or (removed vertices) the resolved root's size."""
    n_by_root = dict(ns_by_root)
    for v, n_comp in schedule.analytic_corrections:
        n_by_root[int(v)] = float(n_comp)
    S, P = prep.omega, prep.pair_credit
    for x in np.nonzero(S > 0)[0]:
        x = int(x)
        if prep.removed[x]:
            root, analytic_n = prep.resolve_root(x)
            n_comp = analytic_n if analytic_n >= 0 else n_by_root.get(int(root))
        else:
            n_comp = n_by_root.get(x)
        if n_comp is None:
            raise RuntimeError(f"no component size recorded for vertex {x}")
        bc[x] += leaf_correction(S[x], n_comp, P[x])


@dataclasses.dataclass
class BCResult:
    bc: np.ndarray  # float64 [n]
    schedule: Schedule
    rounds_run: int
    forward_columns: int  # explicit BFS columns actually traversed
    backward_columns: int  # dependency columns (explicit + derived)


def make_round_fn(
    operator_fn,
    n: int,
    num_levels: int | None = None,
    fused_adjacency=None,
    interpret: bool | None = None,
):
    """Build the jit-able per-round function.

    Args:
      operator_fn:     closure () -> Operator (captures adjacency arrays).
      n:               vertex count.
      num_levels:      static level bound (dry-run) or None (early exit).
      fused_adjacency: when given, run the fused Pallas kernel path on
                       this dense adjacency instead of ``operator_fn``.
      interpret:       Pallas interpret-mode override (CPU validation).

    The returned function maps
      (sources i32 [s], derived i32 [k, 3], omega f32 [n])
        -> (bc_round f32 [n], ns f32 [s+k], roots i32 [s+k])
    """

    def round_fn(sources, derived, omega):
        vertex_ids = jnp.arange(n, dtype=jnp.int32)[:, None]
        src_onehot = (
            (vertex_ids == sources[None, :]) & (sources[None, :] >= 0)
        ).astype(jnp.float32)

        if fused_adjacency is not None:
            fwd = engine.forward_counting_fused(
                fused_adjacency, src_onehot, num_levels=num_levels, interpret=interpret
            )
        else:
            op = operator_fn()
            fwd = engine.forward_counting(op, src_onehot, num_levels=num_levels)
        sigma_c, depth_c = derive_two_degree_columns(fwd.sigma, fwd.depth, derived)
        sigma_all = jnp.concatenate([fwd.sigma, sigma_c], axis=1)
        depth_all = jnp.concatenate([fwd.depth, depth_c], axis=1)
        max_depth = jnp.max(depth_all)

        if fused_adjacency is not None:
            delta = engine.backward_accumulation_fused(
                fused_adjacency,
                sigma_all,
                depth_all,
                omega,
                max_depth,
                num_levels=num_levels,
                interpret=interpret,
            )
        else:
            delta = engine.backward_accumulation(
                op, sigma_all, depth_all, omega, max_depth, num_levels=num_levels
            )

        roots = jnp.concatenate([sources, derived[:, 0]])
        omega_root = jnp.where(
            roots >= 0, omega[jnp.clip(roots, 0, n - 1)], 0.0
        )
        mult = jnp.where(roots >= 0, omega_root + 1.0, 0.0)

        root_onehot = vertex_ids == roots[None, :]
        weighted = jnp.where(root_onehot, 0.0, delta * mult[None, :])
        bc_round = weighted.sum(axis=1)

        # per-column component size  n_s = Σ_{d ≥ 0} (1 + ω)   (paper §3.4.1)
        ns = ((depth_all >= 0) * (1.0 + omega)[:, None]).sum(axis=0)
        return bc_round, ns, roots

    return round_fn


def betweenness_centrality(
    graph: Graph,
    batch_size: int = 32,
    heuristics: str = "h0",
    engine_kind: str = "dense",
    num_levels: int | None = None,
    jit: bool = True,
) -> BCResult:
    """Exact BC of an undirected, unweighted graph (paper conventions:
    unnormalized, both traversal directions counted).

    Args:
      graph:       input graph.
      batch_size:  concurrent sources per round (multi-source width).
      heuristics:  "h0" | "h1" | "h2" | "h3" (paper Fig. 12 naming).
      engine_kind: "dense" (n×n matmul path) or "sparse" (segment-sum).
      num_levels:  optional static level bound (compile-friendly); must be
                   ≥ graph diameter + 1 when given.
    """
    n = graph.n
    schedule, prep, residual, omega_i = build_schedule(
        graph, batch_size=batch_size, heuristics=heuristics
    )
    omega = jnp.asarray(omega_i, jnp.float32)

    fused_adjacency = None
    interpret = None
    if engine_kind == "dense":
        adjacency = jnp.asarray(residual.dense_adjacency(np.float32))
        operator_fn = lambda: engine.make_dense_operator(adjacency)
    elif engine_kind == "sparse":
        src_p, dst_p, _ = residual.padded_arcs(multiple=8)
        src_j, dst_j = jnp.asarray(src_p), jnp.asarray(dst_p)
        operator_fn = lambda: engine.make_sparse_operator(src_j, dst_j, n)
    elif engine_kind in ("pallas", "pallas_bf16"):
        dt = np.float32 if engine_kind == "pallas" else jnp.bfloat16
        fused_adjacency = jnp.asarray(residual.dense_adjacency(np.float32), dt)
        operator_fn = None
        from repro.kernels.ops import on_tpu

        interpret = not on_tpu()
    else:
        raise ValueError(f"unknown engine {engine_kind!r}")

    round_fn = make_round_fn(
        operator_fn,
        n,
        num_levels=num_levels,
        fused_adjacency=fused_adjacency,
        interpret=interpret,
    )
    if jit:
        round_fn = jax.jit(round_fn)

    bc = np.zeros(n, dtype=np.float64)
    ns_by_root: dict[int, float] = {}
    fwd_cols = 0
    bwd_cols = 0
    for rnd in schedule.rounds:
        bc_round, ns, roots = round_fn(
            jnp.asarray(rnd.sources), jnp.asarray(rnd.derived), omega
        )
        bc += np.asarray(bc_round, dtype=np.float64)
        roots_np = np.asarray(roots)
        ns_np = np.asarray(ns, dtype=np.float64)
        for r, nv in zip(roots_np, ns_np):
            if r >= 0:
                ns_by_root[int(r)] = float(nv)
        fwd_cols += int((rnd.sources >= 0).sum())
        bwd_cols += int((rnd.sources >= 0).sum() + (rnd.derived[:, 0] >= 0).sum())

    if prep is not None:
        apply_reduction_corrections(bc, prep, schedule, ns_by_root)

    return BCResult(
        bc=bc,
        schedule=schedule,
        rounds_run=len(schedule.rounds),
        forward_columns=fwd_cols,
        backward_columns=bwd_cols,
    )
