"""The engine layer: the two level loops, written once.

TPU-native formulation of the paper's node-level parallelism (§3.1):
instead of queue-based frontiers with prefix-sum/binary-search data→thread
mapping (a GPU construct), one BFS level is a *masked matrix product* over
a frontier matrix ``F ∈ R^{n×s}`` holding ``s`` concurrent sources:

    forward level ℓ:   t = A @ (σ ⊙ [d = ℓ-1])
                       newly discovered:  d < 0 and t > 0  →  d := ℓ
                       path counts:       σ += t  on  d = ℓ

    backward level ℓ:  g = (1 + δ + ω) / σ  on  d = ℓ+1
                       δ += σ ⊙ (A @ g)     on  d = ℓ          (checking
                       successors — Madduri et al., no predecessor lists)

Both sweeps share the depth array ``d`` as the level structure: the paper's
"reuse the forward prefix-sum offsets in the backward sweep" optimization is
inherited structurally (there are no offsets to recompute).

:func:`forward_counting` and :func:`backward_accumulation` are the *only*
loop implementations in the repository.  They are written against the
:class:`repro.core.operators.TraversalOperator` protocol, so the same
code drives:

* dense / sparse single-device operators (XLA),
* the fused Pallas dense-block operator (one kernel launch per level),
* the 2-D distributed operators, sparse or Pallas-dense-block, inside a
  ``shard_map`` body — liveness (``newly.any()``) and the max depth are
  agreed on through the operator's collective reduction hooks.

ω is the 1-degree reduction weight vector (zeros when the heuristic is
off); the formulas above then reduce to plain Brandes.

Weighted graphs replace the level loops with *bucket* loops
(:func:`forward_buckets` / :func:`backward_buckets`): delta-stepping
distance buckets of width Δ over float32 tentative distances (+inf =
unreached), driven by the
:class:`repro.core.operators.WeightedTraversalOperator` protocol.  A
vertex is *settled* once ``dist < b·Δ`` for the current bucket b; the
frontier of bucket b is its unsettled span ``b·Δ ≤ dist < (b+1)·Δ``.
Light edges (w ≤ Δ) relax to a fixpoint inside the bucket, heavy edges
once after it; σ and δ are recomputed to fixpoints over the
within-bucket shortest-path DAG with distance-equality masks.  All
loop-bound agreements (liveness, next nonempty bucket) go through the
operator's collective hooks so distributed replicas stay in lockstep.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.operators import (
    DenseOperator,
    SparseOperator,
    TraversalOperator,
    as_operator,
)

Operator = Callable[[jnp.ndarray], jnp.ndarray]  # legacy alias (bare A @ x)

__all__ = [
    "make_dense_operator",
    "make_sparse_operator",
    "forward_counting",
    "backward_accumulation",
    "forward_buckets",
    "backward_buckets",
    "ForwardState",
    "WeightedForwardState",
]


class ForwardState(NamedTuple):
    sigma: jnp.ndarray  # f32 [n, s] shortest-path counts
    depth: jnp.ndarray  # i32 [n, s] discovery level (-1 = unreached)
    max_depth: jnp.ndarray  # i32 [] deepest level discovered
    # f32 [] max ABFT checksum residual over all levels (checksum=True
    # runs only; None otherwise — see operators.forward_level_checked)
    check_err: jnp.ndarray | None = None


def make_dense_operator(adjacency: jnp.ndarray) -> DenseOperator:
    """``A @ x`` with a dense [n, n] 0/1 adjacency (undirected ⇒ symmetric)."""
    return DenseOperator(adjacency)


def make_sparse_operator(src: jnp.ndarray, dst: jnp.ndarray, n: int) -> SparseOperator:
    """``A @ x`` via arc-list gather + segment-sum (see SparseOperator)."""
    return SparseOperator(src, dst, n)


def forward_counting(
    operator: TraversalOperator | Operator,
    src_onehot: jnp.ndarray,
    num_levels: int | None = None,
    *,
    checksum: bool = False,
) -> ForwardState:
    """Multi-source shortest-path counting (Alg. 2 analogue).

    Args:
      operator:   a TraversalOperator (or bare ``A @ x`` closure).
      src_onehot: f32 [n_rows, s]; column j is the indicator of source j
                  restricted to the operator's rows (all-zeros columns
                  are inert padding).
      num_levels: None → ``lax.while_loop`` with early exit (real runs);
                  int  → ``lax.fori_loop`` with that static trip count
                  (dry-run / roofline path, so XLA records
                  ``known_trip_count``; extra levels are no-ops).
      checksum:   run the ABFT-checked level steps and carry the running
                  max column-sum residual in ``ForwardState.check_err``
                  (state shapes are unchanged — the lane is transient
                  inside each level).
    """
    op = as_operator(operator)
    if op.n_rows < 0:
        op.n_rows = src_onehot.shape[0]
    sigma0 = src_onehot.astype(jnp.float32)
    depth0 = jnp.where(src_onehot > 0, 0, -1).astype(jnp.int32)
    err0 = jnp.float32(0.0)

    if num_levels is None:
        cap = op.level_cap()

        def cond(carry):
            return carry[3] & (carry[2] <= cap)

        def body(carry):
            sigma, depth, lvl, _, err = carry
            if checksum:
                sigma, depth, local_alive, lerr = op.forward_level_checked(
                    lvl, sigma, depth
                )
                err = jnp.maximum(err, lerr)
            else:
                sigma, depth, local_alive = op.forward_level(lvl, sigma, depth)
            return sigma, depth, lvl + 1, op.reduce_any(local_alive), err

        sigma, depth, lvl, _, err = jax.lax.while_loop(
            cond, body, (sigma0, depth0, jnp.int32(1), jnp.bool_(True), err0)
        )
        max_depth = lvl - 2  # last level that discovered anything
    else:

        def fbody(k, carry):
            sigma, depth, err = carry
            if checksum:
                sigma, depth, _, lerr = op.forward_level_checked(k + 1, sigma, depth)
                err = jnp.maximum(err, lerr)
            else:
                sigma, depth, _ = op.forward_level(k + 1, sigma, depth)
            return sigma, depth, err

        sigma, depth, err = jax.lax.fori_loop(
            0, num_levels, fbody, (sigma0, depth0, err0)
        )
        max_depth = op.reduce_max(jnp.max(depth))

    return ForwardState(
        sigma=sigma,
        depth=depth,
        max_depth=max_depth.astype(jnp.int32),
        check_err=err if checksum else None,
    )


def backward_accumulation(
    operator: TraversalOperator | Operator,
    sigma: jnp.ndarray,
    depth: jnp.ndarray,
    omega: jnp.ndarray,
    max_depth: jnp.ndarray | int,
    num_levels: int | None = None,
    *,
    checksum: bool = False,
) -> jnp.ndarray:
    """Dependency accumulation (Alg. 4/5 analogue, checking successors).

    Returns δ f32 [n_rows, s].  ``omega`` is f32 [n_rows] (1-degree
    weights; zeros disable the heuristic).  ``max_depth`` must already be
    the *global* max (callers on a mesh reduce it with
    ``op.reduce_max``).  Levels run from ``max_depth - 1`` down to 1;
    columns of different depths are handled by masking (this is what makes
    the 2-degree "Dynamic Merging of Frontiers" implicit — see
    heuristics/two_degree.py).

    With ``checksum=True`` every level runs the ABFT-checked step and the
    return value is the pair ``(δ, err)`` — ``err`` the f32 max relative
    column-sum residual across the sweep.
    """
    op = as_operator(operator)
    omega_f = omega.astype(jnp.float32)
    delta0 = jnp.zeros_like(sigma)
    err0 = jnp.float32(0.0)

    if num_levels is None:

        def cond(carry):
            return carry[1] >= 1

        def body(carry):
            delta, lvl, err = carry
            if checksum:
                delta, lerr = op.backward_level_checked(
                    lvl, sigma, depth, omega_f, delta
                )
                err = jnp.maximum(err, lerr)
            else:
                delta = op.backward_level(lvl, sigma, depth, omega_f, delta)
            return delta, lvl - 1, err

        start = jnp.asarray(max_depth, jnp.int32) - 1
        delta, _, err = jax.lax.while_loop(cond, body, (delta0, start, err0))
    else:

        def fbody(k, carry):
            delta, err = carry
            lvl = num_levels - 1 - k  # static bound; masked no-ops when deep
            if checksum:
                delta, lerr = op.backward_level_checked(
                    lvl, sigma, depth, omega_f, delta
                )
                err = jnp.maximum(err, lerr)
            else:
                delta = op.backward_level(lvl, sigma, depth, omega_f, delta)
            return delta, err

        delta, err = jax.lax.fori_loop(0, num_levels - 1, fbody, (delta0, err0))

    return (delta, err) if checksum else delta


class WeightedForwardState(NamedTuple):
    sigma: jnp.ndarray  # f32 [n, s] shortest-path counts
    dist: jnp.ndarray  # f32 [n, s] settled distances (+inf = unreached)


def forward_buckets(operator, src_onehot: jnp.ndarray) -> WeightedForwardState:
    """Multi-source weighted shortest-path counting (delta-stepping).

    The outer while_loop walks nonempty distance buckets.  Per bucket b
    (span [b·Δ, (b+1)·Δ)):

      1. light-edge relaxation to a fixpoint — the frontier is re-derived
         from the tentative distances every iteration, so vertices pulled
         *into* the bucket keep relaxing;
      2. one heavy-edge pass (bucket-b distances are final after step 1:
         any heavy relaxation lands at dist > (b+1)·Δ ≥ the bucket bound);
      3. σ fixpoint with overwrite semantics over the within-bucket
         predecessor DAG — predecessors in earlier buckets are final,
         same-bucket chains converge in DAG-depth iterations;
      4. bucket skip: jump to floor(min unsettled dist / Δ).

    Monotone-min relaxation is globally safe because w > 0: a candidate
    through any frontier vertex exceeds b·Δ, so settled vertices are
    never lowered.  The scalar bucket index is shared by all s batch
    columns (and, through ``reduce_min``/``reduce_any``, by all devices
    on the operator's loop axes) — columns without mass in the current
    bucket idle as masked no-ops, which is what keeps distributed
    replicas' trip counts equal under ``sync_axes``.

    Collective reductions are never evaluated in a while_loop *cond*
    (the liveness flag travels in the carry), matching
    :func:`forward_counting`.
    """
    op = operator
    delta_w = jnp.float32(op.delta)
    inner_cap = op.level_cap()
    # outer trips are bounded by distinct nonempty buckets across the
    # whole batch — up to n per column, so scale the safety cap by s
    outer_cap = op.level_cap() * src_onehot.shape[1] + 1
    sigma0 = src_onehot.astype(jnp.float32)
    dist0 = jnp.where(src_onehot > 0, 0.0, jnp.inf).astype(jnp.float32)

    def outer_cond(carry):
        return carry[3] & (carry[4] <= outer_cap)

    def outer_body(carry):
        sigma, dist, b, _, trips = carry
        lo = b.astype(jnp.float32) * delta_w
        hi = lo + delta_w

        # (1) light-edge relaxation fixpoint over the current bucket
        def l_cond(c):
            return c[1] & (c[2] <= inner_cap)

        def l_body(c):
            d, _, it = c
            frontier = (d >= lo) & (d < hi)
            nd = jnp.minimum(d, op.relax(d, frontier, heavy=False))
            return nd, op.reduce_any(jnp.any(nd < d)), it + 1

        dist, _, _ = jax.lax.while_loop(
            l_cond, l_body, (dist, jnp.bool_(True), jnp.int32(1))
        )

        # (2) heavy edges once: bucket-b distances are now final
        frontier = (dist >= lo) & (dist < hi)
        dist = jnp.minimum(dist, op.relax(dist, frontier, heavy=True))

        # (3) σ fixpoint (overwrite recompute over the within-bucket DAG);
        # dist > 0 keeps the roots' σ = 1 (only roots sit at distance 0
        # because w > 0)
        in_bucket = (dist >= lo) & (dist < hi) & (dist > 0)

        def s_cond(c):
            return c[1] & (c[2] <= inner_cap)

        def s_body(c):
            sg, _, it = c
            contrib = op.sigma_step(jnp.where(dist < hi, sg, 0.0), dist)
            ns = jnp.where(in_bucket, contrib, sg)
            return ns, op.reduce_any(jnp.any(ns != sg)), it + 1

        sigma, _, _ = jax.lax.while_loop(
            s_cond, s_body, (sigma, jnp.bool_(True), jnp.int32(1))
        )

        # (4) skip to the next nonempty bucket
        pending = jnp.where(dist >= hi, dist, jnp.inf)
        mind = op.reduce_min(jnp.min(pending))
        alive = jnp.isfinite(mind)
        nb = jnp.where(
            alive, jnp.floor(jnp.where(alive, mind, 0.0) / delta_w), b + 1
        ).astype(jnp.int32)
        return sigma, dist, nb, alive, trips + 1

    sigma, dist, _, _, _ = jax.lax.while_loop(
        outer_cond,
        outer_body,
        (sigma0, dist0, jnp.int32(0), jnp.bool_(True), jnp.int32(1)),
    )
    return WeightedForwardState(sigma=sigma, dist=dist)


def backward_buckets(
    operator,
    sigma: jnp.ndarray,
    dist: jnp.ndarray,
    omega: jnp.ndarray,
    max_bucket: jnp.ndarray | int,
) -> jnp.ndarray:
    """Weighted dependency accumulation in descending bucket order.

    Returns δ f32 [n_rows, s].  ``max_bucket`` must already be the
    *global* max bucket index (callers on a mesh reduce it with
    ``op.reduce_max_grid`` / ``reduce_max_sync``), so every replica runs
    exactly ``max_bucket + 1`` outer trips — there is deliberately no
    backward bucket skipping, preserving replica lockstep.

    Per bucket (descending): successors in deeper buckets are final in
    δ, lower buckets are excluded by the ``dist ≥ b·Δ`` mask on g, and
    same-bucket successor chains converge through the inner fixpoint.
    The root rows keep δ = 0 through the ``dist > 0`` mask.
    """
    op = operator
    delta_w = jnp.float32(op.delta)
    inner_cap = op.level_cap()
    omega_col = omega.astype(jnp.float32)[:, None]
    safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
    finite = jnp.isfinite(dist)
    delta0 = jnp.zeros_like(sigma)

    def cond(carry):
        return carry[1] >= 0

    def body(carry):
        dacc, b = carry
        lo = b.astype(jnp.float32) * delta_w
        hi = lo + delta_w
        in_bucket = finite & (dist >= lo) & (dist < hi) & (dist > 0)

        def i_cond(c):
            return c[1] & (c[2] <= inner_cap)

        def i_body(c):
            da, _, it = c
            g = jnp.where(
                finite & (dist >= lo), (1.0 + da + omega_col) / safe_sigma, 0.0
            )
            term = sigma * op.delta_step(g, dist)
            nd = jnp.where(in_bucket, term, da)
            return nd, op.reduce_any(jnp.any(nd != da)), it + 1

        dacc, _, _ = jax.lax.while_loop(
            i_cond, i_body, (dacc, jnp.bool_(True), jnp.int32(1))
        )
        return dacc, b - 1

    start = jnp.asarray(max_bucket, jnp.int32)
    dacc, _ = jax.lax.while_loop(cond, body, (delta0, start))
    return dacc
