"""Single-device traversal engine: multi-source BFS + dependency sweep.

TPU-native formulation of the paper's node-level parallelism (§3.1):
instead of queue-based frontiers with prefix-sum/binary-search data→thread
mapping (a GPU construct), one BFS level is a *masked matrix product* over
a frontier matrix ``F ∈ R^{n×s}`` holding ``s`` concurrent sources:

    forward level ℓ:   t = A @ (σ ⊙ [d = ℓ-1])
                       newly discovered:  d < 0 and t > 0  →  d := ℓ
                       path counts:       σ += t  on  d = ℓ

    backward level ℓ:  g = (1 + δ + ω) / σ  on  d = ℓ+1
                       δ += σ ⊙ (A @ g)     on  d = ℓ          (checking
                       successors — Madduri et al., no predecessor lists)

Both sweeps share the depth array ``d`` as the level structure: the paper's
"reuse the forward prefix-sum offsets in the backward sweep" optimization is
inherited structurally (there are no offsets to recompute).

Two interchangeable operators provide ``A @ x``:

* dense  — ``[n, n]`` 0/1 matrix on the MXU (small graphs, Pallas kernel
  target, and the per-block compute of the distributed engine);
* sparse — padded symmetric arc list + gather/``segment_sum`` (the TPU
  replacement for the paper's atomic scatter-adds).

ω is the 1-degree reduction weight vector (zeros when the heuristic is
off); the formulas above then reduce to plain Brandes.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Operator = Callable[[jnp.ndarray], jnp.ndarray]

__all__ = [
    "make_dense_operator",
    "make_sparse_operator",
    "forward_counting",
    "backward_accumulation",
    "forward_counting_fused",
    "backward_accumulation_fused",
    "ForwardState",
]


class ForwardState(NamedTuple):
    sigma: jnp.ndarray  # f32 [n, s] shortest-path counts
    depth: jnp.ndarray  # i32 [n, s] discovery level (-1 = unreached)
    max_depth: jnp.ndarray  # i32 [] deepest level discovered


def make_dense_operator(adjacency: jnp.ndarray) -> Operator:
    """``A @ x`` with a dense [n, n] 0/1 adjacency (undirected ⇒ symmetric)."""

    def apply(x: jnp.ndarray) -> jnp.ndarray:
        return adjacency @ x

    return apply


def make_sparse_operator(src: jnp.ndarray, dst: jnp.ndarray, n: int) -> Operator:
    """``A @ x`` via arc-list gather + segment-sum.

    ``src``/``dst`` are the padded symmetric arc arrays; padding arcs use
    the sentinel vertex ``n`` on both endpoints, which reads from / writes
    to a discarded extra row. ``out[v] = Σ_{(u,v) arcs} x[u]``.
    """

    def apply(x: jnp.ndarray) -> jnp.ndarray:
        x_pad = jnp.concatenate([x, jnp.zeros((1,) + x.shape[1:], x.dtype)], axis=0)
        msgs = x_pad[src]
        out = jax.ops.segment_sum(msgs, dst, num_segments=n + 1)
        return out[:n]

    return apply


def _forward_level(operator: Operator, lvl, sigma, depth):
    frontier = sigma * (depth == lvl - 1)
    contrib = operator(frontier)
    newly = (contrib > 0) & (depth < 0)
    depth = jnp.where(newly, lvl, depth)
    sigma = sigma + jnp.where(newly, contrib, 0.0)
    return sigma, depth, newly.any()


def forward_counting(
    operator: Operator,
    src_onehot: jnp.ndarray,
    num_levels: int | None = None,
) -> ForwardState:
    """Multi-source shortest-path counting (Alg. 2 analogue).

    Args:
      operator:   ``A @ x`` closure.
      src_onehot: f32 [n, s]; column j is the indicator of source j
                  (all-zeros columns are inert padding).
      num_levels: None → ``lax.while_loop`` with early exit (real runs);
                  int  → ``lax.fori_loop`` with that static trip count
                  (dry-run / roofline path, so XLA records
                  ``known_trip_count``; extra levels are no-ops).
    """
    n = src_onehot.shape[0]
    sigma0 = src_onehot.astype(jnp.float32)
    depth0 = jnp.where(src_onehot > 0, 0, -1).astype(jnp.int32)

    if num_levels is None:

        def cond(carry):
            _, _, lvl, alive = carry
            return alive & (lvl <= n)

        def body(carry):
            sigma, depth, lvl, _ = carry
            sigma, depth, alive = _forward_level(operator, lvl, sigma, depth)
            return sigma, depth, lvl + 1, alive

        sigma, depth, lvl, _ = jax.lax.while_loop(
            cond, body, (sigma0, depth0, jnp.int32(1), jnp.bool_(True))
        )
        max_depth = lvl - 2  # last level that discovered anything
    else:

        def fbody(k, carry):
            sigma, depth = carry
            sigma, depth, _ = _forward_level(operator, k + 1, sigma, depth)
            return sigma, depth

        sigma, depth = jax.lax.fori_loop(0, num_levels, fbody, (sigma0, depth0))
        max_depth = jnp.max(depth)

    return ForwardState(sigma=sigma, depth=depth, max_depth=max_depth.astype(jnp.int32))


def _backward_level(operator: Operator, lvl, sigma, depth, omega_col, delta):
    safe_sigma = jnp.where(sigma > 0, sigma, 1.0)
    g = jnp.where(depth == lvl + 1, (1.0 + delta + omega_col) / safe_sigma, 0.0)
    t = operator(g)
    return delta + jnp.where(depth == lvl, sigma * t, 0.0)


def backward_accumulation(
    operator: Operator,
    sigma: jnp.ndarray,
    depth: jnp.ndarray,
    omega: jnp.ndarray,
    max_depth: jnp.ndarray | int,
    num_levels: int | None = None,
) -> jnp.ndarray:
    """Dependency accumulation (Alg. 4/5 analogue, checking successors).

    Returns δ f32 [n, s].  ``omega`` is f32 [n] (1-degree weights; zeros
    disable the heuristic).  Levels run from ``max_depth - 1`` down to 1;
    columns of different depths are handled by masking (this is what makes
    the 2-degree "Dynamic Merging of Frontiers" implicit — see
    heuristics/two_degree.py).
    """
    omega_col = omega.astype(jnp.float32)[:, None]
    delta0 = jnp.zeros_like(sigma)

    if num_levels is None:

        def cond(carry):
            _, lvl = carry
            return lvl >= 1

        def body(carry):
            delta, lvl = carry
            delta = _backward_level(operator, lvl, sigma, depth, omega_col, delta)
            return delta, lvl - 1

        start = jnp.asarray(max_depth, jnp.int32) - 1
        delta, _ = jax.lax.while_loop(cond, body, (delta0, start))
    else:

        def fbody(k, delta):
            lvl = num_levels - 1 - k  # static bound; masked no-ops when deep
            return _backward_level(operator, lvl, sigma, depth, omega_col, delta)

        delta = jax.lax.fori_loop(0, num_levels - 1, fbody, delta0)

    return delta


# --------------------------------------------------------------------------
# Fused Pallas-kernel paths (kernels/frontier_spmm.py, dependency_spmm.py):
# identical semantics, one kernel launch per level, no HBM-materialized
# frontier/g intermediates.  Dense adjacency only.
# --------------------------------------------------------------------------


def forward_counting_fused(
    adjacency: jnp.ndarray,
    src_onehot: jnp.ndarray,
    num_levels: int | None = None,
    interpret: bool | None = None,
) -> ForwardState:
    """Kernel-fused forward counting (semantics == forward_counting)."""
    from repro.kernels import ops as kops

    sigma0 = src_onehot.astype(jnp.float32)
    depth0 = jnp.where(src_onehot > 0, 0, -1).astype(jnp.int32)
    n = src_onehot.shape[0]

    def level(lvl, sigma, depth):
        return kops.frontier_spmm(adjacency, sigma, depth, lvl, interpret=interpret)

    if num_levels is None:

        def cond(carry):
            _, _, lvl, alive = carry
            return alive & (lvl <= n)

        def body(carry):
            sigma, depth, lvl, _ = carry
            sigma2, depth2 = level(lvl, sigma, depth)
            alive = jnp.any(depth2 != depth)
            return sigma2, depth2, lvl + 1, alive

        sigma, depth, lvl, _ = jax.lax.while_loop(
            cond, body, (sigma0, depth0, jnp.int32(1), jnp.bool_(True))
        )
        max_depth = lvl - 2
    else:

        def fbody(k, carry):
            sigma, depth = carry
            return level(k + 1, sigma, depth)

        sigma, depth = jax.lax.fori_loop(0, num_levels, fbody, (sigma0, depth0))
        max_depth = jnp.max(depth)

    return ForwardState(sigma=sigma, depth=depth, max_depth=max_depth.astype(jnp.int32))


def backward_accumulation_fused(
    adjacency: jnp.ndarray,
    sigma: jnp.ndarray,
    depth: jnp.ndarray,
    omega: jnp.ndarray,
    max_depth: jnp.ndarray | int,
    num_levels: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-fused dependency accumulation (== backward_accumulation)."""
    from repro.kernels import ops as kops

    omega_f = omega.astype(jnp.float32)
    delta0 = jnp.zeros_like(sigma)

    def level(lvl, delta):
        return kops.dependency_spmm(
            adjacency, sigma, depth, delta, omega_f, lvl, interpret=interpret
        )

    if num_levels is None:

        def cond(carry):
            _, lvl = carry
            return lvl >= 1

        def body(carry):
            delta, lvl = carry
            return level(lvl, delta), lvl - 1

        start = jnp.asarray(max_depth, jnp.int32) - 1
        delta, _ = jax.lax.while_loop(cond, body, (delta0, start))
    else:

        def fbody(k, delta):
            return level(num_levels - 1 - k, delta)

        delta = jax.lax.fori_loop(0, num_levels - 1, fbody, delta0)

    return delta
