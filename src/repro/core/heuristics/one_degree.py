"""1-degree reduction (paper §3.4.1, multi-component safe).

Preprocessing removes every vertex of degree 1 and records on its
neighbor ``v`` the weight ``ω(v)`` = number of removed leaves.  The BC of
a removed leaf is 0; the BC the leaves *induce* on the rest of the graph
is recovered exactly by three mechanisms (validated against the numpy
oracle in tests/test_heuristics.py):

1. the dependency recursion gains ``+ω(w)``:
       δ_s(v) = Σ_w (σ_sv/σ_sw) (1 + δ_s(w) + ω(w))
   (paths *terminating in* a removed leaf of w);
2. every round rooted at a residual source s is counted with multiplicity
   ``(ω(s)+1)`` (paths *originating from* a removed leaf of s are
   identical to paths from s for all interior vertices other than s);
3. the **leaf correction** credits v itself for paths entering its leaves:
   removing the j-th leaf contributes ``2·(n_comp − j − 1)`` (ordered
   pairs), i.e. in closed form
       BC(v) += 2·ω_v·(n_comp − 1) − ω_v·(ω_v + 1)
   where ``n_comp`` is the size of v's connected component *including*
   removed vertices.  Because the paper supports multiple components,
   ``n_comp`` is not known at preprocessing time; it is recovered during
   v's own traversal as ``n_v = Σ_{u: d_v[u] ≥ 0} (1 + ω(u))`` and the
   correction is applied post-round (paper's option ii — reduction over
   the distance array).  Residual-isolated vertices (every neighbor was a
   leaf) need no traversal: ``n_v = 1 + ω_v`` analytically.

The paper performs a *single* pass (tree vertices are not removed
repeatedly — their footnote 1); we match that default.  Selected as
``heuristics="h1"`` (or "h3" combined with the 2-degree DMF); the
``exhaustive=True`` fixed-point variant is the beyond-paper
"h1t"/"h3t" mode (:data:`repro.core.scheduler.HEURISTICS_MODES`,
README.md § Heuristics).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graphs.graph import Graph

__all__ = ["OneDegreeReduction", "one_degree_reduce", "leaf_correction"]


@dataclasses.dataclass(frozen=True)
class OneDegreeReduction:
    """Result of the preprocessing pass(es).

    Beyond-paper generalization (the paper stops at a single pass — their
    footnote 1): with ``exhaustive=True`` whole pendant *trees* contract.
    Each removed vertex u carries weight ``w(u) = 1 + Σ w(children)``
    (original vertices it represents); per vertex x:

      S(x) = Σ w(removed children of x)   — the generalized ω
      P(x) = Σ_{i<j} w_i·w_j              — cross-branch pair count

    The exact BC credit for the pairs routed through x by its removed
    branches is (derivation in DESIGN.md §2; validated vs. the oracle):

      BC(x) += 2·S·(n_comp − 1 − S) + 2·P

    which reduces to the paper's single-pass formula when all w_i = 1.
    Removed *interior* vertices (tree contraction only) get the same
    credit — they have nonzero BC, unlike the paper's leaves.

    Attributes:
      residual:    graph with removed vertices' arcs dropped.
      omega:       f64 [n] — S(x) (the paper's ω generalized to weights).
      pair_credit: f64 [n] — P(x).
      weight:      f64 [n] — w(x) (1 for residual vertices).
      parent:      i64 [n] — removal attachment (-1 = not removed).
      removed:     bool [n].
      num_removed: total removed vertices.
      iterations:  passes executed.
    """

    residual: Graph
    omega: np.ndarray
    pair_credit: np.ndarray
    weight: np.ndarray
    parent: np.ndarray
    removed: np.ndarray
    num_removed: int
    iterations: int

    def resolve_root(self, u: int) -> tuple[int, float]:
        """(residual root, analytic n_comp or -1) for a removed vertex.

        Walks the parent chain; a 2-cycle means the whole component
        contracted into a mutual K2 pair, whose size is w(u)+w(v)."""
        seen = {u}
        x = u
        while self.removed[x]:
            nxt = int(self.parent[x])
            if nxt in seen:  # mutual-leaf terminal pair
                return x, float(self.weight[x] + self.weight[nxt])
            seen.add(nxt)
            x = nxt
        return x, -1.0


def one_degree_reduce(graph: Graph, exhaustive: bool = False) -> OneDegreeReduction:
    """Vectorized 1-degree removal (Alg. 6 analogue); ``exhaustive=True``
    repeats to a fixed point (pendant-tree contraction, beyond-paper).

    The sequential Alg. 6 sorts edges by source and scans; the equivalent
    data-parallel formulation below is what the distributed version
    (repro/core/distributed.py) executes per shard with a psum'd degree.
    """
    n = graph.n
    src = graph.src.copy()
    dst = graph.dst.copy()
    alive = np.ones(len(src), bool)
    removed = np.zeros(n, bool)
    S = np.zeros(n, np.float64)
    P = np.zeros(n, np.float64)
    w = np.ones(n, np.float64)
    parent = np.full(n, -1, np.int64)

    max_passes = n if exhaustive else 1
    it = 0
    for it in range(1, max_passes + 1):
        deg = np.bincount(src[alive], minlength=n)
        leaf = (deg == 1) & ~removed
        if not leaf.any():
            it -= 1
            break
        m = alive & leaf[src]  # exactly one arc per leaf
        us, vs = src[m], dst[m]
        w_final = 1.0 + S[us]  # finalize the leaf's own subtree weight
        w[us] = w_final
        sum_w = np.zeros(n, np.float64)
        np.add.at(sum_w, vs, w_final)
        sum_w2 = np.zeros(n, np.float64)
        np.add.at(sum_w2, vs, w_final**2)
        # ΔP = S_before·ΔS + Σ_{i<j} w_i w_j  (within this pass)
        P += S * sum_w + (sum_w**2 - sum_w2) / 2.0
        S += sum_w
        parent[us] = vs
        removed[us] = True
        alive &= ~(leaf[src] | leaf[dst])

    residual = Graph(
        n=n,
        src=src[alive],
        dst=dst[alive],
        w=None if graph.w is None else graph.w[alive],
    )
    return OneDegreeReduction(
        residual=residual,
        omega=S,
        pair_credit=P,
        weight=w,
        parent=parent,
        removed=removed,
        num_removed=int(removed.sum()),
        iterations=it,
    )


def leaf_correction(
    omega_v: np.ndarray, n_comp: np.ndarray, pair_credit: np.ndarray | None = None
) -> np.ndarray:
    """Closed-form BC credit for a vertex whose removed branches weigh
    S = omega_v with cross-branch pair count P (see class docstring):

        2·S·(n_comp − 1 − S) + 2·P

    With unit weights (single pass) P = C(S,2) and this reduces to the
    paper's Σ 2·(n − j − 1).  Validated: K_{1,k} center gets k(k-1)."""
    s = omega_v.astype(np.float64)
    if pair_credit is None:
        pair_credit = s * (s - 1.0) / 2.0  # unit-weight branches
    return 2.0 * s * (n_comp - 1.0 - s) + 2.0 * pair_credit
