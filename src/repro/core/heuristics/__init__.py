"""Topology heuristics (paper §3.4): 1-degree reduction, 2-degree DMF."""
from repro.core.heuristics.one_degree import OneDegreeReduction, one_degree_reduce
from repro.core.heuristics.two_degree import claim_two_degree, derive_two_degree_columns

__all__ = [
    "OneDegreeReduction",
    "one_degree_reduce",
    "claim_two_degree",
    "derive_two_degree_columns",
]
