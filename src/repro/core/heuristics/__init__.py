"""Topology heuristics (paper §3.4): 1-degree reduction, 2-degree DMF.

The ``heuristics=`` selector threading through ``build_schedule``, both
BC entry points and ``launch/bc.py --heuristics`` maps to these modules
as follows (:data:`repro.core.scheduler.HEURISTICS_MODES`, paper Fig. 12
naming; see README.md § Heuristics):

  h0    no preprocessing — every eligible vertex runs a forward BFS.
  h1    1-degree reduction (one_degree.py): degree-1 vertices are never
        traversed; their exact BC credit is recovered by the ω-weighted
        recursion + the post-round leaf correction.
  h2    2-degree Dynamic Merging of Frontiers (two_degree.py): a
        2-degree vertex's forward column is *derived* (Alg. 7) from its
        two neighbors' columns in the same round — only its backward
        sweep runs.
  h3    h1 + h2 (the heuristics compose: h2 claims 2-degree vertices of
        the h1 residual graph).
  h1t / h3t   beyond-paper: the 1-degree pass repeats to a fixed point,
        contracting whole pendant trees (one_degree.py
        ``exhaustive=True``); removed interior vertices get the
        generalized 2·S·(n−1−S) + 2·P credit.
"""
from repro.core.heuristics.one_degree import OneDegreeReduction, one_degree_reduce
from repro.core.heuristics.two_degree import claim_two_degree, derive_two_degree_columns

__all__ = [
    "OneDegreeReduction",
    "one_degree_reduce",
    "claim_two_degree",
    "derive_two_degree_columns",
]
