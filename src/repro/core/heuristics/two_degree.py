"""2-degree heuristic — Dynamic Merging of Frontiers (paper §3.4.2).

For a 2-degree vertex ``c`` with neighbors ``a`` and ``b``, every path
from ``c`` starts with a or b, so (Lemma 3.1 + Bellman criterion):

    lvl_c(v) = min(lvl_a(v), lvl_b(v)) + 1
    σ_c(v)   = σ_a(v)            if lvl_a(v) < lvl_b(v)
             = σ_b(v)            if lvl_b(v) < lvl_a(v)
             = σ_a(v) + σ_b(v)   if equal

The forward BFS from ``c`` is therefore *skipped*: its (σ, lvl) column is
derived elementwise (Alg. 7) from the columns of a and b computed in the
same round, and only the backward dependency sweep runs for c.

The paper's Algorithms 8/9 interleave the dependency sweeps of a, b and c
explicitly "level by level" because their GPU engine walks one source
tree at a time.  In the frontier-matrix formulation of
:mod:`repro.core.engine`, the backward sweep is level-synchronous over
*all* columns by construction — appending the derived column to the batch
IS the Dynamic Merging of Frontiers.  A welcome consequence: the paper's
restriction that 2-degree vertices sharing a neighbor cannot all be
processed (their §4.4: only 61701 of 77265 handled) disappears — the only
requirement is that both neighbors are explicit sources of the same
round.  The claim below therefore recovers ⌊n/2⌋ vertices on a cycle
(the paper's upper bound) and strictly more than the paper's
implementation on shared-neighbor topologies.

Selected as ``heuristics="h2"`` (or "h3"/"h3t" combined with the
1-degree reduction, which runs first: degrees here are *residual*
degrees — :data:`repro.core.scheduler.HEURISTICS_MODES`, README.md
§ Heuristics).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

__all__ = ["claim_two_degree", "derive_two_degree_columns"]


def claim_two_degree(
    residual_degrees: np.ndarray,
    adjacency: list[np.ndarray],
    eligible: np.ndarray,
) -> list[tuple[int, int, int]]:
    """Greedy selection of derivable 2-degree vertices.

    A vertex ``c`` with residual degree exactly 2 and neighbors ``a ≠ b``
    is claimed iff neither neighbor has itself been claimed (claimed
    vertices are skipped as sources, so their columns would not exist to
    derive from).  Returns a list of (c, a, b) triples.

    Args:
      residual_degrees: int [n] degrees in the residual graph.
      adjacency:        residual adjacency lists.
      eligible:         bool [n] — vertices that will run as sources.
    """
    n = residual_degrees.shape[0]
    claimed = np.zeros(n, dtype=bool)  # will be derived, not traversed
    pinned = np.zeros(n, dtype=bool)  # must stay an explicit source
    triples: list[tuple[int, int, int]] = []
    for c in np.nonzero(residual_degrees == 2)[0]:
        if not eligible[c] or pinned[c]:
            continue
        nbrs = adjacency[c]
        if len(nbrs) != 2:
            continue
        a, b = int(nbrs[0]), int(nbrs[1])
        if a == b or claimed[a] or claimed[b]:
            continue
        if not (eligible[a] and eligible[b]):
            continue
        claimed[c] = True
        pinned[a] = pinned[b] = True
        triples.append((int(c), a, b))
    return triples


def derive_two_degree_columns(
    sigma_ab: jnp.ndarray,
    depth_ab: jnp.ndarray,
    derived: jnp.ndarray,
    row_ids: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Alg. 7 — derive (σ_c, lvl_c) columns from neighbor columns.

    Args:
      sigma_ab: f32 [n, s] forward σ of the round's explicit sources.
      depth_ab: i32 [n, s] forward depths.
      derived:  i32 [k, 3] rows (c, a_pos, b_pos); positions index the
                round's source axis.  Padding rows use c = -1.
      row_ids:  i32 [n] global vertex id of each local row (defaults to
                ``arange(n)``; the 2-D distributed engine passes its
                owned-chunk ids).

    Returns (σ_c [n, k], d_c [n, k]); padded columns are inert (all zero
    σ, depth -1).
    """
    n = sigma_ab.shape[0]
    c_idx = derived[:, 0]
    a_pos = jnp.maximum(derived[:, 1], 0)
    b_pos = jnp.maximum(derived[:, 2], 0)

    sa = sigma_ab[:, a_pos]  # [n, k]
    sb = sigma_ab[:, b_pos]
    da = depth_ab[:, a_pos]
    db = depth_ab[:, b_pos]

    big = jnp.int32(jnp.iinfo(jnp.int32).max // 2)
    la = jnp.where(da >= 0, da, big)
    lb = jnp.where(db >= 0, db, big)
    lc = jnp.minimum(la, lb) + 1
    dc = jnp.where(lc < big, lc, -1).astype(jnp.int32)
    sc = jnp.where(la < lb, sa, 0.0) + jnp.where(lb < la, sb, 0.0)
    sc = sc + jnp.where(la == lb, sa + sb, 0.0)
    sc = jnp.where(dc >= 0, sc, 0.0)

    # the 2-degree vertex itself is the root of its own derived tree
    if row_ids is None:
        row_ids = jnp.arange(n, dtype=jnp.int32)
    is_c = row_ids[:, None] == c_idx[None, :]
    dc = jnp.where(is_c, 0, dc)
    sc = jnp.where(is_c, 1.0, sc)

    # padding columns (c == -1) are fully inert
    valid = (c_idx >= 0)[None, :]
    dc = jnp.where(valid, dc, -1)
    sc = jnp.where(valid, sc, 0.0)
    return sc, dc
