"""The paper's primary contribution: Multi-GPU (here: multi-pod TPU)
exact Betweenness Centrality — MGBC.

Layers (paper §3):
  engine.py       node-level parallelism — multi-source frontier-matrix
                  traversal (active-edge analogue on the MXU)
  distributed.py  cluster-level — 2-D decomposition over a device mesh
                  (expand/fold collectives) + sub-cluster replication
  scheduler.py    source rounds: the unit of jit, checkpoint, elasticity
  heuristics/     1-degree reduction and 2-degree DMF
  bc.py           single-device driver (semantic reference)
  brandes_ref.py  numpy oracle (Algorithm 1)
"""
from repro.core.bc import BCResult, betweenness_centrality
from repro.core.brandes_ref import brandes_reference

__all__ = ["BCResult", "betweenness_centrality", "brandes_reference"]
