"""The paper's primary contribution: Multi-GPU (here: multi-pod TPU)
exact Betweenness Centrality — MGBC.

Layers (paper §3; see ARCHITECTURE.md for the full picture):
  operators.py    operator layer — TraversalOperator protocol: dense,
                  sparse, fused-Pallas, 2-D-distributed (sparse and
                  Pallas dense-block) implementations of one level
  engine.py       engine layer — the single forward/backward level-loop
                  pair, written against the protocol
  driver.py       driver layer — shared round body (traversal_round) and
                  host round loop (BCDriver: async dispatch, donated BC
                  accumulator, checkpoint/ledger resume, multi-ledger
                  straggler steal/re-deal scheduling)
  bc.py           single-device entry point (semantic reference)
  distributed.py  2-D decomposition over a device mesh (expand/fold
                  collectives) + sub-cluster replication entry point
  scheduler.py    source rounds: the unit of jit, checkpoint, elasticity
  heuristics/     1-degree reduction and 2-degree DMF
  brandes_ref.py  numpy oracle (Algorithm 1)
"""
from repro.core.bc import BCResult, betweenness_centrality
from repro.core.brandes_ref import brandes_reference

__all__ = ["BCResult", "betweenness_centrality", "brandes_reference"]
