"""Reference Brandes' algorithm (Algorithm 1 of the paper), pure numpy.

This is the correctness oracle for every other implementation in the
repository: the JAX single-device engine, the 2-D distributed engine and
all heuristic paths must match it to float tolerance.  O(nm); use on
small/medium graphs only.

Weighted graphs (``graph.w`` set) use the Dijkstra variant: the BFS
queue becomes a binary heap, the predecessor test becomes
``dist[w] == dist[v] + w_vw`` and the dependency sweep walks vertices in
descending settled-distance order (Brandes 2001, §4).
"""
from __future__ import annotations

import heapq
from collections import deque

import numpy as np

from repro.graphs.graph import Graph

__all__ = [
    "brandes_reference",
    "single_source_dependencies",
    "single_source_dependencies_weighted",
]


def single_source_dependencies(
    adj: list[np.ndarray], n: int, s: int, dtype=np.float64
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One Brandes round from source ``s``.

    Returns (delta [n], sigma [n], depth [n]); depth is -1 off-component.
    """
    sigma = np.zeros(n, dtype=dtype)
    depth = np.full(n, -1, dtype=np.int64)
    sigma[s] = 1.0
    depth[s] = 0
    order: list[int] = []
    q: deque[int] = deque([s])
    while q:
        v = q.popleft()
        order.append(v)
        for w in adj[v]:
            if depth[w] < 0:
                depth[w] = depth[v] + 1
                q.append(w)
            if depth[w] == depth[v] + 1:
                sigma[w] += sigma[v]
    delta = np.zeros(n, dtype=dtype)
    for w in reversed(order):
        for v in adj[w]:
            if depth[v] == depth[w] - 1:
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
    return delta, sigma, depth


def single_source_dependencies_weighted(
    wadj: list[tuple[np.ndarray, np.ndarray]], n: int, s: int, dtype=np.float64
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One weighted Brandes round from source ``s`` (Dijkstra forward).

    Returns (delta [n], sigma [n], dist [n]); dist is +inf off-component.
    """
    sigma = np.zeros(n, dtype=dtype)
    dist = np.full(n, np.inf, dtype=dtype)
    sigma[s] = 1.0
    dist[s] = 0.0
    settled = np.zeros(n, dtype=bool)
    order: list[int] = []
    heap: list[tuple[float, int]] = [(0.0, s)]
    while heap:
        dv, v = heapq.heappop(heap)
        if settled[v] or dv > dist[v]:
            continue
        settled[v] = True
        order.append(v)
        nbrs, ws = wadj[v]
        for w, wt in zip(nbrs, ws):
            cand = dist[v] + float(wt)
            if cand < dist[w]:
                dist[w] = cand
                sigma[w] = sigma[v]
                heapq.heappush(heap, (cand, int(w)))
            elif cand == dist[w] and not settled[w]:
                sigma[w] += sigma[v]
    delta = np.zeros(n, dtype=dtype)
    for w in reversed(order):
        nbrs, ws = wadj[w]
        for v, wt in zip(nbrs, ws):
            if dist[v] + float(wt) == dist[w] and sigma[w] > 0:
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
    return delta, sigma, dist


def brandes_reference(
    graph: Graph, sources: np.ndarray | None = None, dtype=np.float64
) -> np.ndarray:
    """Exact betweenness centrality scores (unnormalized, ordered-pair
    convention: for undirected graphs every unordered pair contributes to
    both directions, as in the paper's Formula (1)).  Weighted graphs
    dispatch to the Dijkstra round automatically."""
    n = graph.n
    bc = np.zeros(n, dtype=dtype)
    if sources is None:
        sources = np.arange(n)
    if graph.w is not None:
        wadj = graph.weighted_adjacency_lists()
        for s in sources:
            delta, _, _ = single_source_dependencies_weighted(wadj, n, int(s), dtype=dtype)
            delta[int(s)] = 0.0
            bc += delta
        return bc
    adj = graph.adjacency_lists()
    for s in sources:
        delta, _, _ = single_source_dependencies(adj, n, int(s), dtype=dtype)
        delta[int(s)] = 0.0
        bc += delta
    return bc
