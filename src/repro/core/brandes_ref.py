"""Reference Brandes' algorithm (Algorithm 1 of the paper), pure numpy.

This is the correctness oracle for every other implementation in the
repository: the JAX single-device engine, the 2-D distributed engine and
all heuristic paths must match it to float tolerance.  O(nm); use on
small/medium graphs only.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.graphs.graph import Graph

__all__ = ["brandes_reference", "single_source_dependencies"]


def single_source_dependencies(
    adj: list[np.ndarray], n: int, s: int, dtype=np.float64
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One Brandes round from source ``s``.

    Returns (delta [n], sigma [n], depth [n]); depth is -1 off-component.
    """
    sigma = np.zeros(n, dtype=dtype)
    depth = np.full(n, -1, dtype=np.int64)
    sigma[s] = 1.0
    depth[s] = 0
    order: list[int] = []
    q: deque[int] = deque([s])
    while q:
        v = q.popleft()
        order.append(v)
        for w in adj[v]:
            if depth[w] < 0:
                depth[w] = depth[v] + 1
                q.append(w)
            if depth[w] == depth[v] + 1:
                sigma[w] += sigma[v]
    delta = np.zeros(n, dtype=dtype)
    for w in reversed(order):
        for v in adj[w]:
            if depth[v] == depth[w] - 1:
                delta[v] += sigma[v] / sigma[w] * (1.0 + delta[w])
    return delta, sigma, depth


def brandes_reference(
    graph: Graph, sources: np.ndarray | None = None, dtype=np.float64
) -> np.ndarray:
    """Exact betweenness centrality scores (unnormalized, ordered-pair
    convention: for undirected graphs every unordered pair contributes to
    both directions, as in the paper's Formula (1))."""
    n = graph.n
    adj = graph.adjacency_lists()
    bc = np.zeros(n, dtype=dtype)
    if sources is None:
        sources = np.arange(n)
    for s in sources:
        delta, _, _ = single_source_dependencies(adj, n, int(s), dtype=dtype)
        delta[int(s)] = 0.0
        bc += delta
    return bc
