"""Source-round scheduler.

Brandes' outer loop is embarrassingly parallel over source vertices; the
scheduler turns the eligible source set into fixed-shape *rounds* (the
unit of jit compilation, checkpointing, straggler re-execution and
sub-cluster distribution):

* every round holds ``batch_size`` explicit sources (padded with -1) and
  up to ``derived_per_round`` 2-degree derived columns (c, a_pos, b_pos);
* a derived vertex's two neighbors must be explicit sources *of the same
  round* (their forward columns feed Alg. 7); the packer keeps triples
  intact and demotes a triple to an explicit source on conflict —
  demotion is always correct, only marginally slower;
* rounds are the elastic work unit: on a shrink/grow event the remaining
  rounds are simply re-dealt to the surviving sub-clusters
  (distributed/fault_tolerance.py), and a straggling round can be
  re-issued wholesale because BC accumulation is additive and
  order-independent.

:func:`split_rounds` and :func:`redeal_rounds` are the sub-cluster side
of that elasticity: the static per-replica deal and the straggler
re-deal re-pack consumed by :class:`repro.core.driver.BCDriver`.  Both
are pure functions over round ids so the scheduling policy is
unit-testable without a mesh.
"""
from __future__ import annotations

import dataclasses
import logging

import numpy as np

from repro.core.heuristics.one_degree import OneDegreeReduction, one_degree_reduce
from repro.core.heuristics.two_degree import claim_two_degree
from repro.graphs.graph import Graph

logger = logging.getLogger(__name__)

__all__ = [
    "Round",
    "Schedule",
    "build_schedule",
    "HEURISTICS_MODES",
    "ROOT_ORDERS",
    "MXU_LANES",
    "bfs_depths",
    "estimate_eccentricities",
    "split_rounds",
    "redeal_rounds",
    "validate_batch_size",
]

#: The heuristics selector (paper Fig. 12 naming), the single source of
#: truth for ``--heuristics`` choices and the documentation drift check
#: (tools/check_docs.py): "h0" no heuristics | "h1" 1-degree reduction |
#: "h2" 2-degree DMF | "h3" both; the "t" suffix ("h1t" / "h3t") runs the
#: 1-degree pass to a fixed point (beyond-paper pendant-tree contraction,
#: heuristics/one_degree.py).
HEURISTICS_MODES = ("h0", "h1", "h2", "h3", "h1t", "h3t")

#: explicit-source round-packing orders: "id" fills rounds in vertex-id
#: order (legacy); "eccentricity" sorts by sampled eccentricity
#: descending so similar-depth roots share a round — a round's traversal
#: runs to its *deepest* root's level, so a shallow root batched with a
#: deep one burns the depth difference as masked no-op levels, and under
#: replica lockstep (ring overlap) a whole replica can idle the same way
ROOT_ORDERS = ("id", "eccentricity")

#: MXU lane width: the [n, s] frontier matmul pads the source dimension
#: to this; the batch_size validator hints when the padding wastes more
#: than half a tile
MXU_LANES = 128


def validate_batch_size(
    batch_size: int, *, lanes: int = MXU_LANES, population: int | None = None
) -> int:
    """Validate the multi-source batch width (both entrypoints funnel
    through :func:`build_schedule`, so this covers them all).

    Rejects ``< 1`` outright; logs a hint when the padded column width
    wastes more than half an MXU tile (e.g. ``batch_size=48`` pads to
    128 and masks 80 dead lanes every matmul).  ``population`` is the
    root-pool size actually being scheduled (e.g. a sampled run's
    ``sample_k``): when it is the binding constraint — no wider batch
    could ever fill — the hint is suppressed rather than nagging the
    user to raise a number that cannot help.
    """
    batch_size = int(batch_size)
    if batch_size < 1:
        raise ValueError(
            f"batch_size must be >= 1, got {batch_size}: every round needs "
            "at least one explicit source column"
        )
    pad = (-batch_size) % lanes
    if pad > lanes // 2 and (population is None or population > batch_size):
        better = batch_size - (batch_size % lanes) or lanes
        logger.warning(
            "batch_size=%d pads the source dimension to %d (%d wasted MXU "
            "lanes, more than half a %d-lane tile); %d or a multiple of %d "
            "wastes none",
            batch_size, batch_size + pad, pad, lanes, better, lanes,
        )
    return batch_size


def bfs_depths(graph: Graph, root: int) -> np.ndarray:
    """Exact BFS depth of every vertex from ``root`` (-1 = unreached).

    Vectorized over the symmetric arc list (no per-vertex Python loop):
    each step scatters the frontier through ``src -> dst`` masks.
    """
    depth = np.full(graph.n, -1, np.int64)
    depth[root] = 0
    frontier = np.zeros(graph.n, bool)
    frontier[root] = True
    d = 0
    while frontier.any():
        nxt = np.zeros(graph.n, bool)
        nxt[graph.dst[frontier[graph.src]]] = True
        nxt &= depth < 0
        if not nxt.any():
            break
        d += 1
        depth[nxt] = d
        frontier = nxt
    return depth


def estimate_eccentricities(
    graph: Graph, num_samples: int = 8, seed: int = 0
) -> np.ndarray:
    """Sampled lower-bound eccentricity per vertex (farthest-first BFS).

    Landmarks are chosen farthest-first: the first at random, each next
    at the vertex maximizing its distance to all previous landmarks —
    with unreached vertices (other components) counting as infinitely
    far, so every connected component receives at least one landmark
    *before* the ``num_samples`` refinement budget applies (coverage is
    what makes the estimate usable as a round-packing key on disjoint
    unions; a component with no landmark would estimate 0 and sort with
    the shallow cliques).  ``ecc[v]`` is the max over landmarks of
    ``dist(v, landmark)`` — a lower bound on the true eccentricity,
    exact at ≥1 landmark per component endpoints and, for packing, only
    the *relative* order matters.
    """
    if graph.n == 0:
        return np.zeros(0, np.int64)
    rng = np.random.default_rng(seed)
    ecc = np.zeros(graph.n, np.int64)
    far = np.iinfo(np.int64).max
    mind = np.full(graph.n, far, np.int64)  # min distance to any landmark
    root = int(rng.integers(graph.n))
    taken = 0
    while True:
        depth = bfs_depths(graph, root)
        reached = depth >= 0
        np.maximum(ecc, depth, where=reached, out=ecc)
        # the landmark's own eccentricity is exact from its BFS (it would
        # otherwise self-measure 0 and sort below every shallow root)
        ecc[root] = max(ecc[root], int(depth[reached].max()))
        np.minimum(mind, depth, where=reached, out=mind)
        taken += 1
        root = int(np.argmax(mind))
        if mind[root] == far:
            continue  # an uncovered component: keep going past the budget
        if taken >= num_samples or mind[root] == 0:
            return ecc


@dataclasses.dataclass(frozen=True)
class Round:
    sources: np.ndarray  # int32 [batch_size]; -1 = padding
    derived: np.ndarray  # int32 [derived_per_round, 3]; rows (c, a_pos, b_pos); -1 pad


@dataclasses.dataclass(frozen=True)
class Schedule:
    rounds: list[Round]
    batch_size: int
    derived_per_round: int
    num_explicit: int
    num_derived: int
    num_leaf_skipped: int  # 1-degree vertices never traversed
    num_isolated_omega: int  # residual-isolated vertices resolved analytically
    analytic_corrections: np.ndarray  # f64 [k, 2] rows (v, n_comp) resolved w/o traversal
    #: per-round expected traversal depth (max sampled eccentricity over
    #: the round's roots) — the cost prior for the replica deal
    #: (:func:`split_rounds` ``round_costs``); None unless the schedule
    #: was built with ``root_order="eccentricity"``
    round_depths: np.ndarray | None = None


def _finish_round(src_list, derived_list, batch_size, derived_per_round) -> Round:
    sources = np.full(batch_size, -1, dtype=np.int32)
    sources[: len(src_list)] = src_list
    derived = np.full((derived_per_round, 3), -1, dtype=np.int32)
    for k, (c, ap, bp) in enumerate(derived_list):
        derived[k] = (c, ap, bp)
    return Round(sources=sources, derived=derived)


def build_schedule(
    graph: Graph,
    batch_size: int = 32,
    heuristics: str = "h0",
    derived_per_round: int | None = None,
    root_order: str = "id",
    ecc_samples: int = 8,
    ecc_seed: int = 0,
    roots: np.ndarray | None = None,
) -> tuple[Schedule, OneDegreeReduction | None, Graph, np.ndarray]:
    """Plan the full BC computation.

    Args:
      graph:      input undirected graph.
      batch_size: explicit sources per round (the multi-source width; the
                  paper's sub-cluster work unit).
      heuristics: one of :data:`HEURISTICS_MODES` — "h0" none |
                  "h1" 1-degree | "h2" 2-degree | "h3" both; "h1t"/"h3t"
                  contract whole pendant trees (beyond-paper exhaustive
                  1-degree pass).
      derived_per_round: cap on derived columns per round (default:
                  batch_size // 2 — a triple contributes ≥2 sources).
      root_order: one of :data:`ROOT_ORDERS` — "id" (legacy vertex-id
                  fill) or "eccentricity" (sampled-eccentricity
                  descending, packing similar-depth roots into the same
                  round; also populates ``Schedule.round_depths`` so the
                  replica deal can balance expected cost).
      ecc_samples / ecc_seed: :func:`estimate_eccentricities` budget and
                  landmark seed (only read under "eccentricity").
      roots:      optional explicit root subset (vertex ids): only
                  eligible sources in this set are scheduled — the
                  source-sampling seam (repro.serving).  Requires
                  ``heuristics="h0"``: the 1-/2-degree analytic credits
                  are not separable per root, so a sampled subset could
                  not be rescaled into an unbiased estimate.  Root
                  ordering (including eccentricity packing) applies to
                  the subset unchanged.

    Returns (schedule, one_degree_result_or_None, residual_graph, omega).
    """
    if heuristics not in HEURISTICS_MODES:
        raise ValueError(
            f"unknown heuristics mode {heuristics!r}; expected one of "
            f"{HEURISTICS_MODES}"
        )
    if root_order not in ROOT_ORDERS:
        raise ValueError(
            f"unknown root_order {root_order!r}; expected one of {ROOT_ORDERS}"
        )
    batch_size = validate_batch_size(
        batch_size, population=None if roots is None else len(roots)
    )
    if roots is not None and heuristics != "h0":
        raise ValueError(
            "a root subset (source sampling) requires heuristics='h0': "
            "the 1-/2-degree analytic corrections are not per-root "
            f"additive, so a sampled schedule under {heuristics!r} could "
            "not be rescaled into an unbiased estimator"
        )
    use_h1 = heuristics in ("h1", "h3", "h1t", "h3t")
    use_h2 = heuristics in ("h2", "h3", "h3t")
    exhaustive = heuristics.endswith("t")  # beyond-paper tree contraction
    if derived_per_round is None:
        derived_per_round = max(1, batch_size // 2)

    prep = one_degree_reduce(graph, exhaustive=exhaustive) if use_h1 else None
    residual = prep.residual if prep is not None else graph
    omega = prep.omega if prep is not None else np.zeros(graph.n, dtype=np.float64)

    res_deg = residual.degrees()
    eligible = res_deg >= 1  # traversal-worthy sources
    if roots is not None:
        root_ids = np.asarray(roots, np.int64)
        if root_ids.size and (
            root_ids.min() < 0 or root_ids.max() >= graph.n
        ):
            raise ValueError(
                f"root subset contains out-of-range vertex ids "
                f"(n = {graph.n})"
            )
        keep = np.zeros(graph.n, bool)
        keep[root_ids] = True
        eligible &= keep
    num_leaf_skipped = int(prep.num_removed) if prep is not None else 0

    # residual-isolated vertices with removed leaves: analytic component
    # size n = 1 + omega (star centers, K2 leaves) — no round needed.
    removed_mask = prep.removed if prep is not None else np.zeros(graph.n, bool)
    iso_omega = np.nonzero((res_deg == 0) & (omega > 0) & ~removed_mask)[0]
    analytic = np.stack(
        [iso_omega, 1 + omega[iso_omega]], axis=1
    ).astype(np.float64) if iso_omega.size else np.zeros((0, 2), np.float64)

    triples: list[tuple[int, int, int]] = []
    if use_h2:
        adj = residual.adjacency_lists()
        triples = claim_two_degree(res_deg, adj, eligible)
    derived_set = {c for c, _, _ in triples}

    rounds: list[Round] = []
    cur_src: list[int] = []
    cur_pos: dict[int, int] = {}
    cur_der: list[tuple[int, int, int]] = []
    consumed: set[int] = set()
    demoted: list[int] = []

    def flush():
        nonlocal cur_src, cur_pos, cur_der
        if cur_src or cur_der:
            rounds.append(_finish_round(cur_src, cur_der, batch_size, derived_per_round))
        cur_src, cur_pos, cur_der = [], {}, []

    # 1) place triples (sorted so shared-neighbor triples cluster)
    for c, a, b in sorted(triples, key=lambda t: (t[1], t[2])):
        if batch_size < 2:
            demoted.append(c)  # a triple needs two co-resident sources
            continue
        if a in consumed and a not in cur_pos or b in consumed and b not in cur_pos:
            demoted.append(c)  # neighbor already ran in a closed round
            continue
        need = [v for v in (a, b) if v not in cur_pos]
        if len(cur_src) + len(need) > batch_size or len(cur_der) >= derived_per_round:
            flush()
            need = [v for v in (a, b) if v not in cur_pos]
            if a in consumed or b in consumed:
                demoted.append(c)
                continue
        for v in need:
            cur_pos[v] = len(cur_src)
            cur_src.append(v)
            consumed.add(v)
        cur_der.append((c, cur_pos[a], cur_pos[b]))

    # 2) fill with the remaining explicit sources — in vertex-id order,
    # or deepest-first under "eccentricity" so each round packs
    # similar-depth roots (the round runs to its deepest root's level)
    ecc = (
        estimate_eccentricities(residual, num_samples=ecc_samples, seed=ecc_seed)
        if root_order == "eccentricity"
        else None
    )
    explicit_rest = [
        int(v)
        for v in np.nonzero(eligible)[0]
        if v not in consumed and v not in derived_set
    ] + demoted
    if ecc is not None:
        explicit_rest.sort(key=lambda v: (-int(ecc[v]), v))
    for v in explicit_rest:
        if len(cur_src) >= batch_size:
            flush()
        cur_pos[v] = len(cur_src)
        cur_src.append(v)
        consumed.add(v)
    flush()

    num_derived = sum(int((r.derived[:, 0] >= 0).sum()) for r in rounds)
    num_explicit = sum(int((r.sources >= 0).sum()) for r in rounds)
    round_depths = None
    if ecc is not None:
        round_depths = np.array(
            [
                max(
                    (
                        int(ecc[v])
                        for v in np.concatenate((r.sources, r.derived[:, 0]))
                        if v >= 0
                    ),
                    default=0,
                )
                for r in rounds
            ],
            np.int64,
        )
    schedule = Schedule(
        rounds=rounds,
        batch_size=batch_size,
        derived_per_round=derived_per_round,
        num_explicit=num_explicit,
        num_derived=num_derived,
        num_leaf_skipped=num_leaf_skipped,
        num_isolated_omega=int(iso_omega.size),
        analytic_corrections=analytic,
        round_depths=round_depths,
    )
    return schedule, prep, residual, omega


def split_rounds(
    num_rounds: int, fr: int, committed=(), round_costs=None
) -> list[list[int]]:
    """Static per-replica deal of a schedule's round ids.

    Replica ``r`` receives rounds ``r, r+fr, r+2fr, …`` — the interleaved
    deal, chosen because it reproduces exactly the lane assignment of the
    legacy single-ledger block loop (block ``i`` = rounds
    ``[i·fr, (i+1)·fr)``), so ``straggler="none"`` and the multi-ledger
    policies start from the *same* static assignment and any wall-time
    difference is attributable to the re-deal alone.  Rounds in
    ``committed`` (e.g. from a resumed checkpoint) are excluded.

    ``round_costs`` (one expected cost per round, e.g.
    ``Schedule.round_depths`` from an eccentricity-ordered schedule)
    switches to the *cost-packed* deal: the pool is sorted costliest
    first and consecutive ``fr``-tuples dealt one per lane — the same
    shape as the straggler's :func:`redeal_rounds`, but seeded from the
    eccentricity prior instead of waiting for the EWMA to learn it.  A
    dispatch block then co-schedules similar-cost rounds, so under
    replica lockstep no lane burns masked no-op levels waiting on a much
    deeper partner, and total expected cost balances across ledgers.
    """
    if fr < 1:
        raise ValueError(f"need at least one replica, got fr={fr}")
    done = set(committed)
    if round_costs is None:
        return [
            [rid for rid in range(r, num_rounds, fr) if rid not in done]
            for r in range(fr)
        ]
    costs = [float(c) for c in round_costs]
    if len(costs) != num_rounds:
        raise ValueError(
            f"{num_rounds} rounds but {len(costs)} round costs"
        )
    pool = sorted(
        (rid for rid in range(num_rounds) if rid not in done),
        key=lambda rid: (-costs[rid], rid),
    )
    queues: list[list[int]] = [[] for _ in range(fr)]
    for i, rid in enumerate(pool):
        queues[i % fr].append(rid)
    return queues


def redeal_rounds(
    queues: list[list[int]], lane_cost: list[float]
) -> tuple[list[list[int]], int]:
    """Re-deal pending rounds across replica queues (straggler recovery).

    A sub-cluster dispatch block co-schedules one round per replica and —
    under a ring overlap policy, where the replica axis joins the
    loop-bound reductions — costs the *max* over its rounds' traversal
    depths: a deep round paired with a shallow one makes the shallow
    replica burn the depth difference as masked no-op levels.  The
    re-deal therefore packs *similar-cost* rounds into the same block:
    every pending round is estimated at its current owner's per-round
    cost (the driver's EWMA — rounds were dealt to that lane, so the
    lane's observed history is the best available prior for them), the
    pool is sorted costliest-first, and consecutive ``fr``-tuples are
    dealt one per lane.  The straggler's backlog thus drains into the
    fastest replica's queue head while cheap rounds pair with cheap.

    Returns ``(new_queues, moved)`` where ``moved`` counts rounds that
    changed lanes.  Pure function — order inside a lane is deterministic
    (cost desc, round id asc) so a re-deal is reproducible across a
    kill-and-resume.
    """
    fr = len(queues)
    if fr != len(lane_cost):
        raise ValueError(f"{fr} queues but {len(lane_cost)} lane costs")
    owner = {rid: r for r, q in enumerate(queues) for rid in q}
    pool = sorted(owner, key=lambda rid: (-lane_cost[owner[rid]], rid))
    new_queues: list[list[int]] = [[] for _ in range(fr)]
    for i, rid in enumerate(pool):
        new_queues[i % fr].append(rid)
    moved = sum(
        1 for r, q in enumerate(new_queues) for rid in q if owner[rid] != r
    )
    return new_queues, moved
