"""The driver layer: one round body and one host round loop for all
engines (single-device dense/sparse/Pallas and 2-D distributed).

:func:`traversal_round` is the per-round algebra — forward counting,
2-degree column derivation, dependency accumulation, per-round BC and
component-size (n_s) extraction, plus the round's own traversal depth
(the straggler scheduler's cost signal) — written once against the
:class:`repro.core.operators.TraversalOperator` protocol.  Entry points
wrap it in whatever jit/shard_map shell their operator needs.

:class:`BCDriver` is the host loop shared by
:func:`repro.core.bc.betweenness_centrality`,
:func:`repro.core.distributed.distributed_betweenness_centrality`, the
``repro.launch.bc`` CLI and the benchmarks:

* rounds are dealt in *dispatch blocks* of ``rounds_per_dispatch``
  (1 on a single device; the sub-cluster count ``fr`` on a mesh);
* dispatch is asynchronous: up to ``max_inflight`` blocks are in flight
  and ``device_get`` happens only at block boundaries, so host sync no
  longer serializes rounds;
* the BC accumulator lives on device and is *donated* through a jitted
  add (no per-round host round-trip, no per-round buffer copy); it is
  fetched exactly once, after the last round;
* an optional :class:`repro.distributed.fault_tolerance.RoundLedger`
  makes the loop restartable: committed rounds are re-dealt as inert
  all-padding columns (BC accumulation is additive, padding contributes
  exactly zero), which keeps every dispatch shape static;
* ``straggler`` selects the multi-ledger sub-cluster scheduling policy
  (:data:`STRAGGLER_POLICIES`): with ``"steal"`` or ``"redeal"`` the
  driver keeps one :class:`RoundLedger` *per replica*, tracks a
  per-replica EWMA of per-round wall time (seeded from the roofline's
  ``overlap_step_time`` estimate before any round completes), and moves
  uncommitted rounds between replica queues when one replica straggles.
  Commits then move from dispatch time to drain time and the BC
  accumulate is masked by the commit outcome, so a round dispatched on
  two replicas (speculative tail duplication, or a re-deal racing a
  kill-and-resume) is accumulated exactly once: first commit wins, the
  loser's lane is multiplied by zero *before* the donated add.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.heuristics.one_degree import OneDegreeReduction, leaf_correction
from repro.core.heuristics.two_degree import derive_two_degree_columns
from repro.core.operators import TraversalOperator, as_operator
from repro.core.scheduler import Schedule, redeal_rounds, split_rounds

__all__ = [
    "BCResult",
    "BCDriver",
    "traversal_round",
    "apply_reduction_corrections",
    "STRAGGLER_POLICIES",
    "normalize_straggler",
    "INTEGRITY_MODES",
    "normalize_integrity",
    "DEFAULT_MAX_RETRIES",
    "DEFAULT_RETRY_BACKOFF_S",
]

logger = logging.getLogger(__name__)

#: Sub-cluster straggler-mitigation policies of :class:`BCDriver` (the
#: single source of truth for ``--straggler`` choices and the docs drift
#: check).  ``"none"`` keeps the static deal (one shared ledger, commits
#: at dispatch — the legacy loop).  ``"steal"`` is the conservative
#: multi-ledger policy: work moves only when a replica's queue runs dry —
#: the idle replica pulls the next round from the heaviest backlog, and
#: at the very tail it speculatively *duplicates* the presumed
#: straggler's in-flight round instead of dispatching padding (MapReduce
#: backup tasks; first commit wins).  ``"redeal"`` is the aggressive
#: policy: when a replica's EWMA per-round wall exceeds
#: ``straggler_factor ×`` the fastest replica's, every pending round is
#: re-dealt across the replica queues so similar-cost rounds are
#: co-scheduled (the straggler's backlog drains into the fastest
#: replica's queue).
STRAGGLER_POLICIES = ("none", "steal", "redeal")

_EWMA_ALPHA = 0.5  # weight of the newest per-round wall observation

#: Self-healing defaults: re-dispatches allowed per block (transient
#: errors and quarantined non-finite outputs share the budget) and the
#: base of the exponential backoff between transient retries.  2 retries
#: rides out the one-off XLA hiccups worth retrying; anything persisting
#: past that is a real failure the fallback/caller must see.
DEFAULT_MAX_RETRIES = 2
DEFAULT_RETRY_BACKOFF_S = 0.05

#: Round-integrity modes of :class:`BCDriver` (the single source of
#: truth for ``--integrity`` choices and the docs drift check).
#: ``"off"`` accumulates round outputs unaudited (the legacy behaviour).
#: ``"audit"`` makes every round also return an integrity record — a
#: per-lane bc-sum *claim* computed inside the round — and the driver
#: audits each block host-side at the per-block sync: claim vs the
#: recomputed lane sum (in-transit corruption), BC non-negativity, level
#: and component-size bounds; under ``straggler="steal"`` the
#: speculative duplicate lanes additionally *vote* — digests compared,
#: mismatches quarantined and re-dispatched as a tie-breaker.
#: ``"checksum"`` adds the ABFT ones-checksum lane to every forward and
#: backward SpMM (operators.*_level_checked), carrying the max relative
#: column-sum residual in the record, so in-SpMM corruption is caught
#: the moment it happens — the strongest (and costliest: one extra lane
#: per product) mode.
INTEGRITY_MODES = ("off", "audit", "checksum")

#: ABFT residual threshold: healthy f32 reductions land around 1e-6
#: relative; 1e-3 keeps ~3 orders of magnitude of slack against
#: accumulation-order noise while still catching any corruption that
#: could move BC beyond parity tolerance.
CHECKSUM_TOL = 1e-3
#: Relative tolerance for the bc-sum claim audit (in-round claim vs the
#: host-recomputed lane sum — both f32 reductions in different orders).
CLAIM_RTOL = 1e-4
#: Relative tolerance for the duplicate-vote digest compare: both lanes
#: ran the identical deterministic computation, so any real divergence
#: is corruption.
VOTE_RTOL = 1e-6


def normalize_integrity(mode: str | None) -> str:
    """Validate an integrity mode string (None means "off")."""
    mode = "off" if mode is None else mode
    if mode not in INTEGRITY_MODES:
        raise ValueError(
            f"unknown integrity mode {mode!r}; expected one of {INTEGRITY_MODES}"
        )
    return mode


def normalize_straggler(policy: str | None) -> str:
    """Validate a straggler policy string (None means "none")."""
    policy = "none" if policy is None else policy
    if policy not in STRAGGLER_POLICIES:
        raise ValueError(
            f"unknown straggler policy {policy!r}; expected one of "
            f"{STRAGGLER_POLICIES}"
        )
    return policy


def traversal_round(
    operator: TraversalOperator,
    sources: jnp.ndarray,  # i32 [s]; -1 = padding
    derived: jnp.ndarray,  # i32 [k, 3] rows (c, a_pos, b_pos); -1 = padding
    omega: jnp.ndarray,  # f32 [n_rows] 1-degree weights (operator's rows)
    *,
    num_levels: int | None = None,
    integrity: str = "off",
) -> tuple[jnp.ndarray, ...]:
    """One BC round against the operator protocol.

    Returns
      bc_local  f32 [n_rows] — this round's BC contribution to the
                operator's rows (global BC = sum over rounds/devices),
      ns        f32 [s+k]    — per-column component size n_s (§3.4.1),
                already globally reduced,
      roots     i32 [s+k]    — root vertex of every column (-1 padding),
      levels    i32 []       — traversal depth of *this* round on its own
                grid (``reduce_max_grid``: per-replica even when
                ``sync_axes`` pins the loop bounds to the mesh-wide max).
                0 for an all-padding round.  This is the data-dependent
                cost signal the straggler scheduler attributes wall time
                by.

    With ``integrity != "off"`` (see :data:`INTEGRITY_MODES`) a fifth
    element is returned: ``integ`` f32 [2] = ``[err, claim]`` — the
    round's max ABFT checksum residual (0 in "audit" mode, where the
    checked level steps don't run) and the round's own bc-sum claim
    (``Σ bc_local`` over the whole replica, computed *before* the block
    leaves the device, so the driver can detect corruption in transit
    or in the accumulate path).
    """
    integrity = normalize_integrity(integrity)
    checksum = integrity == "checksum"
    op = as_operator(operator)
    if getattr(op, "weighted", False):
        return _weighted_round(
            op, sources, derived, omega, num_levels=num_levels, integrity=integrity
        )
    omega_f = omega.astype(jnp.float32)
    row_ids = op.row_ids()

    # ---------------------------------------------------------- forward
    src_onehot = (
        (row_ids[:, None] == sources[None, :]) & (sources[None, :] >= 0)
    ).astype(jnp.float32)
    fwd = engine.forward_counting(
        op, src_onehot, num_levels=num_levels, checksum=checksum
    )

    # ------------------------------------------- derived 2-degree columns
    sigma_c, depth_c = derive_two_degree_columns(
        fwd.sigma, fwd.depth, derived, row_ids=row_ids
    )
    sigma_all = jnp.concatenate([fwd.sigma, sigma_c], axis=1)
    depth_all = jnp.concatenate([fwd.depth, depth_c], axis=1)

    # ---------------------------------------------------------- backward
    # decomposed max: grid first (the per-replica depth = the straggler
    # cost signal), then the sync-axes extension for the loop bound — one
    # reduction total when sync_axes is empty (reduce_max_sync is a no-op)
    grid_max = op.reduce_max_grid(jnp.max(depth_all))
    max_depth = op.reduce_max_sync(grid_max)
    bwd = engine.backward_accumulation(
        op,
        sigma_all,
        depth_all,
        omega_f,
        max_depth,
        num_levels=num_levels,
        checksum=checksum,
    )
    delta, bwd_err = bwd if checksum else (bwd, None)

    # --------------------------------------------------------- BC + n_s
    roots = jnp.concatenate([sources, derived[:, 0]])
    omega_root = op.root_omega(roots, omega_f)
    mult = jnp.where(roots >= 0, omega_root + 1.0, 0.0)

    root_onehot = row_ids[:, None] == roots[None, :]
    weighted = jnp.where(root_onehot, 0.0, delta * mult[None, :])
    bc_local = weighted.sum(axis=1)

    # per-column component size  n_s = Σ_{d ≥ 0} (1 + ω)   (paper §3.4.1)
    ns = op.reduce_sum(((depth_all >= 0) * (1.0 + omega_f)[:, None]).sum(axis=0))
    levels = (grid_max + 1).astype(jnp.int32)
    if integrity == "off":
        return bc_local, ns, roots, levels
    # [err, claim]: the replica's max ABFT residual (grid-agreed, so it
    # is replicated like ns) and its own bc-sum claim.  Both are f32
    # scalars computed before the block crosses the device boundary.
    claim = op.reduce_sum(jnp.sum(bc_local))
    if checksum:
        err = op.reduce_max_grid(jnp.maximum(fwd.check_err, bwd_err))
    else:
        err = jnp.float32(0.0)
    integ = jnp.stack(
        [jnp.asarray(err, jnp.float32), jnp.asarray(claim, jnp.float32)]
    )
    return bc_local, ns, roots, levels, integ


def _weighted_round(
    op,
    sources: jnp.ndarray,
    derived: jnp.ndarray,
    omega: jnp.ndarray,
    *,
    num_levels: int | None,
    integrity: str,
) -> tuple[jnp.ndarray, ...]:
    """One *weighted* BC round: the bucket-loop analogue of
    :func:`traversal_round`, same return contract.

    The round's ``levels`` slot carries the bucket count (the same
    data-dependent cost signal the straggler scheduler consumes).  The
    2-degree derivation is level-based and is rejected upstream for
    weighted runs, so ``derived`` is always all-padding here — the
    derived columns stay shape-compatible and inert.  ``num_levels``
    (the static-trip-count dry-run mode) has no weighted analogue: the
    bucket loop's trip count is data-dependent by construction.
    """
    if num_levels is not None:
        raise ValueError(
            "num_levels (static trip count) is not supported for weighted "
            "traversal: the bucket loop's trip count is data-dependent"
        )
    if integrity == "checksum":
        raise ValueError(
            "integrity='checksum' (ABFT level checksums) is level-"
            "synchronous and not supported for weighted traversal; use "
            "integrity='audit'"
        )
    omega_f = omega.astype(jnp.float32)
    row_ids = op.row_ids()

    src_onehot = (
        (row_ids[:, None] == sources[None, :]) & (sources[None, :] >= 0)
    ).astype(jnp.float32)
    fwd = engine.forward_buckets(op, src_onehot)

    # bucket index per (vertex, column): the weighted depth structure
    from repro.kernels.ops import bucket_index

    bucket = bucket_index(fwd.dist, op.delta)

    # derived columns: always padding under weighted (h2/h3 rejected
    # upstream) — kept for shape compatibility with the driver contract
    sigma_c, depth_c = derive_two_degree_columns(
        fwd.sigma, bucket, derived, row_ids=row_ids
    )
    sigma_all = jnp.concatenate([fwd.sigma, sigma_c], axis=1)
    bucket_all = jnp.concatenate([bucket, depth_c], axis=1)

    grid_max = op.reduce_max_grid(jnp.max(bucket_all))
    max_bucket = op.reduce_max_sync(grid_max)
    delta_acc = engine.backward_buckets(op, fwd.sigma, fwd.dist, omega_f, max_bucket)
    delta_all = jnp.concatenate([delta_acc, jnp.zeros_like(sigma_c)], axis=1)

    roots = jnp.concatenate([sources, derived[:, 0]])
    omega_root = op.root_omega(roots, omega_f)
    mult = jnp.where(roots >= 0, omega_root + 1.0, 0.0)

    root_onehot = row_ids[:, None] == roots[None, :]
    contrib = jnp.where(root_onehot, 0.0, delta_all * mult[None, :])
    bc_local = contrib.sum(axis=1)

    ns = op.reduce_sum(((bucket_all >= 0) * (1.0 + omega_f)[:, None]).sum(axis=0))
    levels = (grid_max + 1).astype(jnp.int32)
    if integrity == "off":
        return bc_local, ns, roots, levels
    claim = op.reduce_sum(jnp.sum(bc_local))
    integ = jnp.stack(
        [jnp.float32(0.0), jnp.asarray(claim, jnp.float32)]
    )
    return bc_local, ns, roots, levels, integ


def apply_reduction_corrections(
    bc: np.ndarray,
    prep: OneDegreeReduction,
    schedule,
    ns_by_root: dict[int, float],
) -> None:
    """Add the analytic BC credits of the 1-degree/tree reduction.

    Every vertex x with removed branches (S(x) > 0) — residual or removed
    interior — gets 2·S·(n_comp−1−S) + 2·P (heuristics/one_degree.py).
    n_comp comes from x's own round, the isolated-residual analytic size,
    or (removed vertices) the resolved root's size."""
    n_by_root = dict(ns_by_root)
    for v, n_comp in schedule.analytic_corrections:
        n_by_root[int(v)] = float(n_comp)
    S, P = prep.omega, prep.pair_credit
    for x in np.nonzero(S > 0)[0]:
        x = int(x)
        if prep.removed[x]:
            root, analytic_n = prep.resolve_root(x)
            n_comp = analytic_n if analytic_n >= 0 else n_by_root.get(int(root))
        else:
            n_comp = n_by_root.get(x)
        if n_comp is None:
            raise RuntimeError(f"no component size recorded for vertex {x}")
        bc[x] += leaf_correction(S[x], n_comp, P[x])


@dataclasses.dataclass
class BCResult:
    bc: np.ndarray  # float64 [n]
    schedule: Schedule
    rounds_run: int
    forward_columns: int  # explicit BFS columns actually traversed
    backward_columns: int  # dependency columns (explicit + derived)
    wall_s: float = 0.0  # host wall time of the round loop
    block_times: list[float] | None = None  # per-dispatch-block seconds
    #   (profile / straggler modes only — the driver blocks per block to
    #   measure, so async dispatch is disabled; use for benchmarking and
    #   scheduling, not peak-throughput production)
    straggler_stats: dict | None = None  # multi-ledger scheduler telemetry
    #   (straggler != "none" only): per-replica wall/rounds/levels,
    #   rounds stolen / re-dealt, speculative duplicates, idle estimate.
    stopped_early: bool = False  # a stop_rule halted dispatch before the
    #   schedule was exhausted (adaptive sampling / serving refresh
    #   slices); the bc accumulator holds exactly the committed prefix
    stop_stats: dict | None = None  # the stop rule's own telemetry
    #   (rule.stats when it has one): checks, stability history,
    #   fired_at_block
    roots_accumulated: int = 0  # root columns (explicit + derived) of
    #   every committed round, including rounds resumed from a
    #   checkpoint — the k in the sampled estimator's N/k rescale
    sampling_stats: dict | None = None  # set by the entrypoints when
    #   sampling != "off": mode, k planned, eligible count, applied scale
    recovery_stats: dict | None = None  # self-healing telemetry (always
    #   set by BCDriver): retries, transient_errors, quarantined_blocks,
    #   fallback_recomputes, remesh_events, dead_replicas,
    #   resumed_generation (BCCheckpoint generation the run resumed
    #   from; None = cold start / no checkpoint), plus the "integrity"
    #   sub-dict (mode, checksum/audit failures, max residual, duplicate
    #   votes + verdicts, quarantined rounds, watchdog trips /
    #   re-dispatches / escalations).


def _unpack_block(out):
    """Normalize a round_fn output to the 5-tuple
    ``(bc, ns, roots, levels, integ)`` — legacy 3-tuples (no levels) and
    4-tuples (no integrity record) get ``None`` in the missing slots."""
    if len(out) == 5:
        return tuple(out)
    if len(out) == 4:
        return tuple(out) + (None,)
    bc_blk, ns, roots = out
    return bc_blk, ns, roots, None, None


class BCDriver:
    """Shared host round loop (see module docstring).

    ``round_fn(sources i32 [fr, s], derived i32 [fr, k, 3])`` must return
    device arrays ``(bc_block, ns [fr, s+k], roots [fr, s+k],
    levels [fr])`` where ``bc_block`` has any stable shape whose leading
    dims sum away to the per-vertex contribution ([n] on one device;
    [fr, n_pad] sharded on a mesh).  All graph-constant operands
    (adjacency, ω, arc lists) are expected to be partially applied into
    ``round_fn``.  Legacy 3-tuple round functions (no ``levels``) are
    accepted under ``straggler="none"``.

    ``profile=True`` blocks on every dispatch block and records its wall
    seconds in ``BCResult.block_times`` (plus total ``wall_s``) — the
    measurement mode the overlap benchmarks use; it defeats the async
    pipeline, so leave it off in production.

    ``straggler`` (see :data:`STRAGGLER_POLICIES`) enables the
    multi-ledger sub-cluster scheduler; it requires ``round_fn`` to carry
    a leading replica dim of ``rounds_per_dispatch`` on ``bc_block`` and
    to return ``levels``, and — like ``profile`` — blocks per dispatch
    block (the per-round wall observations are its control signal).
    ``straggler_factor`` is the EWMA ratio that flags a replica as a
    straggler; ``prior_round_s`` seeds every replica's EWMA before any
    round completes (callers pass the roofline ``overlap_step_time``
    estimate — or, under ``autotune``, the measured per-level cost via
    :func:`repro.core.distributed.prior_round_seconds` — symmetric, so
    no re-deal can fire on the prior alone).  ``round_costs`` hands the
    static deal a per-round cost prior (``Schedule.round_depths``): the
    initial queues then pack similar-cost rounds per dispatch block
    instead of interleaving by id.

    **Self-healing** (telemetry in ``BCResult.recovery_stats``):
    transient round failures are retried in place (``max_retries``
    re-dispatches, exponential backoff from ``retry_backoff_s``); the
    numeric guard (``numeric_guard``, auto-on wherever the loop already
    syncs per block) quarantines non-finite bc/ns blocks and re-runs
    them, escalating to ``fallback_round_fn`` — the caller's known-good
    dense path — when the corruption persists; under ``straggler ≠
    "none"`` a :class:`repro.distributed.fault_tolerance.
    ReplicaLostError` from the round_fn triggers an elastic re-mesh
    (``plan_elastic_remesh`` over ``mesh_shape``/``mesh_axes``): the
    dead replica's ledger merges into a survivor's, its backlog is
    re-dealt, and the loop continues at reduced effective ``fr`` with
    the dead lane dealt only padding.
    """

    def __init__(
        self,
        round_fn: Callable,
        schedule: Schedule,
        *,
        n: int,
        prep: OneDegreeReduction | None = None,
        ledger=None,
        checkpoint=None,
        checkpoint_every: int = 8,
        rounds_per_dispatch: int = 1,
        max_inflight: int = 2,
        profile: bool = False,
        straggler: str = "none",
        straggler_factor: float = 2.0,
        prior_round_s: float | None = None,
        round_costs=None,
        max_retries: int = DEFAULT_MAX_RETRIES,
        retry_backoff_s: float = DEFAULT_RETRY_BACKOFF_S,
        numeric_guard: bool | None = None,
        fallback_round_fn: Callable | None = None,
        mesh_shape: tuple[int, ...] | None = None,
        mesh_axes: tuple[str, ...] | None = None,
        integrity: str = "off",
        dispatch_deadline_s: float | None = None,
        clock: Callable[[], float] | None = None,
        sleeper: Callable[[float], None] | None = None,
        stop_rule: Callable[[np.ndarray, int], bool] | None = None,
        level_bound: int | None = None,
    ):
        self.round_fn = round_fn
        #: integrity-audit upper bound on a round's reported traversal
        #: depth.  None = the unweighted structural bound (n + 1 levels).
        #: Weighted callers pass their bucket-count bound — bucket indices
        #: scale with (max distance / Δ), not with n.
        self.level_bound = level_bound
        self.profile = profile
        #: the early-stop seam (repro.serving): a callable
        #: ``(bc_running f64 [n], blocks_done) -> bool`` consulted after
        #: every drained dispatch block — True halts *new* dispatches;
        #: everything already committed stays committed (checkpoints,
        #: chaos and the straggler re-deal compose unchanged because the
        #: consult sits outside the dispatch/commit machinery).  Note the
        #: consult syncs the accumulator to host each block, so it costs
        #: the async static pipeline — adaptive sampling opts in.
        self.stop_rule = stop_rule
        self.schedule = schedule
        self.n = n
        self.prep = prep
        self.checkpoint = checkpoint
        self.checkpoint_every = max(1, checkpoint_every)
        self.straggler = normalize_straggler(straggler)
        self.straggler_factor = float(straggler_factor)
        self.prior_round_s = prior_round_s
        #: per-round expected cost (Schedule.round_depths when the
        #: scheduler packed by eccentricity) — seeds the straggler deal
        #: (split_rounds round_costs) so lanes start cost-balanced
        self.round_costs = round_costs
        self._bc0 = np.zeros(n, np.float64)
        self._ns0: dict[int, float] = {}
        self._fingerprint = None
        self.fr = max(1, rounds_per_dispatch)
        self.max_inflight = max(1, max_inflight)

        # ------------------------------------------------- self-healing
        self.max_retries = max(0, int(max_retries))
        self.retry_backoff_s = float(retry_backoff_s)
        self.fallback_round_fn = fallback_round_fn
        # The guard fetches a per-block finiteness bit, i.e. a host sync.
        # Auto-resolution turns it on exactly where that sync is already
        # paid (profile / straggler modes block per dispatch to measure)
        # or where the caller opted into recovery (a fallback round_fn);
        # the pure-async static fast path stays unsynced unless asked.
        if numeric_guard is None:
            numeric_guard = (
                fallback_round_fn is not None
                or self.straggler != "none"
                or profile
            )
        self.numeric_guard = bool(numeric_guard)
        # mesh geometry for plan_elastic_remesh on replica loss: the
        # replica ('pod') axis is the dispatch lane dim by default;
        # distributed callers pass the true (fr, R, C) shape.
        self.mesh_shape = tuple(mesh_shape) if mesh_shape is not None else (self.fr,)
        self.mesh_axes = tuple(mesh_axes) if mesh_axes is not None else ("pod",)
        self._dead_lanes: set[int] = set()
        self.recovery: dict = {
            "retries": 0,
            "transient_errors": 0,
            "quarantined_blocks": 0,
            "fallback_recomputes": 0,
            "remesh_events": 0,
            "dead_replicas": [],
            "resumed_generation": None,
        }
        # ---------------------------------------------------- integrity
        self.integrity = normalize_integrity(integrity)
        if dispatch_deadline_s is not None and float(dispatch_deadline_s) <= 0:
            raise ValueError(
                f"dispatch_deadline_s must be positive, got {dispatch_deadline_s}"
            )
        self.dispatch_deadline_s = (
            None if dispatch_deadline_s is None else float(dispatch_deadline_s)
        )
        # injectable time sources: the watchdog measures the dispatch
        # call window through ``clock`` and the retry backoff sleeps
        # through ``sleeper``, so chaos/watchdog tests drive both with
        # fakes instead of burning wall-clock
        self._clock = clock if clock is not None else time.monotonic
        self._sleep = sleeper if sleeper is not None else time.sleep
        self.recovery["integrity"] = {
            "mode": self.integrity,
            "checksum_failures": 0,
            "audit_failures": 0,
            "max_checksum_residual": 0.0,
            "votes": 0,
            "vote_mismatches": 0,
            "vote_verdicts": [],
            "quarantined_rounds": 0,
            "watchdog_trips": 0,
            "watchdog_redispatches": 0,
            "watchdog_escalations": 0,
        }
        #: rid -> {"owner": digest, "duplicate": digest} for rounds whose
        #: duplicate vote disagreed; resolved (verdict recorded) when the
        #: tie-breaker re-dispatch commits cleanly
        self._pending_votes: dict[int, dict] = {}
        self._finite_check = jax.jit(
            lambda bc, ns: jnp.isfinite(bc).all() & jnp.isfinite(ns).all()
        )
        # per-lane bc digests for the claim audit and the duplicate vote:
        # (lane sums, global min, global max) in one fetch
        self._block_digest = jax.jit(
            lambda bc: (
                bc.reshape(bc.shape[0], -1).sum(axis=1)
                if bc.ndim > 1
                else bc.sum()[None],
                bc.min(),
                bc.max(),
            )
        )

        from repro.distributed.fault_tolerance import (
            RoundLedger,
            schedule_fingerprint,
        )

        if checkpoint is not None:
            if ledger is not None:
                raise ValueError("pass either a ledger or a checkpoint, not both")
            self._fingerprint = schedule_fingerprint(n, schedule)

        if self.straggler != "none":
            if ledger is not None:
                raise ValueError(
                    "straggler scheduling keeps one ledger per replica; "
                    "pass a checkpoint (or nothing), not an external ledger"
                )
            by_lane: list[list[int]] = [[] for _ in range(self.fr)]
            if checkpoint is not None:
                bc0, ns0, stored = checkpoint.load_namespaced(self._fingerprint)
                if bc0 is not None:
                    self._bc0 = bc0[: n]
                    self._ns0 = ns0
                if len(stored) == self.fr:
                    by_lane = [list(lane) for lane in stored]
                else:  # replica count changed across the resume: merge
                    union = sorted({rid for lane in stored for rid in lane})
                    by_lane[0] = union
            self.ledgers = [RoundLedger.from_state(lane) for lane in by_lane]
            self.ledger = None
        else:
            if checkpoint is not None:
                bc0, ns0, committed = checkpoint.load(self._fingerprint)
                if bc0 is not None:
                    self._bc0 = bc0[: n]
                    self._ns0 = ns0
                ledger = RoundLedger.from_state(committed)
            self.ledger = ledger
            self.ledgers = None
        if checkpoint is not None:
            gen = getattr(checkpoint, "loaded_generation", None)
            self.recovery["resumed_generation"] = gen
            if gen is not None:
                (logger.warning if gen > 0 else logger.info)(
                    "resumed from checkpoint generation %d%s",
                    gen,
                    " (newer snapshots were corrupt)" if gen > 0 else "",
                )
            # resume the recovery telemetry the snapshot carried, so a
            # kill-and-resume keeps its retry/quarantine/re-mesh history
            # instead of resetting the counters to zero
            stored = getattr(checkpoint, "loaded_stats", None)
            if stored:
                for key in (
                    "retries",
                    "transient_errors",
                    "quarantined_blocks",
                    "fallback_recomputes",
                    "remesh_events",
                ):
                    self.recovery[key] = int(stored.get(key, 0))
                sint = stored.get("integrity") or {}
                ist = self.recovery["integrity"]
                for key in list(ist):
                    if key == "mode":
                        continue
                    if key == "vote_verdicts":
                        ist[key] = list(sint.get(key, []))
                    elif key == "max_checksum_residual":
                        ist[key] = float(sint.get(key, 0.0))
                    else:
                        ist[key] = int(sint.get(key, 0))
        # donated device-side accumulate: bc never round-trips per round
        self._accumulate = jax.jit(lambda acc, x: acc + x, donate_argnums=(0,))
        # drain-time masked accumulate (straggler modes): the commit
        # outcome zeroes losing lanes *before* the donated add, so a
        # double-dispatched round contributes exactly once.
        def _bmask(blk, m):
            return blk * m.reshape(m.shape + (1,) * (blk.ndim - 1))

        self._masked_accumulate = jax.jit(
            lambda acc, blk, m: acc + _bmask(blk, m), donate_argnums=(0,)
        )
        self._masked_scale = jax.jit(_bmask)

    # ---------------------------------------------------- self-healing
    def _stats_state(self) -> dict:
        """JSON-serializable recovery telemetry for the checkpoint."""
        out = {
            k: (list(v) if isinstance(v, list) else v)
            for k, v in self.recovery.items()
            if k not in ("resumed_generation", "integrity")
        }
        ist = self.recovery["integrity"]
        out["integrity"] = {
            k: (list(v) if isinstance(v, list) else v) for k, v in ist.items()
        }
        return out

    def _integrity_audit(self, out) -> str | None:
        """Audit one block's output; return a failure reason or None.

        Host-side, at a point where the loop already syncs (the audit
        itself fetches the block digest).  Checks, in order: the ABFT
        checksum residual carried in the integrity record ("checksum"
        mode), the per-lane bc-sum claim vs the recomputed lane digest,
        BC non-negativity, and the level / component-size output-domain
        bounds.  Every check is O(fr + s) host work on already-reduced
        scalars — the O(n·s) work stayed on device.
        """
        bc_blk, ns, roots, levels, integ = out
        ist = self.recovery["integrity"]
        sums_dev, mn_dev, mx_dev = self._block_digest(bc_blk)
        sums = np.asarray(jax.device_get(sums_dev), np.float64).reshape(-1)
        mn = float(jax.device_get(mn_dev))
        scale = max(1.0, float(np.abs(sums).max()))
        if integ is not None:
            ig = np.asarray(jax.device_get(integ), np.float64).reshape(-1, 2)
            resid = float(ig[:, 0].max())
            ist["max_checksum_residual"] = max(
                ist["max_checksum_residual"], resid
            )
            if resid > CHECKSUM_TOL:
                return (
                    f"ABFT checksum residual {resid:.3e} exceeds "
                    f"{CHECKSUM_TOL:g}"
                )
            claims = ig[:, 1]
            if claims.shape[0] == sums.shape[0]:
                diff = float(np.abs(claims - sums).max())
                if diff > CLAIM_RTOL * scale:
                    return (
                        f"bc-sum claim mismatch: |claim - sum| = {diff:.3e} "
                        f"(scale {scale:.3e})"
                    )
        if mn < -CLAIM_RTOL * scale:
            return f"negative BC contribution (min {mn:.3e})"
        if levels is not None:
            lv = np.asarray(jax.device_get(levels)).reshape(-1)
            bound = self.level_bound if self.level_bound is not None else self.n + 1
            if lv.min() < 0 or lv.max() > bound:
                return f"level bound violation (levels {lv.tolist()})"
        ns_np = np.asarray(jax.device_get(ns), np.float64)
        ns_max = float(ns_np.max()) if ns_np.size else 0.0
        if ns_max > self.n * (1.0 + 1e-5) + 1e-6:
            return f"component size {ns_max:.6g} exceeds n = {self.n}"
        return None

    def _dispatch_block(self, srcs, ders):
        """Run ``round_fn`` on one dispatch block with recovery.

        Transient failures (:func:`repro.distributed.fault_tolerance.
        is_transient_error`) are retried in place with exponential
        backoff, up to ``max_retries`` re-dispatches per block.  A
        ``dispatch_deadline_s`` arms the **watchdog**: a dispatch call
        that returns only after the deadline is treated as a wedged
        collective — re-dispatched from the retry budget, then escalated
        as :class:`ReplicaLostError` so the multi-ledger loop re-meshes
        around the suspect replica (the static loop propagates it — it
        has no spare lanes to absorb a loss).  Under the numeric guard a
        block whose bc/ns came back non-finite is *quarantined* — never
        accumulated — and re-dispatched from the same budget; if the
        poison persists the block is recomputed via ``fallback_round_fn``
        (the caller's known-good dense path) with a fresh budget.
        ``integrity != "off"`` runs :meth:`_integrity_audit` on every
        block with the identical quarantine → re-dispatch → fallback →
        raise ladder (terminal error:
        :class:`repro.distributed.fault_tolerance.IntegrityError`).
        :class:`ReplicaLostError` from the round_fn always propagates:
        in-place retry cannot resurrect devices.  Returns the unpacked
        5-tuple.
        """
        from repro.distributed.fault_tolerance import (
            IntegrityError,
            ReplicaLostError,
            is_transient_error,
        )

        srcs_dev = jnp.asarray(srcs)
        ders_dev = jnp.asarray(ders)
        fn = self.round_fn
        attempt = 0
        while True:
            try:
                t0 = self._clock()
                out = _unpack_block(fn(srcs_dev, ders_dev))
                if self.dispatch_deadline_s is not None:
                    # measure to completion of the dispatched values: the
                    # deadline covers a wedged collective inside the call
                    jax.block_until_ready(out[0])
                elapsed = self._clock() - t0
            except Exception as e:
                if is_transient_error(e) and attempt < self.max_retries:
                    backoff = self.retry_backoff_s * (2.0 ** attempt)
                    self.recovery["transient_errors"] += 1
                    self.recovery["retries"] += 1
                    logger.warning(
                        "transient round failure (%s: %s); retry %d/%d "
                        "after %.3fs backoff",
                        type(e).__name__, e, attempt + 1, self.max_retries,
                        backoff,
                    )
                    self._sleep(backoff)
                    attempt += 1
                    continue
                raise
            if (
                self.dispatch_deadline_s is not None
                and elapsed > self.dispatch_deadline_s
            ):
                ist = self.recovery["integrity"]
                ist["watchdog_trips"] += 1
                if attempt < self.max_retries:
                    ist["watchdog_redispatches"] += 1
                    self.recovery["retries"] += 1
                    logger.warning(
                        "dispatch watchdog: block took %.3fs > deadline "
                        "%.3fs; re-dispatching (%d/%d)",
                        elapsed, self.dispatch_deadline_s,
                        attempt + 1, self.max_retries,
                    )
                    attempt += 1
                    continue
                ist["watchdog_escalations"] += 1
                raise ReplicaLostError(
                    -1,
                    f"dispatch exceeded its {self.dispatch_deadline_s:.3f}s "
                    f"deadline {attempt + 1} times (last {elapsed:.3f}s); "
                    f"treating a replica as wedged",
                )
            if self.numeric_guard and not bool(
                self._finite_check(out[0], out[1])
            ):
                self.recovery["quarantined_blocks"] += 1
                if attempt < self.max_retries:
                    self.recovery["retries"] += 1
                    logger.warning(
                        "non-finite bc/ns block quarantined; re-dispatching "
                        "(%d/%d)", attempt + 1, self.max_retries,
                    )
                    attempt += 1
                    continue
                if (
                    self.fallback_round_fn is not None
                    and fn is not self.fallback_round_fn
                ):
                    self.recovery["fallback_recomputes"] += 1
                    logger.warning(
                        "non-finite bc/ns block persists after %d "
                        "re-dispatches; recomputing via the fallback "
                        "round_fn", self.max_retries,
                    )
                    fn = self.fallback_round_fn
                    attempt = 0
                    continue
                raise FloatingPointError(
                    f"non-finite bc/ns block output persisted through "
                    f"{self.max_retries} re-dispatches"
                    + (
                        " and the fallback round_fn"
                        if self.fallback_round_fn is not None
                        else " (no fallback_round_fn supplied)"
                    )
                )
            if self.integrity != "off":
                reason = self._integrity_audit(out)
                if reason is not None:
                    ist = self.recovery["integrity"]
                    if "checksum" in reason:
                        ist["checksum_failures"] += 1
                    else:
                        ist["audit_failures"] += 1
                    self.recovery["quarantined_blocks"] += 1
                    if attempt < self.max_retries:
                        self.recovery["retries"] += 1
                        logger.warning(
                            "integrity audit failed (%s); block quarantined, "
                            "re-dispatching (%d/%d)",
                            reason, attempt + 1, self.max_retries,
                        )
                        attempt += 1
                        continue
                    if (
                        self.fallback_round_fn is not None
                        and fn is not self.fallback_round_fn
                    ):
                        self.recovery["fallback_recomputes"] += 1
                        logger.warning(
                            "integrity failure persists after %d "
                            "re-dispatches (%s); recomputing via the "
                            "fallback round_fn", self.max_retries, reason,
                        )
                        fn = self.fallback_round_fn
                        attempt = 0
                        continue
                    raise IntegrityError(
                        f"round block failed its integrity audit ({reason}) "
                        f"through {self.max_retries} re-dispatches"
                        + (
                            " and the fallback round_fn"
                            if self.fallback_round_fn is not None
                            else " (no fallback_round_fn supplied)"
                        )
                    )
            return out

    # ------------------------------------------------------- legacy deal
    def _blocks(self):
        """Deal rounds into [fr]-sized dispatch blocks of host arrays.

        Ledger-committed rounds are dealt as all-padding (-1) columns:
        shapes stay static, contributions are exactly zero, and the
        ledger keeps exactly-once semantics across restarts and
        speculative re-execution (distributed/fault_tolerance.py).
        Rounds are only *read* here — the commit happens at drain time
        (after the block's results exist), so a dispatch that dies never
        strands its rounds as committed-but-never-accumulated in a
        caller-owned ledger.
        """
        s = self.schedule.batch_size
        k = self.schedule.derived_per_round
        rounds = self.schedule.rounds
        for start in range(0, len(rounds), self.fr):
            block = rounds[start : start + self.fr]
            srcs = np.full((self.fr, s), -1, np.int32)
            ders = np.full((self.fr, k, 3), -1, np.int32)
            live = []
            for r, rnd in enumerate(block):
                rid = start + r
                if self.ledger is not None and self.ledger.is_committed(rid):
                    continue  # already accumulated by a previous run
                srcs[r] = rnd.sources
                ders[r] = rnd.derived
                live.append(rid)
            if live:
                yield srcs, ders, live

    def _count_roots(self, rids) -> int:
        """Root columns (explicit + derived) across the given round ids —
        the k of the sampled estimator's N/k rescale, so it must count
        exactly what the accumulator holds: every *committed* round,
        including rounds resumed from a checkpoint."""
        rounds = self.schedule.rounds
        return sum(
            int((rounds[rid].sources >= 0).sum())
            + int((rounds[rid].derived[:, 0] >= 0).sum())
            for rid in rids
        )

    def _collect_bc(self, bc_acc) -> np.ndarray:
        """Checkpoint-seed + device accumulator, in per-vertex f64 space."""
        bc = self._bc0.copy()
        if bc_acc is not None:
            dev = np.asarray(jax.device_get(bc_acc), np.float64)
            if dev.ndim > 1:  # sub-cluster replicas are additive (§3.3)
                dev = dev.reshape(-1, dev.shape[-1]).sum(axis=0)
            bc = bc + dev[: self.n]
        return bc

    def _finalize(self, bc_acc, ns_by_root) -> np.ndarray:
        bc = self._collect_bc(bc_acc)
        if self.prep is not None:
            apply_reduction_corrections(bc, self.prep, self.schedule, ns_by_root)
        return bc

    def run(self) -> BCResult:
        if self.straggler != "none":
            return self._run_straggler()
        return self._run_static()

    # --------------------------------------------- legacy (static) loop
    def _run_static(self) -> BCResult:
        import time

        bc_acc = None
        inflight: collections.deque = collections.deque()
        ns_by_root: dict[int, float] = dict(self._ns0)
        drained: list[int] = self.ledger.state() if self.checkpoint else []
        rounds_run = 0
        fwd_cols = 0
        bwd_cols = 0
        blocks_done = 0
        stopped_early = False
        blocks_since_snapshot = 0
        block_times: list[float] | None = [] if self.profile else None
        t_start = time.perf_counter()

        def drain_one():
            ns_dev, roots_dev, rids = inflight.popleft()
            roots_np = np.asarray(roots_dev)  # device_get: block boundary
            ns_np = np.asarray(ns_dev, np.float64)
            for r in range(roots_np.shape[0]):
                for root, nv in zip(roots_np[r], ns_np[r]):
                    if root >= 0:
                        ns_by_root[int(root)] = float(nv)
            # commit at drain, not dispatch: the round's contribution now
            # exists on device, so a crash before this point re-deals it
            if self.ledger is not None:
                for rid in rids:
                    self.ledger.try_commit(rid)
            drained.extend(rids)

        def snapshot():
            # drain everything first so (bc, ns, committed) is a
            # consistent prefix — see fault_tolerance.BCCheckpoint.
            while inflight:
                drain_one()
            self.checkpoint.save(
                self._collect_bc(bc_acc), ns_by_root, drained, self._fingerprint,
                stats=self._stats_state(),
            )

        for srcs, ders, live in self._blocks():
            t_blk = time.perf_counter()
            bc_blk, ns, roots, _levels, _integ = self._dispatch_block(srcs, ders)
            if block_times is not None:  # profile: sync to time this block
                jax.block_until_ready(bc_blk)
                block_times.append(time.perf_counter() - t_blk)
            bc_acc = bc_blk if bc_acc is None else self._accumulate(bc_acc, bc_blk)
            inflight.append((ns, roots, live))
            rounds_run += len(live)
            fwd_cols += int((srcs >= 0).sum())
            bwd_cols += int((srcs >= 0).sum() + (ders[:, :, 0] >= 0).sum())
            while len(inflight) > self.max_inflight:
                drain_one()
            blocks_done += 1
            blocks_since_snapshot += 1
            if self.checkpoint is not None and (
                blocks_since_snapshot >= self.checkpoint_every
            ):
                snapshot()
                blocks_since_snapshot = 0
            if self.stop_rule is not None:
                # drain first so the accumulator the rule sees is exactly
                # the committed prefix (what a checkpoint would hold)
                while inflight:
                    drain_one()
                if self.stop_rule(self._collect_bc(bc_acc), blocks_done):
                    stopped_early = True
                    logger.info(
                        "stop rule fired after %d dispatch blocks "
                        "(%d rounds committed); halting dispatch",
                        blocks_done, len(drained),
                    )
                    break
        while inflight:
            drain_one()
        if self.checkpoint is not None:
            snapshot()

        return BCResult(
            bc=self._finalize(bc_acc, ns_by_root),
            schedule=self.schedule,
            rounds_run=rounds_run,
            forward_columns=fwd_cols,
            backward_columns=bwd_cols,
            wall_s=time.perf_counter() - t_start,
            block_times=block_times,
            stopped_early=stopped_early,
            stop_stats=getattr(self.stop_rule, "stats", None),
            roots_accumulated=self._count_roots(drained),
            recovery_stats=dict(self.recovery),
        )

    # ------------------------------------------- multi-ledger scheduler
    def _committed_union(self) -> set[int]:
        out: set[int] = set()
        for led in self.ledgers:
            out |= set(led.state())
        return out

    def _try_commit(self, lane: int, rid: int) -> bool:
        """Exactly-once across *all* replica ledgers (first commit wins)."""
        for led in self.ledgers:
            if led.is_committed(rid):
                return False
        return self.ledgers[lane].try_commit(rid)

    def _run_straggler(self) -> BCResult:
        """The multi-ledger sub-cluster round loop (steal / redeal).

        Differences from the static loop:

        * one round-id queue and one :class:`RoundLedger` per replica,
          seeded by :func:`repro.core.scheduler.split_rounds` minus
          whatever any ledger already committed (merged resume);
        * each dispatch block is *timed* (block_until_ready, as in
          profile mode) and its wall is attributed to the replicas in
          proportion to their observed traversal ``levels`` — under a
          lockstep (ring-overlap) schedule the block wall is shared, so
          depth share is the per-replica signal — feeding a per-replica
          EWMA of per-round seconds;
        * commits happen at *drain* time and the accumulate is masked by
          the commit outcome (donation-safe double-dispatch);
        * between blocks the policy moves pending rounds: ``steal`` pulls
          into idle lanes and duplicates the straggler's round at the
          tail, ``redeal`` re-packs every pending round when the EWMA
          ratio crosses ``straggler_factor``.
        """
        import time

        from repro.distributed.fault_tolerance import ReplicaLostError

        fr = self.fr
        s = self.schedule.batch_size
        k = self.schedule.derived_per_round
        rounds = self.schedule.rounds
        queues = split_rounds(
            len(rounds), fr, self._committed_union(), round_costs=self.round_costs
        )

        prior = self.prior_round_s
        ewma: list[float | None] = [None] * fr
        observed = [False] * fr

        def est(r: int) -> float:
            if ewma[r] is not None:
                return ewma[r]
            return prior if prior is not None else 1.0

        bc_acc = None
        ns_by_root: dict[int, float] = dict(self._ns0)
        rounds_run = 0
        fwd_cols = 0
        bwd_cols = 0
        stopped_early = False
        blocks_since_snapshot = 0
        block_times: list[float] = []
        stats = {
            "policy": self.straggler,
            "factor": self.straggler_factor,
            "replicas": fr,
            "rounds_stolen": 0,
            "rounds_redealt": 0,
            "redeal_events": 0,
            "duplicates_dispatched": 0,
            "duplicates_discarded": 0,
            "per_replica_wall_s": [0.0] * fr,
            "per_replica_rounds": [0] * fr,
            "per_replica_levels": [0] * fr,
            "idle_levels": 0,
            "idle_s_est": 0.0,
        }
        was_flagged = False
        t_start = time.perf_counter()

        def flagged() -> bool:
            vals = [
                ewma[r] for r in range(fr)
                if observed[r] and r not in self._dead_lanes
            ]
            if len(vals) < 2:
                return False
            lo, hi = min(vals), max(vals)
            return lo > 0.0 and hi > self.straggler_factor * lo

        def on_replica_loss(err, lane_rids, duplicate):
            """Self-heal a lost replica lane (nothing from the failed
            dispatch landed): consult the elasticity planner, move the
            dead lane's ledger commits to a survivor (the committed
            union — exactly-once — is unchanged), re-deal its backlog,
            and continue at reduced effective fr (the dead lane is dealt
            only padding from here on, so shapes stay static)."""
            from repro.distributed.fault_tolerance import plan_elastic_remesh

            dead = int(getattr(err, "replica", -1))
            if dead < 0 or dead >= fr or dead in self._dead_lanes:
                raise err
            self._dead_lanes.add(dead)
            survivors = [r for r in range(fr) if r not in self._dead_lanes]
            if not survivors:
                raise err
            self.recovery["remesh_events"] += 1
            self.recovery["dead_replicas"] = sorted(self._dead_lanes)
            # the failed block's owned rounds go back to the front of a
            # surviving queue (duplicates' owners requeue their own copy)
            for r in range(fr):
                rid = lane_rids[r]
                if rid is None or duplicate[r]:
                    continue
                if any(led.is_committed(rid) for led in self.ledgers):
                    continue
                target = r if r in survivors else survivors[0]
                queues[target].insert(0, rid)
            taken = self.ledgers[survivors[0]].merge(self.ledgers[dead])
            orphans = list(queues[dead])
            queues[dead] = []
            for i, rid in enumerate(orphans):
                queues[survivors[i % len(survivors)]].append(rid)
            sub, _ = redeal_rounds(
                [queues[r] for r in survivors], [est(r) for r in survivors]
            )
            for r, q in zip(survivors, sub):
                queues[r] = q
            try:
                total = 1
                for dim in self.mesh_shape:
                    total *= dim
                pod_ax = (
                    self.mesh_axes.index("pod") if "pod" in self.mesh_axes else 0
                )
                per_pod = max(1, total // max(1, self.mesh_shape[pod_ax]))
                plan = plan_elastic_remesh(
                    self.mesh_shape, self.mesh_axes,
                    per_pod * len(self._dead_lanes),
                )
                logger.warning(
                    "replica %d lost: re-mesh %s -> %s (%s); merged %d "
                    "committed rounds into replica %d, re-dealt %d pending",
                    dead, self.mesh_shape, plan.shape, plan.note, taken,
                    survivors[0], len(orphans),
                )
            except Exception as pe:  # planning is advisory, never fatal
                logger.warning(
                    "replica %d lost: elastic re-mesh planning failed "
                    "(%s: %s); continuing on %d surviving lanes",
                    dead, type(pe).__name__, pe, len(survivors),
                )

        def snapshot():
            self.checkpoint.save(
                self._collect_bc(bc_acc),
                ns_by_root,
                [led.state() for led in self.ledgers],
                self._fingerprint,
                stats=self._stats_state(),
            )

        while any(queues):
            alive = [r for r in range(fr) if r not in self._dead_lanes]
            # ---------------------------------------- policy: move work
            if self.straggler == "redeal":
                lengths = [len(queues[r]) for r in alive]
                fire = flagged()
                tail_gap = min(lengths) == 0 and max(lengths) >= 2
                if (fire and not was_flagged) or tail_gap:
                    sub, moved = redeal_rounds(
                        [queues[r] for r in alive], [est(r) for r in alive]
                    )
                    for r, q in zip(alive, sub):
                        queues[r] = q
                    if moved:
                        stats["rounds_redealt"] += moved
                        stats["redeal_events"] += 1
                        logger.info(
                            "straggler redeal: moved %d pending rounds "
                            "(EWMA s/round: %s)",
                            moved,
                            [None if ewma[r] is None else round(ewma[r], 6)
                             for r in alive],
                        )
                was_flagged = fire

            # ----------------------------------------------- form block
            lane_rids: list[int | None] = [
                queues[r].pop(0)
                if r not in self._dead_lanes and queues[r]
                else None
                for r in range(fr)
            ]
            duplicate = [False] * fr
            if self.straggler == "steal":
                # idle lanes pull from the heaviest remaining backlog
                for r in sorted(alive, key=est):
                    if lane_rids[r] is not None:
                        continue
                    donors = [d for d in alive if queues[d]]
                    if not donors:
                        continue
                    donor = max(donors, key=lambda d: len(queues[d]) * est(d))
                    lane_rids[r] = queues[donor].pop(0)
                    stats["rounds_stolen"] += 1
                # tail: still-idle lanes back up the presumed straggler's
                # round instead of dispatching padding (first commit wins)
                working = [r for r in alive if lane_rids[r] is not None]
                idle = [r for r in alive if lane_rids[r] is None]
                if working and idle:
                    slowest = max(working, key=est)
                    for r in idle:
                        lane_rids[r] = lane_rids[slowest]
                        duplicate[r] = True
                        stats["duplicates_dispatched"] += 1
            if all(rid is None for rid in lane_rids):
                continue

            srcs = np.full((fr, s), -1, np.int32)
            ders = np.full((fr, k, 3), -1, np.int32)
            for r, rid in enumerate(lane_rids):
                if rid is not None:
                    srcs[r] = rounds[rid].sources
                    ders[r] = rounds[rid].derived

            # ------------------------------------- dispatch + observe
            t_blk = time.perf_counter()
            try:
                out = self._dispatch_block(srcs, ders)
            except ReplicaLostError as e:
                if int(getattr(e, "replica", -1)) < 0:
                    # unattributed loss (the watchdog escalated a wedged
                    # dispatch without knowing *which* lane hung): suspect
                    # the slowest live lane by EWMA — the one most likely
                    # to be the straggling/wedged participant
                    cands = [
                        r for r in alive if lane_rids[r] is not None
                    ] or alive
                    suspect = max(cands, key=est)
                    e = ReplicaLostError(
                        suspect,
                        f"{e}; suspecting replica {suspect} "
                        f"(slowest EWMA among the dispatched lanes)",
                    )
                on_replica_loss(e, lane_rids, duplicate)
                continue
            bc_blk, ns_dev, roots_dev, levels_dev, _integ = out
            if levels_dev is None:
                raise ValueError(
                    "straggler scheduling needs a round_fn returning "
                    "(bc, ns, roots, levels); got a legacy 3-tuple"
                )
            jax.block_until_ready(bc_blk)
            wall = time.perf_counter() - t_blk
            block_times.append(wall)
            if bc_blk.shape[0] != fr:
                raise ValueError(
                    f"straggler scheduling needs a per-replica bc block "
                    f"(leading dim {fr}); got shape {tuple(bc_blk.shape)}"
                )
            levels_np = np.asarray(levels_dev).reshape(-1).astype(np.int64)
            # duplicate lanes ran work they will discard: they get no wall
            # attribution and no EWMA update (their "cost" belongs to the
            # round's owner lane, which is also in this block)
            own = [
                r for r in range(fr)
                if lane_rids[r] is not None and not duplicate[r]
            ]
            lv_total = int(levels_np[own].sum())
            lv_max = int(levels_np[own].max()) if own else 0
            for r in own:
                share = (
                    levels_np[r] / lv_total if lv_total > 0 else 1.0 / len(own)
                )
                obs = wall * float(share)
                ewma[r] = (
                    obs
                    if ewma[r] is None and prior is None
                    else _EWMA_ALPHA * obs
                    + (1.0 - _EWMA_ALPHA) * (ewma[r] if ewma[r] is not None else prior)
                )
                observed[r] = True
                stats["per_replica_wall_s"][r] += obs
                stats["per_replica_levels"][r] += int(levels_np[r])
                stats["idle_levels"] += lv_max - int(levels_np[r])
            if lv_max > 0 and own:
                idle_frac = sum(lv_max - int(levels_np[r]) for r in own) / (
                    len(own) * lv_max
                )
                stats["idle_s_est"] += wall * idle_frac

            # ---------------------- duplicate vote (free DMR, steal tail)
            # a speculatively duplicated round ran the identical
            # deterministic computation on two replica lanes — compare
            # their bc digests; a mismatch means one lane produced
            # silently corrupt data, so neither copy can be trusted:
            # quarantine the round (no commit, both lanes masked to zero)
            # and re-dispatch it to its owner as the tie-breaker vote.
            quarantined_rids: set[int] = set()
            lane_sums = None
            if self.integrity != "off" and (
                any(duplicate) or self._pending_votes
            ):
                lane_sums = np.asarray(
                    jax.device_get(self._block_digest(bc_blk)[0]), np.float64
                ).reshape(-1)
            if lane_sums is not None and any(duplicate):
                ist = self.recovery["integrity"]
                for r in range(fr):
                    if not duplicate[r]:
                        continue
                    rid = lane_rids[r]
                    owner = next(
                        o for o in range(fr)
                        if lane_rids[o] == rid and not duplicate[o]
                    )
                    ist["votes"] += 1
                    vscale = max(
                        1.0, abs(lane_sums[owner]), abs(lane_sums[r])
                    )
                    if (
                        abs(lane_sums[r] - lane_sums[owner])
                        > VOTE_RTOL * vscale
                    ):
                        ist["vote_mismatches"] += 1
                        if rid in quarantined_rids:
                            continue  # already requeued by another copy
                        ist["quarantined_rounds"] += 1
                        quarantined_rids.add(rid)
                        self._pending_votes[rid] = {
                            "owner": float(lane_sums[owner]),
                            "duplicate": float(lane_sums[r]),
                        }
                        queues[owner].insert(0, rid)
                        logger.warning(
                            "duplicate-vote mismatch on round %d "
                            "(owner lane %d sum %.6g vs duplicate lane %d "
                            "sum %.6g); round quarantined, re-dispatching "
                            "as tie-breaker",
                            rid, owner, lane_sums[owner], r, lane_sums[r],
                        )

            # -------------------------- drain: commit-or-discard + add
            # originals commit before their speculative duplicates, so a
            # backup copy never out-commits the lane that owns the round
            # (keeps duplicates_discarded and per-replica attribution
            # honest; exactly-once holds in either order)
            mask = np.zeros(fr, np.float32)
            roots_np = np.asarray(roots_dev)
            ns_np = np.asarray(ns_dev, np.float64)
            for r in sorted(range(fr), key=lambda r: duplicate[r]):
                rid = lane_rids[r]
                if rid is None or rid in quarantined_rids:
                    continue
                if self._try_commit(r, rid):
                    mask[r] = 1.0
                    rounds_run += 1
                    stats["per_replica_rounds"][r] += 1
                    fwd_cols += int((srcs[r] >= 0).sum())
                    bwd_cols += int(
                        (srcs[r] >= 0).sum() + (ders[r, :, 0] >= 0).sum()
                    )
                    for root, nv in zip(roots_np[r], ns_np[r]):
                        if root >= 0:
                            ns_by_root[int(root)] = float(nv)
                    pend = self._pending_votes.pop(rid, None)
                    if pend is not None and lane_sums is not None:
                        # tie-breaker verdict: which original lane agreed
                        # with this clean recompute (i.e. was correct)
                        tie = float(lane_sums[r])

                        def close(a, b):
                            return abs(a - b) <= VOTE_RTOL * max(
                                1.0, abs(a), abs(b)
                            )

                        matched = (
                            "owner" if close(tie, pend["owner"])
                            else "duplicate" if close(tie, pend["duplicate"])
                            else "neither"
                        )
                        self.recovery["integrity"]["vote_verdicts"].append(
                            {"round": int(rid), "matched": matched}
                        )
                        logger.warning(
                            "duplicate-vote tie-breaker for round %d: "
                            "the %s lane was correct", rid, matched,
                        )
                elif duplicate[r]:
                    stats["duplicates_discarded"] += 1
            mask_dev = jnp.asarray(mask)
            bc_acc = (
                self._masked_scale(bc_blk, mask_dev)
                if bc_acc is None
                else self._masked_accumulate(bc_acc, bc_blk, mask_dev)
            )

            blocks_since_snapshot += 1
            if self.checkpoint is not None and (
                blocks_since_snapshot >= self.checkpoint_every
            ):
                snapshot()
                blocks_since_snapshot = 0
            # the stop seam: commits already happened at this block's
            # drain (exactly-once is settled), so halting here leaves a
            # clean committed prefix for the checkpoint/re-deal to own
            if self.stop_rule is not None and self.stop_rule(
                self._collect_bc(bc_acc), len(block_times)
            ):
                stopped_early = True
                logger.info(
                    "stop rule fired after %d dispatch blocks "
                    "(%d rounds committed); halting dispatch",
                    len(block_times), rounds_run,
                )
                break

        if self.checkpoint is not None:
            snapshot()
        logger.info(
            "straggler=%s: %d rounds, %d stolen, %d re-dealt (%d events), "
            "%d/%d duplicates discarded, idle ≈ %.3fs of %.3fs wall",
            self.straggler,
            rounds_run,
            stats["rounds_stolen"],
            stats["rounds_redealt"],
            stats["redeal_events"],
            stats["duplicates_discarded"],
            stats["duplicates_dispatched"],
            stats["idle_s_est"],
            time.perf_counter() - t_start,
        )
        return BCResult(
            bc=self._finalize(bc_acc, ns_by_root),
            schedule=self.schedule,
            rounds_run=rounds_run,
            forward_columns=fwd_cols,
            backward_columns=bwd_cols,
            wall_s=time.perf_counter() - t_start,
            block_times=block_times,
            stopped_early=stopped_early,
            stop_stats=getattr(self.stop_rule, "stats", None),
            roots_accumulated=self._count_roots(
                sorted(self._committed_union())
            ),
            straggler_stats=stats,
            recovery_stats=dict(self.recovery),
        )
