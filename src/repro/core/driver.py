"""The driver layer: one round body and one host round loop for all
engines (single-device dense/sparse/Pallas and 2-D distributed).

:func:`traversal_round` is the per-round algebra — forward counting,
2-degree column derivation, dependency accumulation, per-round BC and
component-size (n_s) extraction, plus the round's own traversal depth
(the straggler scheduler's cost signal) — written once against the
:class:`repro.core.operators.TraversalOperator` protocol.  Entry points
wrap it in whatever jit/shard_map shell their operator needs.

:class:`BCDriver` is the host loop shared by
:func:`repro.core.bc.betweenness_centrality`,
:func:`repro.core.distributed.distributed_betweenness_centrality`, the
``repro.launch.bc`` CLI and the benchmarks:

* rounds are dealt in *dispatch blocks* of ``rounds_per_dispatch``
  (1 on a single device; the sub-cluster count ``fr`` on a mesh);
* dispatch is asynchronous: up to ``max_inflight`` blocks are in flight
  and ``device_get`` happens only at block boundaries, so host sync no
  longer serializes rounds;
* the BC accumulator lives on device and is *donated* through a jitted
  add (no per-round host round-trip, no per-round buffer copy); it is
  fetched exactly once, after the last round;
* an optional :class:`repro.distributed.fault_tolerance.RoundLedger`
  makes the loop restartable: committed rounds are re-dealt as inert
  all-padding columns (BC accumulation is additive, padding contributes
  exactly zero), which keeps every dispatch shape static;
* ``straggler`` selects the multi-ledger sub-cluster scheduling policy
  (:data:`STRAGGLER_POLICIES`): with ``"steal"`` or ``"redeal"`` the
  driver keeps one :class:`RoundLedger` *per replica*, tracks a
  per-replica EWMA of per-round wall time (seeded from the roofline's
  ``overlap_step_time`` estimate before any round completes), and moves
  uncommitted rounds between replica queues when one replica straggles.
  Commits then move from dispatch time to drain time and the BC
  accumulate is masked by the commit outcome, so a round dispatched on
  two replicas (speculative tail duplication, or a re-deal racing a
  kill-and-resume) is accumulated exactly once: first commit wins, the
  loser's lane is multiplied by zero *before* the donated add.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.heuristics.one_degree import OneDegreeReduction, leaf_correction
from repro.core.heuristics.two_degree import derive_two_degree_columns
from repro.core.operators import TraversalOperator, as_operator
from repro.core.scheduler import Schedule, redeal_rounds, split_rounds

__all__ = [
    "BCResult",
    "BCDriver",
    "traversal_round",
    "apply_reduction_corrections",
    "STRAGGLER_POLICIES",
    "normalize_straggler",
    "DEFAULT_MAX_RETRIES",
    "DEFAULT_RETRY_BACKOFF_S",
]

logger = logging.getLogger(__name__)

#: Sub-cluster straggler-mitigation policies of :class:`BCDriver` (the
#: single source of truth for ``--straggler`` choices and the docs drift
#: check).  ``"none"`` keeps the static deal (one shared ledger, commits
#: at dispatch — the legacy loop).  ``"steal"`` is the conservative
#: multi-ledger policy: work moves only when a replica's queue runs dry —
#: the idle replica pulls the next round from the heaviest backlog, and
#: at the very tail it speculatively *duplicates* the presumed
#: straggler's in-flight round instead of dispatching padding (MapReduce
#: backup tasks; first commit wins).  ``"redeal"`` is the aggressive
#: policy: when a replica's EWMA per-round wall exceeds
#: ``straggler_factor ×`` the fastest replica's, every pending round is
#: re-dealt across the replica queues so similar-cost rounds are
#: co-scheduled (the straggler's backlog drains into the fastest
#: replica's queue).
STRAGGLER_POLICIES = ("none", "steal", "redeal")

_EWMA_ALPHA = 0.5  # weight of the newest per-round wall observation

#: Self-healing defaults: re-dispatches allowed per block (transient
#: errors and quarantined non-finite outputs share the budget) and the
#: base of the exponential backoff between transient retries.  2 retries
#: rides out the one-off XLA hiccups worth retrying; anything persisting
#: past that is a real failure the fallback/caller must see.
DEFAULT_MAX_RETRIES = 2
DEFAULT_RETRY_BACKOFF_S = 0.05


def normalize_straggler(policy: str | None) -> str:
    """Validate a straggler policy string (None means "none")."""
    policy = "none" if policy is None else policy
    if policy not in STRAGGLER_POLICIES:
        raise ValueError(
            f"unknown straggler policy {policy!r}; expected one of "
            f"{STRAGGLER_POLICIES}"
        )
    return policy


def traversal_round(
    operator: TraversalOperator,
    sources: jnp.ndarray,  # i32 [s]; -1 = padding
    derived: jnp.ndarray,  # i32 [k, 3] rows (c, a_pos, b_pos); -1 = padding
    omega: jnp.ndarray,  # f32 [n_rows] 1-degree weights (operator's rows)
    *,
    num_levels: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One BC round against the operator protocol.

    Returns
      bc_local  f32 [n_rows] — this round's BC contribution to the
                operator's rows (global BC = sum over rounds/devices),
      ns        f32 [s+k]    — per-column component size n_s (§3.4.1),
                already globally reduced,
      roots     i32 [s+k]    — root vertex of every column (-1 padding),
      levels    i32 []       — traversal depth of *this* round on its own
                grid (``reduce_max_grid``: per-replica even when
                ``sync_axes`` pins the loop bounds to the mesh-wide max).
                0 for an all-padding round.  This is the data-dependent
                cost signal the straggler scheduler attributes wall time
                by.
    """
    op = as_operator(operator)
    omega_f = omega.astype(jnp.float32)
    row_ids = op.row_ids()

    # ---------------------------------------------------------- forward
    src_onehot = (
        (row_ids[:, None] == sources[None, :]) & (sources[None, :] >= 0)
    ).astype(jnp.float32)
    fwd = engine.forward_counting(op, src_onehot, num_levels=num_levels)

    # ------------------------------------------- derived 2-degree columns
    sigma_c, depth_c = derive_two_degree_columns(
        fwd.sigma, fwd.depth, derived, row_ids=row_ids
    )
    sigma_all = jnp.concatenate([fwd.sigma, sigma_c], axis=1)
    depth_all = jnp.concatenate([fwd.depth, depth_c], axis=1)

    # ---------------------------------------------------------- backward
    # decomposed max: grid first (the per-replica depth = the straggler
    # cost signal), then the sync-axes extension for the loop bound — one
    # reduction total when sync_axes is empty (reduce_max_sync is a no-op)
    grid_max = op.reduce_max_grid(jnp.max(depth_all))
    max_depth = op.reduce_max_sync(grid_max)
    delta = engine.backward_accumulation(
        op, sigma_all, depth_all, omega_f, max_depth, num_levels=num_levels
    )

    # --------------------------------------------------------- BC + n_s
    roots = jnp.concatenate([sources, derived[:, 0]])
    omega_root = op.root_omega(roots, omega_f)
    mult = jnp.where(roots >= 0, omega_root + 1.0, 0.0)

    root_onehot = row_ids[:, None] == roots[None, :]
    weighted = jnp.where(root_onehot, 0.0, delta * mult[None, :])
    bc_local = weighted.sum(axis=1)

    # per-column component size  n_s = Σ_{d ≥ 0} (1 + ω)   (paper §3.4.1)
    ns = op.reduce_sum(((depth_all >= 0) * (1.0 + omega_f)[:, None]).sum(axis=0))
    levels = (grid_max + 1).astype(jnp.int32)
    return bc_local, ns, roots, levels


def apply_reduction_corrections(
    bc: np.ndarray,
    prep: OneDegreeReduction,
    schedule,
    ns_by_root: dict[int, float],
) -> None:
    """Add the analytic BC credits of the 1-degree/tree reduction.

    Every vertex x with removed branches (S(x) > 0) — residual or removed
    interior — gets 2·S·(n_comp−1−S) + 2·P (heuristics/one_degree.py).
    n_comp comes from x's own round, the isolated-residual analytic size,
    or (removed vertices) the resolved root's size."""
    n_by_root = dict(ns_by_root)
    for v, n_comp in schedule.analytic_corrections:
        n_by_root[int(v)] = float(n_comp)
    S, P = prep.omega, prep.pair_credit
    for x in np.nonzero(S > 0)[0]:
        x = int(x)
        if prep.removed[x]:
            root, analytic_n = prep.resolve_root(x)
            n_comp = analytic_n if analytic_n >= 0 else n_by_root.get(int(root))
        else:
            n_comp = n_by_root.get(x)
        if n_comp is None:
            raise RuntimeError(f"no component size recorded for vertex {x}")
        bc[x] += leaf_correction(S[x], n_comp, P[x])


@dataclasses.dataclass
class BCResult:
    bc: np.ndarray  # float64 [n]
    schedule: Schedule
    rounds_run: int
    forward_columns: int  # explicit BFS columns actually traversed
    backward_columns: int  # dependency columns (explicit + derived)
    wall_s: float = 0.0  # host wall time of the round loop
    block_times: list[float] | None = None  # per-dispatch-block seconds
    #   (profile / straggler modes only — the driver blocks per block to
    #   measure, so async dispatch is disabled; use for benchmarking and
    #   scheduling, not peak-throughput production)
    straggler_stats: dict | None = None  # multi-ledger scheduler telemetry
    #   (straggler != "none" only): per-replica wall/rounds/levels,
    #   rounds stolen / re-dealt, speculative duplicates, idle estimate.
    recovery_stats: dict | None = None  # self-healing telemetry (always
    #   set by BCDriver): retries, transient_errors, quarantined_blocks,
    #   fallback_recomputes, remesh_events, dead_replicas,
    #   resumed_generation (BCCheckpoint generation the run resumed
    #   from; None = cold start / no checkpoint).


def _unpack_block(out):
    """Accept 3-tuple (legacy) or 4-tuple round_fn outputs."""
    if len(out) == 4:
        return out
    bc_blk, ns, roots = out
    return bc_blk, ns, roots, None


class BCDriver:
    """Shared host round loop (see module docstring).

    ``round_fn(sources i32 [fr, s], derived i32 [fr, k, 3])`` must return
    device arrays ``(bc_block, ns [fr, s+k], roots [fr, s+k],
    levels [fr])`` where ``bc_block`` has any stable shape whose leading
    dims sum away to the per-vertex contribution ([n] on one device;
    [fr, n_pad] sharded on a mesh).  All graph-constant operands
    (adjacency, ω, arc lists) are expected to be partially applied into
    ``round_fn``.  Legacy 3-tuple round functions (no ``levels``) are
    accepted under ``straggler="none"``.

    ``profile=True`` blocks on every dispatch block and records its wall
    seconds in ``BCResult.block_times`` (plus total ``wall_s``) — the
    measurement mode the overlap benchmarks use; it defeats the async
    pipeline, so leave it off in production.

    ``straggler`` (see :data:`STRAGGLER_POLICIES`) enables the
    multi-ledger sub-cluster scheduler; it requires ``round_fn`` to carry
    a leading replica dim of ``rounds_per_dispatch`` on ``bc_block`` and
    to return ``levels``, and — like ``profile`` — blocks per dispatch
    block (the per-round wall observations are its control signal).
    ``straggler_factor`` is the EWMA ratio that flags a replica as a
    straggler; ``prior_round_s`` seeds every replica's EWMA before any
    round completes (callers pass the roofline ``overlap_step_time``
    estimate — or, under ``autotune``, the measured per-level cost via
    :func:`repro.core.distributed.prior_round_seconds` — symmetric, so
    no re-deal can fire on the prior alone).  ``round_costs`` hands the
    static deal a per-round cost prior (``Schedule.round_depths``): the
    initial queues then pack similar-cost rounds per dispatch block
    instead of interleaving by id.

    **Self-healing** (telemetry in ``BCResult.recovery_stats``):
    transient round failures are retried in place (``max_retries``
    re-dispatches, exponential backoff from ``retry_backoff_s``); the
    numeric guard (``numeric_guard``, auto-on wherever the loop already
    syncs per block) quarantines non-finite bc/ns blocks and re-runs
    them, escalating to ``fallback_round_fn`` — the caller's known-good
    dense path — when the corruption persists; under ``straggler ≠
    "none"`` a :class:`repro.distributed.fault_tolerance.
    ReplicaLostError` from the round_fn triggers an elastic re-mesh
    (``plan_elastic_remesh`` over ``mesh_shape``/``mesh_axes``): the
    dead replica's ledger merges into a survivor's, its backlog is
    re-dealt, and the loop continues at reduced effective ``fr`` with
    the dead lane dealt only padding.
    """

    def __init__(
        self,
        round_fn: Callable,
        schedule: Schedule,
        *,
        n: int,
        prep: OneDegreeReduction | None = None,
        ledger=None,
        checkpoint=None,
        checkpoint_every: int = 8,
        rounds_per_dispatch: int = 1,
        max_inflight: int = 2,
        profile: bool = False,
        straggler: str = "none",
        straggler_factor: float = 2.0,
        prior_round_s: float | None = None,
        round_costs=None,
        max_retries: int = DEFAULT_MAX_RETRIES,
        retry_backoff_s: float = DEFAULT_RETRY_BACKOFF_S,
        numeric_guard: bool | None = None,
        fallback_round_fn: Callable | None = None,
        mesh_shape: tuple[int, ...] | None = None,
        mesh_axes: tuple[str, ...] | None = None,
    ):
        self.round_fn = round_fn
        self.profile = profile
        self.schedule = schedule
        self.n = n
        self.prep = prep
        self.checkpoint = checkpoint
        self.checkpoint_every = max(1, checkpoint_every)
        self.straggler = normalize_straggler(straggler)
        self.straggler_factor = float(straggler_factor)
        self.prior_round_s = prior_round_s
        #: per-round expected cost (Schedule.round_depths when the
        #: scheduler packed by eccentricity) — seeds the straggler deal
        #: (split_rounds round_costs) so lanes start cost-balanced
        self.round_costs = round_costs
        self._bc0 = np.zeros(n, np.float64)
        self._ns0: dict[int, float] = {}
        self._fingerprint = None
        self.fr = max(1, rounds_per_dispatch)
        self.max_inflight = max(1, max_inflight)

        # ------------------------------------------------- self-healing
        self.max_retries = max(0, int(max_retries))
        self.retry_backoff_s = float(retry_backoff_s)
        self.fallback_round_fn = fallback_round_fn
        # The guard fetches a per-block finiteness bit, i.e. a host sync.
        # Auto-resolution turns it on exactly where that sync is already
        # paid (profile / straggler modes block per dispatch to measure)
        # or where the caller opted into recovery (a fallback round_fn);
        # the pure-async static fast path stays unsynced unless asked.
        if numeric_guard is None:
            numeric_guard = (
                fallback_round_fn is not None
                or self.straggler != "none"
                or profile
            )
        self.numeric_guard = bool(numeric_guard)
        # mesh geometry for plan_elastic_remesh on replica loss: the
        # replica ('pod') axis is the dispatch lane dim by default;
        # distributed callers pass the true (fr, R, C) shape.
        self.mesh_shape = tuple(mesh_shape) if mesh_shape is not None else (self.fr,)
        self.mesh_axes = tuple(mesh_axes) if mesh_axes is not None else ("pod",)
        self._dead_lanes: set[int] = set()
        self.recovery: dict = {
            "retries": 0,
            "transient_errors": 0,
            "quarantined_blocks": 0,
            "fallback_recomputes": 0,
            "remesh_events": 0,
            "dead_replicas": [],
            "resumed_generation": None,
        }
        self._finite_check = jax.jit(
            lambda bc, ns: jnp.isfinite(bc).all() & jnp.isfinite(ns).all()
        )

        from repro.distributed.fault_tolerance import (
            RoundLedger,
            schedule_fingerprint,
        )

        if checkpoint is not None:
            if ledger is not None:
                raise ValueError("pass either a ledger or a checkpoint, not both")
            self._fingerprint = schedule_fingerprint(n, schedule)

        if self.straggler != "none":
            if ledger is not None:
                raise ValueError(
                    "straggler scheduling keeps one ledger per replica; "
                    "pass a checkpoint (or nothing), not an external ledger"
                )
            by_lane: list[list[int]] = [[] for _ in range(self.fr)]
            if checkpoint is not None:
                bc0, ns0, stored = checkpoint.load_namespaced(self._fingerprint)
                if bc0 is not None:
                    self._bc0 = bc0[: n]
                    self._ns0 = ns0
                if len(stored) == self.fr:
                    by_lane = [list(lane) for lane in stored]
                else:  # replica count changed across the resume: merge
                    union = sorted({rid for lane in stored for rid in lane})
                    by_lane[0] = union
            self.ledgers = [RoundLedger.from_state(lane) for lane in by_lane]
            self.ledger = None
        else:
            if checkpoint is not None:
                bc0, ns0, committed = checkpoint.load(self._fingerprint)
                if bc0 is not None:
                    self._bc0 = bc0[: n]
                    self._ns0 = ns0
                ledger = RoundLedger.from_state(committed)
            self.ledger = ledger
            self.ledgers = None
        if checkpoint is not None:
            gen = getattr(checkpoint, "loaded_generation", None)
            self.recovery["resumed_generation"] = gen
            if gen is not None:
                (logger.warning if gen > 0 else logger.info)(
                    "resumed from checkpoint generation %d%s",
                    gen,
                    " (newer snapshots were corrupt)" if gen > 0 else "",
                )
        # donated device-side accumulate: bc never round-trips per round
        self._accumulate = jax.jit(lambda acc, x: acc + x, donate_argnums=(0,))
        # drain-time masked accumulate (straggler modes): the commit
        # outcome zeroes losing lanes *before* the donated add, so a
        # double-dispatched round contributes exactly once.
        def _bmask(blk, m):
            return blk * m.reshape(m.shape + (1,) * (blk.ndim - 1))

        self._masked_accumulate = jax.jit(
            lambda acc, blk, m: acc + _bmask(blk, m), donate_argnums=(0,)
        )
        self._masked_scale = jax.jit(_bmask)

    # ---------------------------------------------------- self-healing
    def _dispatch_block(self, srcs, ders):
        """Run ``round_fn`` on one dispatch block with recovery.

        Transient failures (:func:`repro.distributed.fault_tolerance.
        is_transient_error`) are retried in place with exponential
        backoff, up to ``max_retries`` re-dispatches per block.  Under
        the numeric guard a block whose bc/ns came back non-finite is
        *quarantined* — never accumulated — and re-dispatched from the
        same budget; if the poison persists the block is recomputed via
        ``fallback_round_fn`` (the caller's known-good dense path) with
        a fresh budget.  :class:`ReplicaLostError` always propagates:
        in-place retry cannot resurrect devices — the multi-ledger loop
        re-meshes instead.  Returns the unpacked 4-tuple.
        """
        import time

        from repro.distributed.fault_tolerance import is_transient_error

        srcs_dev = jnp.asarray(srcs)
        ders_dev = jnp.asarray(ders)
        fn = self.round_fn
        attempt = 0
        while True:
            try:
                out = _unpack_block(fn(srcs_dev, ders_dev))
            except Exception as e:
                if is_transient_error(e) and attempt < self.max_retries:
                    backoff = self.retry_backoff_s * (2.0 ** attempt)
                    self.recovery["transient_errors"] += 1
                    self.recovery["retries"] += 1
                    logger.warning(
                        "transient round failure (%s: %s); retry %d/%d "
                        "after %.3fs backoff",
                        type(e).__name__, e, attempt + 1, self.max_retries,
                        backoff,
                    )
                    time.sleep(backoff)
                    attempt += 1
                    continue
                raise
            if self.numeric_guard and not bool(
                self._finite_check(out[0], out[1])
            ):
                self.recovery["quarantined_blocks"] += 1
                if attempt < self.max_retries:
                    self.recovery["retries"] += 1
                    logger.warning(
                        "non-finite bc/ns block quarantined; re-dispatching "
                        "(%d/%d)", attempt + 1, self.max_retries,
                    )
                    attempt += 1
                    continue
                if (
                    self.fallback_round_fn is not None
                    and fn is not self.fallback_round_fn
                ):
                    self.recovery["fallback_recomputes"] += 1
                    logger.warning(
                        "non-finite bc/ns block persists after %d "
                        "re-dispatches; recomputing via the fallback "
                        "round_fn", self.max_retries,
                    )
                    fn = self.fallback_round_fn
                    attempt = 0
                    continue
                raise FloatingPointError(
                    f"non-finite bc/ns block output persisted through "
                    f"{self.max_retries} re-dispatches"
                    + (
                        " and the fallback round_fn"
                        if self.fallback_round_fn is not None
                        else " (no fallback_round_fn supplied)"
                    )
                )
            return out

    # ------------------------------------------------------- legacy deal
    def _blocks(self):
        """Deal rounds into [fr]-sized dispatch blocks of host arrays.

        Ledger-committed rounds are dealt as all-padding (-1) columns:
        shapes stay static, contributions are exactly zero, and the
        ledger keeps exactly-once semantics across restarts and
        speculative re-execution (distributed/fault_tolerance.py).
        Rounds are only *read* here — the commit happens at drain time
        (after the block's results exist), so a dispatch that dies never
        strands its rounds as committed-but-never-accumulated in a
        caller-owned ledger.
        """
        s = self.schedule.batch_size
        k = self.schedule.derived_per_round
        rounds = self.schedule.rounds
        for start in range(0, len(rounds), self.fr):
            block = rounds[start : start + self.fr]
            srcs = np.full((self.fr, s), -1, np.int32)
            ders = np.full((self.fr, k, 3), -1, np.int32)
            live = []
            for r, rnd in enumerate(block):
                rid = start + r
                if self.ledger is not None and self.ledger.is_committed(rid):
                    continue  # already accumulated by a previous run
                srcs[r] = rnd.sources
                ders[r] = rnd.derived
                live.append(rid)
            if live:
                yield srcs, ders, live

    def _collect_bc(self, bc_acc) -> np.ndarray:
        """Checkpoint-seed + device accumulator, in per-vertex f64 space."""
        bc = self._bc0.copy()
        if bc_acc is not None:
            dev = np.asarray(jax.device_get(bc_acc), np.float64)
            if dev.ndim > 1:  # sub-cluster replicas are additive (§3.3)
                dev = dev.reshape(-1, dev.shape[-1]).sum(axis=0)
            bc = bc + dev[: self.n]
        return bc

    def _finalize(self, bc_acc, ns_by_root) -> np.ndarray:
        bc = self._collect_bc(bc_acc)
        if self.prep is not None:
            apply_reduction_corrections(bc, self.prep, self.schedule, ns_by_root)
        return bc

    def run(self) -> BCResult:
        if self.straggler != "none":
            return self._run_straggler()
        return self._run_static()

    # --------------------------------------------- legacy (static) loop
    def _run_static(self) -> BCResult:
        import time

        bc_acc = None
        inflight: collections.deque = collections.deque()
        ns_by_root: dict[int, float] = dict(self._ns0)
        drained: list[int] = self.ledger.state() if self.checkpoint else []
        rounds_run = 0
        fwd_cols = 0
        bwd_cols = 0
        blocks_since_snapshot = 0
        block_times: list[float] | None = [] if self.profile else None
        t_start = time.perf_counter()

        def drain_one():
            ns_dev, roots_dev, rids = inflight.popleft()
            roots_np = np.asarray(roots_dev)  # device_get: block boundary
            ns_np = np.asarray(ns_dev, np.float64)
            for r in range(roots_np.shape[0]):
                for root, nv in zip(roots_np[r], ns_np[r]):
                    if root >= 0:
                        ns_by_root[int(root)] = float(nv)
            # commit at drain, not dispatch: the round's contribution now
            # exists on device, so a crash before this point re-deals it
            if self.ledger is not None:
                for rid in rids:
                    self.ledger.try_commit(rid)
            drained.extend(rids)

        def snapshot():
            # drain everything first so (bc, ns, committed) is a
            # consistent prefix — see fault_tolerance.BCCheckpoint.
            while inflight:
                drain_one()
            self.checkpoint.save(
                self._collect_bc(bc_acc), ns_by_root, drained, self._fingerprint
            )

        for srcs, ders, live in self._blocks():
            t_blk = time.perf_counter()
            bc_blk, ns, roots, _levels = self._dispatch_block(srcs, ders)
            if block_times is not None:  # profile: sync to time this block
                jax.block_until_ready(bc_blk)
                block_times.append(time.perf_counter() - t_blk)
            bc_acc = bc_blk if bc_acc is None else self._accumulate(bc_acc, bc_blk)
            inflight.append((ns, roots, live))
            rounds_run += len(live)
            fwd_cols += int((srcs >= 0).sum())
            bwd_cols += int((srcs >= 0).sum() + (ders[:, :, 0] >= 0).sum())
            while len(inflight) > self.max_inflight:
                drain_one()
            blocks_since_snapshot += 1
            if self.checkpoint is not None and (
                blocks_since_snapshot >= self.checkpoint_every
            ):
                snapshot()
                blocks_since_snapshot = 0
        while inflight:
            drain_one()
        if self.checkpoint is not None:
            snapshot()

        return BCResult(
            bc=self._finalize(bc_acc, ns_by_root),
            schedule=self.schedule,
            rounds_run=rounds_run,
            forward_columns=fwd_cols,
            backward_columns=bwd_cols,
            wall_s=time.perf_counter() - t_start,
            block_times=block_times,
            recovery_stats=dict(self.recovery),
        )

    # ------------------------------------------- multi-ledger scheduler
    def _committed_union(self) -> set[int]:
        out: set[int] = set()
        for led in self.ledgers:
            out |= set(led.state())
        return out

    def _try_commit(self, lane: int, rid: int) -> bool:
        """Exactly-once across *all* replica ledgers (first commit wins)."""
        for led in self.ledgers:
            if led.is_committed(rid):
                return False
        return self.ledgers[lane].try_commit(rid)

    def _run_straggler(self) -> BCResult:
        """The multi-ledger sub-cluster round loop (steal / redeal).

        Differences from the static loop:

        * one round-id queue and one :class:`RoundLedger` per replica,
          seeded by :func:`repro.core.scheduler.split_rounds` minus
          whatever any ledger already committed (merged resume);
        * each dispatch block is *timed* (block_until_ready, as in
          profile mode) and its wall is attributed to the replicas in
          proportion to their observed traversal ``levels`` — under a
          lockstep (ring-overlap) schedule the block wall is shared, so
          depth share is the per-replica signal — feeding a per-replica
          EWMA of per-round seconds;
        * commits happen at *drain* time and the accumulate is masked by
          the commit outcome (donation-safe double-dispatch);
        * between blocks the policy moves pending rounds: ``steal`` pulls
          into idle lanes and duplicates the straggler's round at the
          tail, ``redeal`` re-packs every pending round when the EWMA
          ratio crosses ``straggler_factor``.
        """
        import time

        from repro.distributed.fault_tolerance import ReplicaLostError

        fr = self.fr
        s = self.schedule.batch_size
        k = self.schedule.derived_per_round
        rounds = self.schedule.rounds
        queues = split_rounds(
            len(rounds), fr, self._committed_union(), round_costs=self.round_costs
        )

        prior = self.prior_round_s
        ewma: list[float | None] = [None] * fr
        observed = [False] * fr

        def est(r: int) -> float:
            if ewma[r] is not None:
                return ewma[r]
            return prior if prior is not None else 1.0

        bc_acc = None
        ns_by_root: dict[int, float] = dict(self._ns0)
        rounds_run = 0
        fwd_cols = 0
        bwd_cols = 0
        blocks_since_snapshot = 0
        block_times: list[float] = []
        stats = {
            "policy": self.straggler,
            "factor": self.straggler_factor,
            "replicas": fr,
            "rounds_stolen": 0,
            "rounds_redealt": 0,
            "redeal_events": 0,
            "duplicates_dispatched": 0,
            "duplicates_discarded": 0,
            "per_replica_wall_s": [0.0] * fr,
            "per_replica_rounds": [0] * fr,
            "per_replica_levels": [0] * fr,
            "idle_levels": 0,
            "idle_s_est": 0.0,
        }
        was_flagged = False
        t_start = time.perf_counter()

        def flagged() -> bool:
            vals = [
                ewma[r] for r in range(fr)
                if observed[r] and r not in self._dead_lanes
            ]
            if len(vals) < 2:
                return False
            lo, hi = min(vals), max(vals)
            return lo > 0.0 and hi > self.straggler_factor * lo

        def on_replica_loss(err, lane_rids, duplicate):
            """Self-heal a lost replica lane (nothing from the failed
            dispatch landed): consult the elasticity planner, move the
            dead lane's ledger commits to a survivor (the committed
            union — exactly-once — is unchanged), re-deal its backlog,
            and continue at reduced effective fr (the dead lane is dealt
            only padding from here on, so shapes stay static)."""
            from repro.distributed.fault_tolerance import plan_elastic_remesh

            dead = int(getattr(err, "replica", -1))
            if dead < 0 or dead >= fr or dead in self._dead_lanes:
                raise err
            self._dead_lanes.add(dead)
            survivors = [r for r in range(fr) if r not in self._dead_lanes]
            if not survivors:
                raise err
            self.recovery["remesh_events"] += 1
            self.recovery["dead_replicas"] = sorted(self._dead_lanes)
            # the failed block's owned rounds go back to the front of a
            # surviving queue (duplicates' owners requeue their own copy)
            for r in range(fr):
                rid = lane_rids[r]
                if rid is None or duplicate[r]:
                    continue
                if any(led.is_committed(rid) for led in self.ledgers):
                    continue
                target = r if r in survivors else survivors[0]
                queues[target].insert(0, rid)
            taken = self.ledgers[survivors[0]].merge(self.ledgers[dead])
            orphans = list(queues[dead])
            queues[dead] = []
            for i, rid in enumerate(orphans):
                queues[survivors[i % len(survivors)]].append(rid)
            sub, _ = redeal_rounds(
                [queues[r] for r in survivors], [est(r) for r in survivors]
            )
            for r, q in zip(survivors, sub):
                queues[r] = q
            try:
                total = 1
                for dim in self.mesh_shape:
                    total *= dim
                pod_ax = (
                    self.mesh_axes.index("pod") if "pod" in self.mesh_axes else 0
                )
                per_pod = max(1, total // max(1, self.mesh_shape[pod_ax]))
                plan = plan_elastic_remesh(
                    self.mesh_shape, self.mesh_axes,
                    per_pod * len(self._dead_lanes),
                )
                logger.warning(
                    "replica %d lost: re-mesh %s -> %s (%s); merged %d "
                    "committed rounds into replica %d, re-dealt %d pending",
                    dead, self.mesh_shape, plan.shape, plan.note, taken,
                    survivors[0], len(orphans),
                )
            except Exception as pe:  # planning is advisory, never fatal
                logger.warning(
                    "replica %d lost: elastic re-mesh planning failed "
                    "(%s: %s); continuing on %d surviving lanes",
                    dead, type(pe).__name__, pe, len(survivors),
                )

        def snapshot():
            self.checkpoint.save(
                self._collect_bc(bc_acc),
                ns_by_root,
                [led.state() for led in self.ledgers],
                self._fingerprint,
            )

        while any(queues):
            alive = [r for r in range(fr) if r not in self._dead_lanes]
            # ---------------------------------------- policy: move work
            if self.straggler == "redeal":
                lengths = [len(queues[r]) for r in alive]
                fire = flagged()
                tail_gap = min(lengths) == 0 and max(lengths) >= 2
                if (fire and not was_flagged) or tail_gap:
                    sub, moved = redeal_rounds(
                        [queues[r] for r in alive], [est(r) for r in alive]
                    )
                    for r, q in zip(alive, sub):
                        queues[r] = q
                    if moved:
                        stats["rounds_redealt"] += moved
                        stats["redeal_events"] += 1
                        logger.info(
                            "straggler redeal: moved %d pending rounds "
                            "(EWMA s/round: %s)",
                            moved,
                            [None if ewma[r] is None else round(ewma[r], 6)
                             for r in alive],
                        )
                was_flagged = fire

            # ----------------------------------------------- form block
            lane_rids: list[int | None] = [
                queues[r].pop(0)
                if r not in self._dead_lanes and queues[r]
                else None
                for r in range(fr)
            ]
            duplicate = [False] * fr
            if self.straggler == "steal":
                # idle lanes pull from the heaviest remaining backlog
                for r in sorted(alive, key=est):
                    if lane_rids[r] is not None:
                        continue
                    donors = [d for d in alive if queues[d]]
                    if not donors:
                        continue
                    donor = max(donors, key=lambda d: len(queues[d]) * est(d))
                    lane_rids[r] = queues[donor].pop(0)
                    stats["rounds_stolen"] += 1
                # tail: still-idle lanes back up the presumed straggler's
                # round instead of dispatching padding (first commit wins)
                working = [r for r in alive if lane_rids[r] is not None]
                idle = [r for r in alive if lane_rids[r] is None]
                if working and idle:
                    slowest = max(working, key=est)
                    for r in idle:
                        lane_rids[r] = lane_rids[slowest]
                        duplicate[r] = True
                        stats["duplicates_dispatched"] += 1
            if all(rid is None for rid in lane_rids):
                continue

            srcs = np.full((fr, s), -1, np.int32)
            ders = np.full((fr, k, 3), -1, np.int32)
            for r, rid in enumerate(lane_rids):
                if rid is not None:
                    srcs[r] = rounds[rid].sources
                    ders[r] = rounds[rid].derived

            # ------------------------------------- dispatch + observe
            t_blk = time.perf_counter()
            try:
                out = self._dispatch_block(srcs, ders)
            except ReplicaLostError as e:
                on_replica_loss(e, lane_rids, duplicate)
                continue
            bc_blk, ns_dev, roots_dev, levels_dev = out
            if levels_dev is None:
                raise ValueError(
                    "straggler scheduling needs a round_fn returning "
                    "(bc, ns, roots, levels); got a legacy 3-tuple"
                )
            jax.block_until_ready(bc_blk)
            wall = time.perf_counter() - t_blk
            block_times.append(wall)
            if bc_blk.shape[0] != fr:
                raise ValueError(
                    f"straggler scheduling needs a per-replica bc block "
                    f"(leading dim {fr}); got shape {tuple(bc_blk.shape)}"
                )
            levels_np = np.asarray(levels_dev).reshape(-1).astype(np.int64)
            # duplicate lanes ran work they will discard: they get no wall
            # attribution and no EWMA update (their "cost" belongs to the
            # round's owner lane, which is also in this block)
            own = [
                r for r in range(fr)
                if lane_rids[r] is not None and not duplicate[r]
            ]
            lv_total = int(levels_np[own].sum())
            lv_max = int(levels_np[own].max()) if own else 0
            for r in own:
                share = (
                    levels_np[r] / lv_total if lv_total > 0 else 1.0 / len(own)
                )
                obs = wall * float(share)
                ewma[r] = (
                    obs
                    if ewma[r] is None and prior is None
                    else _EWMA_ALPHA * obs
                    + (1.0 - _EWMA_ALPHA) * (ewma[r] if ewma[r] is not None else prior)
                )
                observed[r] = True
                stats["per_replica_wall_s"][r] += obs
                stats["per_replica_levels"][r] += int(levels_np[r])
                stats["idle_levels"] += lv_max - int(levels_np[r])
            if lv_max > 0 and own:
                idle_frac = sum(lv_max - int(levels_np[r]) for r in own) / (
                    len(own) * lv_max
                )
                stats["idle_s_est"] += wall * idle_frac

            # -------------------------- drain: commit-or-discard + add
            # originals commit before their speculative duplicates, so a
            # backup copy never out-commits the lane that owns the round
            # (keeps duplicates_discarded and per-replica attribution
            # honest; exactly-once holds in either order)
            mask = np.zeros(fr, np.float32)
            roots_np = np.asarray(roots_dev)
            ns_np = np.asarray(ns_dev, np.float64)
            for r in sorted(range(fr), key=lambda r: duplicate[r]):
                rid = lane_rids[r]
                if rid is None:
                    continue
                if self._try_commit(r, rid):
                    mask[r] = 1.0
                    rounds_run += 1
                    stats["per_replica_rounds"][r] += 1
                    fwd_cols += int((srcs[r] >= 0).sum())
                    bwd_cols += int(
                        (srcs[r] >= 0).sum() + (ders[r, :, 0] >= 0).sum()
                    )
                    for root, nv in zip(roots_np[r], ns_np[r]):
                        if root >= 0:
                            ns_by_root[int(root)] = float(nv)
                elif duplicate[r]:
                    stats["duplicates_discarded"] += 1
            mask_dev = jnp.asarray(mask)
            bc_acc = (
                self._masked_scale(bc_blk, mask_dev)
                if bc_acc is None
                else self._masked_accumulate(bc_acc, bc_blk, mask_dev)
            )

            blocks_since_snapshot += 1
            if self.checkpoint is not None and (
                blocks_since_snapshot >= self.checkpoint_every
            ):
                snapshot()
                blocks_since_snapshot = 0

        if self.checkpoint is not None:
            snapshot()
        logger.info(
            "straggler=%s: %d rounds, %d stolen, %d re-dealt (%d events), "
            "%d/%d duplicates discarded, idle ≈ %.3fs of %.3fs wall",
            self.straggler,
            rounds_run,
            stats["rounds_stolen"],
            stats["rounds_redealt"],
            stats["redeal_events"],
            stats["duplicates_discarded"],
            stats["duplicates_dispatched"],
            stats["idle_s_est"],
            time.perf_counter() - t_start,
        )
        return BCResult(
            bc=self._finalize(bc_acc, ns_by_root),
            schedule=self.schedule,
            rounds_run=rounds_run,
            forward_columns=fwd_cols,
            backward_columns=bwd_cols,
            wall_s=time.perf_counter() - t_start,
            block_times=block_times,
            straggler_stats=stats,
            recovery_stats=dict(self.recovery),
        )
