"""The driver layer: one round body and one host round loop for all
engines (single-device dense/sparse/Pallas and 2-D distributed).

:func:`traversal_round` is the per-round algebra — forward counting,
2-degree column derivation, dependency accumulation, per-round BC and
component-size (n_s) extraction — written once against the
:class:`repro.core.operators.TraversalOperator` protocol.  Entry points
wrap it in whatever jit/shard_map shell their operator needs.

:class:`BCDriver` is the host loop shared by
:func:`repro.core.bc.betweenness_centrality`,
:func:`repro.core.distributed.distributed_betweenness_centrality`, the
``repro.launch.bc`` CLI and the benchmarks:

* rounds are dealt in *dispatch blocks* of ``rounds_per_dispatch``
  (1 on a single device; the sub-cluster count ``fr`` on a mesh);
* dispatch is asynchronous: up to ``max_inflight`` blocks are in flight
  and ``device_get`` happens only at block boundaries, so host sync no
  longer serializes rounds;
* the BC accumulator lives on device and is *donated* through a jitted
  add (no per-round host round-trip, no per-round buffer copy); it is
  fetched exactly once, after the last round;
* an optional :class:`repro.distributed.fault_tolerance.RoundLedger`
  makes the loop restartable: committed rounds are re-dealt as inert
  all-padding columns (BC accumulation is additive, padding contributes
  exactly zero), which keeps every dispatch shape static.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine
from repro.core.heuristics.one_degree import OneDegreeReduction, leaf_correction
from repro.core.heuristics.two_degree import derive_two_degree_columns
from repro.core.operators import TraversalOperator, as_operator
from repro.core.scheduler import Schedule

__all__ = [
    "BCResult",
    "BCDriver",
    "traversal_round",
    "apply_reduction_corrections",
]


def traversal_round(
    operator: TraversalOperator,
    sources: jnp.ndarray,  # i32 [s]; -1 = padding
    derived: jnp.ndarray,  # i32 [k, 3] rows (c, a_pos, b_pos); -1 = padding
    omega: jnp.ndarray,  # f32 [n_rows] 1-degree weights (operator's rows)
    *,
    num_levels: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One BC round against the operator protocol.

    Returns
      bc_local  f32 [n_rows] — this round's BC contribution to the
                operator's rows (global BC = sum over rounds/devices),
      ns        f32 [s+k]    — per-column component size n_s (§3.4.1),
                already globally reduced,
      roots     i32 [s+k]    — root vertex of every column (-1 padding).
    """
    op = as_operator(operator)
    omega_f = omega.astype(jnp.float32)
    row_ids = op.row_ids()

    # ---------------------------------------------------------- forward
    src_onehot = (
        (row_ids[:, None] == sources[None, :]) & (sources[None, :] >= 0)
    ).astype(jnp.float32)
    fwd = engine.forward_counting(op, src_onehot, num_levels=num_levels)

    # ------------------------------------------- derived 2-degree columns
    sigma_c, depth_c = derive_two_degree_columns(
        fwd.sigma, fwd.depth, derived, row_ids=row_ids
    )
    sigma_all = jnp.concatenate([fwd.sigma, sigma_c], axis=1)
    depth_all = jnp.concatenate([fwd.depth, depth_c], axis=1)

    # ---------------------------------------------------------- backward
    max_depth = op.reduce_max(jnp.max(depth_all))
    delta = engine.backward_accumulation(
        op, sigma_all, depth_all, omega_f, max_depth, num_levels=num_levels
    )

    # --------------------------------------------------------- BC + n_s
    roots = jnp.concatenate([sources, derived[:, 0]])
    omega_root = op.root_omega(roots, omega_f)
    mult = jnp.where(roots >= 0, omega_root + 1.0, 0.0)

    root_onehot = row_ids[:, None] == roots[None, :]
    weighted = jnp.where(root_onehot, 0.0, delta * mult[None, :])
    bc_local = weighted.sum(axis=1)

    # per-column component size  n_s = Σ_{d ≥ 0} (1 + ω)   (paper §3.4.1)
    ns = op.reduce_sum(((depth_all >= 0) * (1.0 + omega_f)[:, None]).sum(axis=0))
    return bc_local, ns, roots


def apply_reduction_corrections(
    bc: np.ndarray,
    prep: OneDegreeReduction,
    schedule,
    ns_by_root: dict[int, float],
) -> None:
    """Add the analytic BC credits of the 1-degree/tree reduction.

    Every vertex x with removed branches (S(x) > 0) — residual or removed
    interior — gets 2·S·(n_comp−1−S) + 2·P (heuristics/one_degree.py).
    n_comp comes from x's own round, the isolated-residual analytic size,
    or (removed vertices) the resolved root's size."""
    n_by_root = dict(ns_by_root)
    for v, n_comp in schedule.analytic_corrections:
        n_by_root[int(v)] = float(n_comp)
    S, P = prep.omega, prep.pair_credit
    for x in np.nonzero(S > 0)[0]:
        x = int(x)
        if prep.removed[x]:
            root, analytic_n = prep.resolve_root(x)
            n_comp = analytic_n if analytic_n >= 0 else n_by_root.get(int(root))
        else:
            n_comp = n_by_root.get(x)
        if n_comp is None:
            raise RuntimeError(f"no component size recorded for vertex {x}")
        bc[x] += leaf_correction(S[x], n_comp, P[x])


@dataclasses.dataclass
class BCResult:
    bc: np.ndarray  # float64 [n]
    schedule: Schedule
    rounds_run: int
    forward_columns: int  # explicit BFS columns actually traversed
    backward_columns: int  # dependency columns (explicit + derived)
    wall_s: float = 0.0  # host wall time of the round loop
    block_times: list[float] | None = None  # per-dispatch-block seconds
    #   (profile mode only — the driver blocks per block to measure, so
    #   async dispatch is disabled; use for benchmarking, not production)


class BCDriver:
    """Shared host round loop (see module docstring).

    ``round_fn(sources i32 [fr, s], derived i32 [fr, k, 3])`` must return
    device arrays ``(bc_block, ns [fr, s+k], roots [fr, s+k])`` where
    ``bc_block`` has any stable shape whose leading dims sum away to the
    per-vertex contribution ([n] on one device; [fr, n_pad] sharded on a
    mesh).  All graph-constant operands (adjacency, ω, arc lists) are
    expected to be partially applied into ``round_fn``.

    ``profile=True`` blocks on every dispatch block and records its wall
    seconds in ``BCResult.block_times`` (plus total ``wall_s``) — the
    measurement mode the overlap benchmarks use; it defeats the async
    pipeline, so leave it off in production.
    """

    def __init__(
        self,
        round_fn: Callable,
        schedule: Schedule,
        *,
        n: int,
        prep: OneDegreeReduction | None = None,
        ledger=None,
        checkpoint=None,
        checkpoint_every: int = 8,
        rounds_per_dispatch: int = 1,
        max_inflight: int = 2,
        profile: bool = False,
    ):
        self.round_fn = round_fn
        self.profile = profile
        self.schedule = schedule
        self.n = n
        self.prep = prep
        self.checkpoint = checkpoint
        self.checkpoint_every = max(1, checkpoint_every)
        self._bc0 = np.zeros(n, np.float64)
        self._ns0: dict[int, float] = {}
        self._fingerprint = None
        if checkpoint is not None:
            if ledger is not None:
                raise ValueError("pass either a ledger or a checkpoint, not both")
            from repro.distributed.fault_tolerance import (
                RoundLedger,
                schedule_fingerprint,
            )

            self._fingerprint = schedule_fingerprint(n, schedule)
            bc0, ns0, committed = checkpoint.load(self._fingerprint)
            if bc0 is not None:
                self._bc0 = bc0[:n]
                self._ns0 = ns0
            ledger = RoundLedger.from_state(committed)
        self.ledger = ledger
        self.fr = max(1, rounds_per_dispatch)
        self.max_inflight = max(1, max_inflight)
        # donated device-side accumulate: bc never round-trips per round
        self._accumulate = jax.jit(lambda acc, x: acc + x, donate_argnums=(0,))

    def _blocks(self):
        """Deal rounds into [fr]-sized dispatch blocks of host arrays.

        Ledger-committed rounds are dealt as all-padding (-1) columns:
        shapes stay static, contributions are exactly zero, and the
        ledger keeps exactly-once semantics across restarts and
        speculative re-execution (distributed/fault_tolerance.py).
        """
        s = self.schedule.batch_size
        k = self.schedule.derived_per_round
        rounds = self.schedule.rounds
        for start in range(0, len(rounds), self.fr):
            block = rounds[start : start + self.fr]
            srcs = np.full((self.fr, s), -1, np.int32)
            ders = np.full((self.fr, k, 3), -1, np.int32)
            live = []
            for r, rnd in enumerate(block):
                rid = start + r
                if self.ledger is not None and not self.ledger.try_commit(rid):
                    continue  # already accumulated by a previous run
                srcs[r] = rnd.sources
                ders[r] = rnd.derived
                live.append(rid)
            if live:
                yield srcs, ders, live

    def _collect_bc(self, bc_acc) -> np.ndarray:
        """Checkpoint-seed + device accumulator, in per-vertex f64 space."""
        bc = self._bc0.copy()
        if bc_acc is not None:
            dev = np.asarray(jax.device_get(bc_acc), np.float64)
            if dev.ndim > 1:  # sub-cluster replicas are additive (§3.3)
                dev = dev.reshape(-1, dev.shape[-1]).sum(axis=0)
            bc = bc + dev[: self.n]
        return bc

    def run(self) -> BCResult:
        import time

        bc_acc = None
        inflight: collections.deque = collections.deque()
        ns_by_root: dict[int, float] = dict(self._ns0)
        drained: list[int] = self.ledger.state() if self.checkpoint else []
        rounds_run = 0
        fwd_cols = 0
        bwd_cols = 0
        blocks_since_snapshot = 0
        block_times: list[float] | None = [] if self.profile else None
        t_start = time.perf_counter()

        def drain_one():
            ns_dev, roots_dev, rids = inflight.popleft()
            roots_np = np.asarray(roots_dev)  # device_get: block boundary
            ns_np = np.asarray(ns_dev, np.float64)
            for r in range(roots_np.shape[0]):
                for root, nv in zip(roots_np[r], ns_np[r]):
                    if root >= 0:
                        ns_by_root[int(root)] = float(nv)
            drained.extend(rids)

        def snapshot():
            # drain everything first so (bc, ns, committed) is a
            # consistent prefix — see fault_tolerance.BCCheckpoint.
            while inflight:
                drain_one()
            self.checkpoint.save(
                self._collect_bc(bc_acc), ns_by_root, drained, self._fingerprint
            )

        for srcs, ders, live in self._blocks():
            t_blk = time.perf_counter()
            bc_blk, ns, roots = self.round_fn(jnp.asarray(srcs), jnp.asarray(ders))
            if block_times is not None:  # profile: sync to time this block
                jax.block_until_ready(bc_blk)
                block_times.append(time.perf_counter() - t_blk)
            bc_acc = bc_blk if bc_acc is None else self._accumulate(bc_acc, bc_blk)
            inflight.append((ns, roots, live))
            rounds_run += len(live)
            fwd_cols += int((srcs >= 0).sum())
            bwd_cols += int((srcs >= 0).sum() + (ders[:, :, 0] >= 0).sum())
            while len(inflight) > self.max_inflight:
                drain_one()
            blocks_since_snapshot += 1
            if self.checkpoint is not None and (
                blocks_since_snapshot >= self.checkpoint_every
            ):
                snapshot()
                blocks_since_snapshot = 0
        while inflight:
            drain_one()
        if self.checkpoint is not None:
            snapshot()

        bc = self._collect_bc(bc_acc)
        if self.prep is not None:
            apply_reduction_corrections(bc, self.prep, self.schedule, ns_by_root)

        return BCResult(
            bc=bc,
            schedule=self.schedule,
            rounds_run=rounds_run,
            forward_columns=fwd_cols,
            backward_columns=bwd_cols,
            wall_s=time.perf_counter() - t_start,
            block_times=block_times,
        )
