"""Distributed MGBC: 2-D decomposition + sub-clustering (paper §3.2-3.3).

Communication structure per traversal level, per sub-cluster (an R×C
grid of devices; see graphs/partition.py for the chunk layout):

  expand (vertical, paper Alg. 2 line 15):
      all_gather(frontier-σ chunk, axis=row)  →  F[cols_j]  on every
      device of grid column j — O(√p) partners.
  local compute (node level):
      * ``engine_kind="sparse"`` — gather F[src_local] + segment_sum
        into dst_local (the TPU replacement for the CUDA active-edge
        kernel);
      * ``engine_kind="pallas"`` / ``"pallas_bf16"`` — the device's dense
        adjacency block on the MXU via the fused frontier/dependency
        SpMM kernels in partial mode (kernels/frontier_spmm.py) — the
        fine-grained dense-block compute the 2-D decomposition is
        designed to feed.
  fold (horizontal, Alg. 2 line 19):
      psum_scatter(partials, axis=col) — sums the C partial
      contributions and delivers each device exactly its owned chunk.

That is the *barrier* schedule (``overlap="none"``): every device idles
through both collectives.  ``overlap="expand"`` replaces the all_gather
with R-1 ``ppermute`` ring steps, accumulating each device's per-chunk
product against the chunk in hand while the next is in flight (paper
Fig. 2 pipelining / collective-matmul decomposition);
``overlap="expand+fold"`` additionally replaces the psum_scatter with a
C-1-step reduce ring, leaving no monolithic collective on the level's
critical path — per level the cost drops from T_comm + T_compute toward
max(T_comm, T_compute).

The traversal itself — level loops, round algebra, host loop — is NOT
implemented here: the shard_map body below constructs a
:class:`repro.core.operators.DistributedOperator` (or its Pallas
dense-block subclass) and runs the same
:func:`repro.core.driver.traversal_round` /
:class:`repro.core.driver.BCDriver` as the single-device path.

With the sparse operator, *all* state stays owner-sharded and only
frontier-σ / g ever travel — the depth test of the edge's far endpoint
is folded into the gathered quantity (one exchange per level; recorded
as a beyond-paper optimization in EXPERIMENTS.md §Perf).  The Pallas
dense-block operator exchanges (σ, d) forward and (σ, d, δ, ω) backward
— the paper's §3.2 exchange set — in return for fusing the mask / g
recompute into the MXU block matmul.

Sub-clustering (paper §3.3): a leading mesh axis carries ``fr`` graph
replicas, each processing different source rounds; BC is additive so the
final merge sums the replica dim (host-side, in the shared driver, so a
straggling/preempted replica's round can be re-issued — see
distributed/fault_tolerance.py).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.driver import BCDriver, traversal_round
from repro.core.operators import (
    DistributedOperator,
    DistributedPallasOperator,
    normalize_overlap,
)
from repro.core.scheduler import Schedule, build_schedule
from repro.graphs.graph import Graph
from repro.graphs.partition import TwoDPartition, partition_2d

__all__ = [
    "make_distributed_round_fn",
    "distributed_graph_arrays",
    "distributed_betweenness_centrality",
    "one_degree_reduce_distributed",
]


def distributed_graph_arrays(
    partition: TwoDPartition, engine_kind: str, overlap: str = "none"
) -> tuple[jnp.ndarray, ...]:
    """Device arrays for the graph operands of a distributed round fn.

    The single source of the engine_kind × overlap → operand-layout
    mapping (entry point, benchmarks and tests all lower the same
    layout): sparse uses the flat arc arrays, or the ring-sliced layout
    under a ring overlap policy; the Pallas engines use dense blocks
    (bf16 for ``"pallas_bf16"``).
    """
    if engine_kind == "sparse":
        if normalize_overlap(overlap) != "none":
            ring_src, ring_dst = partition.ring_arcs()
            return (jnp.asarray(ring_src), jnp.asarray(ring_dst))
        return (jnp.asarray(partition.src_local), jnp.asarray(partition.dst_local))
    dt = jnp.bfloat16 if engine_kind == "pallas_bf16" else jnp.float32
    return (jnp.asarray(partition.dense_blocks(np.float32), dt),)


def one_degree_reduce_distributed(
    graph: Graph, mesh: Mesh, axis_name: str | tuple[str, ...] = "data"
) -> tuple[np.ndarray, np.ndarray]:
    """Distributed 1-degree preprocessing (paper Alg. 6, §3.4.1).

    The paper 1-D-partitions edges, sorts by source and scans; the
    data-parallel equivalent shards the arc list over ``axis_name``,
    computes degrees with a local segment-sum + psum, then marks arcs
    incident to a leaf and accumulates ω the same way.  Near-linear
    scaling (paper Fig. 10) follows from the arc shards being independent
    except for two n-sized all-reduces.

    Returns (omega int64 [n], arc_removed bool [m2]) — identical to the
    host-side :func:`repro.core.heuristics.one_degree.one_degree_reduce`.
    """
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    n = graph.n
    src_p, dst_p, m2 = graph.padded_arcs(multiple=p)

    def body(src, dst):
        ones = jnp.ones_like(src, dtype=jnp.float32)
        deg = jax.lax.psum(
            jax.ops.segment_sum(ones, src, num_segments=n + 1), axes
        )
        leaf = deg == 1.0  # sentinel vertex n has huge degree, never a leaf
        removed = leaf[src] | leaf[dst]
        omega = jax.lax.psum(
            jax.ops.segment_sum(leaf[src].astype(jnp.float32), dst, num_segments=n + 1),
            axes,
        )
        return omega[:n], removed

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes), P(axes)),
        out_specs=(P(), P(axes)),
        check_vma=False,
    )
    omega, removed = jax.jit(fn)(jnp.asarray(src_p), jnp.asarray(dst_p))
    return (
        np.asarray(omega, np.int64),
        np.asarray(removed)[:m2],
    )


def _grid_axes(mesh: Mesh, row_axis: str, col_axis: str, replica_axis: str | None):
    R = mesh.shape[row_axis]
    C = mesh.shape[col_axis]
    fr = mesh.shape[replica_axis] if replica_axis is not None else 1
    return R, C, fr


def make_distributed_round_fn(
    partition: TwoDPartition,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    num_levels: int | None = None,
    fuse_backward_payload: bool = True,
    engine_kind: str = "sparse",
    interpret: bool | None = None,
    overlap: str = "none",
):
    """Build the sub-cluster-parallel, 2-D-distributed round function.

    With ``engine_kind="sparse"`` (arc-list local compute) the returned
    jitted function maps
      (src_local  i32 [R, C, max_arcs]   — sharded (row, col),
       dst_local  i32 [R, C, max_arcs]   — sharded (row, col),
       omega      f32 [n_pad]            — sharded ((col, row)),
       sources    i32 [fr, s]            — sharded (replica),
       derived    i32 [fr, k, 3]         — sharded (replica))
      -> (bc  f32 [fr, n_pad]  — sharded (replica, (col, row)),
          ns  f32 [fr, s+k]    — sharded (replica),
          roots i32 [fr, s+k]  — sharded (replica))

    With ``engine_kind="pallas"`` / ``"pallas_bf16"`` (dense-block MXU
    local compute) the two arc arrays are replaced by one argument:
      (blocks  f32/bf16 [R, C, C·chunk, R·chunk] — sharded (row, col),
       omega, sources, derived)  ->  same outputs.
    Build the blocks with :meth:`TwoDPartition.dense_blocks`.

    ``fuse_backward_payload`` keeps σ-frontier and g exchanges as a single
    gathered tensor each (the paper's overlap/fusion idea, §3.2 Fig. 2);
    setting it False splits the backward gather into two half-width
    collectives to mimic the paper's unfused σ/d exchange for the
    Fig. 9 benchmark (sparse engine only).

    ``overlap`` selects the collective schedule per
    :data:`repro.core.operators.OVERLAP_POLICIES`: ``"none"`` keeps the
    barrier all_gather → compute → psum_scatter level step; ``"expand"``
    ring-pipelines the gather (ppermute steps interleaved with per-chunk
    block compute); ``"expand+fold"`` additionally turns the fold into a
    reduce ring.  Under a ring policy the sparse engine's two arc
    arguments are the *ring-sliced* layout
    (i32 [R, C, R, max_ring_arcs] from
    :meth:`TwoDPartition.ring_arcs`) instead of the flat arc arrays —
    same arity, per-row-chunk slicing.
    """
    R, C, fr = _grid_axes(mesh, row_axis, col_axis, replica_axis)
    if (R, C) != (partition.R, partition.C):
        raise ValueError(
            f"mesh grid {(R, C)} != partition grid {(partition.R, partition.C)}"
        )
    if engine_kind not in ("sparse", "pallas", "pallas_bf16"):
        raise ValueError(f"unknown distributed engine {engine_kind!r}")
    overlap = normalize_overlap(overlap)
    use_pallas = engine_kind != "sparse"
    if use_pallas and not fuse_backward_payload:
        raise ValueError("split backward payload is a sparse-engine benchmark mode")
    if overlap != "none" and not fuse_backward_payload:
        raise ValueError(
            "split backward payload is a barrier-schedule benchmark mode; "
            "it cannot be combined with a ring overlap policy"
        )
    if use_pallas and interpret is None:
        from repro.kernels.ops import on_tpu

        interpret = not on_tpu()
    chunk = partition.chunk
    # Ring hops are mesh-wide collective-permutes: sub-cluster replicas
    # must stay in level-loop lockstep or the rendezvous deadlocks (the
    # extra levels a shallow replica runs are masked no-ops) — see
    # operators.DistributedOperator (sync_axes).
    sync_axes = (
        (replica_axis,) if replica_axis is not None and overlap != "none" else ()
    )

    def round_body(op, omega, sources, derived):
        bc_owned, ns, roots = traversal_round(
            op, sources[0], derived[0], omega, num_levels=num_levels
        )
        return bc_owned[None], ns[None], roots[None]

    if use_pallas:

        def body(blocks, omega, sources, derived):
            op = DistributedPallasOperator(
                blocks[0, 0],  # [C*chunk, R*chunk] local dense block
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                interpret=interpret,
                overlap=overlap,
                sync_axes=sync_axes,
            )
            return round_body(op, omega, sources, derived)

        graph_specs = (P(row_axis, col_axis, None, None),)
    elif overlap != "none":

        def body(ring_src, ring_dst, omega, sources, derived):
            op = DistributedOperator(
                None,
                None,
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                overlap=overlap,
                ring_src_local=ring_src[0, 0],  # [R, max_ring_arcs] local view
                ring_dst_local=ring_dst[0, 0],
                sync_axes=sync_axes,
            )
            return round_body(op, omega, sources, derived)

        graph_specs = (
            P(row_axis, col_axis, None, None),
            P(row_axis, col_axis, None, None),
        )
    else:

        def body(src_local, dst_local, omega, sources, derived):
            op = DistributedOperator(
                src_local[0, 0],  # [max_arcs] local arc views
                dst_local[0, 0],
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                split_backward=not fuse_backward_payload,
            )
            return round_body(op, omega, sources, derived)

        graph_specs = (
            P(row_axis, col_axis, None),
            P(row_axis, col_axis, None),
        )

    rep = (replica_axis,) if replica_axis is not None else (None,)
    in_specs = graph_specs + (
        P((col_axis, row_axis)),
        P(*rep, None),
        P(*rep, None, None),
    )
    out_specs = (
        P(*rep, (col_axis, row_axis)),
        P(*rep, None),
        P(*rep, None),
    )
    shmapped = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return jax.jit(shmapped)


def distributed_betweenness_centrality(
    graph: Graph,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    batch_size: int = 16,
    heuristics: str = "h0",
    num_levels: int | None = None,
    engine_kind: str = "sparse",
    overlap: str = "none",
    ledger=None,
    checkpoint=None,
) -> tuple[np.ndarray, Schedule]:
    """Run the full distributed BC computation on ``mesh``.

    Rounds are dealt ``fr`` at a time (one per sub-cluster) by the shared
    :class:`repro.core.driver.BCDriver`; the replica merge sums the
    replica dim after the loop so a straggling/preempted replica's round
    can be re-issued (fault tolerance path, distributed/fault_tolerance.py).
    ``engine_kind`` selects the block-local compute: "sparse" (arc list)
    or "pallas"/"pallas_bf16" (fused dense-block kernels); ``overlap``
    selects the collective schedule (barrier vs ring-pipelined — see
    :func:`make_distributed_round_fn`).
    """
    overlap = normalize_overlap(overlap)
    schedule, prep, residual, omega_i = build_schedule(
        graph, batch_size=batch_size, heuristics=heuristics
    )
    R, C, fr = _grid_axes(mesh, row_axis, col_axis, replica_axis)
    part = partition_2d(residual, R, C)

    round_fn = make_distributed_round_fn(
        part,
        mesh,
        row_axis=row_axis,
        col_axis=col_axis,
        replica_axis=replica_axis,
        num_levels=num_levels,
        engine_kind=engine_kind,
        overlap=overlap,
    )

    omega_pad = np.zeros(part.n_pad, np.float32)
    omega_pad[: graph.n] = omega_i
    # reorder omega into chunk-owner layout: flat position = chunk-id*chunk + off
    # chunk ids are contiguous in vertex order, so identity layout works.
    omega_dev = jnp.asarray(omega_pad)

    graph_args = distributed_graph_arrays(part, engine_kind, overlap)

    def block_fn(sources, derived):
        return round_fn(*graph_args, omega_dev, sources, derived)

    driver = BCDriver(
        block_fn,
        schedule,
        n=graph.n,
        prep=prep,
        ledger=ledger,
        checkpoint=checkpoint,
        rounds_per_dispatch=fr,
    )
    result = driver.run()
    return result.bc, schedule
