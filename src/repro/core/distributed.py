"""Distributed MGBC: 2-D decomposition + sub-clustering (paper §3.2-3.3).

Communication structure per traversal level, per sub-cluster (an R×C
grid of devices; see graphs/partition.py for the chunk layout):

  expand (vertical, paper Alg. 2 line 15):
      all_gather(frontier-σ chunk, axis=row)  →  F[cols_j]  on every
      device of grid column j — O(√p) partners.
  local compute (node level):
      gather F[src_local] + segment_sum into dst_local — the TPU
      replacement for the CUDA active-edge kernel.
  fold (horizontal, Alg. 2 line 19):
      psum_scatter(partials, axis=col) — sums the C partial
      contributions and delivers each device exactly its owned chunk.

The backward sweep is the mirror image with g = (1+δ+ω)/σ masked to
depth lvl+1.  Unlike the paper (which exchanges d and σ between the two
phases, §3.2), *all* state here stays owner-sharded and only
frontier-σ / g ever travel — the depth test of the edge's far endpoint
is folded into the gathered quantity.  This removes one exchange per
round entirely (recorded as a beyond-paper optimization in
EXPERIMENTS.md §Perf).

Sub-clustering (paper §3.3): a leading mesh axis carries ``fr`` graph
replicas, each processing different source rounds; BC is additive so the
final merge is one psum (or a host-side sum over the replica dim, which
is what we do to keep the round function replica-local).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bc import apply_reduction_corrections
from repro.core.heuristics.two_degree import derive_two_degree_columns
from repro.core.scheduler import Schedule, build_schedule
from repro.graphs.graph import Graph
from repro.graphs.partition import TwoDPartition, partition_2d

__all__ = [
    "DistributedBCPlan",
    "make_distributed_round_fn",
    "distributed_betweenness_centrality",
    "one_degree_reduce_distributed",
]


def one_degree_reduce_distributed(
    graph: Graph, mesh: Mesh, axis_name: str | tuple[str, ...] = "data"
) -> tuple[np.ndarray, np.ndarray]:
    """Distributed 1-degree preprocessing (paper Alg. 6, §3.4.1).

    The paper 1-D-partitions edges, sorts by source and scans; the
    data-parallel equivalent shards the arc list over ``axis_name``,
    computes degrees with a local segment-sum + psum, then marks arcs
    incident to a leaf and accumulates ω the same way.  Near-linear
    scaling (paper Fig. 10) follows from the arc shards being independent
    except for two n-sized all-reduces.

    Returns (omega int64 [n], arc_removed bool [m2]) — identical to the
    host-side :func:`repro.core.heuristics.one_degree.one_degree_reduce`.
    """
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    n = graph.n
    src_p, dst_p, m2 = graph.padded_arcs(multiple=p)

    def body(src, dst):
        ones = jnp.ones_like(src, dtype=jnp.float32)
        deg = jax.lax.psum(
            jax.ops.segment_sum(ones, src, num_segments=n + 1), axes
        )
        leaf = deg == 1.0  # sentinel vertex n has huge degree, never a leaf
        removed = leaf[src] | leaf[dst]
        omega = jax.lax.psum(
            jax.ops.segment_sum(leaf[src].astype(jnp.float32), dst, num_segments=n + 1),
            axes,
        )
        return omega[:n], removed

    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes), P(axes)),
        out_specs=(P(), P(axes)),
        check_vma=False,
    )
    omega, removed = jax.jit(fn)(jnp.asarray(src_p), jnp.asarray(dst_p))
    return (
        np.asarray(omega, np.int64),
        np.asarray(removed)[:m2],
    )


@dataclasses.dataclass
class DistributedBCPlan:
    """Everything needed to run distributed rounds on a mesh."""

    mesh: Mesh
    partition: TwoDPartition
    replica_axis: str | None
    row_axis: str
    col_axis: str
    round_fn: object  # jitted round function
    n_replicas: int


def _grid_axes(mesh: Mesh, row_axis: str, col_axis: str, replica_axis: str | None):
    R = mesh.shape[row_axis]
    C = mesh.shape[col_axis]
    fr = mesh.shape[replica_axis] if replica_axis is not None else 1
    return R, C, fr


def make_distributed_round_fn(
    partition: TwoDPartition,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    num_levels: int | None = None,
    fuse_backward_payload: bool = True,
):
    """Build the sub-cluster-parallel, 2-D-distributed round function.

    The returned jitted function maps
      (src_local  i32 [R, C, max_arcs]   — sharded (row, col),
       dst_local  i32 [R, C, max_arcs]   — sharded (row, col),
       omega      f32 [n_pad]            — sharded ((col, row)),
       sources    i32 [fr, s]            — sharded (replica),
       derived    i32 [fr, k, 3]         — sharded (replica))
      -> (bc  f32 [fr, n_pad]  — sharded (replica, (col, row)),
          ns  f32 [fr, s+k]    — sharded (replica),
          roots i32 [fr, s+k]  — sharded (replica))

    ``fuse_backward_payload`` keeps σ-frontier and g exchanges as a single
    gathered tensor each (the paper's overlap/fusion idea, §3.2 Fig. 2);
    setting it False splits the backward gather into two half-width
    collectives to mimic the paper's unfused σ/d exchange for the
    Fig. 9 benchmark.
    """
    R, C, fr = _grid_axes(mesh, row_axis, col_axis, replica_axis)
    if (R, C) != (partition.R, partition.C):
        raise ValueError(
            f"mesh grid {(R, C)} != partition grid {(partition.R, partition.C)}"
        )
    chunk = partition.chunk
    n_pad = partition.n_pad
    grid_axes = (row_axis, col_axis)

    def body(src_local, dst_local, omega, sources, derived):
        # strip the sharded leading dims: local views
        src_local = src_local[0, 0]  # [max_arcs]
        dst_local = dst_local[0, 0]
        sources = sources[0]  # [s]
        derived = derived[0]  # [k, 3]
        omega_o = omega  # [chunk] owned slice
        s = sources.shape[0]

        i = jax.lax.axis_index(row_axis)
        j = jax.lax.axis_index(col_axis)
        base = (j * R + i) * chunk  # first owned global vertex id
        owned_ids = base + jnp.arange(chunk, dtype=jnp.int32)  # [chunk]

        def spmv(x_owned):
            """A @ x for the owned chunks: expand → local → fold."""
            x_col = jax.lax.all_gather(x_owned, row_axis, tiled=True)  # [R*chunk, s]
            msgs = x_col[src_local]  # [max_arcs, s]
            partial = jax.ops.segment_sum(
                msgs, dst_local, num_segments=C * chunk + 1
            )[: C * chunk]
            return jax.lax.psum_scatter(
                partial, col_axis, scatter_dimension=0, tiled=True
            )  # [chunk, s]

        # ---------------------------------------------------- forward
        src_onehot = (
            (owned_ids[:, None] == sources[None, :]) & (sources[None, :] >= 0)
        ).astype(jnp.float32)
        sigma = src_onehot
        depth = jnp.where(src_onehot > 0, 0, -1).astype(jnp.int32)

        def fwd_level(lvl, sigma, depth):
            frontier = sigma * (depth == lvl - 1)
            t = spmv(frontier)
            newly = (t > 0) & (depth < 0)
            depth = jnp.where(newly, lvl, depth)
            sigma = sigma + jnp.where(newly, t, 0.0)
            alive = jax.lax.psum(newly.any().astype(jnp.int32), grid_axes) > 0
            return sigma, depth, alive

        if num_levels is None:

            def cond(carry):
                _, _, lvl, alive = carry
                return alive & (lvl <= n_pad)

            def fbody(carry):
                sigma, depth, lvl, _ = carry
                sigma, depth, alive = fwd_level(lvl, sigma, depth)
                return sigma, depth, lvl + 1, alive

            sigma, depth, _, _ = jax.lax.while_loop(
                cond, fbody, (sigma, depth, jnp.int32(1), jnp.bool_(True))
            )
        else:

            def fbody(k, carry):
                sigma, depth = carry
                sigma, depth, _ = fwd_level(k + 1, sigma, depth)
                return sigma, depth

            sigma, depth = jax.lax.fori_loop(0, num_levels, fbody, (sigma, depth))

        # ------------------------------------- derived 2-degree columns
        sigma_c, depth_c = derive_two_degree_columns(
            sigma, depth, derived, row_ids=owned_ids
        )
        c_idx = derived[:, 0]
        sigma_all = jnp.concatenate([sigma, sigma_c], axis=1)
        depth_all = jnp.concatenate([depth, depth_c], axis=1)

        # ---------------------------------------------------- backward
        max_depth = jax.lax.pmax(jnp.max(depth_all), grid_axes)
        omega_col = omega_o.astype(jnp.float32)[:, None]
        delta0 = jnp.zeros_like(sigma_all)
        safe_sigma = jnp.where(sigma_all > 0, sigma_all, 1.0)

        def bwd_level(lvl, delta):
            g = jnp.where(
                depth_all == lvl + 1, (1.0 + delta + omega_col) / safe_sigma, 0.0
            )
            if fuse_backward_payload:
                t = spmv(g)
            else:  # paper-style split payload (benchmark mode)
                half = g.shape[1] // 2
                t = jnp.concatenate([spmv(g[:, :half]), spmv(g[:, half:])], axis=1)
            return delta + jnp.where(depth_all == lvl, sigma_all * t, 0.0)

        if num_levels is None:

            def bcond(carry):
                _, lvl = carry
                return lvl >= 1

            def bbody(carry):
                delta, lvl = carry
                return bwd_level(lvl, delta), lvl - 1

            delta, _ = jax.lax.while_loop(bcond, bbody, (delta0, max_depth - 1))
        else:

            def bbody(k, delta):
                return bwd_level(num_levels - 1 - k, delta)

            delta = jax.lax.fori_loop(0, num_levels - 1, bbody, delta0)

        # ------------------------------------------------- BC + n_s
        roots = jnp.concatenate([sources, c_idx])
        omega_root_local = jnp.where(
            (roots[None, :] == owned_ids[:, None]), omega_col, 0.0
        ).sum(axis=0)
        omega_root = jax.lax.psum(omega_root_local, grid_axes)
        mult = jnp.where(roots >= 0, omega_root + 1.0, 0.0)

        root_onehot = owned_ids[:, None] == roots[None, :]
        weighted = jnp.where(root_onehot, 0.0, delta * mult[None, :])
        bc_owned = weighted.sum(axis=1)  # [chunk]

        ns_local = ((depth_all >= 0) * (1.0 + omega_col)).sum(axis=0)
        ns = jax.lax.psum(ns_local, grid_axes)  # [s+k]

        return bc_owned[None], ns[None], roots[None]

    # sharding specs
    rep = (replica_axis,) if replica_axis is not None else (None,)
    in_specs = (
        P(row_axis, col_axis, None),
        P(row_axis, col_axis, None),
        P((col_axis, row_axis)),
        P(*rep, None),
        P(*rep, None, None),
    )
    out_specs = (
        P(*rep, (col_axis, row_axis)),
        P(*rep, None),
        P(*rep, None),
    )
    shmapped = jax.shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return jax.jit(shmapped)


def distributed_betweenness_centrality(
    graph: Graph,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    batch_size: int = 16,
    heuristics: str = "h0",
    num_levels: int | None = None,
) -> tuple[np.ndarray, Schedule]:
    """Run the full distributed BC computation on ``mesh``.

    Rounds are dealt ``fr`` at a time (one per sub-cluster); the replica
    sum happens host-side so a straggling/preempted replica's round can be
    re-issued (fault tolerance path, see distributed/fault_tolerance.py).
    """
    schedule, prep, residual, omega_i = build_schedule(
        graph, batch_size=batch_size, heuristics=heuristics
    )
    R, C, fr = _grid_axes(mesh, row_axis, col_axis, replica_axis)
    part = partition_2d(residual, R, C)

    round_fn = make_distributed_round_fn(
        part,
        mesh,
        row_axis=row_axis,
        col_axis=col_axis,
        replica_axis=replica_axis,
        num_levels=num_levels,
    )

    n_pad = part.n_pad
    omega_pad = np.zeros(n_pad, np.float32)
    omega_pad[: graph.n] = omega_i
    # reorder omega into chunk-owner layout: flat position = chunk-id*chunk + off
    # chunk ids are contiguous in vertex order, so identity layout works.
    omega_dev = jnp.asarray(omega_pad)

    s = schedule.batch_size
    k = schedule.derived_per_round
    bc = np.zeros(graph.n, np.float64)
    ns_by_root: dict[int, float] = {}

    rounds = list(schedule.rounds)
    for start in range(0, len(rounds), fr):
        block = rounds[start : start + fr]
        srcs = np.full((fr, s), -1, np.int32)
        ders = np.full((fr, k, 3), -1, np.int32)
        for r, rnd in enumerate(block):
            srcs[r] = rnd.sources
            ders[r] = rnd.derived
        bc_r, ns_r, roots_r = round_fn(
            jnp.asarray(part.src_local),
            jnp.asarray(part.dst_local),
            omega_dev,
            jnp.asarray(srcs),
            jnp.asarray(ders),
        )
        bc += np.asarray(bc_r, np.float64).sum(axis=0)[: graph.n]
        roots_np = np.asarray(roots_r)
        ns_np = np.asarray(ns_r, np.float64)
        for r in range(len(block)):
            for root, nv in zip(roots_np[r], ns_np[r]):
                if root >= 0:
                    ns_by_root[int(root)] = float(nv)

    if prep is not None:
        apply_reduction_corrections(bc, prep, schedule, ns_by_root)

    return bc, schedule
