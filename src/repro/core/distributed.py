"""Distributed MGBC: 2-D decomposition + sub-clustering (paper §3.2-3.3).

Communication structure per traversal level, per sub-cluster (an R×C
grid of devices; see graphs/partition.py for the chunk layout):

  expand (vertical, paper Alg. 2 line 15):
      all_gather(frontier-σ chunk, axis=row)  →  F[cols_j]  on every
      device of grid column j — O(√p) partners.
  local compute (node level):
      * ``engine_kind="sparse"`` — gather F[src_local] + segment_sum
        into dst_local (the TPU replacement for the CUDA active-edge
        kernel);
      * ``engine_kind="pallas"`` / ``"pallas_bf16"`` — the device's dense
        adjacency block on the MXU via the fused frontier/dependency
        SpMM kernels in partial mode (kernels/frontier_spmm.py) — the
        fine-grained dense-block compute the 2-D decomposition is
        designed to feed.
  fold (horizontal, Alg. 2 line 19):
      psum_scatter(partials, axis=col) — sums the C partial
      contributions and delivers each device exactly its owned chunk.

That is the *barrier* schedule (``overlap="none"``): every device idles
through both collectives.  ``overlap="expand"`` replaces the all_gather
with R-1 ``ppermute`` ring steps, accumulating each device's per-chunk
product against the chunk in hand while the next is in flight (paper
Fig. 2 pipelining / collective-matmul decomposition);
``overlap="expand+fold"`` additionally replaces the psum_scatter with a
C-1-step reduce ring, leaving no monolithic collective on the level's
critical path — per level the cost drops from T_comm + T_compute toward
max(T_comm, T_compute).

The traversal itself — level loops, round algebra, host loop — is NOT
implemented here: the shard_map body below constructs a
:class:`repro.core.operators.DistributedOperator` (or its Pallas
dense-block subclass) and runs the same
:func:`repro.core.driver.traversal_round` /
:class:`repro.core.driver.BCDriver` as the single-device path.

With the sparse operator, *all* state stays owner-sharded and only
frontier-σ / g ever travel — the depth test of the edge's far endpoint
is folded into the gathered quantity (one exchange per level; recorded
as a beyond-paper optimization in EXPERIMENTS.md §Perf).  The Pallas
dense-block operator exchanges (σ, d) forward and (σ, d, δ, ω) backward
— the paper's §3.2 exchange set — in return for fusing the mask / g
recompute into the MXU block matmul.

Sub-clustering (paper §3.3): a leading mesh axis carries ``fr`` graph
replicas, each processing different source rounds; BC is additive so the
final merge sums the replica dim (host-side, in the shared driver, so a
straggling/preempted replica's round can be re-issued — and, with
``straggler="steal"|"redeal"``, actively moved between replicas by the
driver's multi-ledger scheduler; see core/driver.py and
distributed/fault_tolerance.py).
"""
from __future__ import annotations

import logging
import os

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.driver import (
    BCDriver,
    DEFAULT_MAX_RETRIES,
    DEFAULT_RETRY_BACKOFF_S,
    normalize_integrity,
    traversal_round,
)
from repro.core.operators import (
    DistributedOperator,
    DistributedPallasHybridOperator,
    DistributedPallasOperator,
    DistributedPallasSparseOperator,
    DistributedWeightedDenseOperator,
    DistributedWeightedOperator,
    auto_delta,
    normalize_overlap,
)
from repro.core.scheduler import Schedule, build_schedule
from repro.graphs.graph import Graph
from repro.graphs.partition import TwoDPartition, partition_2d
from repro.roofline.model import (
    V5E,
    auto_overlap_policy,
    cell_kernel_choice,
    device_hbm_footprint,
    sparse_tile_bytes,
)

__all__ = [
    "DIST_ENGINE_KINDS",
    "make_distributed_round_fn",
    "distributed_graph_arrays",
    "distributed_betweenness_centrality",
    "one_degree_reduce_distributed",
    "resolve_overlap",
    "hybrid_cell_choice",
    "level_time_estimates",
    "prior_round_seconds",
    "weighted_prior_levels",
    "estimate_device_footprint",
    "check_device_memory",
    "WATCHDOG_SAFETY",
    "WATCHDOG_MIN_DEADLINE_S",
]

logger = logging.getLogger(__name__)

#: ``dispatch_deadline_s="auto"`` resolves to
#: ``max(WATCHDOG_MIN_DEADLINE_S, WATCHDOG_SAFETY × prior_round_seconds)``.
#: The factor is deliberately generous: the roofline prior models steady
#: state, while the first dispatch also pays jit compilation, and a false
#: watchdog trip evicts a healthy replica.
WATCHDOG_SAFETY = 50.0
WATCHDOG_MIN_DEADLINE_S = 60.0

#: block-local compute engines of the distributed path: arc-list
#: gather/segment-sum, fused dense-block Pallas (f32 / bf16 A-stream),
#: the blocked-sparse (BCSR tile list) Pallas engine, or the per-cell
#: dense/BCSR hybrid for skewed meshes.
DIST_ENGINE_KINDS = ("sparse", "pallas", "pallas_bf16", "pallas_sparse", "pallas_hybrid")


def hybrid_cell_choice(
    partition: TwoDPartition,
    bm: int | None = None,
    bk: int | None = None,
    *,
    threshold: float = 1.0,
    tile_counts: dict | None = None,
    measured: tuple[float, float] | None = None,
) -> tuple[np.ndarray, dict]:
    """Resolve the hybrid engine's per-cell dense-vs-BCSR choice.

    Thin wrapper over :func:`repro.roofline.model.cell_kernel_choice`
    feeding it the per-cell stored-tile counts from the partition's
    shared counting pass (pass ``tile_counts`` to reuse a dict already
    computed this resolve; the underlying arc→tile pass is cached either
    way).  The choice is logged — like ``overlap="auto"`` — so runs are
    auditable, and overridable via ``threshold``
    (``--hybrid-threshold``).  ``measured`` is the autotuner's
    (dense_level_s, sparse_level_s) calibration pair: when present the
    break-even compares measured seconds instead of the roofline's bytes
    model.  Returns ``(dense_cells, tile_counts)``.
    """
    counts = tile_counts or partition.blocked_sparse_counts(bm, bk)
    dense_cells = cell_kernel_choice(
        counts["stored_full_cell"],
        R=partition.R,
        C=partition.C,
        chunk=partition.chunk,
        bm=counts["bm"],
        bk=counts["bk"],
        threshold=threshold,
        measured=measured,
    )
    logger.info(
        "hybrid cell choice (threshold %.3g, tile %dx%d, %s): %d dense / "
        "%d sparse cells %s",
        threshold,
        counts["bm"],
        counts["bk"],
        "measured costs" if measured is not None else "roofline bytes",
        int(dense_cells.sum()),
        int(dense_cells.size - dense_cells.sum()),
        dense_cells.astype(int).tolist(),
    )
    return dense_cells, counts


def distributed_graph_arrays(
    partition: TwoDPartition,
    engine_kind: str,
    overlap: str = "none",
    tile: tuple[int, int] | None = None,
    dense_cells: np.ndarray | None = None,
    hybrid_threshold: float = 1.0,
    weights: np.ndarray | None = None,
) -> tuple[jnp.ndarray, ...]:
    """Device arrays for the graph operands of a distributed round fn.

    The single source of the engine_kind × overlap → operand-layout
    mapping (entry point, benchmarks and tests all lower the same
    layout): sparse uses the flat arc arrays, or the ring-sliced layout
    under a ring overlap policy; the dense Pallas engines use dense
    blocks (bf16 for ``"pallas_bf16"``); ``"pallas_sparse"`` uses the
    blocked tile layout (full tile list, or per-ring-chunk slices under
    a ring policy) — always (tiles, tile_rows, tile_cols);
    ``"pallas_hybrid"`` prepends the dense blocks and appends the i32
    per-cell choice mask — (blocks, tiles, tile_rows, tile_cols,
    dense_cells), each cell's data materialized only in its chosen
    representation (:meth:`TwoDPartition.blocked_hybrid`).  ``tile``
    overrides the blocked-sparse (bm, bk) tile shape (default: the
    largest lane-friendly divisor of ``chunk`` ≤ 128); ``dense_cells``
    overrides the hybrid per-cell choice (default: resolved from the
    roofline threshold via :func:`hybrid_cell_choice`).

    ``weights`` (f32 [num_arcs], graph arc order) swaps the 0/1 operand
    values for edge weights — the bucketed-traversal operand set.  The
    weighted layouts are always the barrier (non-ring) forms regardless
    of ``overlap`` (weighted rounds run barrier collectives; overlap
    only governs replica loop lockstep): sparse grows a third f32
    [R, C, max_arcs] arc-weight array; the dense engines carry f32
    weight blocks even under ``"pallas_bf16"`` (the σ/δ equality masks
    need exact distances, so weights never downcast).
    """
    if engine_kind == "sparse":
        if weights is not None:
            return (
                jnp.asarray(partition.src_local),
                jnp.asarray(partition.dst_local),
                jnp.asarray(partition.arc_weights(weights)),
            )
        if normalize_overlap(overlap) != "none":
            ring_src, ring_dst = partition.ring_arcs()
            return (jnp.asarray(ring_src), jnp.asarray(ring_dst))
        return (jnp.asarray(partition.src_local), jnp.asarray(partition.dst_local))
    if engine_kind in ("pallas_sparse", "pallas_hybrid"):
        ring = weights is None and normalize_overlap(overlap) != "none"
        bm, bk = tile if tile is not None else (None, None)
        if engine_kind == "pallas_sparse":
            layout = partition.blocked_sparse(bm, bk, ring=ring, weights=weights)
            lead: tuple = ()
        else:
            if dense_cells is None:
                dense_cells, _ = hybrid_cell_choice(
                    partition, bm, bk, threshold=hybrid_threshold
                )
            hybrid = partition.blocked_hybrid(
                bm, bk, dense_cells=dense_cells, ring=ring, weights=weights
            )
            layout = hybrid.sparse
            lead = (jnp.asarray(hybrid.blocks),)
        if ring:
            tiles = (
                jnp.asarray(layout.ring_tiles),
                jnp.asarray(layout.ring_tile_rows),
                jnp.asarray(layout.ring_tile_cols),
            )
        else:
            tiles = (
                jnp.asarray(layout.tiles),
                jnp.asarray(layout.tile_rows),
                jnp.asarray(layout.tile_cols),
            )
        if engine_kind == "pallas_hybrid":
            return lead + tiles + (jnp.asarray(dense_cells.astype(np.int32)),)
        return tiles
    if weights is not None:
        return (jnp.asarray(partition.dense_blocks(np.float32, weights=weights)),)
    dt = jnp.bfloat16 if engine_kind == "pallas_bf16" else jnp.float32
    return (jnp.asarray(partition.dense_blocks(np.float32), dt),)


def estimate_device_footprint(
    partition: TwoDPartition,
    engine_kind: str,
    batch_size: int,
    *,
    bm: int | None = None,
    bk: int | None = None,
    overlap: str = "none",
    tile_counts: dict | None = None,
    dense_cells: np.ndarray | None = None,
    hybrid_threshold: float = 1.0,
) -> dict:
    """Per-device adjacency + state HBM bytes for one engine (pre-compile).

    Thin adapter over :func:`repro.roofline.model.device_hbm_footprint`
    filling in the partition-derived quantities; prices what the chosen
    ``overlap`` actually allocates, not a lower bound.  For the
    blocked-sparse engine that is the layout's *stored* tile count —
    true nonzero tiles plus row-complete fillers, pad-to-worst-cell,
    and (under a ring policy) the R per-slot slices
    (:meth:`TwoDPartition.blocked_sparse_counts`, no tile data
    materialized; pass a precomputed ``tile_counts`` to reuse one
    counting-pass dict across resolve/guard — the underlying arc→tile
    pass is cached on the partition either way).  For the hybrid engine
    it is the actually-shipped mixed layout: the dense-block operand
    every device allocates PLUS the sparse tile list masked to the
    sparse-chosen cells (``dense_cells``, default: the roofline choice
    at ``hybrid_threshold``) — shard_map uniformity makes the resident
    adjacency the union of the two representations even though each
    cell only *streams* its chosen one.  For the arc-list engine under
    a ring policy it is the 2·R·max_ring_arcs ring layout
    (:meth:`TwoDPartition.ring_arcs_max`), not the flat arc arrays.
    ``bm``/``bk`` override the default tile shape; pass the same
    ``tile`` the engine will be built with.
    """
    ring = normalize_overlap(overlap) != "none"
    kw: dict = {}
    if engine_kind == "pallas_sparse":
        counts = tile_counts or partition.blocked_sparse_counts(bm, bk)
        kw = dict(
            nnz_tiles=counts["stored_tiles_ring" if ring else "stored_tiles_full"],
            bm=counts["bm"],
            bk=counts["bk"],
        )
    elif engine_kind == "pallas_hybrid":
        if dense_cells is None:
            dense_cells, _ = hybrid_cell_choice(
                partition, bm, bk, threshold=hybrid_threshold,
                tile_counts=tile_counts,
            )
        # accept the i32 form the mask ships in (graph args / JSON records)
        dense_cells = np.asarray(dense_cells, bool)
        counts = partition.blocked_sparse_counts(bm, bk, cells=~dense_cells)
        kw = dict(
            nnz_tiles=counts["stored_tiles_ring" if ring else "stored_tiles_full"],
            bm=counts["bm"],
            bk=counts["bk"],
        )
    elif engine_kind == "sparse":
        max_arcs = int(partition.src_local.shape[-1])
        if ring:
            max_arcs = partition.R * partition.ring_arcs_max()
        kw = dict(max_arcs=max_arcs)
    return device_hbm_footprint(
        engine_kind,
        R=partition.R,
        C=partition.C,
        chunk=partition.chunk,
        batch_size=batch_size,
        **kw,
    )


def check_device_memory(
    partition: TwoDPartition,
    engine_kind: str,
    batch_size: int,
    hbm_limit_bytes: float | None,
    *,
    bm: int | None = None,
    bk: int | None = None,
    overlap: str = "none",
    tile_counts: dict | None = None,
    dense_cells: np.ndarray | None = None,
) -> dict:
    """Fail-fast memory guard: error *before* compiling instead of
    OOMing mid-round, with an actionable suggestion.  Returns the
    footprint record (always computed, so callers can report it).
    ``dense_cells`` is the hybrid engine's resolved per-cell choice, so
    the guard prices the actually-shipped mixed layout."""
    foot = estimate_device_footprint(
        partition, engine_kind, batch_size,
        bm=bm, bk=bk, overlap=overlap, tile_counts=tile_counts,
        dense_cells=dense_cells,
    )
    logger.info(
        "per-device HBM footprint (%s): adjacency %.3f GiB + state %.3f GiB "
        "= %.3f GiB%s",
        engine_kind,
        foot["adjacency_bytes"] / 2**30,
        foot["state_bytes"] / 2**30,
        foot["total_bytes"] / 2**30,
        ""
        if hbm_limit_bytes is None
        else f" (budget {hbm_limit_bytes/2**30:.2f} GiB)",
    )
    if hbm_limit_bytes is not None and foot["total_bytes"] > hbm_limit_bytes:
        suggestions = []
        if engine_kind in ("pallas", "pallas_bf16", "pallas_hybrid"):
            # hybrid ships the dense operand on every device (shard_map
            # uniformity); pure blocked-sparse is the strictly smaller layout
            sparse_foot = estimate_device_footprint(
                partition, "pallas_sparse", batch_size,
                bm=bm, bk=bk, overlap=overlap, tile_counts=tile_counts,
            )
            if sparse_foot["total_bytes"] <= hbm_limit_bytes:
                suggestions.append(
                    "engine_kind='pallas_sparse' (blocked-sparse adjacency: "
                    f"{sparse_foot['total_bytes']/2**30:.2f} GiB/device)"
                )
        suggestions.append("a larger mesh (per-device footprint scales ~1/p)")
        raise MemoryError(
            f"engine_kind={engine_kind!r} needs "
            f"{foot['total_bytes']/2**30:.2f} GiB/device "
            f"(adjacency {foot['adjacency_bytes']/2**30:.2f} GiB + state "
            f"{foot['state_bytes']/2**30:.2f} GiB) but the HBM budget is "
            f"{hbm_limit_bytes/2**30:.2f} GiB; try " + " or ".join(suggestions)
        )
    return foot


def level_time_estimates(
    partition: TwoDPartition,
    engine_kind: str,
    batch_size: int,
    *,
    bm: int | None = None,
    bk: int | None = None,
    tile_counts: dict | None = None,
    dense_cells: np.ndarray | None = None,
    hw=V5E,
) -> tuple[float, float, float]:
    """Roofline prices of one traversal level: (compute, expand, fold) s.

    The shared pricing behind ``overlap="auto"`` (:func:`resolve_overlap`)
    and the straggler scheduler's EWMA prior
    (:func:`prior_round_seconds`): block compute from the
    engine-dependent FLOPs / A-stream bytes, expand/fold collective
    bytes from the α-β link model.  The hybrid engine is priced per
    cell — each cell streams its *chosen* representation
    (``dense_cells``, default: the roofline choice), and the level waits
    for the slowest cell, so the compute term is the per-cell maximum.
    """
    R, C, chunk, s = partition.R, partition.C, partition.chunk, batch_size
    from repro.roofline.model import adjacency_stream_bytes

    if engine_kind in ("pallas", "pallas_bf16"):
        flops = 2.0 * (C * chunk) * (R * chunk) * s
        a_bytes = adjacency_stream_bytes(engine_kind, R=R, C=C, chunk=chunk)
    elif engine_kind == "pallas_sparse":
        counts = tile_counts or partition.blocked_sparse_counts(bm, bk)
        bm, bk, nnz = counts["bm"], counts["bk"], counts["nnz_max"]
        flops = 2.0 * nnz * bm * bk * s
        a_bytes = adjacency_stream_bytes(
            engine_kind, R=R, C=C, chunk=chunk, nnz_tiles=nnz, bm=bm, bk=bk
        )
    elif engine_kind == "pallas_hybrid":
        counts = tile_counts or partition.blocked_sparse_counts(bm, bk)
        if dense_cells is None:
            dense_cells, _ = hybrid_cell_choice(
                partition, bm, bk, tile_counts=counts
            )
        bm, bk = counts["bm"], counts["bk"]
        dense_flops = 2.0 * (C * chunk) * (R * chunk) * s
        dense_bytes = adjacency_stream_bytes("pallas", R=R, C=C, chunk=chunk)
        stored = np.asarray(counts["stored_full_cell"], np.float64)
        cell_flops = np.where(dense_cells, dense_flops, 2.0 * stored * bm * bk * s)
        cell_bytes = np.where(
            dense_cells, dense_bytes, stored * sparse_tile_bytes(bm, bk)
        )
        cell_s = np.maximum(
            cell_flops / hw.peak_bf16_flops, cell_bytes / hw.hbm_bandwidth
        )
        flops, a_bytes = float(cell_flops.max()), float(cell_bytes.max())
        compute_s = float(cell_s.max())  # the level waits for the slowest cell
    else:  # arc-list: one gather+add per arc per source column
        max_arcs = int(partition.src_local.shape[-1])
        flops = 2.0 * max_arcs * s
        a_bytes = adjacency_stream_bytes(
            engine_kind, R=R, C=C, chunk=chunk, max_arcs=max_arcs
        )
    if engine_kind != "pallas_hybrid":
        compute_s = max(flops / hw.peak_bf16_flops, a_bytes / hw.hbm_bandwidth)
    from repro.roofline.model import exchange_operands

    n_operands = exchange_operands(engine_kind)[0]  # forward exchange set
    expand_s = (R - 1) * chunk * s * 4 * n_operands / hw.ici_link_bandwidth
    fold_s = (C - 1) / C * (C * chunk) * s * 4 / hw.ici_link_bandwidth
    return compute_s, expand_s, fold_s


#: Nominal level count pricing the straggler prior: forward + backward
#: sweeps of a shallow (RMAT-like) traversal.  The prior only seeds every
#: replica's EWMA symmetrically — it cannot flag a straggler by itself —
#: so the constant's job is order-of-magnitude, not accuracy.
PRIOR_LEVELS = 16


def prior_round_seconds(
    partition: TwoDPartition,
    engine_kind: str,
    batch_size: int,
    overlap: str,
    *,
    bm: int | None = None,
    bk: int | None = None,
    tile_counts: dict | None = None,
    dense_cells: np.ndarray | None = None,
    hw=V5E,
    measured_level_s: float | None = None,
    prior_levels: int | None = None,
) -> float:
    """Per-round wall estimate — the straggler EWMA's prior.

    With ``measured_level_s`` (the autotuner's measured per-level wall of
    the resolved config) the prior is simply ``measured × PRIOR_LEVELS``
    — a real time scale instead of a modelled one.  Otherwise one level
    is priced under the resolved collective schedule
    (:func:`repro.roofline.model.overlap_step_time` via
    :func:`repro.roofline.model.auto_overlap_policy`'s estimate table) ×
    :data:`PRIOR_LEVELS` nominal levels.  Gives the scheduler a
    before-any-observation time scale (paper-motivated: round wall is
    data-dependent and unknown until traversal).

    ``prior_levels`` overrides the nominal level count — weighted runs
    substitute the expected *bucket* count of the bucketed traversal
    (≈ depth·w̄/Δ), since a round's trip unit is a distance bucket, not
    a BFS level (:func:`weighted_prior_levels`).
    """
    levels = PRIOR_LEVELS if prior_levels is None else int(prior_levels)
    if measured_level_s is not None:
        return float(measured_level_s) * levels
    compute_s, expand_s, fold_s = level_time_estimates(
        partition, engine_kind, batch_size,
        bm=bm, bk=bk, tile_counts=tile_counts, dense_cells=dense_cells, hw=hw,
    )
    _, estimates = auto_overlap_policy(
        compute_s, expand_s, fold_s, partition.R, partition.C, hw=hw
    )
    return estimates[normalize_overlap(overlap)] * levels


def weighted_prior_levels(w: np.ndarray, delta: float) -> int:
    """Expected bucket count standing in for :data:`PRIOR_LEVELS`.

    A weighted round's trip unit is a width-Δ distance bucket; at the
    nominal :data:`PRIOR_LEVELS` hop depth the traversal spans roughly
    ``PRIOR_LEVELS · w̄`` distance, i.e. ``⌈PRIOR_LEVELS · w̄ / Δ⌉``
    buckets (never less than the unweighted constant — a wide Δ merges
    buckets but each still costs at least a level's collectives).
    """
    w = np.asarray(w, np.float64)
    w_mean = float(w.mean()) if w.size else 1.0
    return max(PRIOR_LEVELS, int(np.ceil(PRIOR_LEVELS * w_mean / float(delta))))


def resolve_overlap(
    overlap: str | None,
    partition: TwoDPartition,
    engine_kind: str,
    batch_size: int,
    *,
    bm: int | None = None,
    bk: int | None = None,
    tile_counts: dict | None = None,
    dense_cells: np.ndarray | None = None,
    hw=V5E,
    measured: dict | None = None,
) -> str:
    """Resolve ``overlap="auto"`` from measured or roofline level costs.

    Prices one level's block compute (engine-dependent FLOPs/A-stream)
    and expand/fold collective bytes with the α-β link model, then picks
    the schedule :func:`repro.roofline.model.auto_overlap_policy`
    estimates fastest.  ``measured`` (policy -> measured per-level
    seconds from the autotune cache) takes precedence: when any policy
    has a measurement the pick compares measured policies only.  The
    choice is logged (logging INFO + returned); passing an explicit
    policy bypasses this entirely.  ``bm``/``bk``: the blocked-sparse
    tile shape the engine will actually be built with (defaults to the
    partition default), so the estimate prices the real layout;
    ``dense_cells``: the hybrid engine's resolved per-cell choice, for
    the same reason.
    """
    if overlap != "auto":
        return normalize_overlap(overlap)
    compute_s, expand_s, fold_s = level_time_estimates(
        partition, engine_kind, batch_size,
        bm=bm, bk=bk, tile_counts=tile_counts, dense_cells=dense_cells, hw=hw,
    )
    policy, estimates = auto_overlap_policy(
        compute_s, expand_s, fold_s, partition.R, partition.C, hw=hw,
        measured=measured,
    )
    logger.info(
        "overlap='auto' -> %r for engine %s (%s per-level estimates: %s)",
        policy,
        engine_kind,
        "measured" if measured else "roofline",
        {k: f"{v*1e6:.2f}us" for k, v in estimates.items()},
    )
    return policy


def one_degree_reduce_distributed(
    graph: Graph, mesh: Mesh, axis_name: str | tuple[str, ...] = "data"
) -> tuple[np.ndarray, np.ndarray]:
    """Distributed 1-degree preprocessing (paper Alg. 6, §3.4.1).

    The paper 1-D-partitions edges, sorts by source and scans; the
    data-parallel equivalent shards the arc list over ``axis_name``,
    computes degrees with a local segment-sum + psum, then marks arcs
    incident to a leaf and accumulates ω the same way.  Near-linear
    scaling (paper Fig. 10) follows from the arc shards being independent
    except for two n-sized all-reduces.

    Returns (omega int64 [n], arc_removed bool [m2]) — identical to the
    host-side :func:`repro.core.heuristics.one_degree.one_degree_reduce`.
    """
    axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    p = 1
    for a in axes:
        p *= mesh.shape[a]
    n = graph.n
    src_p, dst_p, m2 = graph.padded_arcs(multiple=p)

    def body(src, dst):
        ones = jnp.ones_like(src, dtype=jnp.float32)
        deg = jax.lax.psum(
            jax.ops.segment_sum(ones, src, num_segments=n + 1), axes
        )
        leaf = deg == 1.0  # sentinel vertex n has huge degree, never a leaf
        removed = leaf[src] | leaf[dst]
        omega = jax.lax.psum(
            jax.ops.segment_sum(leaf[src].astype(jnp.float32), dst, num_segments=n + 1),
            axes,
        )
        return omega[:n], removed

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes), P(axes)),
        out_specs=(P(), P(axes)),
        check_vma=False,
    )
    omega, removed = jax.jit(fn)(jnp.asarray(src_p), jnp.asarray(dst_p))
    return (
        np.asarray(omega, np.int64),
        np.asarray(removed)[:m2],
    )


def _grid_axes(mesh: Mesh, row_axis: str, col_axis: str, replica_axis: str | None):
    R = mesh.shape[row_axis]
    C = mesh.shape[col_axis]
    fr = mesh.shape[replica_axis] if replica_axis is not None else 1
    return R, C, fr


def make_distributed_round_fn(
    partition: TwoDPartition,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    num_levels: int | None = None,
    fuse_backward_payload: bool = True,
    engine_kind: str = "sparse",
    interpret: bool | None = None,
    overlap: str = "none",
    integrity: str = "off",
    weighted: bool = False,
    delta: float | None = None,
):
    """Build the sub-cluster-parallel, 2-D-distributed round function.

    With ``engine_kind="sparse"`` (arc-list local compute) the returned
    jitted function maps
      (src_local  i32 [R, C, max_arcs]   — sharded (row, col),
       dst_local  i32 [R, C, max_arcs]   — sharded (row, col),
       omega      f32 [n_pad]            — sharded ((col, row)),
       sources    i32 [fr, s]            — sharded (replica),
       derived    i32 [fr, k, 3]         — sharded (replica))
      -> (bc  f32 [fr, n_pad]  — sharded (replica, (col, row)),
          ns  f32 [fr, s+k]    — sharded (replica),
          roots i32 [fr, s+k]  — sharded (replica),
          levels i32 [fr]      — sharded (replica): each replica's own
          traversal depth this round, the straggler scheduler's
          per-round cost signal)

    With ``engine_kind="pallas"`` / ``"pallas_bf16"`` (dense-block MXU
    local compute) the two arc arrays are replaced by one argument:
      (blocks  f32/bf16 [R, C, C·chunk, R·chunk] — sharded (row, col),
       omega, sources, derived)  ->  same outputs.
    Build the blocks with :meth:`TwoDPartition.dense_blocks`.

    With ``engine_kind="pallas_sparse"`` (blocked-sparse BCSR local
    compute) the graph operands are the tile layout of
    :meth:`TwoDPartition.blocked_sparse`:
      (tiles      f32 [R, C, T, bm, bk]  — sharded (row, col),
       tile_rows  i32 [R, C, T],
       tile_cols  i32 [R, C, T],
       omega, sources, derived)  ->  same outputs;
    under a ring overlap policy the three arrays are the per-ring-chunk
    slices ([R, C, R, Tr, ...], ``blocked_sparse(ring=True)``) — same
    arity, one extra slot dim.  Per-device adjacency memory is
    O(nnz_tiles·bm·bk) instead of the dense engines' O(n_pad²/p).

    With ``engine_kind="pallas_hybrid"`` (per-cell dense/BCSR mix) the
    graph operands prepend the dense blocks and append the choice mask:
      (blocks     f32 [R, C, C·chunk, R·chunk] — sharded (row, col),
       tiles/tile_rows/tile_cols — as for ``pallas_sparse``,
       dense_cells i32 [R, C]    — sharded (row, col),
       omega, sources, derived)  ->  same outputs;
    each cell holds data only in its chosen representation
    (:meth:`TwoDPartition.blocked_hybrid`) and dispatches its fused
    kernels through a local ``lax.cond`` on its choice scalar.

    ``fuse_backward_payload`` keeps σ-frontier and g exchanges as a single
    gathered tensor each (the paper's overlap/fusion idea, §3.2 Fig. 2);
    setting it False splits the backward gather into two half-width
    collectives to mimic the paper's unfused σ/d exchange for the
    Fig. 9 benchmark (sparse engine only).

    ``overlap`` selects the collective schedule per
    :data:`repro.core.operators.OVERLAP_POLICIES`: ``"none"`` keeps the
    barrier all_gather → compute → psum_scatter level step; ``"expand"``
    ring-pipelines the gather (ppermute steps interleaved with per-chunk
    block compute); ``"expand+fold"`` additionally turns the fold into a
    reduce ring.  Under a ring policy the sparse engine's two arc
    arguments are the *ring-sliced* layout
    (i32 [R, C, R, max_ring_arcs] from
    :meth:`TwoDPartition.ring_arcs`) instead of the flat arc arrays —
    same arity, per-row-chunk slicing.

    ``integrity`` (:data:`repro.core.driver.INTEGRITY_MODES`) makes each
    round self-verifying: with ``"audit"`` or ``"checksum"`` the output
    grows a fifth slot, f32 [fr, 2] — per replica the max ABFT checksum
    residual over all level steps (``"checksum"`` only; 0 otherwise) and
    the replica's claimed bc-block sum, which the driver cross-checks
    against the delivered block at drain time.  ``"checksum"`` requires
    the fused backward payload: the checksum lane rides the column axis
    through every exchange, and the split σ/d gather would carry it
    through only half the backward operands.

    ``weighted=True`` (with a positive ``delta`` bucket width) swaps the
    level-synchronous round for the bucketed weighted traversal.  The
    operand layouts are the barrier (non-ring) forms from
    :func:`distributed_graph_arrays` with ``weights=``: the sparse
    engine's signature grows a third f32 arc-weight array; the dense
    Pallas engines take one f32 weight-block operand; the BCSR/hybrid
    tile layouts keep their unweighted arity and are densified per
    device cell inside the shard_map body (fused weighted tile kernels
    are the documented follow-up — weighted compute is XLA contractions
    either way).  Collectives run the barrier schedule regardless of
    ``overlap``, which only keeps sub-cluster replicas in bucket-loop
    lockstep (``sync_axes``); ``num_levels`` (static trip counts) and
    ``integrity="checksum"`` (a level-synchronous ABFT lane) are
    rejected.
    """
    R, C, fr = _grid_axes(mesh, row_axis, col_axis, replica_axis)
    if (R, C) != (partition.R, partition.C):
        raise ValueError(
            f"mesh grid {(R, C)} != partition grid {(partition.R, partition.C)}"
        )
    if engine_kind not in DIST_ENGINE_KINDS:
        raise ValueError(f"unknown distributed engine {engine_kind!r}")
    overlap = normalize_overlap(overlap)
    integrity = normalize_integrity(integrity)
    use_pallas = engine_kind != "sparse"  # any fused-kernel engine
    if use_pallas and not fuse_backward_payload:
        raise ValueError("split backward payload is a sparse-engine benchmark mode")
    if integrity == "checksum" and not fuse_backward_payload:
        raise ValueError(
            "integrity='checksum' needs the fused backward payload: the "
            "checksum lane must travel with every exchanged operand"
        )
    if overlap != "none" and not fuse_backward_payload:
        raise ValueError(
            "split backward payload is a barrier-schedule benchmark mode; "
            "it cannot be combined with a ring overlap policy"
        )
    if weighted:
        if delta is None or not (float(delta) > 0):
            raise ValueError(
                f"weighted rounds need a positive bucket width delta, got {delta}"
            )
        if num_levels is not None:
            raise ValueError(
                "num_levels is a static level bound for the level-synchronous "
                "engine; the weighted bucket loop's trip count is data-dependent"
            )
        if integrity == "checksum":
            raise ValueError(
                "integrity='checksum' is a level-synchronous ABFT lane; "
                "weighted rounds support integrity='audit'"
            )
        if not fuse_backward_payload:
            raise ValueError(
                "split backward payload is an unweighted sparse-engine "
                "benchmark mode"
            )
    if use_pallas and interpret is None:
        from repro.kernels.ops import on_tpu

        interpret = not on_tpu()
    chunk = partition.chunk
    # Ring hops are mesh-wide collective-permutes: sub-cluster replicas
    # must stay in level-loop lockstep or the rendezvous deadlocks (the
    # extra levels a shallow replica runs are masked no-ops) — see
    # operators.DistributedOperator (sync_axes).
    sync_axes = (
        (replica_axis,) if replica_axis is not None and overlap != "none" else ()
    )

    def round_body(op, omega, sources, derived):
        out = traversal_round(
            op, sources[0], derived[0], omega, num_levels=num_levels,
            integrity=integrity,
        )
        # levels is grid-reduced but *per replica* (reduce_max_grid), the
        # straggler scheduler's cost signal — sharded on the replica axis.
        # With integrity on, a fifth slot carries the per-replica
        # [checksum residual, claimed bc sum] pair.
        return tuple(x[None] for x in out)

    if weighted:
        from repro.kernels.blocked_spmm import tiles_to_dense

        delta_f = float(delta)

        def weighted_dense_op(block):
            return DistributedWeightedDenseOperator(
                block,
                delta=delta_f,
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                sync_axes=sync_axes,
            )

        if engine_kind == "sparse":

            def body(src_local, dst_local, w_local, omega, sources, derived):
                op = DistributedWeightedOperator(
                    src_local[0, 0],
                    dst_local[0, 0],
                    w_local[0, 0],
                    delta=delta_f,
                    chunk=chunk,
                    R=R,
                    C=C,
                    row_axis=row_axis,
                    col_axis=col_axis,
                    sync_axes=sync_axes,
                )
                return round_body(op, omega, sources, derived)

            graph_specs = (
                P(row_axis, col_axis, None),
                P(row_axis, col_axis, None),
                P(row_axis, col_axis, None),
            )
        elif engine_kind == "pallas_sparse":
            # weighted BCSR: ship the (weighted) tile layout, densify the
            # local cell in-body — same operands/specs as unweighted, but
            # the compute runs the dense weight-block bucket operator
            def body(tiles, trows, tcols, omega, sources, derived):
                block = tiles_to_dense(
                    tiles[0, 0], trows[0, 0], tcols[0, 0], C * chunk, R * chunk
                )
                return round_body(weighted_dense_op(block), omega, sources, derived)

            graph_specs = (
                P(row_axis, col_axis, None, None, None),
                P(row_axis, col_axis, None),
                P(row_axis, col_axis, None),
            )
        elif engine_kind == "pallas_hybrid":

            def body(blocks, tiles, trows, tcols, dcell, omega, sources, derived):
                from_tiles = tiles_to_dense(
                    tiles[0, 0], trows[0, 0], tcols[0, 0], C * chunk, R * chunk
                )
                block = jnp.where(dcell[0, 0] != 0, blocks[0, 0], from_tiles)
                return round_body(weighted_dense_op(block), omega, sources, derived)

            graph_specs = (
                P(row_axis, col_axis, None, None),
                P(row_axis, col_axis, None, None, None),
                P(row_axis, col_axis, None),
                P(row_axis, col_axis, None),
                P(row_axis, col_axis),
            )
        else:  # pallas / pallas_bf16: one f32 weight-block operand

            def body(blocks, omega, sources, derived):
                return round_body(
                    weighted_dense_op(blocks[0, 0]), omega, sources, derived
                )

            graph_specs = (P(row_axis, col_axis, None, None),)
    elif engine_kind == "pallas_sparse":
        # (tiles, tile_rows, tile_cols): [R, C, T, bm, bk]-shaped full
        # layout, or [R, C, R, Tr, bm, bk]-shaped ring slices — the two
        # layouts have the same arity, so one body serves both and the
        # static ``overlap`` decides which operator slots they fill.
        ring = overlap != "none"

        def body(tiles, trows, tcols, omega, sources, derived):
            local = (tiles[0, 0], trows[0, 0], tcols[0, 0])
            kw = (
                dict(ring_tiles=local[0], ring_tile_rows=local[1], ring_tile_cols=local[2])
                if ring
                else dict(tiles=local[0], tile_rows=local[1], tile_cols=local[2])
            )
            op = DistributedPallasSparseOperator(
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                interpret=interpret,
                overlap=overlap,
                sync_axes=sync_axes,
                **kw,
            )
            return round_body(op, omega, sources, derived)

        nd = 6 if ring else 5  # tiles rank; index arrays are nd - 2
        graph_specs = (
            P(row_axis, col_axis, *([None] * (nd - 2))),
            P(row_axis, col_axis, *([None] * (nd - 4))),
            P(row_axis, col_axis, *([None] * (nd - 4))),
        )
    elif engine_kind == "pallas_hybrid":
        # (blocks, tiles, tile_rows, tile_cols, dense_cells): the dense
        # operand and the (possibly ring-sliced) tile layout travel
        # together; the i32 [R, C] choice mask tells each cell which one
        # it streams (lax.cond inside the operator's _partial_* hooks).
        ring = overlap != "none"

        def body(blocks, tiles, trows, tcols, dcell, omega, sources, derived):
            local = (tiles[0, 0], trows[0, 0], tcols[0, 0])
            kw = (
                dict(ring_tiles=local[0], ring_tile_rows=local[1], ring_tile_cols=local[2])
                if ring
                else dict(tiles=local[0], tile_rows=local[1], tile_cols=local[2])
            )
            op = DistributedPallasHybridOperator(
                blocks[0, 0],  # [C*chunk, R*chunk] local dense data (or zeros)
                dcell[0, 0] != 0,  # this cell's kernel choice
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                interpret=interpret,
                overlap=overlap,
                sync_axes=sync_axes,
                **kw,
            )
            return round_body(op, omega, sources, derived)

        nd = 6 if ring else 5  # tiles rank; index arrays are nd - 2
        graph_specs = (
            P(row_axis, col_axis, None, None),
            P(row_axis, col_axis, *([None] * (nd - 2))),
            P(row_axis, col_axis, *([None] * (nd - 4))),
            P(row_axis, col_axis, *([None] * (nd - 4))),
            P(row_axis, col_axis),
        )
    elif use_pallas:

        def body(blocks, omega, sources, derived):
            op = DistributedPallasOperator(
                blocks[0, 0],  # [C*chunk, R*chunk] local dense block
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                interpret=interpret,
                overlap=overlap,
                sync_axes=sync_axes,
            )
            return round_body(op, omega, sources, derived)

        graph_specs = (P(row_axis, col_axis, None, None),)
    elif overlap != "none":

        def body(ring_src, ring_dst, omega, sources, derived):
            op = DistributedOperator(
                None,
                None,
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                overlap=overlap,
                ring_src_local=ring_src[0, 0],  # [R, max_ring_arcs] local view
                ring_dst_local=ring_dst[0, 0],
                sync_axes=sync_axes,
            )
            return round_body(op, omega, sources, derived)

        graph_specs = (
            P(row_axis, col_axis, None, None),
            P(row_axis, col_axis, None, None),
        )
    else:

        def body(src_local, dst_local, omega, sources, derived):
            op = DistributedOperator(
                src_local[0, 0],  # [max_arcs] local arc views
                dst_local[0, 0],
                chunk=chunk,
                R=R,
                C=C,
                row_axis=row_axis,
                col_axis=col_axis,
                split_backward=not fuse_backward_payload,
            )
            return round_body(op, omega, sources, derived)

        graph_specs = (
            P(row_axis, col_axis, None),
            P(row_axis, col_axis, None),
        )

    rep = (replica_axis,) if replica_axis is not None else (None,)
    in_specs = graph_specs + (
        P((col_axis, row_axis)),
        P(*rep, None),
        P(*rep, None, None),
    )
    out_specs = (
        P(*rep, (col_axis, row_axis)),
        P(*rep, None),
        P(*rep, None),
        P(*rep),
    )
    if integrity != "off":
        out_specs = out_specs + (P(*rep, None),)
    shmapped = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
    )
    return jax.jit(shmapped)


def distributed_betweenness_centrality(
    graph: Graph,
    mesh: Mesh,
    *,
    row_axis: str = "data",
    col_axis: str = "model",
    replica_axis: str | None = None,
    batch_size: int = 16,
    heuristics: str = "h0",
    num_levels: int | None = None,
    engine_kind: str = "sparse",
    overlap: str = "none",
    tile: tuple[int, int] | None = None,
    hybrid_threshold: float = 1.0,
    hbm_limit_bytes: float | None = None,
    ledger=None,
    checkpoint=None,
    straggler: str = "none",
    straggler_factor: float = 2.0,
    autotune: str = "off",
    autotune_cache=None,
    chaos=None,
    max_retries: int = DEFAULT_MAX_RETRIES,
    retry_backoff_s: float = DEFAULT_RETRY_BACKOFF_S,
    numeric_guard: bool | None = None,
    integrity: str = "off",
    dispatch_deadline_s=None,
    clock=None,
    sleeper=None,
    sampling: str = "off",
    sample_frac: float | None = None,
    sample_k: int | None = None,
    sample_seed: int = 0,
    stop_rule=None,
    full_result: bool = False,
    weighted: bool = False,
    delta: float | None = None,
):
    """Run the full distributed BC computation on ``mesh``.

    Rounds are dealt ``fr`` at a time (one per sub-cluster) by the shared
    :class:`repro.core.driver.BCDriver`; the replica merge sums the
    replica dim after the loop so a straggling/preempted replica's round
    can be re-issued (fault tolerance path, distributed/fault_tolerance.py).
    ``straggler`` selects the multi-ledger sub-cluster scheduling policy
    (:data:`repro.core.driver.STRAGGLER_POLICIES`): under ``"steal"`` or
    ``"redeal"`` the driver keeps one round ledger per replica, seeds its
    per-replica EWMA from the roofline prior
    (:func:`prior_round_seconds`) and moves uncommitted rounds between
    replica queues when one replica's per-round wall exceeds
    ``straggler_factor ×`` the fastest replica's; requires a
    ``replica_axis``.
    ``engine_kind`` selects the block-local compute
    (:data:`DIST_ENGINE_KINDS`: arc-list "sparse", fused dense-block
    "pallas"/"pallas_bf16", or blocked-sparse "pallas_sparse");
    ``overlap`` selects the collective schedule (barrier vs
    ring-pipelined — see :func:`make_distributed_round_fn`), with
    ``"auto"`` resolved from the roofline estimate
    (:func:`resolve_overlap`); ``tile`` overrides the blocked-sparse
    (bm, bk) tile shape.  With ``engine_kind="pallas_hybrid"`` the
    per-cell dense-vs-BCSR choice is resolved once from the roofline's
    bytes-streamed threshold (:func:`hybrid_cell_choice`, logged) and
    shared by the overlap resolve, the memory guard and the layout
    build; ``hybrid_threshold`` overrides the break-even point
    (0 forces all-dense, a large value all-sparse).
    ``hbm_limit_bytes`` arms the fail-fast
    memory guard (:func:`check_device_memory`): the per-device
    adjacency + state footprint is checked *before* compilation and an
    over-budget engine errors with a suggestion instead of OOMing
    mid-round.
    ``autotune`` (:data:`repro.autotune.AUTOTUNE_MODES`) swaps the
    roofline guesses behind the tile pick, the hybrid cell choice,
    ``overlap="auto"`` and the straggler prior for cached measurements
    (``"cache"``: consult only; ``"measure"``: micro-bench on a miss and
    record — measure-once), and switches the scheduler to
    eccentricity-packed rounds (``root_order="eccentricity"``) whose
    per-round depth prior seeds the replica deal.  ``autotune_cache`` is
    the persistent cache: a path, a :class:`repro.autotune.CostCache`,
    or None for in-memory.

    **Robustness.**  ``chaos`` (a ``--chaos`` spec string or
    :class:`repro.distributed.chaos.FaultPlan`) wraps the round fn in
    :class:`~repro.distributed.chaos.ChaosRoundFn` and the
    checkpoint/autotune-cache writers in the matching file-seam chaos
    wrappers, injecting the plan's faults deterministically; the
    unwrapped round fn doubles as the driver's ``fallback_round_fn``
    (known-good recompute path for persistently non-finite blocks).
    ``max_retries`` / ``retry_backoff_s`` / ``numeric_guard`` are the
    driver's self-healing knobs (core/driver.py); recovery telemetry
    lands in ``BCResult.recovery_stats`` (plus a ``"chaos"`` sub-dict
    with injection counters when a plan was active).

    ``integrity`` (:data:`repro.core.driver.INTEGRITY_MODES`) makes every
    round self-verifying: ``"audit"`` cross-checks each drained block
    against its in-graph claimed sum plus output-domain invariants
    (BC non-negativity, level bounds); ``"checksum"`` additionally runs
    the ABFT column-sum lane through every level SpMM.  A failed audit
    quarantines and re-dispatches the block (then the clean fallback,
    then :class:`~repro.distributed.fault_tolerance.IntegrityError`);
    under ``straggler="steal"`` duplicated tail rounds also get
    duplicate-vote SDC detection.  ``dispatch_deadline_s`` arms the
    dispatch watchdog — a float deadline in seconds, or ``"auto"`` for
    ``max(WATCHDOG_MIN_DEADLINE_S, WATCHDOG_SAFETY × prior round
    seconds)`` from the roofline/autotune prior; a dispatch exceeding it
    escalates hang → re-dispatch → replica loss (absorbed by the elastic
    re-mesh).  ``clock`` / ``sleeper`` are injectable time sources for
    the watchdog and the retry/stall sleeps (tests; default real time).
    Detection counters land in ``recovery_stats["integrity"]``.

    **Sampling** (``sampling`` — :data:`repro.serving.SAMPLING_MODES`):
    ``"fixed"`` runs a seeded root subset (``sample_frac`` /
    ``sample_k``) through the *same* scheduler — eccentricity packing,
    the replica deal, checkpoints and chaos all apply to the subset
    unchanged — and rescales the result by N/k; ``"adaptive"``
    additionally arms the driver's ``stop_rule`` seam (default
    :class:`repro.serving.AdaptiveStopRule`; override via ``stop_rule``,
    e.g. :class:`repro.serving.BlockBudgetStop` for serving refresh
    slices) so dispatch halts once the running accumulator's top-k
    ranks stabilize, rescaling by the roots actually committed.
    Requires ``heuristics="h0"`` (per-root additivity).  The expected
    sampled-run wall (rounds × the straggler prior's per-round seconds)
    is logged via :func:`repro.roofline.model.sampled_run_seconds`.

    ``full_result`` returns the :class:`~repro.core.driver.BCResult`
    instead of the legacy ``(bc, schedule)`` pair.

    **Weighted graphs.**  ``weighted=True`` runs the bucketed weighted
    traversal (delta-stepping-style distance buckets of width ``delta``,
    auto-derived from the weight distribution when None — see
    :func:`repro.core.operators.auto_delta`).  Requires edge weights on
    the graph, ``heuristics`` in
    :data:`repro.core.bc.WEIGHTED_HEURISTICS` (the level-based 2-degree
    rewrites assume unit edge lengths), no ``num_levels``, integrity
    ``"off"``/``"audit"`` (the checksum lane is level-synchronous) and
    ``autotune="off"`` (the micro-bench measures level-synchronous
    kernels).  ``overlap`` keeps its lockstep role but the collectives
    run the barrier schedule (ring-pipelined bucket relaxation is future
    work); the straggler prior prices bucket counts instead of levels
    (:func:`weighted_prior_levels`).
    """
    from repro.autotune import as_cache, normalize_autotune, plan_autotune, sample_batch
    from repro.distributed.chaos import (
        ChaosCheckpoint,
        ChaosCostCache,
        ChaosFS,
        ChaosRoundFn,
        FaultPlan,
    )

    chaos_plan = FaultPlan.parse(chaos)
    chaos_fs = ChaosFS(chaos_plan) if chaos_plan else None
    if chaos_fs is not None:
        if isinstance(autotune_cache, (str, os.PathLike)):
            autotune_cache = ChaosCostCache(autotune_cache, chaos_fs)
        if checkpoint is not None:
            checkpoint = ChaosCheckpoint(checkpoint, chaos_fs)

    from repro.serving.sampling import (
        AdaptiveStopRule,
        eligible_roots,
        plan_sampling,
    )

    sample_plan = plan_sampling(
        eligible_roots(graph), sampling, sample_frac, sample_k, sample_seed
    )
    if sample_plan.mode != "off" and heuristics != "h0":
        raise ValueError(
            "sampling requires heuristics='h0': the 1-/2-degree analytic "
            "corrections are not per-root additive, so a sampled run "
            "could not be rescaled into an unbiased estimator"
        )
    if stop_rule is not None and sample_plan.mode == "off":
        raise ValueError(
            "a stop_rule truncates the schedule, which is only meaningful "
            "as a rescaled estimate; pass sampling='fixed' or 'adaptive'"
        )
    if sample_plan.mode == "adaptive" and stop_rule is None:
        stop_rule = AdaptiveStopRule()

    autotune = normalize_autotune(autotune)
    integrity = normalize_integrity(integrity)
    if weighted:
        from repro.core.bc import WEIGHTED_HEURISTICS

        if graph.w is None:
            raise ValueError(
                "weighted=True needs edge weights: build the graph with "
                "Graph.from_edges(..., weights=) or a weighted generator "
                "(graphs.generators WEIGHT_MODES)"
            )
        if heuristics not in WEIGHTED_HEURISTICS:
            raise ValueError(
                f"heuristics={heuristics!r} is level-based (2-degree "
                f"derivation assumes unit edge lengths); weighted runs "
                f"accept {WEIGHTED_HEURISTICS}"
            )
        if num_levels is not None:
            raise ValueError(
                "num_levels is a static level bound for the level-"
                "synchronous engine; the weighted bucket loop's trip "
                "count is data-dependent"
            )
        if integrity == "checksum":
            raise ValueError(
                "integrity='checksum' is a level-synchronous ABFT lane; "
                "weighted runs support integrity='audit'"
            )
        if autotune != "off":
            raise ValueError(
                "autotune measures the level-synchronous kernels; run "
                "weighted with autotune='off'"
            )
        if delta is None:
            delta = auto_delta(graph)
        delta = float(delta)
        if not (delta > 0 and np.isfinite(delta)):
            raise ValueError(f"delta must be positive and finite, got {delta}")
    elif delta is not None:
        raise ValueError("delta is only meaningful with weighted=True")
    schedule, prep, residual, omega_i = build_schedule(
        graph, batch_size=batch_size, heuristics=heuristics,
        root_order="eccentricity" if autotune != "off" else "id",
        roots=sample_plan.roots,
    )
    R, C, fr = _grid_axes(mesh, row_axis, col_axis, replica_axis)
    part = partition_2d(residual, R, C)

    plan = None
    if autotune != "off" and schedule.rounds:
        sources0, derived0 = sample_batch(schedule, fr)
        plan = plan_autotune(
            part,
            mesh,
            engine_kind=engine_kind,
            overlap=overlap,
            batch_size=batch_size,
            tile=tile,
            mode=autotune,
            cache=as_cache(autotune_cache),
            graph=residual,
            fr=fr,
            row_axis=row_axis,
            col_axis=col_axis,
            replica_axis=replica_axis,
            sources=sources0,
            derived=derived0,
            hybrid_threshold=hybrid_threshold,
        )
        if tile is None and plan.tile is not None:
            tile = plan.tile
        logger.info("autotune[%s]: %s", autotune, plan.report())

    bm, bk = tile if tile is not None else (None, None)
    # ONE host arc→tile counting pass (cached on the partition) serves
    # the hybrid cell choice, the auto-overlap estimate, the memory
    # guard, and the layout build that follows
    tile_counts = (
        part.blocked_sparse_counts(bm, bk)
        if engine_kind in ("pallas_sparse", "pallas_hybrid")
        else None
    )
    dense_cells = None
    if engine_kind == "pallas_hybrid":
        dense_cells, _ = hybrid_cell_choice(
            part, bm, bk, threshold=hybrid_threshold, tile_counts=tile_counts,
            measured=plan.cell_costs if plan is not None else None,
        )
    if weighted:
        # weighted collectives run the barrier schedule; overlap only
        # keeps replicas in bucket-loop lockstep, so "auto" has nothing
        # to price — resolve it to the barrier policy
        if overlap == "auto":
            logger.info("overlap='auto' -> 'none' (weighted rounds are barrier-schedule)")
            overlap = "none"
        overlap = normalize_overlap(overlap)
    else:
        overlap = resolve_overlap(
            overlap, part, engine_kind, batch_size,
            bm=bm, bk=bk, tile_counts=tile_counts, dense_cells=dense_cells,
            measured=plan.overlap_level_s if plan is not None else None,
        )
    check_device_memory(
        part, engine_kind, batch_size, hbm_limit_bytes,
        bm=bm, bk=bk, overlap="none" if weighted else overlap,
        tile_counts=tile_counts, dense_cells=dense_cells,
    )

    round_fn = make_distributed_round_fn(
        part,
        mesh,
        row_axis=row_axis,
        col_axis=col_axis,
        replica_axis=replica_axis,
        num_levels=num_levels,
        engine_kind=engine_kind,
        overlap=overlap,
        integrity=integrity,
        weighted=weighted,
        delta=delta,
    )

    omega_pad = np.zeros(part.n_pad, np.float32)
    omega_pad[: graph.n] = omega_i
    # reorder omega into chunk-owner layout: flat position = chunk-id*chunk + off
    # chunk ids are contiguous in vertex order, so identity layout works.
    omega_dev = jnp.asarray(omega_pad)

    graph_args = distributed_graph_arrays(
        part, engine_kind, overlap, tile=tile, dense_cells=dense_cells,
        weights=residual.w if weighted else None,
    )

    def block_fn(sources, derived):
        return round_fn(*graph_args, omega_dev, sources, derived)

    from repro.core.driver import normalize_straggler

    straggler = normalize_straggler(straggler)
    prior_round_s = None
    if (
        straggler != "none"
        or dispatch_deadline_s == "auto"
        or sample_plan.mode != "off"
    ):
        if straggler != "none" and replica_axis is None:
            raise ValueError(
                "straggler scheduling re-deals rounds between sub-cluster "
                "replicas; pass replica_axis (a mesh with fr > 1)"
            )
        prior_round_s = prior_round_seconds(
            part, engine_kind, batch_size, "none" if weighted else overlap,
            bm=bm, bk=bk, tile_counts=tile_counts, dense_cells=dense_cells,
            measured_level_s=(
                plan.level_s_for(overlap) if plan is not None else None
            ),
            prior_levels=(
                weighted_prior_levels(residual.w, delta) if weighted else None
            ),
        )
    if sample_plan.mode != "off":
        from repro.roofline.model import sampled_run_seconds

        logger.info(
            "sampling[%s]: %d of %d eligible roots in %d rounds "
            "(seed %d); expected wall ≈ %.3gs at the %.3gs/round prior",
            sample_plan.mode, sample_plan.k, sample_plan.num_eligible,
            len(schedule.rounds), sample_plan.seed,
            sampled_run_seconds(len(schedule.rounds), fr, prior_round_s),
            prior_round_s,
        )
    if dispatch_deadline_s == "auto":
        # generous on purpose: the prior models steady-state rounds, but
        # the first dispatch pays jit compilation on top
        dispatch_deadline_s = max(
            WATCHDOG_MIN_DEADLINE_S, WATCHDOG_SAFETY * float(prior_round_s)
        )
        logger.info("dispatch watchdog: auto deadline %.1fs", dispatch_deadline_s)

    dispatch_fn = block_fn
    fallback_fn = None
    if chaos_plan:
        dispatch_fn = ChaosRoundFn(block_fn, chaos_plan, sleeper=sleeper)
        fallback_fn = block_fn  # the unwrapped, known-good path

    level_bound = None
    if weighted:
        # the audit's "levels" are bucket indices: ≤ ⌈(n-1)·w_max/Δ⌉
        w_max = float(residual.w.max()) if residual.w.size else 1.0
        level_bound = int(np.ceil(graph.n * w_max / delta)) + 2

    driver = BCDriver(
        dispatch_fn,
        schedule,
        n=graph.n,
        prep=prep,
        level_bound=level_bound,
        ledger=ledger,
        checkpoint=checkpoint,
        rounds_per_dispatch=fr,
        straggler=straggler,
        straggler_factor=straggler_factor,
        prior_round_s=prior_round_s,
        round_costs=schedule.round_depths,
        max_retries=max_retries,
        retry_backoff_s=retry_backoff_s,
        numeric_guard=numeric_guard,
        fallback_round_fn=fallback_fn,
        integrity=integrity,
        dispatch_deadline_s=dispatch_deadline_s,
        clock=clock,
        sleeper=sleeper,
        stop_rule=stop_rule,
        # the planner's taxonomy for elastic re-mesh on replica loss:
        # replica lanes are 'pod' groups, the grid is data × model
        mesh_shape=(fr, R, C),
        mesh_axes=("pod", "data", "model"),
    )
    result = driver.run()
    from repro.core.bc import apply_sampling_rescale

    result = apply_sampling_rescale(result, sample_plan)
    if chaos_plan:
        result.recovery_stats["chaos"] = {
            "plan": repr(chaos_plan),
            "dispatch_calls": dispatch_fn.calls,
            "checkpoint_saves": chaos_fs.checkpoint_saves,
            "cache_puts": chaos_fs.cache_puts,
            "files_corrupted": list(chaos_fs.files_corrupted),
        }
    if full_result:
        return result
    return result.bc, schedule
